"""The ``Study`` facade: the whole Split-Et-Impera pipeline behind one
typed, chainable object.

Before this module, running the paper's workflow meant hand-stitching
five subsystems — ``core.saliency`` -> ``core.qos.rank_candidates`` ->
``netsim.measure_flow`` -> ``fleet.DeploymentPlanner`` ->
``runtime.SplitRuntime`` — converting between their design-point
representations at every seam.  A ``Study`` carries one
:class:`~repro.api.types.SplitCandidate` per design point end-to-end:

    study = Study("vgg16", data=(xs, ys))
    best = (study.profile()            # CS curve (Grad-CAM saliency)
                 .candidates()         # legal cuts + LC/RC, CS-ranked
                 .calibrate()          # optional: measured cost tables
                 .simulate()           # single link (or fleet=(trace, mix),
                                       #  or path=[hop, hop] for K-cut lists)
                 .suggest(qos))        # Pareto + best QoS match
    runtime = study.deploy()           # ready SplitRuntime for the cut(s)

Multi-tier chains ride the same verbs: ``simulate(path=...)`` prices
K-cut candidates over a multi-hop ``NetworkPath`` (sequentially and
pipelined), ``suggest(qos, tiers=TierTopology(...))`` searches cut-list
x stage->tier assignment, and ``deploy()`` then executes the winning cut
list as a K+1-stage runtime.

Stages are lazily cached: each runs at most once unless called again
explicitly, and any stage you skip is run on demand with defaults (so
``Study(m).suggest(qos)`` is legal).  Re-running a stage invalidates the
stages after it.

Cost selection is uniform: after :meth:`calibrate`, *both* the
single-link simulator and the fleet planner price flows from the
measured :class:`~repro.runtime.calibrate.CalibrationTable`, falling
back to the analytic FLOPs model for cells the grid didn't cover —
``simulate`` never needs to know which source answered.

Telemetry rides the same chain: ``study.observe()`` arms a
``repro.obs.Recorder`` and returns a live
:class:`~repro.obs.report.TelemetryReport`; every stage that runs
*afterwards* records into it — fleet simulations emit request lifecycle
spans and windowed metrics, planners phase spans, deployed runtimes
per-stage/per-hop span trees — and
``report.to_chrome_trace("trace.json")`` exports the lot for Perfetto.
Without ``observe()`` every subsystem sees the null recorder and pays
nothing.

``Study`` accepts a :class:`~repro.models.layered.LayeredModel`, a
transformer ``ModelConfig`` (viewed through ``transformer_as_layered``),
or a config name: ``"vgg16"`` builds the CPU-trainable VGG variant, any
``repro.configs`` arch name (``"llama3.2-3b"``, ``"rwkv6-1.6b"``,
``"whisper-tiny"``, ...) resolves through the registry and is reduced to
its CPU-scale variant unless ``reduce=False``.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, replace
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.types import (SplitCandidate, legal_cut_list_candidates,
                             legal_split_candidates)
from repro.core import bottleneck as B
from repro.core import qos as Q
from repro.core.saliency import candidate_split_points, cumulative_saliency
from repro.core.scenarios import PLATFORMS, PlatformProfile
from repro.models.layered import LayeredModel
from repro.netsim.channel import Channel
from repro.netsim.simulator import (ApplicationSimulator, NetworkConfig,
                                    as_path, flow_latency_s, measure_flow)

_VGG_NAMES = ("vgg16", "vgg16-cifar10", "vgg")


def _platform(p) -> PlatformProfile:
    if isinstance(p, str):
        if p not in PLATFORMS:
            raise KeyError(f"unknown platform {p!r}; known: {sorted(PLATFORMS)}")
        return PLATFORMS[p]
    return p


@dataclass(frozen=True)
class StudyScenario:
    """Where a Study's design points run: edge/server platforms and the
    link between them.  Platforms may be given as ``core.scenarios``
    profile names."""
    edge: PlatformProfile = PLATFORMS["edge-embedded"]
    server: PlatformProfile = PLATFORMS["server-gpu"]
    channel: Channel = None
    protocol: str = "tcp"
    n_frames: int = 8

    def __post_init__(self):
        object.__setattr__(self, "edge", _platform(self.edge))
        object.__setattr__(self, "server", _platform(self.server))
        if self.channel is None:
            # clean gigabit link, deterministic under the default seed
            object.__setattr__(self, "channel", Channel(1e-4, 1e9, 1e9, seed=0))

    def netcfg(self) -> NetworkConfig:
        return NetworkConfig(self.protocol, self.channel)


class Study:
    """One end-to-end split-computing design study.  See module docstring."""

    def __init__(self, model="vgg16", scenario: Optional[StudyScenario] = None,
                 *, params=None, data=None, lc=None, seed=0, reduce=None,
                 batch: Optional[int] = None, seq_len: int = 32,
                 compression: float = 0.5):
        self.scenario = scenario if scenario is not None else StudyScenario()
        if not isinstance(self.scenario, StudyScenario):
            raise TypeError("scenario must be a StudyScenario (use "
                            "StudyScenario(edge=..., channel=...))")
        self.seed = seed
        self.compression = compression
        self.lc_model, self.lc_params = lc if lc is not None else (None, None)
        self._data = data
        self._recorder = None            # armed by observe()
        self._resolve_model(model, params, reduce, batch, seq_len)
        # stage caches
        self._cs = None
        self._layer_idx = None
        self._candidates = None
        self._ae_map = {}
        self._calibration = None
        self._mode = None                # 'link' | 'fleet' after simulate()
        self._verdicts = None
        self._planner = None
        self._fleet = None
        self._fleet_engine = "event"     # cluster engine of the last fleet sim
        self._points = None
        self._suggested = None
        self._plans = None
        self._deployment_stats = None    # traced joint validation (observe)
        self._path = None                # NetworkPath of the last path sim
        self._tier_topology = None
        self._tier_plans = None
        self._tier_best = None

    # ------------------------------------------------------- resolution ----
    def _resolve_model(self, model, params, reduce, batch, seq_len):
        self.cfg = None
        if isinstance(model, str):
            if model.lower() in _VGG_NAMES:
                from repro.models.vgg import vgg_cifar
                hw = (self._data[0].shape[1] if self._data is not None else 16)
                model = vgg_cifar(n_classes=8, input_hw=hw, width_mult=0.25)
            else:
                from repro.configs import get_config
                model = get_config(model)
        if not isinstance(model, LayeredModel):     # a transformer ModelConfig
            from repro.models import transformer as T
            from repro.models.common import reduced
            from repro.models.layered import transformer_as_layered
            if reduce or reduce is None:
                model = reduced(model, dtype="float32")
            self.cfg = model
            backbone = (params if params is not None
                        else T.init_params(jax.random.PRNGKey(self.seed), model))
            model = transformer_as_layered(model, backbone)
            params = model.init(jax.random.PRNGKey(self.seed))
        self.model = model
        self.params = (params if params is not None
                       else model.init(jax.random.PRNGKey(self.seed)))
        self._build_sample(batch, seq_len)

    def _build_sample(self, batch, seq_len):
        """The example input the study profiles, costs and calibrates with
        (``x``/``labels``), plus the per-frame input payload in bytes."""
        rng = np.random.default_rng(self.seed)
        if self.cfg is not None:                     # transformer batch dict
            cfg, b = self.cfg, batch or 2
            st = seq_len - (cfg.n_patches if cfg.family == "vlm" else 0)
            x = {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (b, st)), jnp.int32)}
            if cfg.family == "vlm":
                x["patch_embeds"] = jnp.asarray(
                    rng.normal(size=(b, cfg.n_patches, cfg.d_frontend)),
                    jnp.float32)
            if cfg.family == "encdec":
                x["frames"] = jnp.asarray(
                    rng.normal(size=(b, cfg.n_frames, cfg.d_frontend)),
                    jnp.float32)
            self._x, self._labels = x, jnp.asarray(
                rng.integers(0, cfg.vocab, (b, st)), jnp.int32)
            leaves = jax.tree.leaves(x)
            self._sample = x
            self.input_bytes = sum(l.nbytes for l in leaves) // b
        elif self._data is not None:                 # measured image data
            xs, ys = self._data
            n = min(len(xs), 32)
            self._x = jnp.asarray(xs[:n])
            self._labels = jnp.asarray(ys[:n])
            self._sample = None                      # input_shape suffices
            self.input_bytes = int(np.prod(xs.shape[1:])) * 4
        else:                                        # synthetic image input
            b = batch or 8
            shape = (b,) + tuple(self.model.input_shape)
            self._x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
            self._labels = jnp.asarray(
                rng.integers(0, self.model.n_classes, b), jnp.int32)
            self._sample = None
            self.input_bytes = int(np.prod(shape[1:])) * 4

    # --------------------------------------------------------- telemetry ----
    def observe(self, *, window_s: float = 0.05):
        """Arm telemetry and return a live
        :class:`~repro.obs.report.TelemetryReport`.

        The first call creates the study's ``repro.obs.Recorder``
        (``window_s`` sets the fleet metrics sampling window, simulated
        seconds); every stage that runs afterwards records into it —
        call ``observe()`` *before* the stages you want traced.
        Subsequent calls return the same live report (the recorder is
        shared, so spans and time series keep accumulating across
        stages).  Export with ``report.to_chrome_trace(path)`` and open
        in Perfetto (https://ui.perfetto.dev).
        """
        if self._recorder is None:
            from repro.obs import Recorder
            self._recorder = Recorder(window_s=window_s)
        return self._recorder.report()

    @property
    def _obs(self):
        """The armed recorder, or the shared null recorder (free)."""
        if self._recorder is not None:
            return self._recorder
        from repro.obs import NULL
        return NULL

    # ---------------------------------------------------------- training ----
    def fit(self, *, steps: int = 300, lr: float = 5e-3, batch: int = 32,
            data_iter=None) -> "Study":
        """Train the backbone on the toy conveyor-belt task (paper §V
        recipe: Adam, lr 5e-3) — image ``LayeredModel``\\ s only; the
        transformer zoo trains through ``repro.training``.  ``data_iter``
        overrides the synthetic stream with real ``(x, y)`` batches."""
        if self.cfg is not None:
            raise NotImplementedError(
                "Study.fit trains image LayeredModels; train transformer "
                "backbones with repro.training and pass params=")
        from repro.training.optimizer import adam_init, adam_update
        if data_iter is None:
            from repro.data.synthetic import toy_image_iter
            data_iter = toy_image_iter(batch, hw=self.model.input_shape[0],
                                       seed=self.seed,
                                       n_classes=self.model.n_classes)
        model, opt = self.model, adam_init(self.params)

        @jax.jit
        def step(params, opt, x, y):
            def lf(p):
                logits = model.apply(p, x)
                lse = jax.nn.logsumexp(logits, -1)
                gold = jnp.take_along_axis(logits, y[:, None], 1)[:, 0]
                return jnp.mean(lse - gold)
            loss, g = jax.value_and_grad(lf)(params)
            params, opt = adam_update(params, g, opt, lr)
            return params, opt, loss

        params = self.params
        for _ in range(steps):
            x, y = next(data_iter)
            params, opt, _ = step(params, opt, jnp.asarray(x), jnp.asarray(y))
        self.params = params
        # trained weights invalidate every derived stage
        self._cs = self._candidates = self._calibration = None
        self._ae_map, self._mode = {}, None
        return self

    def eval_accuracy(self, data=None, n: int = 256) -> float:
        """Top-1 accuracy of the current backbone on ``data`` (default: a
        held-out draw of the toy task for image models, the study's own
        sample batch otherwise)."""
        if data is None:
            if self.cfg is None and len(self.model.input_shape) == 3:
                from repro.data.synthetic import toy_images
                data = toy_images(n, hw=self.model.input_shape[0], seed=777,
                                  n_classes=self.model.n_classes)
            else:
                data = (self._x, self._labels)
        xs, ys = data
        logits = np.asarray(self.model.apply(self.params, jax.tree.map(
            jnp.asarray, xs)))
        return float((logits.argmax(-1) == np.asarray(ys)).mean())

    # ------------------------------------------------------------ stages ----
    def profile(self, *, layer_idx: Optional[Sequence[int]] = None) -> "Study":
        """Stage 1: the cumulative-saliency (CS) curve over ``layer_idx``
        (default: conv/pool feature ops for CNNs, blocks for transformer
        views) — the paper's accuracy proxy for split-point ranking."""
        if layer_idx is None:
            if any(l.kind == "conv" for l in self.model.layers):
                from repro.models.vgg import feature_index
                layer_idx = feature_index(self.model)
            else:
                layer_idx = list(range(1, len(self.model.layers) - 1))
        self._layer_idx = list(layer_idx)
        self._cs = cumulative_saliency(self.model, self.params, self._x,
                                       self._labels, layer_idx=self._layer_idx)
        self._candidates = None                      # invalidate downstream
        self._mode = None
        return self

    @property
    def cs_curve(self) -> np.ndarray:
        if self._cs is None:
            self.profile()
        return self._cs

    @property
    def layer_idx(self) -> list:
        if self._layer_idx is None:
            self.profile()
        return self._layer_idx

    def candidates(self, *, top_n: int = 3,
                   include_lc_rc: bool = True) -> "Study":
        """Stage 2: CS-ranked design points.  SC cuts are the CS local
        maxima restricted to legal cuts (``core.split.validate_cut`` is
        the legality authority); when the curve has no interior maxima
        (short models), the highest-CS legal cuts stand in.  LC and RC
        bracket the list per the paper."""
        cs, li = self.cs_curve, self.layer_idx
        points = candidate_split_points(self.model, cs, li, top_n=top_n)
        if not points:
            ranked = sorted(legal_split_candidates(self.model, cs, li),
                            key=lambda c: -c.accuracy_proxy)
            points = [c.split_layer for c in ranked[:top_n]]
        cands = Q.rank_candidates(cs, li, points, include_lc_rc=include_lc_rc)
        self._candidates = [replace(c, compression=self.compression)
                            if c.kind == "SC" else c for c in cands]
        self._mode = None
        return self

    @property
    def candidate_list(self) -> list:
        if self._candidates is None:
            self.candidates()
        return self._candidates

    def split_candidates(self) -> list:
        """The SC subset of :attr:`candidate_list` (helper for stages that
        only operate on actual cuts)."""
        return [c for c in self.candidate_list if c.kind == "SC"]

    def bottlenecks(self, *, steps: int = 100, rate: Optional[float] = None,
                    cuts: Optional[Sequence[int]] = None, lr: float = 5e-4,
                    data_iter=None) -> "Study":
        """Optional stage: train a bottleneck AE per SC cut (paper Eq. 3,
        backbone frozen).  Without ``data_iter`` the study's own sample
        batch is cycled — enough for the demo pipelines; pass a real
        iterator for production AEs."""
        rate = self.compression if rate is None else rate
        cuts = [c.split_layer for c in self.split_candidates()] \
            if cuts is None else list(cuts)
        if data_iter is None:
            data_iter = itertools.repeat((self._x, self._labels))
        for cut in cuts:
            self._ae_map[cut], _ = B.train_bottleneck(
                self.model, self.params, cut, data_iter, steps=steps,
                lr=lr, rate=rate, seed=self.seed)
        self._mode = None
        return self

    def calibrate(self, *, splits: Optional[Sequence[int]] = None,
                  iters: int = 3, quantize: bool = True,
                  fused: bool = False) -> "Study":
        """Optional stage: execute the real head/tail stages and wire codec
        on this host and keep the measured
        :class:`~repro.runtime.calibrate.CalibrationTable`.  Every later
        ``simulate`` (single-link *and* fleet) prices flows from it,
        falling back to the analytic model for uncovered cells.
        ``fused=True`` measures the fused-boundary runtime (codec jitted
        into the stages) and quotes those costs to the planners."""
        from repro.runtime.calibrate import calibrate as _calibrate
        splits = [c.split_layer for c in self.split_candidates()] \
            if splits is None else list(splits)
        with self._obs.tracer.span("study.calibrate", tid="study",
                                   cat="study") as sp:
            sp.args.update(n_splits=len(splits), iters=iters, fused=fused)
            self._calibration = _calibrate(self.model, self.params, splits,
                                           ae_map=self._ae_map, x=self._x,
                                           iters=iters, quantize=quantize,
                                           fused=fused)
        self._mode = None
        return self

    @property
    def calibration(self):
        return self._calibration

    # ---------------------------------------------------------- simulate ----
    def _netcfg(self, network) -> NetworkConfig:
        if network is None:
            return self.scenario.netcfg()
        if isinstance(network, NetworkConfig):
            return network
        if isinstance(network, Channel):
            return NetworkConfig(self.scenario.protocol, network)
        raise TypeError("network must be a NetworkConfig or Channel")

    def simulate(self, network=None, fleet=None, path=None, *,
                 n_frames: Optional[int] = None, tiers=None,
                 n_micro: int = 4, top_m: int = 8,
                 batch: Optional[int] = None, refine: Optional[int] = None,
                 engine: str = "event",
                 space=None, **space_overrides) -> "Study":
        """Stage 3: communication-aware simulation of every candidate.

        ``network``: a single link (``NetworkConfig`` or ``Channel``;
        default: the study scenario's link) — produces one
        ``SimVerdict`` per candidate.  ``fleet``: ``(trace,
        device_classes)`` — runs the QoS deployment planner over
        split x protocol x batch x replicas instead.  ``path``: a
        multi-hop chain (``netsim.NetworkPath`` or a sequence of
        ``Channel``/``NetworkConfig`` hops) — simulates K-cut candidates
        (K = number of hops), each priced sequentially *and* as an
        ``n_micro``-way pipelined microbatch schedule; the verdict
        latency is the pipelined one.  ``tiers`` names the K+1 platform
        chain for the path mode (default: the scenario's edge, then its
        server for every later stage); ``top_m`` bounds the CS-ranked
        cut lists simulated.  Cost source (analytic vs calibrated) is
        selected uniformly for single-link and fleet modes by the
        preceding :meth:`calibrate` call, per cell; path mode prices
        analytically.

        **Latency unit**: single-link verdicts are per *frame*
        (``batch=1``); path-mode verdicts are the makespan of one
        ``batch``-frame sample (microbatching needs a batch to chop;
        default: the study sample's own batch) — pass ``batch=1`` to
        compare against single-link numbers under one QoS budget.

        ``refine`` (fleet mode): two-phase search — screen every
        (candidate, protocol) leg with the closed-form analytic engine
        (``netsim.analytic``) and evaluate only the per-device Pareto
        front + ``refine`` fastest legs exactly; ``None`` (default)
        evaluates everything exactly.

        ``engine`` (fleet mode): the cluster simulator pricing each
        grid point — ``"event"`` (default, exact), ``"vectorized"``
        (the arrival-level NumPy engine; megafleet-scale traces), or
        ``"auto"``.  Non-event engines follow the screen/refine
        contract: Pareto-front points are re-priced by the event engine
        before :meth:`suggest` can choose them, and the observed
        deployment run inherits the same engine choice.
        """
        n_frames = self.scenario.n_frames if n_frames is None else n_frames
        if fleet is not None:
            return self._simulate_fleet(fleet, n_frames, space,
                                        space_overrides, refine, engine)
        if path is not None:
            return self._simulate_path(path, tiers, n_frames, n_micro,
                                       top_m, batch)
        netcfg = self._netcfg(network)
        verdicts = []
        measured = self._data is not None and self.cfg is None
        tracer = self._obs.tracer
        for cand in self.candidate_list:
            scen = cand.scenario(self.scenario.edge, self.scenario.server)
            with tracer.span(f"study.simulate:{cand.label}", tid="study",
                             cat="study") as sp:
                flow = measure_flow(scen, netcfg, self.model, self.params,
                                    self.input_bytes, n_frames=n_frames,
                                    cost=self._calibration,
                                    sample=self._sample)
                sp.args.update(wire_bytes=flow["wire_bytes"],
                               cost_source=flow["cost_source"])
            if measured:
                sim = ApplicationSimulator(
                    self.model, self.params, netcfg,
                    ae=self._ae_map.get(cand.split_layer),
                    lc_model=self.lc_model, lc_params=self.lc_params)
                v = sim.simulate(scen, np.asarray(self._x),
                                 np.asarray(self._labels),
                                 n_frames=n_frames, flow=flow)
                meta = dict(v.meta, cost_source=flow["cost_source"])
                verdicts.append(Q.SimVerdict(cand, v.latency_s, v.accuracy,
                                             meta))
            else:
                verdicts.append(Q.SimVerdict(
                    cand, flow_latency_s(flow), cand.accuracy_proxy,
                    meta={"wire_bytes": flow["wire_bytes"],
                          "cost_source": flow["cost_source"],
                          "edge_s": flow["edge_s"],
                          "server_s": flow["server_s"]}))
        self._verdicts, self._mode = verdicts, "link"
        self._path = None            # a non-path sim owns later deploys
        self._suggested = self._plans = self._tier_best = None
        return self

    def _frame_batch(self) -> int:
        """The study sample's own frame batch — what the multi-tier
        modes price one 'sample' as."""
        import jax as _jax
        return int(_jax.tree.leaves(self._sample if self._sample is not None
                                    else self._x)[0].shape[0])

    def _simulate_path(self, path, tiers, n_frames, n_micro,
                       top_m, batch=None) -> "Study":
        """Multi-hop link mode: one verdict per K-cut candidate."""
        batch = self._frame_batch() if batch is None else batch
        path = as_path(path, self.scenario.protocol)
        if tiers is not None:
            tiers = tuple(_platform(t) for t in tiers)
        cands = legal_cut_list_candidates(
            self.model, len(path), self.cs_curve, self.layer_idx,
            top_m=top_m)
        if not cands:
            raise ValueError(
                f"{self.model.name!r} has no legal {len(path)}-cut lists "
                f"covered by the CS curve (fewer cuts than hops?)")
        verdicts = []
        for cand in cands:
            cand = replace(cand, compression=self.compression)
            scen = cand.scenario(self.scenario.edge, self.scenario.server)
            flow = measure_flow(scen, path, self.model, self.params,
                                self.input_bytes, n_frames=n_frames,
                                sample=self._sample, tiers=tiers,
                                batch=batch, n_micro=n_micro)
            pipe = flow["pipeline"]
            verdicts.append(Q.SimVerdict(
                cand, pipe.latency_s, cand.accuracy_proxy,
                meta={"sequential_s": flow_latency_s(flow),
                      "speedup": pipe.speedup, "n_micro": n_micro,
                      "batch": batch,
                      "stage_s": flow["stage_s"],
                      "hop_bytes": flow["hop_bytes"],
                      "wire_bytes": flow["wire_bytes"],
                      "cost_source": flow["cost_source"]}))
        self._verdicts, self._mode = verdicts, "link"
        self._path = path
        self._suggested = self._plans = self._tier_best = None
        return self

    def _proxy_accuracy_fn(self):
        proxies = {(c.kind, c.split_layer): c.accuracy_proxy
                   for c in self.candidate_list}

        def accuracy_fn(scenario, netcfg):
            split = getattr(scenario.split_plan, "split_layer", None)
            acc = proxies.get((scenario.kind, split), 0.0)
            if netcfg.protocol == "udp":             # lossy link degrades
                acc -= netcfg.channel.loss_rate
            return acc
        return accuracy_fn

    def _make_planner(self, n_frames):
        from repro.fleet.planner import DeploymentPlanner
        measured = self._data is not None and self.cfg is None
        return DeploymentPlanner(
            self.model, self.params, cs_curve=self.cs_curve,
            layer_idx=self.layer_idx, ae_map=self._ae_map,
            eval_data=((np.asarray(self._x), np.asarray(self._labels))
                       if measured else None),
            accuracy_fn=None if measured else self._proxy_accuracy_fn(),
            lc_model=self.lc_model, lc_params=self.lc_params,
            server_platform=self.scenario.server,
            input_bytes=self.input_bytes, n_frames=n_frames,
            cost=self._calibration, sample=self._sample,
            obs=self._obs)

    def _make_space(self, space, overrides):
        from repro.fleet.planner import SearchSpace
        if space is not None:
            return space
        sps = tuple(c.split_layer for c in self.split_candidates())
        kw = dict(split_points=sps, include_lc=self.lc_model is not None)
        kw.update(overrides)
        return SearchSpace(**kw)

    def _simulate_fleet(self, fleet, n_frames, space, overrides,
                        refine=None, engine="event") -> "Study":
        trace, devices = fleet
        self._planner = self._make_planner(n_frames)
        space = self._make_space(space, overrides)
        self._fleet, self._space = (trace, devices), space
        self._fleet_engine = engine
        self._points = self._planner.search(trace, devices, space,
                                            refine=refine, engine=engine)
        self._mode = "fleet"
        self._path = None
        self._suggested = self._plans = self._tier_best = None
        return self

    def adapt(self, scenario, *, qos=None, space=None, config=None,
              initial: Optional[str] = None, engine: str = "vectorized",
              n_frames: int = 8, **space_overrides) -> dict:
        """Run the online adaptive replanner over a regime-change
        scenario and race it against the strongest static plan.

        ``scenario`` is a :class:`repro.fleet.scenario.RegimeChangeTrace`
        (phases + faults); the controller's candidate grid comes from
        the same planner configuration ``simulate(fleet=...)`` would
        build (CS-ranked splits x protocol x batch x replicas, measured
        costs when the study is calibrated).  Returns ``{"adaptive":
        AdaptiveRunResult, "static": AdaptiveRunResult, "controller":
        AdaptiveController}`` — ``static`` is the *best* fixed plan in
        the grid run over the same scenario (same era machinery, same
        physical faults), the fair baseline for the adaptive p99.
        """
        from repro.fleet.controller import AdaptiveController
        self._planner = self._make_planner(n_frames)
        space = self._make_space(space, space_overrides)
        controller = AdaptiveController.from_planner(
            self._planner, space, qos=qos, config=config)
        with self._obs.tracer.span("study.adapt", tid="study",
                                   cat="study") as sp:
            adaptive = controller.run(scenario, initial=initial,
                                      engine=engine)
            static = controller.best_static(scenario, engine=engine)
            sp.args.update(
                n_candidates=len(controller.candidates), engine=engine,
                n_switches=adaptive.n_switches,
                adaptive_p99_ms=round(adaptive.p99_s * 1e3, 3),
                static_p99_ms=round(static.p99_s * 1e3, 3))
        return {"adaptive": adaptive, "static": static,
                "controller": controller}

    @property
    def verdicts(self) -> list:
        if self._mode == "fleet":
            # don't silently throw away an expensive fleet search —
            # single-link verdicts would reset the fleet plans
            raise RuntimeError(
                "study is in fleet mode (plan_points / suggest(qos) hold "
                "the results); call simulate() explicitly for single-link "
                "verdicts")
        if self._mode != "link":
            self.simulate()
        return self._verdicts

    @property
    def plan_points(self) -> list:
        if self._mode != "fleet":
            raise RuntimeError("plan_points needs simulate(fleet=...) first")
        return self._points

    @property
    def planner(self):
        """The underlying ``DeploymentPlanner`` of the last fleet
        simulation (for joint validation via
        ``fleet.planner.simulate_deployment``)."""
        if self._planner is None:
            raise RuntimeError("planner needs simulate(fleet=...) first")
        return self._planner

    @property
    def deployment_stats(self):
        """Per-group ``ClusterStats`` from the traced joint validation an
        observed fleet suggestion runs (``observe()`` then
        ``suggest(qos)``); ``None`` when telemetry is off."""
        return self._deployment_stats

    # ------------------------------------------------------------ output ----
    def pareto(self) -> list:
        """The non-dominated set of the last simulation — accuracy/latency
        for a single link, (p99, accuracy, server FLOPs/s) per device
        class for a fleet."""
        if self._mode == "fleet":
            return self._planner.pareto_front(self._points)
        return Q.pareto(self.verdicts)

    def suggest(self, qos, tiers=None, *, n_micro: int = 4,
                batch: Optional[int] = None, refine: Optional[int] = None,
                **tier_kw):
        """Stage 4: the best design meeting ``qos``
        (:class:`~repro.core.qos.QoSRequirements`).  Single-link mode
        returns a ``SimVerdict`` (or None); fleet mode returns
        ``{device_name: PlanPoint | None}``.  Runs any missing stage with
        defaults first.

        ``tiers``: a ``fleet.TierTopology`` (device -> edge -> cloud
        chain) — searches cut-list x stage->tier assignment over it
        (``fleet.plan_tiers``: exhaustive closed-form screen, then exact
        event-engine refinement of the shortlist — ``refine`` sizes the
        shortlist, default 8 + the Pareto front) and returns the best
        feasible ``TierPlan`` (or None); a later :meth:`deploy` executes
        that plan's cut list live.  Tier-plan latencies are makespans of
        one ``batch``-frame sample (default: the study sample's own
        batch) — size the QoS budget to that unit, or pass ``batch=1``
        for per-frame budgets.
        """
        if tiers is not None:
            from repro.fleet.planner import plan_tiers, suggest_tier_plan
            self._tier_topology = tiers
            if refine is not None:
                tier_kw = dict(tier_kw, refine=refine)
            self._tier_plans = plan_tiers(
                self.model, self.params, tiers, n_micro=n_micro,
                cs_curve=self.cs_curve, layer_idx=self.layer_idx,
                compression=self.compression, sample=self._sample,
                batch=self._frame_batch() if batch is None else batch,
                obs=self._obs, **tier_kw)
            self._tier_best = suggest_tier_plan(self._tier_plans, qos)
            self._suggested = self._plans = None     # latest suggestion wins
            return self._tier_best
        self._tier_best = None                       # latest suggestion wins
        if self._mode == "fleet":
            self._plans = self._planner.suggest(qos, self._fleet,
                                                points=self._points)
            if self._recorder is not None and any(
                    p is not None and p.label != "LC"
                    for p in self._plans.values()):
                # the observed fleet run: re-simulate the *chosen* plans
                # jointly (shared clusters, mixed trace) under the
                # recorder — the planner's grid sims stay untraced
                from repro.fleet.planner import simulate_deployment
                trace, devices = self._fleet
                self._deployment_stats = simulate_deployment(
                    self._plans, trace, devices, self._planner,
                    obs=self._recorder, engine=self._fleet_engine)
            return self._plans
        best = Q.suggest(self.verdicts, qos)
        self._suggested = best
        return best

    @property
    def tier_plans(self) -> list:
        """Every evaluated ``TierPlan`` of the last ``suggest(qos,
        tiers=...)`` call, sorted by pipelined latency."""
        if self._tier_plans is None:
            raise RuntimeError("tier_plans needs suggest(qos, tiers=...) "
                               "first")
        return self._tier_plans

    def _chosen_candidate(self, candidate, device) -> tuple:
        """(candidate, wire hops) the deployment should execute.

        ``hops`` is the per-hop pricing argument for ``SplitRuntime``:
        a protocol string (study channel on every hop), a list of
        ``NetworkConfig``\\ s, or ``NetworkPath`` hops.
        """
        if candidate is not None:
            return (SplitCandidate.from_any(candidate).validate(self.model),
                    self.scenario.protocol)
        if self._tier_best is not None:      # multi-tier suggestion
            plan = self._tier_best
            cand = SplitCandidate.sc(plan.splits, plan.accuracy_proxy,
                                     compression=self.compression)
            return cand, plan.runtime_path(self._tier_topology)
        if self._plans is not None:          # fleet suggestion
            plans = {d: p for d, p in self._plans.items() if p is not None}
            if device is None and len(plans) == 1:
                device = next(iter(plans))
            if device not in plans:
                raise ValueError(f"no feasible plan for device {device!r}; "
                                 f"feasible: {sorted(plans)}")
            p = plans[device]
            return (SplitCandidate.from_any((p.label, p.split_layer)),
                    p.protocol or self.scenario.protocol)
        if self._suggested is None:
            raise RuntimeError("deploy() after suggest(qos), or pass "
                               "candidate=")
        cand = SplitCandidate.from_any(self._suggested.candidate)
        if self._path is not None and len(cand.splits) == len(self._path):
            return cand, list(self._path.hops)   # the simulated hop chain
        return cand, self.scenario.protocol

    def deploy(self, candidate=None, *, device=None, serve: bool = False,
               n_slots: int = 4, quantize: bool = True, backend=None,
               fused: bool = False, faults=None, recovery=None):
        """Stage 5: a ready runtime for the chosen cut (or cut list).

        Returns a :class:`~repro.runtime.engine.SplitRuntime` executing
        the suggested SC design live — stage -> int8 wire -> stage, one
        hop per cut, the study scenario's channel (or the suggested tier
        plan's / simulated path's hop chain) pricing each hop — or, with
        ``serve=True``, a :class:`~repro.runtime.engine.TailServer`
        batching many clients' tail requests.  ``candidate`` overrides
        the suggestion (``'SC@2+5'`` / a cut tuple name multi-cut
        designs); ``device`` picks a fleet plan.  RC/LC designs have no
        cut to execute and raise with guidance.

        ``faults`` (a :class:`~repro.runtime.faults.FaultPlan`) injects
        the deterministic fault schedule into the returned runtime or
        server; ``recovery`` (a
        :class:`~repro.runtime.faults.RecoveryPolicy`) tunes the
        retry/backoff/degradation machinery.  Both default to off — the
        zero-fault fast path is untouched.
        """
        cand, hops = self._chosen_candidate(candidate, device)
        if cand.kind != "SC":
            raise ValueError(
                f"suggested design is {cand.label}: nothing to split — run "
                f"the whole model on the "
                f"{'server' if cand.kind == 'RC' else 'edge'} instead "
                f"(deploy() builds split runtimes; pass candidate='SC@<k>' "
                f"to force a cut)")
        splits = cand.splits
        ae = ({c: self._ae_map[c] for c in splits if c in self._ae_map}
              or None)
        if serve:
            from repro.runtime.engine import TailServer
            from repro.runtime.partition import make_partition
            part = make_partition(self.model, self.params, splits, ae)
            return TailServer(part, n_slots=n_slots, faults=faults)
        from repro.runtime.engine import SplitRuntime
        if isinstance(hops, str):            # protocol over the study link
            return SplitRuntime(self.model, self.params, splits, ae=ae,
                                channel=self.scenario.channel, protocol=hops,
                                quantize=quantize, backend=backend,
                                fused=fused, obs=self._recorder,
                                faults=faults, recovery=recovery)
        return SplitRuntime(self.model, self.params, splits, ae=ae,
                            channel=hops, quantize=quantize, backend=backend,
                            fused=fused, obs=self._recorder,
                            faults=faults, recovery=recovery)
