"""``repro.api`` — the one-stop facade for the Split-Et-Impera pipeline.

    from repro.api import Study, QoSRequirements, Channel

    study = Study("vgg16", data=(xs, ys))
    verdict = (study.profile()          # CS curve (Grad-CAM saliency)
                    .candidates()       # legal cuts, LC/RC ranked
                    .calibrate()        # optional: measured cost tables
                    .simulate()         # netsim single link (or fleet=...)
                    .suggest(qos))      # Pareto + best QoS match
    runtime = study.deploy()            # ready SplitRuntime for the cut

Everything an end-to-end script needs is re-exported here, so examples
and downstream users import from ``repro.api`` only.

Attribute access is lazy (PEP 562): ``core.qos`` imports
``repro.api.types`` at import time, so this package initialiser must not
eagerly import the facade (which imports ``core.qos`` back).
"""
from __future__ import annotations

_EXPORTS = {
    # the facade
    "Study": ("repro.api.study", "Study"),
    "StudyScenario": ("repro.api.study", "StudyScenario"),
    # the shared type layer
    "SplitCandidate": ("repro.api.types", "SplitCandidate"),
    "CostModel": ("repro.api.types", "CostModel"),
    "AnalyticCost": ("repro.api.types", "AnalyticCost"),
    "CostStack": ("repro.api.types", "CostStack"),
    "legal_split_candidates": ("repro.api.types", "legal_split_candidates"),
    "legal_cut_list_candidates": ("repro.api.types",
                                  "legal_cut_list_candidates"),
    # the vocabulary end-to-end scripts need
    "QoSRequirements": ("repro.core.qos", "QoSRequirements"),
    "SimVerdict": ("repro.core.qos", "SimVerdict"),
    "SplitPlan": ("repro.core.split", "SplitPlan"),
    "validate_cuts": ("repro.core.split", "validate_cuts"),
    "legal_cut_lists": ("repro.core.split", "legal_cut_lists"),
    "Scenario": ("repro.core.scenarios", "Scenario"),
    "PLATFORMS": ("repro.core.scenarios", "PLATFORMS"),
    "Channel": ("repro.netsim.channel", "Channel"),
    "INTERFACES": ("repro.netsim.channel", "INTERFACES"),
    "compose_channels": ("repro.netsim.channel", "compose_channels"),
    "NetworkConfig": ("repro.netsim.simulator", "NetworkConfig"),
    "NetworkPath": ("repro.netsim.simulator", "NetworkPath"),
    "PipelineResult": ("repro.netsim.simulator", "PipelineResult"),
    "simulate_pipeline": ("repro.netsim.simulator", "simulate_pipeline"),
    "DeviceClass": ("repro.fleet.traffic", "DeviceClass"),
    "generate_trace": ("repro.fleet.traffic", "generate_trace"),
    "SearchSpace": ("repro.fleet.planner", "SearchSpace"),
    "DeploymentPlanner": ("repro.fleet.planner", "DeploymentPlanner"),
    "simulate_deployment": ("repro.fleet.planner", "simulate_deployment"),
    "Tier": ("repro.fleet.planner", "Tier"),
    "TierTopology": ("repro.fleet.planner", "TierTopology"),
    "TierPlan": ("repro.fleet.planner", "TierPlan"),
    "plan_tiers": ("repro.fleet.planner", "plan_tiers"),
    "suggest_tier_plan": ("repro.fleet.planner", "suggest_tier_plan"),
    "CalibrationTable": ("repro.runtime.calibrate", "CalibrationTable"),
    "calibrate": ("repro.runtime.calibrate", "calibrate"),
    # telemetry (Study.observe and standalone recorders)
    "Recorder": ("repro.obs", "Recorder"),
    "NullRecorder": ("repro.obs", "NullRecorder"),
    "TelemetryReport": ("repro.obs", "TelemetryReport"),
    # toy data for the runnable walkthroughs
    "toy_images": ("repro.data.synthetic", "toy_images"),
    "toy_image_iter": ("repro.data.synthetic", "toy_image_iter"),
    "SplitRuntime": ("repro.runtime.engine", "SplitRuntime"),
    "TailServer": ("repro.runtime.engine", "TailServer"),
    "run_clients": ("repro.runtime.engine", "run_clients"),
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro.api' has no attribute {name!r}")
    import importlib
    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value          # cache for subsequent lookups
    return value


def __dir__():
    return __all__
