"""The shared type layer of the ``repro.api`` facade.

Before this module existed, a design point changed shape at every
hand-off: ``core.qos`` ranked ``Candidate`` objects, ``fleet.planner``
searched ``(label, split_layer)`` tuples, and the runtime executed
``SplitPlan``s — with ad-hoc conversions at each seam.  This module is
the single vocabulary every layer speaks:

* :class:`SplitCandidate` — one design point (LC / RC / SC@k), carried
  unchanged from saliency profiling through simulation to deployment.
  It absorbs ``core.qos.Candidate`` (which is now an alias), the
  planner's design tuples (tuple-compatible via ``__iter__``/``__eq__``)
  and names its executable form (:meth:`plan` -> ``core.split.SplitPlan``).
* :class:`CostModel` — the protocol every cost source implements:
  :class:`AnalyticCost` (FLOPs / effective-throughput model),
  ``runtime.calibrate.CalibrationTable`` (measured), and
  :class:`CostStack` (first-match composition).  ``netsim.measure_flow``
  and ``fleet.DeploymentPlanner`` consume any of them through the same
  two methods, so switching analytic -> calibrated is one argument.

Split legality has exactly one authority: ``core.split.validate_cut``.
:meth:`SplitCandidate.validate` and :func:`legal_split_candidates` route
through it; no other module re-implements the check.

Imports from the rest of the package are deliberately lazy (inside
methods) where needed: ``core.qos`` imports this module at import time,
so this module must not import ``core.qos`` back.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Protocol, Sequence, runtime_checkable


@dataclass(frozen=True, eq=False)
class SplitCandidate:
    """One LC / RC / SC design point, end-to-end.

    ``label`` is the display form (``'LC'`` | ``'RC'`` | ``'SC@<layer>'``,
    or ``'SC@<c1>+<c2>+..'`` for a multi-tier cut list) kept as the
    primary field for compatibility with the historical
    ``core.qos.Candidate`` (now an alias of this class).  Tuple
    compatibility (iteration, indexing, equality with
    ``(label, split_layer)``) keeps the planner's legacy call sites and
    tests working unchanged.

    ``splits`` is the canonical ordered cut list (empty for LC/RC); the
    scalar ``split_layer`` stays as the first (edge-side) cut, so 1-cut
    candidates are indistinguishable from the pre-multi-tier shape.

    Identity (``__eq__``/``__hash__``) is the *design point* — label and
    cut list — not the annotations (``accuracy_proxy``, ``compression``):
    two SC@4 candidates with different proxies are the same point, which
    makes equality transitive with the tuple form and lets the planner
    deduplicate candidates in sets/dicts.
    """
    label: str                       # 'LC' | 'RC' | 'SC@<layer>[+<layer>..]'
    split_layer: Optional[int] = None
    accuracy_proxy: float = 0.0      # CS value at the cut (ranking key)
    compression: float = 0.5         # bottleneck rate for the SC plan
    wire_dtype_bytes: int = 4
    splits: Optional[tuple] = None   # ordered cut list; derived when None

    def __post_init__(self):
        if self.kind == "SC":
            if self.splits is None:
                cuts = (() if self.split_layer is None
                        else (int(self.split_layer),))
            else:
                from repro.core.split import normalize_cuts
                cuts = normalize_cuts(self.splits)
            object.__setattr__(self, "splits", cuts)
            if self.split_layer is None and cuts:
                object.__setattr__(self, "split_layer", cuts[0])
        else:
            object.__setattr__(self, "splits", ())

    # ------------------------------------------------------ constructors ----
    @classmethod
    def sc(cls, split, accuracy_proxy: float = 0.0,
           compression: float = 0.5, wire_dtype_bytes: int = 4) -> "SplitCandidate":
        """An SC design point at one cut (int) or a cut list (sequence)."""
        from repro.core.split import normalize_cuts
        cuts = normalize_cuts(split)
        label = "SC@" + "+".join(str(c) for c in cuts)
        return cls(label, cuts[0], accuracy_proxy,
                   compression, wire_dtype_bytes, splits=cuts)

    @classmethod
    def rc(cls, accuracy_proxy: float = 1.0) -> "SplitCandidate":
        """Remote Computation: the server runs the whole model (full accuracy)."""
        return cls("RC", None, accuracy_proxy)

    @classmethod
    def lc(cls, accuracy_proxy: float = 0.0) -> "SplitCandidate":
        """Local Computation: the edge runs a lightweight local model."""
        return cls("LC", None, accuracy_proxy)

    @classmethod
    def from_any(cls, obj) -> "SplitCandidate":
        """Coerce any legacy design-point representation.

        Accepts a :class:`SplitCandidate` (returned as-is), a
        ``core.split.SplitPlan``, a ``(label, split_layer)`` tuple (the
        planner's historical shape), a bare split layer ``int``, or a
        label string (``'RC'``, ``'LC'``, ``'SC@4'``).
        """
        if isinstance(obj, cls):
            return obj
        from repro.core.split import SplitPlan
        if isinstance(obj, SplitPlan):
            return cls.sc(obj.splits, compression=obj.compression,
                          wire_dtype_bytes=obj.wire_dtype_bytes)
        if isinstance(obj, int):
            return cls.sc(obj)
        if isinstance(obj, str):
            kind, _, layer = obj.partition("@")
            if kind == "SC" and layer:
                return cls.sc(tuple(int(c) for c in layer.split("+")))
            if kind in ("RC", "LC") and not layer:
                return cls.rc() if kind == "RC" else cls.lc()
            raise ValueError(f"unparseable candidate label {obj!r}")
        if isinstance(obj, tuple):
            import numbers
            if obj and all(isinstance(c, numbers.Integral) for c in obj):
                return cls.sc(obj)               # a bare ordered cut list
            if len(obj) == 2:
                label, split = obj
                out = cls.from_any(label)
                if out.kind == "SC":
                    from repro.core.split import normalize_cuts
                    if split is None or normalize_cuts(split) != out.splits:
                        raise ValueError(
                            f"label {label!r} disagrees with split {split!r}")
                return out
        raise TypeError(f"cannot interpret {type(obj).__name__} as a SplitCandidate")

    # ------------------------------------------------------------- views ----
    @property
    def kind(self) -> str:
        """'LC' | 'RC' | 'SC' (the scenario family, without the layer)."""
        return self.label.partition("@")[0]

    def plan(self):
        """The executable ``core.split.SplitPlan`` (SC only, else None)."""
        if self.kind != "SC":
            return None
        from repro.core.split import SplitPlan
        return SplitPlan(self.split_layer, self.compression,
                         self.wire_dtype_bytes, splits=self.splits)

    def scenario(self, edge=None, server=None):
        """The ``core.scenarios.Scenario`` this candidate simulates as."""
        from repro.core.scenarios import PLATFORMS, Scenario
        return Scenario(self.kind, self.plan(),
                        edge=edge or PLATFORMS["edge-embedded"],
                        server=server or PLATFORMS["server-gpu"])

    def validate(self, model) -> "SplitCandidate":
        """Legality-check the cut list against ``model`` (SC only; no-op
        for LC/RC).  Routes through ``core.split.validate_cuts`` — the
        single legality authority in the repo."""
        if self.kind == "SC":
            from repro.core.split import validate_cuts
            validate_cuts(model, self.splits)
        return self

    def with_proxy(self, accuracy_proxy: float) -> "SplitCandidate":
        return replace(self, accuracy_proxy=accuracy_proxy)

    # ---------------------------------------------------- tuple protocol ----
    def _as_tuple(self) -> tuple:
        if len(self.splits) > 1:
            return (self.label, self.splits)
        return (self.label, self.split_layer)

    def __iter__(self):
        return iter(self._as_tuple())

    def __getitem__(self, i):
        return self._as_tuple()[i]

    def __eq__(self, other):
        # Design-point identity, shared with the legacy tuple shape.
        # Comparing annotations too (the pre-multi-tier behaviour) made
        # equality non-transitive with the tuple form, which broke
        # set/dict deduplication in the planner.
        if isinstance(other, SplitCandidate):
            return self._as_tuple() == other._as_tuple()
        if isinstance(other, tuple):
            return self._as_tuple() == other
        return NotImplemented

    def __hash__(self):
        return hash(self._as_tuple())


def legal_split_candidates(model, cs_curve=None,
                           layer_idx: Optional[Sequence[int]] = None) -> list:
    """Every legal SC cut of ``model`` as :class:`SplitCandidate`\\ s.

    Legality comes from ``core.split.legal_cuts`` /
    ``core.split.validate_cut`` — callers (the planner's default space,
    the Study facade) use this instead of re-deriving cut sets.  With a
    CS curve and its ``layer_idx``, candidates carry their accuracy
    proxy and only cuts the curve covers are returned.
    """
    from repro.core.split import legal_cuts
    cuts = legal_cuts(model)
    if cs_curve is None:
        return [SplitCandidate.sc(c) for c in cuts]
    pos = {sp: i for i, sp in enumerate(layer_idx)}
    return [SplitCandidate.sc(c, float(cs_curve[pos[c]]))
            for c in cuts if c in pos]


def legal_cut_list_candidates(model, n_cuts: int, cs_curve=None,
                              layer_idx: Optional[Sequence[int]] = None,
                              pool: Optional[Sequence[int]] = None,
                              top_m: Optional[int] = None) -> list:
    """Every legal ``n_cuts``-way cut list of ``model`` as multi-cut
    :class:`SplitCandidate`\\ s — the K-way analogue of
    :func:`legal_split_candidates`.

    ``pool`` restricts the cuts considered (e.g. the CS-ranked shortlist);
    with a CS curve, a list's accuracy proxy is the *minimum* CS over its
    cuts (the weakest stage boundary bounds the chain) and only covered
    cuts are used.  ``top_m`` keeps the highest-proxy lists.
    """
    from repro.core.split import legal_cut_lists
    pos = ({} if cs_curve is None
           else {sp: i for i, sp in enumerate(layer_idx)})
    keep = set(pool) if pool is not None else None
    covered = (lambda c: (keep is None or c in keep)
               and (cs_curve is None or c in pos))
    out = [SplitCandidate.sc(
        combo, min(float(cs_curve[pos[c]]) for c in combo)
        if cs_curve is not None else 0.0)
        for combo in legal_cut_lists(model, n_cuts)
        if all(covered(c) for c in combo)]
    out.sort(key=lambda c: -c.accuracy_proxy)
    return out[:top_m] if top_m else out


# ------------------------------------------------------------ cost layer ----
@runtime_checkable
class CostModel(Protocol):
    """What every cost source looks like to the simulators.

    ``flow_times(kind, split, batch)`` prices one frame-batch of a flow:
    a dict with ``edge_s`` / ``server_s`` / ``wire_bytes`` /
    ``cost_source`` keys, or ``None`` when this source cannot price the
    cell (callers fall through to the next source).  ``server_cost``
    yields the per-replica batched service-time model
    (``serving.engine.BatchCostModel``) for the server-side stage, or
    ``None``.  Implementations: :class:`AnalyticCost` (FLOPs model),
    ``runtime.calibrate.CalibrationTable`` (measured),
    :class:`CostStack` (composition).
    """
    batch: int

    def flow_times(self, kind: str, split: Optional[int] = None,
                   batch: Optional[int] = None) -> Optional[dict]: ...

    def server_cost(self, split: Optional[int], platform): ...


def scale_flow_times(times: dict, src_batch: int, batch: int) -> dict:
    """First-order rescale of a flow-times dict quoted at ``src_batch``
    to ``batch`` frames (linear model; re-measure at the serving batch
    for exact numbers)."""
    if not src_batch or src_batch == batch:
        return times
    s = batch / src_batch
    return {**times,
            "edge_s": times["edge_s"] * s,
            "server_s": times["server_s"] * s,
            "wire_bytes": int(round(times["wire_bytes"] * s))}


@dataclass
class AnalyticCost:
    """The FLOPs / effective-throughput cost model behind one interface.

    Wraps ``core.scenarios.scenario_times_and_payload`` (and
    ``serving.engine.BatchCostModel.for_split``) so the analytic path is
    a :class:`CostModel` like any other.  ``sample`` is an optional
    example input (array or pytree, e.g. a transformer batch dict) used
    to derive activation shapes and FLOPs for models whose
    ``input_shape`` alone cannot describe the input.
    """
    model: object
    params: object
    input_bytes: int
    edge: object = None              # PlatformProfile; defaults in __post_init__
    server: object = None
    batch: int = 1
    compression: float = 0.5
    wire_dtype_bytes: int = 4
    sample: object = None

    def __post_init__(self):
        from repro.core.scenarios import PLATFORMS
        if self.edge is None:
            self.edge = PLATFORMS["edge-embedded"]
        if self.server is None:
            self.server = PLATFORMS["server-gpu"]

    def flow_times(self, kind: str, split: Optional[int] = None,
                   batch: Optional[int] = None) -> Optional[dict]:
        from repro.core.scenarios import Scenario, scenario_times_and_payload
        from repro.core.split import SplitPlan
        plan = (SplitPlan(split, self.compression, self.wire_dtype_bytes)
                if kind == "SC" else None)
        scenario = Scenario(kind, plan, edge=self.edge, server=self.server)
        times = dict(scenario_times_and_payload(
            scenario, self.model, self.params, input_bytes=self.input_bytes,
            batch=self.batch, sample=self.sample), cost_source="analytic")
        return scale_flow_times(times, self.batch,
                                self.batch if batch is None else batch)

    def server_cost(self, split: Optional[int], platform):
        from repro.serving.engine import BatchCostModel
        return BatchCostModel.for_split(self.model, self.params, split,
                                        platform, sample=self.sample)


@dataclass
class CostStack:
    """First-match composition of :class:`CostModel` sources.

    ``CostStack([table, analytic])`` prices a cell from the calibration
    table when it covers it and falls back to the analytic model
    otherwise — the uniform selection rule the Study facade uses for
    ``simulate(...)`` after an optional ``calibrate()``.
    """
    sources: list

    @property
    def batch(self) -> int:
        return self.sources[0].batch if self.sources else 1

    def flow_times(self, kind: str, split: Optional[int] = None,
                   batch: Optional[int] = None) -> Optional[dict]:
        for src in self.sources:
            times = src.flow_times(kind, split, batch=batch)
            if times is not None:
                return times
        return None

    def server_cost(self, split: Optional[int], platform):
        for src in self.sources:
            cost = src.server_cost(split, platform)
            if cost is not None:
                return cost
        return None
