"""The Split-Et-Impera simulator: supervisor / sensing / transmitter /
netsim / receiver (paper §IV, Fig. 1-ii/iii).

Inputs, matching the paper's list: (1) test scenario LC/RC/SC, (2-3) the
trained model, (4) the test set, (5) the communication-network modelling
parameters (protocol, channel latency, capacity, interface speed,
saboteur).  Output: per-configuration latency and *measured* accuracy —
under UDP the receiver zeroes the payload chunks of lost datagrams and the
tail network runs on the corrupted tensor, so the accuracy degradation is
real, not modelled.
"""
from __future__ import annotations

import math
import warnings
from collections import deque
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bottleneck as B
from repro.core.qos import Candidate, SimVerdict
from repro.core.scenarios import (Scenario, scenario_times_and_payload,
                                  stage_times_and_payloads)
from .channel import Channel
from .events import EventQueue
from .protocols import MTU_BYTES, simulate_transfer


@dataclass(frozen=True)
class NetworkConfig:
    protocol: str                  # 'tcp' | 'udp'
    channel: Channel
    mtu: int = MTU_BYTES


@dataclass(frozen=True)
class NetworkPath:
    """An ordered chain of wire hops (device -> edge -> ... -> cloud).

    The multi-tier counterpart of :class:`NetworkConfig`: hop k connects
    tier k to tier k+1 and carries the activation after cut k of a
    K-cut plan.  Hops may be given as ``NetworkConfig`` or bare
    ``Channel`` (priced over ``default_protocol``).
    """
    hops: tuple
    default_protocol: str = "tcp"

    def __post_init__(self):
        norm = tuple(h if isinstance(h, NetworkConfig)
                     else NetworkConfig(self.default_protocol, h)
                     for h in self.hops)
        object.__setattr__(self, "hops", norm)

    def __len__(self):
        return len(self.hops)

    def __iter__(self):
        return iter(self.hops)

    def __getitem__(self, k) -> NetworkConfig:
        return self.hops[k]

    def channels(self) -> list:
        return [h.channel for h in self.hops]


def as_path(net, protocol: str = "tcp") -> NetworkPath:
    """Coerce a NetworkPath / NetworkConfig / Channel / hop sequence."""
    if isinstance(net, NetworkPath):
        return net
    if isinstance(net, (NetworkConfig, Channel)):
        return NetworkPath((net,), default_protocol=protocol)
    return NetworkPath(tuple(net), default_protocol=protocol)


class _LegacyCalibration:
    """Adapter for the deprecated ``calibration=`` argument: the old
    contract was "any object with ``flow_times(kind, split)``" (no
    ``batch`` parameter — the caller rescaled).  This keeps such objects
    working through the ``CostModel`` interface."""

    def __init__(self, table):
        self._table = table
        self.batch = getattr(table, "batch", 0)

    def flow_times(self, kind, split=None, batch=None):
        times = self._table.flow_times(kind, split)
        if times is not None and batch:
            from repro.api.types import scale_flow_times
            times = scale_flow_times(times, self.batch or batch, batch)
        return times

    def server_cost(self, split, platform):
        fn = getattr(self._table, "server_cost", None)
        if fn is not None:
            return fn(split, platform)
        # pre-CostModel planner contract: a ``lookup(kind, split)`` whose
        # entry carries the measured per-cal-batch server wall clock
        lookup = getattr(self._table, "lookup", None)
        if lookup is None:
            return None
        entry = lookup("SC" if split is not None else "RC", split)
        if entry is None:
            return None
        from repro.serving.engine import BatchCostModel
        per_item = entry.server_s / max(1, self.batch or 1)
        return BatchCostModel.from_measured(per_item, platform.flops_per_s)


# ------------------------------------------------- pipelined microbatching ----
@dataclass
class PipelineResult:
    """Makespan of one sample through a K-hop stage chain, microbatched.

    ``latency_s`` is the pipelined makespan (last microbatch leaves the
    last stage); ``sequential_s`` is the no-overlap reference (sum of
    stage times + one full-payload transfer per hop).  The speedup comes
    from hop-k transfer overlapping stage-k+1 compute (and the other
    hops) across microbatches, GPipe-style.
    """
    latency_s: float
    sequential_s: float
    n_micro: int
    stage_s: tuple                   # full-sample stage times the sim used
    hop_bytes: tuple
    micro_done_s: tuple              # per-microbatch exit times

    @property
    def speedup(self) -> float:
        return self.sequential_s / self.latency_s if self.latency_s else 1.0


def simulate_pipeline(stage_s, hop_bytes, path, *, n_micro: int = 4,
                      stream: int = 0,
                      check_closed_form: bool = False) -> PipelineResult:
    """Event-driven microbatched execution of a multi-tier split sample.

    The sample is chopped into ``n_micro`` microbatches; each tier and
    each link is a serial resource (one microbatch at a time, FIFO), so
    hop-k transfer of microbatch m overlaps stage-k+1 compute of
    microbatch m-1 — scheduled on the shared discrete-event engine
    (``netsim.events.EventQueue``), per-microbatch transfer durations
    priced by the transport models on ``ceil(bytes / n_micro)`` payloads.

    ``stage_s``: K+1 full-sample stage compute times (zero entries model
    pass-through tiers); ``hop_bytes``: K full-sample payloads; ``path``:
    the K-hop :class:`NetworkPath`.

    ``check_closed_form``: cross-check this result against the closed
    form in ``netsim.analytic`` (loss-free paths only — with loss the
    closed form is a screen, not a price) and raise ``AssertionError``
    on >1e-9 relative divergence.  The planner's refinement stage runs
    with this on, so the screen can never silently disagree with the
    event engine — which stays the single semantic authority.
    """
    path = as_path(path)
    K = len(path)
    if len(stage_s) != K + 1 or len(hop_bytes) != K:
        raise ValueError(f"{K}-hop path needs {K + 1} stage times and {K} "
                         f"payloads, got {len(stage_s)}/{len(hop_bytes)}")
    if n_micro < 1:
        raise ValueError(f"n_micro must be >= 1, got {n_micro}")
    mb_stage = [s / n_micro for s in stage_s]
    mb_dur = [[simulate_transfer(cfg.protocol,
                                 max(1, math.ceil(b / n_micro)),
                                 cfg.channel, mtu=cfg.mtu,
                                 stream=stream * 977 + 97 * k + m).duration_s
               for m in range(n_micro)]
              for k, (cfg, b) in enumerate(zip(path, hop_bytes))]

    q = EventQueue()
    tier_busy = [False] * (K + 1)
    tier_q = [deque() for _ in range(K + 1)]
    link_busy = [False] * K
    link_q = [deque() for _ in range(K)]
    done = {}

    def maybe_compute(k):
        if tier_busy[k] or not tier_q[k]:
            return
        m = tier_q[k].popleft()
        tier_busy[k] = True
        q.schedule(q.now + mb_stage[k], lambda: stage_done(k, m))

    def stage_done(k, m):
        tier_busy[k] = False
        if k == K:
            done[m] = q.now
        else:
            link_q[k].append(m)
            maybe_send(k)
        maybe_compute(k)

    def maybe_send(k):
        if link_busy[k] or not link_q[k]:
            return
        m = link_q[k].popleft()
        link_busy[k] = True
        dur = mb_dur[k][m]
        # the link is busy for the sender-clocked part of the transfer;
        # the last bit then propagates for one channel latency while the
        # next microbatch may already be serialising behind it
        busy = max(dur - path[k].channel.latency_s, 0.0)

        def freed(k=k):
            link_busy[k] = False
            maybe_send(k)

        def delivered(k=k, m=m):
            tier_q[k + 1].append(m)
            maybe_compute(k + 1)
        q.schedule(q.now + busy, freed)
        q.schedule(q.now + dur, delivered)

    for m in range(n_micro):
        tier_q[0].append(m)
    maybe_compute(0)
    q.run()
    sequential = sum(stage_s) + sum(
        simulate_transfer(cfg.protocol, b, cfg.channel, mtu=cfg.mtu,
                          stream=stream * 977 + 97 * k).duration_s
        for k, (cfg, b) in enumerate(zip(path, hop_bytes)))
    result = PipelineResult(max(done.values()), sequential, n_micro,
                            tuple(stage_s), tuple(hop_bytes),
                            tuple(done[m] for m in range(n_micro)))
    if check_closed_form:
        from . import analytic
        if analytic.path_params(path).exact:
            cf_pipe, cf_seq = analytic.closed_form_pipeline(
                stage_s, hop_bytes, path, n_micro=n_micro)
            analytic.assert_event_match("pipelined makespan", cf_pipe,
                                        result.latency_s)
            analytic.assert_event_match("sequential makespan", cf_seq,
                                        result.sequential_s)
    return result


def measure_flow(scenario: Scenario, netcfg, model, params,
                 input_bytes: int, n_frames: int = 8, *,
                 cost=None, calibration=None, batch: int = 1,
                 sample=None, tiers=None, n_micro=None) -> dict:
    """Per-flow latency decomposition of one scenario over one network.

    Returns ``edge_s``/``server_s`` compute times, the wire payload, and
    ``n_frames`` independent :class:`TransferResult` draws (empty for LC).
    ``ApplicationSimulator.simulate`` consumes this for single-link runs;
    ``repro.fleet.planner`` consumes it to cost whole deployments without
    re-deriving the timing model.

    ``netcfg`` may also be a :class:`NetworkPath` (or hop sequence): a
    K-cut SC plan is then priced hop by hop — stage k's compute on tier
    k (``tiers``: the K+1 platform chain; default: the scenario's edge
    followed by its server for every later stage), hop k's transfer over
    path entry k.  The returned dict adds per-stage keys (``stage_s``,
    ``hop_bytes``, ``hop_frames``, ``hop_wire_s``) while keeping the flat
    2-tier aggregates (``edge_s`` = stage 0, ``server_s`` = later stages,
    ``wire_s[f]`` = frame f's whole-path transfer), so
    :func:`flow_latency_s` reads as the *sequential* multi-hop latency.
    With ``n_micro``, the pipelined-microbatch makespan is added as
    ``pipeline`` / ``pipeline_s`` — hop-k transfer overlapping stage-k+1
    compute (:func:`simulate_pipeline`), the multi-tier speed win.
    Multi-hop flows are priced analytically (``cost`` sources only cover
    the 2-tier cells).

    ``cost``: any :class:`repro.api.types.CostModel` — a
    ``runtime.calibrate.CalibrationTable`` (measured), an
    ``api.types.AnalyticCost``, or a ``CostStack`` of both.  When it
    prices this scenario's cell, compute times and the wire payload come
    from it (the returned dict's ``cost_source`` says which path produced
    them); cells it can't price fall back to the built-in analytic
    FLOPs/throughput model.  Cost sources quoted at a different batch
    size rescale linearly to ``batch`` (first-order model; re-calibrate
    at the serving batch for exact numbers).

    ``calibration``: deprecated alias of ``cost`` (pre-``repro.api``
    signature), kept as a shim.

    ``sample``: example input pytree forwarded to the analytic fallback
    for models whose ``input_shape`` cannot describe the input.
    """
    if calibration is not None:
        warnings.warn("measure_flow(calibration=...) is deprecated; pass "
                      "cost=... (any repro.api.types.CostModel)",
                      DeprecationWarning, stacklevel=2)
        if cost is None:
            cost = _LegacyCalibration(calibration)
    plan = scenario.split_plan
    n_cuts = len(getattr(plan, "splits", ()) or ())
    if (isinstance(netcfg, NetworkPath) or n_cuts > 1
            or not isinstance(netcfg, NetworkConfig)):
        if cost is not None:
            warnings.warn(
                "cost sources only price 2-tier cells; this multi-hop "
                "path flow is priced analytically and cost= is ignored",
                stacklevel=2)
        return _measure_path_flow(scenario, as_path(netcfg), model, params,
                                  input_bytes, n_frames, batch=batch,
                                  sample=sample, tiers=tiers,
                                  n_micro=n_micro)
    times = None
    if cost is not None:
        split = getattr(scenario.split_plan, "split_layer", None)
        times = cost.flow_times(scenario.kind, split, batch=batch)
    if times is None:
        times = dict(scenario_times_and_payload(scenario, model, params,
                                                input_bytes=input_bytes,
                                                batch=batch, sample=sample),
                     cost_source="analytic")
    frames = []
    if times["wire_bytes"] > 0:
        frames = [simulate_transfer(netcfg.protocol, times["wire_bytes"],
                                    netcfg.channel, stream=f, mtu=netcfg.mtu)
                  for f in range(n_frames)]
    return {**times, "frames": frames,
            "wire_s": [t.duration_s for t in frames],
            # per-frame retransmit counts: what reliable delivery cost
            # beyond the packet count (0 for UDP — it never resends)
            "retries": [t.n_transmissions - t.n_packets for t in frames]}


def _measure_path_flow(scenario: Scenario, path: NetworkPath, model, params,
                       input_bytes: int, n_frames: int, *, batch: int,
                       sample=None, tiers=None, n_micro=None) -> dict:
    """Multi-hop pricing behind :func:`measure_flow` (SC and RC flows)."""
    plan = scenario.split_plan
    if scenario.kind == "SC":
        cuts = plan.splits
        if len(path) != len(cuts):
            raise ValueError(
                f"{len(cuts)}-cut plan needs a {len(cuts)}-hop path, got "
                f"{len(path)} hops (pass one NetworkConfig per hop)")
        if tiers is None:
            tiers = (scenario.edge,) + (scenario.server,) * len(cuts)
        st = stage_times_and_payloads(model, params, plan, tiers, batch,
                                      sample=sample)
        stage_s, hop_bytes = st["stage_s"], st["hop_bytes"]
    elif scenario.kind == "RC":
        # the raw input traverses the whole path; the last tier computes
        from repro.core.stats import total_flops
        from repro.core.scenarios import _sample_scale
        flops = (total_flops(model, params, batch, sample=sample)
                 * _sample_scale(batch, sample))
        server = (tiers[-1] if tiers else scenario.server)
        stage_s = [0.0] * len(path) + [server.compute_time(flops)]
        hop_bytes = [input_bytes] * len(path)   # 2-tier RC convention
    else:                            # LC never touches the network
        from repro.core.stats import total_flops
        from repro.core.scenarios import _sample_scale
        flops = (total_flops(model, params, batch, sample=sample)
                 * _sample_scale(batch, sample))
        edge = (tiers[0] if tiers else scenario.edge)
        stage_s, hop_bytes, path = [edge.compute_time(flops)], [], as_path(())
    hop_frames = [[simulate_transfer(cfg.protocol, b, cfg.channel,
                                     stream=f * 131 + k, mtu=cfg.mtu)
                   for f in range(n_frames)]
                  for k, (cfg, b) in enumerate(zip(path, hop_bytes))]
    wire_s = [sum(hop_frames[k][f].duration_s for k in range(len(path)))
              for f in range(n_frames)]
    flow = {"edge_s": stage_s[0], "server_s": sum(stage_s[1:]),
            "wire_bytes": sum(hop_bytes), "cost_source": "analytic",
            "stage_s": list(stage_s), "hop_bytes": list(hop_bytes),
            "hop_frames": hop_frames,
            "hop_wire_s": [[t.duration_s for t in hf] for hf in hop_frames],
            "hop_retries": [[t.n_transmissions - t.n_packets for t in hf]
                            for hf in hop_frames],
            "frames": hop_frames[0] if hop_frames else [],
            "wire_s": wire_s,
            "retries": [sum(hop_frames[k][f].n_transmissions
                            - hop_frames[k][f].n_packets
                            for k in range(len(path)))
                        for f in range(n_frames)]}
    if n_micro is not None:
        pipe = simulate_pipeline(stage_s, hop_bytes, path, n_micro=n_micro)
        flow["pipeline"] = pipe
        flow["pipeline_s"] = pipe.latency_s
    return flow


def flow_latency_s(flow: dict) -> float:
    """One-frame latency of a :func:`measure_flow` result:
    edge compute + mean wire transfer + server compute."""
    wire = float(np.mean(flow["wire_s"])) if flow["wire_s"] else 0.0
    return flow["edge_s"] + wire + flow["server_s"]


def chunk_mask_from_packets(n_elems: int, delivered: np.ndarray,
                            elem_bytes: int, mtu: int) -> np.ndarray:
    """Map per-packet delivery to a per-element keep mask (receiver view)."""
    per_pkt = max(1, mtu // elem_bytes)
    mask = np.ones(n_elems, bool)
    for p in np.nonzero(~delivered)[0]:
        mask[p * per_pkt:(p + 1) * per_pkt] = False
    return mask


class ApplicationSimulator:
    """Drives n_frames of the sensing->transmit->receive->infer loop."""

    def __init__(self, model, params, netcfg: NetworkConfig, *,
                 ae=None, lc_model=None, lc_params=None, wire_dtype_bytes=4):
        self.model, self.params = model, params
        self.netcfg = netcfg
        self.ae = ae
        self.lc_model, self.lc_params = lc_model, lc_params
        self.wire_dtype_bytes = wire_dtype_bytes

    # -------------------------------------------------------- inference ----
    def _apply_batched(self, fn, xs, masks, batch=64):
        outs = []
        for i in range(0, xs.shape[0], batch):
            xb = xs[i:i + batch]
            mb = None if masks is None else masks[i:i + batch]
            outs.append(np.asarray(fn(xb, mb)))
        return np.concatenate(outs)

    def _accuracy(self, preds: np.ndarray, ys: np.ndarray) -> float:
        return float((preds.argmax(-1) == ys).mean())

    # -------------------------------------------------------- scenarios ----
    def simulate(self, scenario: Scenario, xs: np.ndarray, ys: np.ndarray,
                 n_frames: int = 32, *, flow: dict = None) -> SimVerdict:
        """``flow``: a precomputed :func:`measure_flow` result to reuse
        (the planner shares one per leg); measured fresh when omitted."""
        proto = self.netcfg.protocol
        times = flow if flow is not None else measure_flow(
            scenario, self.netcfg, self.model, self.params,
            input_bytes=int(np.prod(xs.shape[1:])) * 4, n_frames=n_frames)

        if scenario.kind == "LC":
            model, params = self.lc_model or self.model, self.lc_params or self.params
            fn = jax.jit(lambda xb: model.apply(params, xb))
            preds = self._apply_batched(lambda xb, _: fn(xb), xs, None)
            total_flops_t = times["edge_s"]
            return SimVerdict(Candidate("LC", None), total_flops_t,
                              self._accuracy(preds, ys),
                              meta={"wire_bytes": 0, "transfers": []})

        # transmission: n_frames transfers with distinct loss draws
        frames = times["frames"]
        lat = (times["edge_s"] + times["server_s"]
               + float(np.mean(times["wire_s"])))

        # accuracy: TCP delivers everything; UDP corrupts the payload
        if scenario.kind == "RC":
            apply_clean = jax.jit(lambda xb: self.model.apply(self.params, xb))

            def fn(xb, mb):
                if mb is None:
                    return apply_clean(xb)
                return apply_clean(xb * mb.reshape(xb.shape))
            n_elems = int(np.prod(xs.shape[1:]))
        else:  # SC
            split = scenario.split_plan.split_layer
            z_shape = jax.eval_shape(
                lambda x: B.head_forward(self.model, self.params, self.ae, split, x),
                jax.ShapeDtypeStruct((1,) + tuple(xs.shape[1:]), jnp.float32)).shape
            n_elems = int(np.prod(z_shape[1:]))
            sc_fwd = jax.jit(lambda xb, mb: B.split_forward(
                self.model, self.params, self.ae, split, xb,
                None if mb is None else mb))

            def fn(xb, mb):
                if mb is None:
                    return B.split_forward(self.model, self.params, self.ae, split, xb)
                return sc_fwd(xb, mb.reshape((xb.shape[0],) + z_shape[1:]))

        if proto == "tcp":
            preds = self._apply_batched(lambda xb, _: fn(xb, None), xs, None)
        else:
            masks = np.stack([
                chunk_mask_from_packets(
                    n_elems, frames[i % len(frames)].delivered,
                    self.wire_dtype_bytes, self.netcfg.mtu)
                for i in range(xs.shape[0])]).astype(np.float32)
            preds = self._apply_batched(fn, xs, masks)

        label = scenario.label()
        return SimVerdict(Candidate(label, getattr(scenario.split_plan, "split_layer", None)),
                          lat, self._accuracy(preds, ys),
                          meta={"wire_bytes": times["wire_bytes"],
                                "mean_tx": float(np.mean([t.n_transmissions for t in frames])),
                                "edge_s": times["edge_s"],
                                "server_s": times["server_s"]})
