"""The Split-Et-Impera simulator: supervisor / sensing / transmitter /
netsim / receiver (paper §IV, Fig. 1-ii/iii).

Inputs, matching the paper's list: (1) test scenario LC/RC/SC, (2-3) the
trained model, (4) the test set, (5) the communication-network modelling
parameters (protocol, channel latency, capacity, interface speed,
saboteur).  Output: per-configuration latency and *measured* accuracy —
under UDP the receiver zeroes the payload chunks of lost datagrams and the
tail network runs on the corrupted tensor, so the accuracy degradation is
real, not modelled.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bottleneck as B
from repro.core.qos import Candidate, SimVerdict
from repro.core.scenarios import Scenario, scenario_times_and_payload
from .channel import Channel
from .protocols import MTU_BYTES, simulate_transfer


@dataclass(frozen=True)
class NetworkConfig:
    protocol: str                  # 'tcp' | 'udp'
    channel: Channel
    mtu: int = MTU_BYTES


class _LegacyCalibration:
    """Adapter for the deprecated ``calibration=`` argument: the old
    contract was "any object with ``flow_times(kind, split)``" (no
    ``batch`` parameter — the caller rescaled).  This keeps such objects
    working through the ``CostModel`` interface."""

    def __init__(self, table):
        self._table = table
        self.batch = getattr(table, "batch", 0)

    def flow_times(self, kind, split=None, batch=None):
        times = self._table.flow_times(kind, split)
        if times is not None and batch:
            from repro.api.types import scale_flow_times
            times = scale_flow_times(times, self.batch or batch, batch)
        return times

    def server_cost(self, split, platform):
        fn = getattr(self._table, "server_cost", None)
        if fn is not None:
            return fn(split, platform)
        # pre-CostModel planner contract: a ``lookup(kind, split)`` whose
        # entry carries the measured per-cal-batch server wall clock
        lookup = getattr(self._table, "lookup", None)
        if lookup is None:
            return None
        entry = lookup("SC" if split is not None else "RC", split)
        if entry is None:
            return None
        from repro.serving.engine import BatchCostModel
        per_item = entry.server_s / max(1, self.batch or 1)
        return BatchCostModel.from_measured(per_item, platform.flops_per_s)


def measure_flow(scenario: Scenario, netcfg: NetworkConfig, model, params,
                 input_bytes: int, n_frames: int = 8, *,
                 cost=None, calibration=None, batch: int = 1,
                 sample=None) -> dict:
    """Per-flow latency decomposition of one scenario over one network.

    Returns ``edge_s``/``server_s`` compute times, the wire payload, and
    ``n_frames`` independent :class:`TransferResult` draws (empty for LC).
    ``ApplicationSimulator.simulate`` consumes this for single-link runs;
    ``repro.fleet.planner`` consumes it to cost whole deployments without
    re-deriving the timing model.

    ``cost``: any :class:`repro.api.types.CostModel` — a
    ``runtime.calibrate.CalibrationTable`` (measured), an
    ``api.types.AnalyticCost``, or a ``CostStack`` of both.  When it
    prices this scenario's cell, compute times and the wire payload come
    from it (the returned dict's ``cost_source`` says which path produced
    them); cells it can't price fall back to the built-in analytic
    FLOPs/throughput model.  Cost sources quoted at a different batch
    size rescale linearly to ``batch`` (first-order model; re-calibrate
    at the serving batch for exact numbers).

    ``calibration``: deprecated alias of ``cost`` (pre-``repro.api``
    signature), kept as a shim.

    ``sample``: example input pytree forwarded to the analytic fallback
    for models whose ``input_shape`` cannot describe the input.
    """
    if calibration is not None:
        warnings.warn("measure_flow(calibration=...) is deprecated; pass "
                      "cost=... (any repro.api.types.CostModel)",
                      DeprecationWarning, stacklevel=2)
        if cost is None:
            cost = _LegacyCalibration(calibration)
    times = None
    if cost is not None:
        split = getattr(scenario.split_plan, "split_layer", None)
        times = cost.flow_times(scenario.kind, split, batch=batch)
    if times is None:
        times = dict(scenario_times_and_payload(scenario, model, params,
                                                input_bytes=input_bytes,
                                                batch=batch, sample=sample),
                     cost_source="analytic")
    frames = []
    if times["wire_bytes"] > 0:
        frames = [simulate_transfer(netcfg.protocol, times["wire_bytes"],
                                    netcfg.channel, stream=f, mtu=netcfg.mtu)
                  for f in range(n_frames)]
    return {**times, "frames": frames,
            "wire_s": [t.duration_s for t in frames]}


def flow_latency_s(flow: dict) -> float:
    """One-frame latency of a :func:`measure_flow` result:
    edge compute + mean wire transfer + server compute."""
    wire = float(np.mean(flow["wire_s"])) if flow["wire_s"] else 0.0
    return flow["edge_s"] + wire + flow["server_s"]


def chunk_mask_from_packets(n_elems: int, delivered: np.ndarray,
                            elem_bytes: int, mtu: int) -> np.ndarray:
    """Map per-packet delivery to a per-element keep mask (receiver view)."""
    per_pkt = max(1, mtu // elem_bytes)
    mask = np.ones(n_elems, bool)
    for p in np.nonzero(~delivered)[0]:
        mask[p * per_pkt:(p + 1) * per_pkt] = False
    return mask


class ApplicationSimulator:
    """Drives n_frames of the sensing->transmit->receive->infer loop."""

    def __init__(self, model, params, netcfg: NetworkConfig, *,
                 ae=None, lc_model=None, lc_params=None, wire_dtype_bytes=4):
        self.model, self.params = model, params
        self.netcfg = netcfg
        self.ae = ae
        self.lc_model, self.lc_params = lc_model, lc_params
        self.wire_dtype_bytes = wire_dtype_bytes

    # -------------------------------------------------------- inference ----
    def _apply_batched(self, fn, xs, masks, batch=64):
        outs = []
        for i in range(0, xs.shape[0], batch):
            xb = xs[i:i + batch]
            mb = None if masks is None else masks[i:i + batch]
            outs.append(np.asarray(fn(xb, mb)))
        return np.concatenate(outs)

    def _accuracy(self, preds: np.ndarray, ys: np.ndarray) -> float:
        return float((preds.argmax(-1) == ys).mean())

    # -------------------------------------------------------- scenarios ----
    def simulate(self, scenario: Scenario, xs: np.ndarray, ys: np.ndarray,
                 n_frames: int = 32, *, flow: dict = None) -> SimVerdict:
        """``flow``: a precomputed :func:`measure_flow` result to reuse
        (the planner shares one per leg); measured fresh when omitted."""
        proto = self.netcfg.protocol
        times = flow if flow is not None else measure_flow(
            scenario, self.netcfg, self.model, self.params,
            input_bytes=int(np.prod(xs.shape[1:])) * 4, n_frames=n_frames)

        if scenario.kind == "LC":
            model, params = self.lc_model or self.model, self.lc_params or self.params
            fn = jax.jit(lambda xb: model.apply(params, xb))
            preds = self._apply_batched(lambda xb, _: fn(xb), xs, None)
            total_flops_t = times["edge_s"]
            return SimVerdict(Candidate("LC", None), total_flops_t,
                              self._accuracy(preds, ys),
                              meta={"wire_bytes": 0, "transfers": []})

        # transmission: n_frames transfers with distinct loss draws
        frames = times["frames"]
        lat = (times["edge_s"] + times["server_s"]
               + float(np.mean(times["wire_s"])))

        # accuracy: TCP delivers everything; UDP corrupts the payload
        if scenario.kind == "RC":
            apply_clean = jax.jit(lambda xb: self.model.apply(self.params, xb))

            def fn(xb, mb):
                if mb is None:
                    return apply_clean(xb)
                return apply_clean(xb * mb.reshape(xb.shape))
            n_elems = int(np.prod(xs.shape[1:]))
        else:  # SC
            split = scenario.split_plan.split_layer
            z_shape = jax.eval_shape(
                lambda x: B.head_forward(self.model, self.params, self.ae, split, x),
                jax.ShapeDtypeStruct((1,) + tuple(xs.shape[1:]), jnp.float32)).shape
            n_elems = int(np.prod(z_shape[1:]))
            sc_fwd = jax.jit(lambda xb, mb: B.split_forward(
                self.model, self.params, self.ae, split, xb,
                None if mb is None else mb))

            def fn(xb, mb):
                if mb is None:
                    return B.split_forward(self.model, self.params, self.ae, split, xb)
                return sc_fwd(xb, mb.reshape((xb.shape[0],) + z_shape[1:]))

        if proto == "tcp":
            preds = self._apply_batched(lambda xb, _: fn(xb, None), xs, None)
        else:
            masks = np.stack([
                chunk_mask_from_packets(
                    n_elems, frames[i % len(frames)].delivered,
                    self.wire_dtype_bytes, self.netcfg.mtu)
                for i in range(xs.shape[0])]).astype(np.float32)
            preds = self._apply_batched(fn, xs, masks)

        label = scenario.label()
        return SimVerdict(Candidate(label, getattr(scenario.split_plan, "split_layer", None)),
                          lat, self._accuracy(preds, ys),
                          meta={"wire_bytes": times["wire_bytes"],
                                "mean_tx": float(np.mean([t.n_transmissions for t in frames])),
                                "edge_s": times["edge_s"],
                                "server_s": times["server_s"]})
