"""Transport-layer models on the discrete-event engine (paper §IV).

TCP: windowed reliable stream.  Lost packets are detected by retransmission
timeout (RTO = 2*RTT + serialization) and resent until delivered — latency
grows with the loss rate, accuracy is preserved (Fig. 3 / Fig. 4 left).

UDP: fire-and-forget.  Latency is loss-independent; lost packets are simply
missing at the receiver (Fig. 4 right) — the receiver zeroes the matching
payload chunks and accuracy degrades.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .channel import Channel
from .events import EventQueue

MTU_BYTES = 1500
# default TCP send window (packets); netsim.analytic's closed form keys
# on the same constant, so tune it here, not at call sites
TCP_WINDOW = 32


class RetryBudgetExceeded(RuntimeError):
    """A simulated TCP transfer gave up: some packet exceeded
    ``max_rounds`` retransmissions (a link so lossy the transfer is
    effectively infeasible).  Typed so planners can map the design point
    to *infeasible* and keep sweeping instead of crashing."""

    def __init__(self, packet: int, rounds: int, loss_rate: float):
        super().__init__(
            f"TCP retry budget exceeded: packet {packet} hit {rounds} "
            f"rounds on a loss_rate={loss_rate} channel")
        self.packet = packet
        self.rounds = rounds
        self.loss_rate = loss_rate


@dataclass
class TransferResult:
    duration_s: float                 # first-bit-sent -> last-byte-delivered
    n_packets: int
    n_transmissions: int              # includes retransmits
    delivered: np.ndarray             # bool per packet (UDP can drop)

    @property
    def loss_fraction(self) -> float:
        return 1.0 - float(self.delivered.mean()) if len(self.delivered) else 0.0


def n_packets_for(n_bytes: int, mtu: int = MTU_BYTES) -> int:
    return max(1, math.ceil(n_bytes / mtu))


def simulate_tcp(n_bytes: int, ch: Channel, *, window: int = TCP_WINDOW,
                 mtu: int = MTU_BYTES, stream: int = 0,
                 max_rounds: int = 64) -> TransferResult:
    """Windowed reliable transfer; returns total delivery time."""
    n = n_packets_for(n_bytes, mtu)
    ser = ch.serialization_s(mtu)
    rtt = 2 * ch.latency_s
    rto = 2 * rtt + ser + 1e-6
    rng = np.random.default_rng((ch.seed, stream, 17))

    q = EventQueue()
    state = {
        "pending": list(range(n)),     # packets needing (re)send, FIFO
        "outstanding": set(),
        "acked": np.zeros(n, bool),
        "link_free": 0.0,
        "done_time": 0.0,
        "tx": 0,
        "rounds": np.zeros(n, int),
        "rto_timer": {},               # pkt -> live EventHandle
    }

    def try_send():
        while state["pending"] and len(state["outstanding"]) < window:
            pkt = state["pending"].pop(0)
            if state["acked"][pkt]:
                continue
            start = max(q.now, state["link_free"])
            state["link_free"] = start + ser
            state["tx"] += 1
            state["outstanding"].add(pkt)
            state["rounds"][pkt] += 1
            if state["rounds"][pkt] > max_rounds:
                raise RetryBudgetExceeded(pkt, int(state["rounds"][pkt]),
                                          ch.loss_rate)
            lost = rng.random() < ch.loss_rate
            if not lost:
                q.schedule(state["link_free"] + ch.latency_s,
                           lambda p=pkt: on_arrive(p))
            state["rto_timer"][pkt] = q.schedule(
                state["link_free"] + rto, lambda p=pkt: on_timeout(p))

    def on_arrive(pkt):
        # data arrives; ACK flies back one propagation later
        q.schedule(q.now + ch.latency_s, lambda p=pkt: on_ack(p))
        state["done_time"] = max(state["done_time"], q.now)

    def on_ack(pkt):
        if not state["acked"][pkt]:
            state["acked"][pkt] = True
            state["outstanding"].discard(pkt)
            timer = state["rto_timer"].pop(pkt, None)
            if timer is not None:
                timer.cancel()
            try_send()

    def on_timeout(pkt):
        if not state["acked"][pkt] and pkt in state["outstanding"]:
            state["outstanding"].discard(pkt)
            state["pending"].append(pkt)
            try_send()

    q.schedule(0.0, try_send)
    q.run()
    assert state["acked"].all()
    return TransferResult(state["done_time"], n, state["tx"], np.ones(n, bool))


def simulate_udp(n_bytes: int, ch: Channel, *, mtu: int = MTU_BYTES,
                 stream: int = 0) -> TransferResult:
    """Unreliable transfer: back-to-back datagrams, no recovery."""
    n = n_packets_for(n_bytes, mtu)
    ser = ch.serialization_s(mtu)
    lost = ch.loss_mask(n, stream)
    delivered = ~lost
    # last *delivered* packet determines perceived arrival; if everything is
    # lost the receiver still waits out the stream (sender-clocked).
    if delivered.any():
        last = int(np.max(np.nonzero(delivered)[0]))
    else:
        last = n - 1
    duration = (last + 1) * ser + ch.latency_s
    return TransferResult(duration, n, n, delivered)


def simulate_transfer(protocol: str, n_bytes: int, ch: Channel, *,
                      stream: int = 0, **kw) -> TransferResult:
    if protocol == "tcp":
        return simulate_tcp(n_bytes, ch, stream=stream, **kw)
    if protocol == "udp":
        return simulate_udp(n_bytes, ch, stream=stream, **kw)
    raise ValueError(f"unknown protocol {protocol!r}")
