"""Vectorized closed-form makespan engine (the planner fast path).

The tier planner's search space — cut list x stage->tier assignment
(x protocol x batch in the fleet planner) — grows combinatorially, and
pricing every combination with one discrete-event simulation each
(``simulate_pipeline`` schedules n_micro transfers per hop, each a
per-packet event run) caps how much of the space can be explored.  This
module prices the *whole* candidate set as array operations instead:

* :func:`transfer_duration_s` — closed forms of the zero-loss transport
  models in ``netsim.protocols``.  UDP is ``n_pkts * ser + lat``.  TCP's
  windowed send obeys ``f[j] = max(f[j-1], f[j-W] + 2*lat) + ser`` (a
  packet goes out when the link frees *and* the window opens); solving
  the recurrence gives ``f[n-1] = (r+1)*ser + q*max(W*ser, 2*lat+ser)``
  with ``q, r = divmod(n-1, W)`` — the two maximum terms are the
  link-bound and ack-bound steady states.
* :func:`pipeline_makespan_s` — the GPipe fill/drain + bottleneck form
  of the microbatched schedule.  With per-microbatch hop durations
  constant (the zero-loss case), the event engine is a deterministic
  flow shop — tiers and links are serial FIFO resources, propagation is
  a pure delay — whose makespan is exactly
  ``sum(per-microbatch stage and hop times) + (n_micro-1) * bottleneck``
  where the bottleneck is the slowest serial resource (stage time / n or
  sender-busy hop time).  Per-hop packetisation overhead is kept (each
  microbatch pays ``ceil``-rounded packets), so the planner's
  unchopped-fallback decision (``sequential < pipelined``) is identical
  to the event engine's.

**Contract**: the event engine in ``netsim.events``/``netsim.protocols``
stays the single semantic authority.  On loss-free paths
(:attr:`PathParams.exact`) the closed form must agree with
``simulate_pipeline`` to 1e-9 relative — enforced by the
``check_closed_form`` hook the planner's refinement stage runs — and on
lossy paths it is a *screen* only (loss-free optimistic bound for TCP,
upper bound for UDP): survivors must be re-priced by the event engine.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .protocols import TCP_WINDOW


@dataclass(frozen=True)
class PathParams:
    """Per-hop channel/protocol constants of a ``NetworkPath``, as arrays
    ready for broadcasting against ``(n_combos, n_hops)`` payload
    tensors."""
    ser_s: np.ndarray           # one-MTU serialization time per hop
    latency_s: np.ndarray       # propagation delay per hop
    mtu: np.ndarray             # packet size per hop (bytes)
    is_tcp: np.ndarray          # bool per hop
    window: np.ndarray          # TCP send window per hop
    loss_rate: np.ndarray       # saboteur loss per hop

    @property
    def n_hops(self) -> int:
        return len(self.ser_s)

    @property
    def exact(self) -> bool:
        """True when the closed form equals the event engine (no loss:
        transfer durations are deterministic and microbatch-independent)."""
        return bool((self.loss_rate == 0.0).all())


def path_params(path) -> PathParams:
    """Extract :class:`PathParams` from a ``NetworkPath`` (or anything
    ``netsim.simulator.as_path`` accepts)."""
    from .simulator import as_path
    path = as_path(path)
    for h in path:
        if h.protocol not in ("tcp", "udp"):
            raise ValueError(f"unknown protocol {h.protocol!r}")
    return PathParams(
        ser_s=np.array([h.channel.serialization_s(h.mtu) for h in path]),
        latency_s=np.array([h.channel.latency_s for h in path]),
        mtu=np.array([float(h.mtu) for h in path]),
        is_tcp=np.array([h.protocol == "tcp" for h in path]),
        window=np.array([float(TCP_WINDOW) for _ in path.hops]),
        loss_rate=np.array([h.channel.loss_rate for h in path]),
    )


def transfer_duration_s(n_bytes, pp: PathParams) -> np.ndarray:
    """Zero-loss transfer durations, vectorized.

    ``n_bytes``: array whose last axis runs over the path's hops
    (``(..., n_hops)``); returns the same shape.  Matches
    ``protocols.simulate_tcp`` / ``simulate_udp`` exactly at
    ``loss_rate == 0`` (both charge a full-MTU serialization per packet,
    and a zero-byte payload still costs one packet).
    """
    n_bytes = np.asarray(n_bytes, dtype=float)
    n_pkts = np.maximum(1.0, np.ceil(n_bytes / pp.mtu))
    ser, lat = pp.ser_s, pp.latency_s
    # TCP: q full window cycles at the steady-state rate (link-bound
    # W*ser vs ack-bound 2*lat+ser), then r+1 back-to-back packets
    q, r = np.divmod(n_pkts - 1.0, pp.window)
    cycle = np.maximum(pp.window * ser, 2.0 * lat + ser)
    tcp = (r + 1.0) * ser + q * cycle + lat
    udp = n_pkts * ser + lat
    return np.where(pp.is_tcp, tcp, udp)


def pipeline_makespan_s(stage_s, hop_bytes, pp: PathParams,
                        n_micro: int = 4, hop_mask=None) -> tuple:
    """Closed-form ``(pipelined, sequential)`` makespans, vectorized.

    ``stage_s``: ``(..., n_tiers)`` per-stage compute times (zero entries
    model pass-through tiers); ``hop_bytes``: ``(..., n_hops)`` payloads;
    ``hop_mask``: optional bool ``(..., n_hops)`` marking which physical
    links a combo actually crosses (a plan ending early uses a prefix of
    the chain) — unused hops contribute nothing.

    The pipelined form is the deterministic-flow-shop makespan: the first
    microbatch's end-to-end path time plus ``n_micro - 1`` periods of the
    bottleneck serial resource, where a link holds a microbatch for its
    sender-clocked time (duration minus one propagation delay, the same
    convention ``simulate_pipeline`` frees links under).
    """
    if n_micro < 1:
        raise ValueError(f"n_micro must be >= 1, got {n_micro}")
    stage_s = np.asarray(stage_s, dtype=float)
    hop_bytes = np.asarray(hop_bytes, dtype=float)
    if hop_mask is None:
        hop_mask = np.ones(hop_bytes.shape, dtype=bool)
    full = np.where(hop_mask, transfer_duration_s(hop_bytes, pp), 0.0)
    seq = stage_s.sum(-1) + full.sum(-1)

    mb_bytes = np.maximum(1.0, np.ceil(hop_bytes / n_micro))
    mb = np.where(hop_mask, transfer_duration_s(mb_bytes, pp), 0.0)
    busy = np.where(hop_mask, np.maximum(mb - pp.latency_s, 0.0), 0.0)
    stage_mb = stage_s / n_micro
    bottleneck = np.maximum(stage_mb.max(-1, initial=0.0),
                            busy.max(-1, initial=0.0))
    pipe = stage_mb.sum(-1) + mb.sum(-1) + (n_micro - 1) * bottleneck
    return pipe, seq


def closed_form_pipeline(stage_s, hop_bytes, path, *,
                         n_micro: int = 4) -> tuple:
    """Scalar convenience: ``(pipelined_s, sequential_s)`` of one combo —
    same validation as ``simulate_pipeline``."""
    pp = path_params(path)
    if len(stage_s) != pp.n_hops + 1 or len(hop_bytes) != pp.n_hops:
        raise ValueError(
            f"{pp.n_hops}-hop path needs {pp.n_hops + 1} stage times and "
            f"{pp.n_hops} payloads, got {len(stage_s)}/{len(hop_bytes)}")
    pipe, seq = pipeline_makespan_s(
        np.asarray(stage_s, dtype=float)[None, :],
        np.asarray(hop_bytes, dtype=float)[None, :], pp, n_micro)
    return float(pipe[0]), float(seq[0])


def assert_event_match(name: str, closed: float, event: float,
                       rel: float = 1e-9) -> None:
    """The screen-analytically / refine-exactly contract: on exact paths
    the closed form must reproduce the event engine."""
    if not math.isclose(closed, event, rel_tol=rel, abs_tol=1e-15):
        raise AssertionError(
            f"closed-form {name} diverged from the event engine: "
            f"{closed!r} vs {event!r} (rel tol {rel}) — the event engine "
            f"is the semantic authority; fix netsim.analytic")
