"""Communication-aware discrete-event simulation (paper §IV)."""
from .channel import Channel, INTERFACES, compose_channels  # noqa: F401
from .protocols import (RetryBudgetExceeded,        # noqa: F401
                        simulate_transfer)
from .simulator import (ApplicationSimulator, NetworkConfig,  # noqa: F401
                        NetworkPath, PipelineResult, simulate_pipeline)
