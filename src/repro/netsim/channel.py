"""Channel model (the *netsim* layer): propagation delay, capacity,
interface speed, and the loss *saboteur* (paper §IV's five parameters).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Channel:
    latency_s: float            # propagation delay per packet
    capacity_bps: float         # link available bandwidth
    interface_bps: float        # physical interface speed (NIC)
    loss_rate: float = 0.0      # saboteur: per-packet loss probability
    seed: int = 0

    @property
    def effective_bps(self) -> float:
        return min(self.capacity_bps, self.interface_bps)

    def serialization_s(self, n_bytes: int) -> float:
        return n_bytes * 8.0 / self.effective_bps

    def loss_mask(self, n: int, stream: int = 0) -> np.ndarray:
        """Deterministic per-packet loss draws (True = lost)."""
        rng = np.random.default_rng((self.seed, stream))
        return rng.random(n) < self.loss_rate


def degrade(channel: Channel, *, capacity_factor: float = 1.0,
            latency_factor: float = 1.0, loss_add: float = 0.0) -> Channel:
    """A degraded copy of ``channel``: capacity scaled down, propagation
    delay scaled up, extra loss compounded on top of the existing rate.
    The interface speed is physical and does not degrade."""
    if not (0.0 < capacity_factor <= 1.0):
        raise ValueError(f"capacity_factor must be in (0, 1], "
                         f"got {capacity_factor}")
    if latency_factor < 1.0:
        raise ValueError(f"latency_factor must be >= 1, got {latency_factor}")
    loss = 1.0 - (1.0 - channel.loss_rate) * (1.0 - loss_add)
    return Channel(channel.latency_s * latency_factor,
                   channel.capacity_bps * capacity_factor,
                   channel.interface_bps, loss_rate=loss, seed=channel.seed)


@dataclass(frozen=True)
class ChannelSchedule:
    """A channel whose parameters change at scheduled simulated times.

    ``events`` is a sorted tuple of ``(t_s, Channel)``: from ``t_s``
    onward the link *is* that channel (absolute replacement, not a
    delta — compose with :func:`degrade` to derive one).  :meth:`at`
    answers "which channel carries a transfer starting at ``t``", which
    is how the adaptive controller prices per-arrival wire legs;
    :meth:`schedule_on` arms one named event per change on an
    ``EventQueue`` (the same loop ``ClusterSim`` runs on), so an
    embedding simulation observes link changes as they happen rather
    than by polling.
    """
    base: Channel
    events: tuple = ()               # ((t_s, Channel), ...) sorted by t_s

    def __post_init__(self):
        ev = tuple(sorted(self.events, key=lambda e: e[0]))
        object.__setattr__(self, "events", ev)

    def at(self, t: float) -> Channel:
        ch = self.base
        for t_ev, c in self.events:
            if t_ev <= t:
                ch = c
            else:
                break
        return ch

    def epoch(self, t: float) -> int:
        """Index of the link regime active at ``t`` (0 = base) — a
        cache key for anything priced per link state."""
        k = 0
        for t_ev, _ in self.events:
            if t_ev <= t:
                k += 1
            else:
                break
        return k

    def schedule_on(self, queue, on_change) -> list:
        """Schedule ``on_change(t_s, channel)`` for every future event
        on ``queue`` (a ``netsim.events.EventQueue``); returns the event
        handles so an embedder can cancel them."""
        return [queue.schedule_named(
                    t_ev, lambda t=t_ev, c=ch: on_change(t, c),
                    "link-change")
                for t_ev, ch in self.events if t_ev >= queue.now]


def compose_channels(channels) -> Channel:
    """The effective single channel of a multi-link store-and-forward
    segment: latencies add, bandwidth is the bottleneck link, loss
    compounds (``1 - prod(1 - p)``).  Used when a logical wire hop of a
    tier plan traverses several physical links (a skipped tier forwards
    without computing) but the consumer prices one transfer per hop.
    """
    channels = list(channels)
    if not channels:
        raise ValueError("compose_channels needs at least one channel")
    if len(channels) == 1:
        return channels[0]
    loss = 1.0
    for ch in channels:
        loss *= 1.0 - ch.loss_rate
    return Channel(sum(ch.latency_s for ch in channels),
                   min(ch.capacity_bps for ch in channels),
                   min(ch.interface_bps for ch in channels),
                   loss_rate=1.0 - loss, seed=channels[0].seed)


# Interface presets from the paper (§IV): Gigabit, Fast-Ethernet, Wi-Fi.
INTERFACES = {
    "gigabit": 1000e6,
    "fast-ethernet": 100e6,
    "wifi": 160e6,
    "10gbe": 10e9,
    # TPU fabric profiles for the multi-pod adaptation (DESIGN.md §3)
    "tpu-ici-link": 50e9 * 8,          # 50 GB/s per ICI link
    "tpu-dcn": 25e9,
}
