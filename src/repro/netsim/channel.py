"""Channel model (the *netsim* layer): propagation delay, capacity,
interface speed, and the loss *saboteur* (paper §IV's five parameters).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Channel:
    latency_s: float            # propagation delay per packet
    capacity_bps: float         # link available bandwidth
    interface_bps: float        # physical interface speed (NIC)
    loss_rate: float = 0.0      # saboteur: per-packet loss probability
    seed: int = 0

    @property
    def effective_bps(self) -> float:
        return min(self.capacity_bps, self.interface_bps)

    def serialization_s(self, n_bytes: int) -> float:
        return n_bytes * 8.0 / self.effective_bps

    def loss_mask(self, n: int, stream: int = 0) -> np.ndarray:
        """Deterministic per-packet loss draws (True = lost)."""
        rng = np.random.default_rng((self.seed, stream))
        return rng.random(n) < self.loss_rate


# Interface presets from the paper (§IV): Gigabit, Fast-Ethernet, Wi-Fi.
INTERFACES = {
    "gigabit": 1000e6,
    "fast-ethernet": 100e6,
    "wifi": 160e6,
    "10gbe": 10e9,
    # TPU fabric profiles for the multi-pod adaptation (DESIGN.md §3)
    "tpu-ici-link": 50e9 * 8,          # 50 GB/s per ICI link
    "tpu-dcn": 25e9,
}
