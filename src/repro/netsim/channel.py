"""Channel model (the *netsim* layer): propagation delay, capacity,
interface speed, and the loss *saboteur* (paper §IV's five parameters).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Channel:
    latency_s: float            # propagation delay per packet
    capacity_bps: float         # link available bandwidth
    interface_bps: float        # physical interface speed (NIC)
    loss_rate: float = 0.0      # saboteur: per-packet loss probability
    seed: int = 0

    @property
    def effective_bps(self) -> float:
        return min(self.capacity_bps, self.interface_bps)

    def serialization_s(self, n_bytes: int) -> float:
        return n_bytes * 8.0 / self.effective_bps

    def loss_mask(self, n: int, stream: int = 0) -> np.ndarray:
        """Deterministic per-packet loss draws (True = lost)."""
        rng = np.random.default_rng((self.seed, stream))
        return rng.random(n) < self.loss_rate


def compose_channels(channels) -> Channel:
    """The effective single channel of a multi-link store-and-forward
    segment: latencies add, bandwidth is the bottleneck link, loss
    compounds (``1 - prod(1 - p)``).  Used when a logical wire hop of a
    tier plan traverses several physical links (a skipped tier forwards
    without computing) but the consumer prices one transfer per hop.
    """
    channels = list(channels)
    if not channels:
        raise ValueError("compose_channels needs at least one channel")
    if len(channels) == 1:
        return channels[0]
    loss = 1.0
    for ch in channels:
        loss *= 1.0 - ch.loss_rate
    return Channel(sum(ch.latency_s for ch in channels),
                   min(ch.capacity_bps for ch in channels),
                   min(ch.interface_bps for ch in channels),
                   loss_rate=1.0 - loss, seed=channels[0].seed)


# Interface presets from the paper (§IV): Gigabit, Fast-Ethernet, Wi-Fi.
INTERFACES = {
    "gigabit": 1000e6,
    "fast-ethernet": 100e6,
    "wifi": 160e6,
    "10gbe": 10e9,
    # TPU fabric profiles for the multi-pod adaptation (DESIGN.md §3)
    "tpu-ici-link": 50e9 * 8,          # 50 GB/s per ICI link
    "tpu-dcn": 25e9,
}
