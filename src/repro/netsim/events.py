"""The shared discrete-event engine (the *supervisor* layer, paper §IV).

One implementation serves every simulator in the repo: the per-flow
transport models in ``repro.netsim.protocols`` and the fleet-scale cluster
model in ``repro.fleet.cluster`` both schedule onto this queue — there is
deliberately no second event loop anywhere.

Executes events in correct temporal order; callbacks may schedule further
events.  Deterministic tie-breaking by insertion sequence keeps runs
reproducible.  ``schedule`` returns an :class:`EventHandle` so timers that
become moot (TCP retransmission timeouts after the ACK, dynamic-batching
windows that fill early) can be cancelled instead of firing dead.

Telemetry: pass a ``repro.obs.Recorder`` as ``obs`` and every fired
event becomes an instant span on the simulated clock (named by the
``label`` given to :meth:`EventQueue.schedule_named`, falling back to
the callback's qualname) plus ``events.fired`` / ``events.cancelled``
counters; each :meth:`run` is wrapped in an event-chain span.  Cancelled
events are *counted, never spanned* — a span means the callback ran.
With the default :data:`repro.obs.NULL` recorder the hot loop is the
uninstrumented one (dispatch happens once per ``run`` call, not per
event), so tracing off costs nothing measurable —
``benchmarks/bench_obs.py`` gates the ceiling.
"""
from __future__ import annotations

import heapq
from typing import Callable

from repro.obs import NULL


class EventHandle:
    """Cancellation token for a scheduled event.

    ``cancel`` after the event already fired is a harmless no-op: the
    event left the heap when it ran, so the flag is never read again.
    ``label`` is telemetry metadata — set only when ``schedule`` was
    given one (the slot stays unset otherwise, keeping handle
    construction on the hot path as cheap as the uninstrumented
    engine's; the traced loop reads it with ``getattr``).
    """

    __slots__ = ("time", "seq", "cancelled", "label")

    def __init__(self, time: float, seq: int):
        self.time = time
        self.seq = seq
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True


class EventQueue:
    def __init__(self, obs=None):
        self._q = []
        self._seq = 0
        self.now = 0.0
        self.n_fired = 0          # events executed (cancelled ones excluded)
        self.n_cancelled = 0
        self.obs = NULL if obs is None else obs

    def schedule(self, time: float, fn: Callable[[], None]) -> EventHandle:
        assert time >= self.now - 1e-12, (time, self.now)
        h = EventHandle(time, self._seq)
        heapq.heappush(self._q, (time, self._seq, fn, h))
        self._seq += 1
        return h

    def schedule_named(self, time: float, fn: Callable[[], None],
                       label: str) -> EventHandle:
        """:meth:`schedule` plus a telemetry label naming the event's
        instant span in exported traces.  A separate method (one extra
        attribute store) so the unlabelled hot path stays exactly the
        uninstrumented engine's — even a defaulted ``label=None``
        parameter on :meth:`schedule` costs a measurable fraction of a
        bare event cycle, and ``bench_obs`` gates that at <1%."""
        h = self.schedule(time, fn)
        h.label = label
        return h

    def peek(self) -> float:
        """Time of the next live event (inf when drained)."""
        while self._q and self._q[0][3].cancelled:
            heapq.heappop(self._q)
        return self._q[0][0] if self._q else float("inf")

    def run(self, until: float = float("inf"), max_events: int = 10_000_000) -> None:
        if self.obs.enabled:
            return self._run_traced(until, max_events)
        n = 0
        while self._q and self._q[0][0] <= until:
            t, _, fn, h = heapq.heappop(self._q)
            if h.cancelled:
                self.n_cancelled += 1
                continue
            self.now = t
            fn()
            n += 1
            self.n_fired += 1
            if n >= max_events:
                raise RuntimeError("event budget exceeded (livelock?)")

    def _run_traced(self, until: float, max_events: int) -> None:
        """The recording twin of :meth:`run` — same semantics, plus an
        instant span per fired event and fired/cancelled counters.  Kept
        separate so the null path above stays the bare hot loop."""
        tracer = self.obs.tracer
        c_fired = self.obs.metrics.counter("events.fired")
        c_cancelled = self.obs.metrics.counter("events.cancelled")
        t_start, n = self.now, 0
        while self._q and self._q[0][0] <= until:
            t, _, fn, h = heapq.heappop(self._q)
            if h.cancelled:
                self.n_cancelled += 1
                c_cancelled.inc()
                continue
            self.now = t
            tracer.instant(getattr(h, "label", None)
                           or getattr(fn, "__qualname__", "event"),
                           t, clock="sim", tid="events", cat="event")
            fn()
            n += 1
            self.n_fired += 1
            c_fired.inc()
            if n >= max_events:
                raise RuntimeError("event budget exceeded (livelock?)")
        if n:
            tracer.add("event-chain", t_start, self.now, clock="sim",
                       tid="events", cat="event", args={"n_events": n})

    def empty(self) -> bool:
        return self.peek() == float("inf")
