"""Minimal discrete-event engine (the *supervisor* layer, paper §IV).

Executes events in correct temporal order; callbacks may schedule further
events.  Deterministic tie-breaking by insertion sequence keeps runs
reproducible.
"""
from __future__ import annotations

import heapq
from typing import Callable


class EventQueue:
    def __init__(self):
        self._q = []
        self._seq = 0
        self.now = 0.0

    def schedule(self, time: float, fn: Callable[[], None]) -> None:
        assert time >= self.now - 1e-12, (time, self.now)
        heapq.heappush(self._q, (time, self._seq, fn))
        self._seq += 1

    def run(self, until: float = float("inf"), max_events: int = 10_000_000) -> None:
        n = 0
        while self._q and self._q[0][0] <= until:
            t, _, fn = heapq.heappop(self._q)
            self.now = t
            fn()
            n += 1
            if n >= max_events:
                raise RuntimeError("event budget exceeded (livelock?)")

    def empty(self) -> bool:
        return not self._q
