"""The shared discrete-event engine (the *supervisor* layer, paper §IV).

One implementation serves every simulator in the repo: the per-flow
transport models in ``repro.netsim.protocols`` and the fleet-scale cluster
model in ``repro.fleet.cluster`` both schedule onto this queue — there is
deliberately no second event loop anywhere.

Executes events in correct temporal order; callbacks may schedule further
events.  Deterministic tie-breaking by insertion sequence keeps runs
reproducible.  ``schedule`` returns an :class:`EventHandle` so timers that
become moot (TCP retransmission timeouts after the ACK, dynamic-batching
windows that fill early) can be cancelled instead of firing dead.
"""
from __future__ import annotations

import heapq
from typing import Callable


class EventHandle:
    """Cancellation token for a scheduled event."""

    __slots__ = ("time", "seq", "cancelled")

    def __init__(self, time: float, seq: int):
        self.time = time
        self.seq = seq
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True


class EventQueue:
    def __init__(self):
        self._q = []
        self._seq = 0
        self.now = 0.0
        self.n_fired = 0          # events executed (cancelled ones excluded)
        self.n_cancelled = 0

    def schedule(self, time: float, fn: Callable[[], None]) -> EventHandle:
        assert time >= self.now - 1e-12, (time, self.now)
        h = EventHandle(time, self._seq)
        heapq.heappush(self._q, (time, self._seq, fn, h))
        self._seq += 1
        return h

    def peek(self) -> float:
        """Time of the next live event (inf when drained)."""
        while self._q and self._q[0][3].cancelled:
            heapq.heappop(self._q)
        return self._q[0][0] if self._q else float("inf")

    def run(self, until: float = float("inf"), max_events: int = 10_000_000) -> None:
        n = 0
        while self._q and self._q[0][0] <= until:
            t, _, fn, h = heapq.heappop(self._q)
            if h.cancelled:
                self.n_cancelled += 1
                continue
            self.now = t
            fn()
            n += 1
            self.n_fired += 1
            if n >= max_events:
                raise RuntimeError("event budget exceeded (livelock?)")

    def empty(self) -> bool:
        return self.peek() == float("inf")
