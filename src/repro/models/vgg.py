"""VGG16 as a :class:`LayeredModel` — the paper's experimental workhorse.

Matches the torchvision VGG16 the paper instruments (Table I/II: 138,357,544
parameters, 224x224x3 input, 1000 classes), plus a reduced CIFAR-style
variant (``vgg_cifar``) that is actually trainable on CPU for the paper's
experiments (CIFAR10 is "a placeholder" in the paper itself, §V).

Layout is NHWC (TPU-native).  The layer list mirrors the paper's indexing:
conv/relu pairs and maxpools in 5 blocks — Fig. 2's split candidates
(block2_pool=5*, block3_pool=9*, block4_pool=13*, block4_conv2=11,
block5_conv2=15) refer to *feature-extractor op indices* counting
conv/pool ops, which we preserve via ``feature_index``.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .layered import Layer, LayeredModel

VGG16_PLAN = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
              512, 512, 512, "M", 512, 512, 512, "M"]


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    std = math.sqrt(2.0 / fan_in)
    k1, _ = jax.random.split(key)
    return {"w": jax.random.normal(k1, (kh, kw, cin, cout), jnp.float32) * std,
            "b": jnp.zeros((cout,), jnp.float32)}


def _conv_apply(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _pool_apply(_, x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def _linear_init(key, fin, fout):
    std = math.sqrt(1.0 / fin)
    k1, _ = jax.random.split(key)
    return {"w": jax.random.normal(k1, (fin, fout), jnp.float32) * std,
            "b": jnp.zeros((fout,), jnp.float32)}


def build_vgg(plan=None, *, input_hw=224, in_ch=3, n_classes=1000,
              classifier_width=4096, name="vgg16") -> LayeredModel:
    plan = plan or VGG16_PLAN
    layers = []
    cin = in_ch
    hw = input_hw
    for spec in plan:
        if spec == "M":
            if hw < 2:   # tiny inputs: skip pools that would hit 0x0
                continue
            layers.append(Layer(f"pool{len(layers)}", "pool",
                                lambda k: {}, _pool_apply, splittable=True))
            hw //= 2
        else:
            cout = spec
            layers.append(Layer(f"conv{len(layers)}", "conv",
                                partial(_conv_init, kh=3, kw=3, cin=cin, cout=cout),
                                _conv_apply, splittable=False))
            layers.append(Layer(f"relu{len(layers)}", "relu",
                                lambda k: {}, lambda p, x: jax.nn.relu(x),
                                splittable=True))
            cin = cout
    feat = hw * hw * cin
    layers.append(Layer("flatten", "flatten", lambda k: {},
                        lambda p, x: x.reshape(x.shape[0], -1), splittable=True))
    dims = [feat, classifier_width, classifier_width, n_classes]
    for i in range(3):
        layers.append(Layer(f"fc{i}", "linear",
                            partial(_linear_init, fin=dims[i], fout=dims[i + 1]),
                            lambda p, x: x @ p["w"] + p["b"],
                            splittable=i < 2))
        if i < 2:
            layers.append(Layer(f"fc{i}_relu", "relu", lambda k: {},
                                lambda p, x: jax.nn.relu(x), splittable=True))
    return LayeredModel(name=name, layers=layers,
                        input_shape=(input_hw, input_hw, in_ch),
                        n_classes=n_classes)


def vgg16() -> LayeredModel:
    """Full VGG16: 138,357,544 params (paper Table II)."""
    return build_vgg()


VGG_CIFAR_PLAN = [32, 32, "M", 64, 64, "M", 128, 128, "M"]


def vgg_cifar(n_classes=10, input_hw=32, width_mult=1.0) -> LayeredModel:
    """Reduced VGG for CPU-trainable paper experiments.

    Same VGG idiom (stacks of 3x3 conv+ReLU and maxpools, blocks of
    irregular output size — the property that makes split-point choice
    non-trivial, §V) but 6 convs / 3 blocks so it trains from scratch on
    CPU without batchnorm.  ``vgg16()`` stays the exact 138M-param net for
    the Tables I-II reproduction.
    """
    plan = [max(8, int(c * width_mult)) if c != "M" else "M"
            for c in VGG_CIFAR_PLAN]
    return build_vgg(plan, input_hw=input_hw, in_ch=3, n_classes=n_classes,
                     classifier_width=256, name="vgg_cifar")


def feature_index(model: LayeredModel) -> list:
    """Indices of conv/pool ops in paper numbering (conv+pool ops only).

    Fig. 2's x-axis counts the 18 feature ops (13 conv + 5 pool); returns the
    LayeredModel layer index of each, taking the post-ReLU activation for
    convs (saliency is computed on post-activation maps).
    """
    out = []
    for i, l in enumerate(model.layers):
        if l.kind == "conv":
            out.append(i + 1)      # the relu right after
        elif l.kind == "pool":
            out.append(i)
    return out


def n_params(model: LayeredModel, params) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree.leaves(params))
