"""Mamba (S6) selective-state-space mixer, used by the Jamba hybrid.

Follows the Mamba block from arXiv:2312.00752 as instantiated in Jamba
(arXiv:2403.19887): in-proj to (x, z), depthwise causal conv, data-dependent
(dt, B, C), diagonal state update, gated out-proj.  Sequence mode is a
``lax.scan`` over time; decode mode keeps a (conv, ssm) state pair.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_dense

DT_RANK = 16


def d_inner(cfg) -> int:
    return cfg.mamba_expand * cfg.d_model


def init_mamba(key, cfg) -> dict:
    d = cfg.d_model
    di, ds, dc = d_inner(cfg), cfg.mamba_d_state, cfg.mamba_d_conv
    ks = jax.random.split(key, 5)
    dt = cfg.jdtype
    return {
        "in_proj": init_dense(ks[0], d, 2 * di, dt),
        "conv": (jax.random.normal(ks[1], (dc, di), jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": init_dense(ks[2], di, DT_RANK + 2 * ds, dt),
        "dt_proj": init_dense(ks[3], DT_RANK, di, jnp.float32),
        "dt_bias": jnp.zeros((di,), jnp.float32) + jnp.log(jnp.expm1(0.01)),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": init_dense(ks[4], di, d, dt),
    }


def _ssm_params(p, xc, ds):
    """xc: (..., di) conv output -> dt (..., di), B (..., ds), C (..., ds)."""
    proj = xc @ p["x_proj"]
    dt_r, B, C = jnp.split(proj.astype(jnp.float32), [DT_RANK, DT_RANK + ds], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"] + p["dt_bias"])
    return dt, B, C


def mamba_seq(p, x, cfg, init_state=None, *, chunk: int = 128, shard_fn=None):
    """Full-sequence mamba. x: (B,S,D) -> (y (B,S,D), (conv_state, ssm_state)).

    Chunked recurrence: the (B,*,di,ds) discretised operands are only ever
    materialised per ``chunk`` timesteps, and ``jax.checkpoint`` at chunk
    boundaries bounds the backward-pass residency to one chunk of carries —
    without this a 4k-step training scan saves a (B,di,ds) f32 carry per
    step (tens of GB/device; see EXPERIMENTS.md §Perf).
    """
    sf = shard_fn or (lambda a, k: a)
    b, s, d = x.shape
    di, ds, dc = d_inner(cfg), cfg.mamba_d_state, cfg.mamba_d_conv
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                       # (B,S,di)
    xi, z = sf(xi, "mamba_inner"), sf(z, "mamba_inner")
    # depthwise causal conv over time
    if init_state is not None:
        pad = init_state[0].astype(xi.dtype)                # (B,dc-1,di)
    else:
        pad = jnp.zeros((b, dc - 1, di), xi.dtype)
    xp = jnp.concatenate([pad, xi], axis=1)                 # (B,S+dc-1,di)
    xc = sum(xp[:, i:i + s, :] * p["conv"][i] for i in range(dc)) + p["conv_b"]
    xc = sf(jax.nn.silu(xc), "mamba_inner")
    dt, B, C = _ssm_params(p, xc, ds)                       # (B,S,di),(B,S,ds)x2
    dt = sf(dt, "mamba_inner")
    A = -jnp.exp(p["A_log"])                                # (di,ds)

    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    nc = s // chunk

    def chunk_body(h, inp):
        dt_c, B_c, C_c, xc_c = inp                          # (B,chunk,...)
        dA = jnp.exp(dt_c[..., None] * A)                   # (B,chunk,di,ds)
        dBx = dt_c[..., None] * B_c[:, :, None, :] * xc_c.astype(jnp.float32)[..., None]

        def step(h, s_inp):
            dA_t, dBx_t, C_t = s_inp
            h = sf(dA_t * h + dBx_t, "mamba_state")         # (B,di,ds)
            y = jnp.einsum("bds,bs->bd", h, C_t)
            return h, y

        xs = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0),
              jnp.moveaxis(C_c, 1, 0))
        h, ys = jax.lax.scan(step, h, xs)
        return sf(h, "mamba_state"), jnp.moveaxis(ys, 0, 1)  # (B,chunk,di)

    def split_chunks(a):
        return jnp.moveaxis(a.reshape(b, nc, chunk, *a.shape[2:]), 1, 0)

    h0 = init_state[1] if init_state is not None else jnp.zeros((b, di, ds), jnp.float32)
    h0 = sf(h0, "mamba_state")
    xs = tuple(split_chunks(a) for a in (dt, B, C, xc))
    with jax.named_scope("mamba_scan"):   # kernel-replaceable (hlo_cost)
        h, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, di) + p["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    conv_state = xp[:, -(dc - 1):, :].astype(jnp.float32)
    return y, (conv_state, h)


def mamba_step(p, x, state, cfg):
    """One-token decode. x: (B,1,D); state=(conv (B,dc-1,di) f32, ssm (B,di,ds) f32)."""
    di, ds, dc = d_inner(cfg), cfg.mamba_d_state, cfg.mamba_d_conv
    conv_state, h = state
    xz = x[:, 0, :] @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                       # (B,di)
    win = jnp.concatenate([conv_state.astype(xi.dtype), xi[:, None, :]], axis=1)  # (B,dc,di)
    xc = jax.nn.silu(jnp.einsum("bcd,cd->bd", win, p["conv"]) + p["conv_b"])
    dt, B, C = _ssm_params(p, xc, ds)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)
    h = dA * h + dt[..., None] * B[:, None, :] * xc.astype(jnp.float32)[..., None]
    y = jnp.einsum("bds,bs->bd", h, C) + p["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return y[:, None, :], (win[:, 1:, :].astype(jnp.float32), h)


def init_state(cfg, batch):
    di, ds, dc = d_inner(cfg), cfg.mamba_d_state, cfg.mamba_d_conv
    return (jnp.zeros((batch, dc - 1, di), jnp.float32),
            jnp.zeros((batch, di, ds), jnp.float32))
