"""Model configuration shared by every architecture in the zoo.

A single frozen dataclass describes all six families (dense / moe / ssm /
hybrid / encdec / vlm).  Family-specific fields are simply unused by the
others.  Configs for the ten assigned architectures live in
``repro.configs`` and are plain instances of :class:`ModelConfig`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (deepseek / qwen3 / jamba style)."""

    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # always-on shared experts (deepseek)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance auxiliary loss
    moe_every: int = 1            # apply MoE FFN every k-th layer (jamba: 2)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # one of FAMILIES
    n_layers: int                 # decoder layers
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                     # dense FFN hidden dim (MoE: see moe.d_expert)
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False        # qwen2 uses QKV bias
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    # hybrid (jamba): within each period of `attn_period` layers, exactly one
    # attention mixer (at index `attn_index`), the rest Mamba.
    attn_period: int = 0          # 0 => pure attention stack (dense/moe/..)
    attn_index: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_lora_dim: int = 32
    # encoder-decoder (whisper) / vlm frontends (stubbed per the brief)
    n_enc_layers: int = 0
    n_frames: int = 0             # audio frames delivered by the stub frontend
    n_patches: int = 0            # vision patches delivered by the stub frontend
    d_frontend: int = 0           # stub embedding dim before projector
    # serving variants
    sliding_window: Optional[int] = None  # beyond-paper sliding-window attn
    dtype: str = "bfloat16"
    # reference for where this config comes from (paper / model card)
    source: str = ""

    def __post_init__(self):
        assert self.family in FAMILIES, self.family

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def is_attn_layer(self, i: int) -> bool:
        """Is decoder layer ``i`` an attention mixer (vs mamba)?"""
        if self.attn_free:
            return False
        if self.attn_period <= 0:
            return True
        return (i % self.attn_period) == self.attn_index

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return (i % self.moe.moe_every) == (self.moe.moe_every - 1)

    # ---- parameter counting (used by roofline MODEL_FLOPS and stats) ----
    def param_counts(self) -> dict:
        """Analytic parameter counts: total and "active" (MoE top-k only)."""
        d, hd = self.d_model, self.hd
        H, K = self.n_heads, self.n_kv_heads
        attn = d * (H * hd) + 2 * d * (K * hd) + (H * hd) * d
        if self.qkv_bias:
            attn += (H + 2 * K) * hd
        dense_ffn = 3 * d * self.d_ff
        per_layer_total = []
        per_layer_active = []
        for i in range(self.n_layers):
            mix = attn if self.is_attn_layer(i) else self._mamba_params()
            if self.family == "ssm":
                mix = self._rwkv_params()
                ffn_t = ffn_a = 2 * d * self.d_ff  # rwkv channel-mix: 2 mats
            elif self.is_moe_layer(i):
                m = self.moe
                ffn_t = 3 * d * m.d_expert * (m.n_experts + m.n_shared) + d * m.n_experts
                ffn_a = 3 * d * m.d_expert * (m.top_k + m.n_shared) + d * m.n_experts
            else:
                ffn_t = ffn_a = dense_ffn
            norms = 2 * d
            per_layer_total.append(mix + ffn_t + norms)
            per_layer_active.append(mix + ffn_a + norms)
        emb = self.vocab * d
        head = 0 if self.tie_embeddings else self.vocab * d
        enc = 0
        if self.family == "encdec":
            # encoder: self-attn + ffn; decoder additionally carries cross-attn
            enc = self.n_enc_layers * (attn + dense_ffn + 2 * d)
            per_layer_total = [p + attn + d for p in per_layer_total]
            per_layer_active = [p + attn + d for p in per_layer_active]
        proj = 2 * self.d_frontend * d if self.family == "vlm" else 0
        total = sum(per_layer_total) + emb + head + enc + proj + d
        active = sum(per_layer_active) + emb + head + enc + proj + d
        return {"total": total, "active": active, "embedding": emb + head}

    def _mamba_params(self) -> int:
        d = self.d_model
        di = self.mamba_expand * d
        ds = self.mamba_d_state
        return (d * 2 * di            # in_proj (x, z)
                + di * self.mamba_d_conv
                + di * (2 * ds + 1)   # B, C, dt data-dependent projections
                + di                  # dt bias
                + di * ds             # A (log)
                + di                  # D skip
                + di * d)             # out_proj

    def _rwkv_params(self) -> int:
        d = self.d_model
        lo = self.rwkv_lora_dim
        # r,k,v,g,o projections + decay/mix loras + per-head params
        return 5 * d * d + 2 * d * lo + 2 * lo * d + 6 * d


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A small same-family variant for CPU smoke tests (<=2 layers, d<=512)."""
    d_model = min(cfg.d_model, 128)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    small = dict(
        n_layers=2 if cfg.attn_period <= 0 else cfg.attn_period,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=d_model // n_heads,
        d_ff=min(cfg.d_ff, 256),
        vocab=min(cfg.vocab, 512),
        n_enc_layers=min(cfg.n_enc_layers, 2),
        n_frames=min(cfg.n_frames, 16) if cfg.n_frames else 0,
        n_patches=min(cfg.n_patches, 8) if cfg.n_patches else 0,
        d_frontend=min(cfg.d_frontend, 64) if cfg.d_frontend else 0,
        name=cfg.name + "-reduced",
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2), d_expert=min(cfg.moe.d_expert, 64),
            n_shared=min(cfg.moe.n_shared, 1))
    if cfg.family == "ssm":
        small["rwkv_head_dim"] = 32
        small["rwkv_lora_dim"] = 16
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
