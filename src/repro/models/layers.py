"""Shared transformer primitives: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

All functions are pure; parameters are plain dicts of jnp arrays.  The
attention entry points cover the three execution modes the framework
needs:

* ``attention``          — full (B,S) self-attention, chunked "flash" style
                           scan over KV blocks so the S×S score matrix is
                           never materialised (important for prefill_32k).
* ``decode_attention``   — one new token against a KV cache (decode shapes).
* causal and sliding-window masking (the beyond-paper variant that makes
  ``long_500k`` runnable for dense architectures).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------- norms ----
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(dt)


# ----------------------------------------------------------------- rope ----
def rope_tables(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """cos/sin tables for the given absolute positions: (..., head_dim//2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B,S,H,D); cos/sin: (B,S,D/2) or (S,D/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:  # (S, D/2) -> broadcast over batch
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:              # (B, S, D/2)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


# ------------------------------------------------------------ attention ----
def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B,S,K,D) -> (B,S,K*n_rep,D) by repeating each kv head."""
    if n_rep == 1:
        return k
    b, s, kh, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, d)).reshape(b, s, kh * n_rep, d)


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]) -> jax.Array:
    """(Sq,Sk) additive bias from causal / sliding-window constraints."""
    m = jnp.zeros((q_pos.shape[0], k_pos.shape[0]), jnp.float32)
    if causal:
        m = jnp.where(k_pos[None, :] > q_pos[:, None], NEG_INF, m)
    if window is not None:
        m = jnp.where(k_pos[None, :] <= q_pos[:, None] - window, NEG_INF, m)
    return m


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              window: Optional[int] = None, q_chunk: int = 512,
              kv_chunk: int = 1024) -> jax.Array:
    """Chunked (flash-style) multi-head GQA attention.

    q: (B,Sq,H,D);  k,v: (B,Sk,K,D) with H % K == 0.  Returns (B,Sq,H,D).
    Scans over KV chunks with a running (max, sum, acc) triple so memory is
    O(Sq * kv_chunk) instead of O(Sq * Sk).
    """
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    k = repeat_kv(k, h // kh)
    v = repeat_kv(v, h // kh)
    scale = 1.0 / math.sqrt(d)

    if sq * sk <= 512 * 512:  # small: plain path (also the reference path)
        bias = _mask_bias(jnp.arange(sq), jnp.arange(sk), causal and sq > 1, window)
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
        s = s + bias[None, None]
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    while sq % q_chunk:        # non-power-of-two seq (whisper's 1500 frames)
        q_chunk -= 1
    while sk % kv_chunk:
        kv_chunk -= 1
    return _flash_chunked(q, k, v, causal=causal, window=window,
                          q_chunk=q_chunk, kv_chunk=kv_chunk, scale=scale)


@jax.named_scope("flash_attention")
def _flash_chunked(q, k, v, *, causal, window, q_chunk, kv_chunk, scale):
    """XLA-fallback flash attention, scope-tagged (jax.named_scope) so the
    HLO cost analyzer can attribute its HBM traffic — the Pallas kernel
    keeps all of it in VMEM on the TPU target; see benchmarks/roofline.py
    kernel-adjusted memory term."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    nq, nk = sq // q_chunk, sk // kv_chunk
    qc = q.reshape(b, nq, q_chunk, h, d)
    kc = k.reshape(b, nk, kv_chunk, h, d)
    vc = v.reshape(b, nk, kv_chunk, h, d)

    def per_q_block(qi, qb):
        q_pos = qi * q_chunk + jnp.arange(q_chunk)
        qb32 = qb.astype(jnp.float32) * scale

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, kb, vb = inp
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb32, kb.astype(jnp.float32))
            s = s + _mask_bias(q_pos, k_pos, causal, window)[None, None]
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + p.sum(-1)
            # bf16 softmax weights into the PV matmul: halves the largest
            # attention buffer and feeds the MXU its native dtype; the
            # accumulator stays f32 (flash-kernel convention). §Perf iter 3.
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vb.dtype), vb)
            acc = acc * alpha[..., None] + pv.astype(jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((b, h, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((b, h, q_chunk), jnp.float32),
                jnp.zeros((b, h, q_chunk, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kc.swapaxes(0, 1), vc.swapaxes(0, 1)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.swapaxes(1, 2).astype(q.dtype)  # (B,q_chunk,H,D)

    out = jax.lax.map(lambda args: per_q_block(*args),
                      (jnp.arange(nq), qc.swapaxes(0, 1)))
    return out.swapaxes(0, 1).reshape(b, sq, h, d)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_pos: jax.Array, q_pos: jax.Array,
                     window: Optional[int] = None) -> jax.Array:
    """One-token attention against a cache.

    q: (B,1,H,D); caches: (B,S,K,D); kv_pos: (B,S) absolute position of every
    cache slot (-1 for empty; ring buffers permute positions arbitrarily);
    q_pos: (B,) absolute position of the new token.
    """
    b, _, h, d = q.shape
    kh = k_cache.shape[2]
    g = h // kh
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, h, d).reshape(b, kh, g, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32)) * scale
    valid = (kv_pos >= 0) & (kv_pos <= q_pos[:, None])
    if window is not None:
        valid &= kv_pos > (q_pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------- linear ----
def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = x @ w
    if b is not None:
        y = y + b
    return y


def swiglu(x: jax.Array, p: dict) -> jax.Array:
    """SwiGLU MLP: p = {w_gate, w_up, w_down}."""
    return dense(jax.nn.silu(dense(x, p["w_gate"])) * dense(x, p["w_up"]), p["w_down"])


def gelu_mlp(x: jax.Array, p: dict) -> jax.Array:
    """GELU MLP (whisper-style): p = {w_in, b_in, w_out, b_out}."""
    return dense(jax.nn.gelu(dense(x, p["w_in"], p["b_in"])), p["w_out"], p["b_out"])


# ------------------------------------------------------------------ init ----
def init_dense(key, fan_in, fan_out, dtype) -> jax.Array:
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, (fan_in, fan_out), jnp.float32) * std).astype(dtype)


def init_attn(key, cfg, with_bias=None, cross=False) -> dict:
    """GQA attention params. cross=True reuses the same shape for cross-attn."""
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if cross:
        kh = h  # whisper cross-attn is MHA
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    p = {
        "wq": init_dense(ks[0], d, h * hd, dt),
        "wk": init_dense(ks[1], d, kh * hd, dt),
        "wv": init_dense(ks[2], d, kh * hd, dt),
        "wo": init_dense(ks[3], h * hd, d, dt),
    }
    bias = cfg.qkv_bias if with_bias is None else with_bias
    if bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kh * hd,), dt)
        p["bv"] = jnp.zeros((kh * hd,), dt)
    return p


def init_swiglu(key, d_model, d_ff, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {"w_gate": init_dense(ks[0], d_model, d_ff, dtype),
            "w_up": init_dense(ks[1], d_model, d_ff, dtype),
            "w_down": init_dense(ks[2], d_ff, d_model, dtype)}


def init_gelu_mlp(key, d_model, d_ff, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {"w_in": init_dense(ks[0], d_model, d_ff, dtype),
            "b_in": jnp.zeros((d_ff,), dtype),
            "w_out": init_dense(ks[1], d_ff, d_model, dtype),
            "b_out": jnp.zeros((d_model,), dtype)}
