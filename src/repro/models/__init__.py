from . import transformer, vgg                      # noqa: F401
from .common import ModelConfig, MoEConfig, reduced  # noqa: F401
from .layered import LayeredModel, transformer_as_layered  # noqa: F401
