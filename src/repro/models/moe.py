"""Mixture-of-Experts FFN with group-local capacity dispatch.

Covers the three assigned MoE flavours:
  * deepseek-moe-16b : 2 shared + 64 routed, top-6, fine-grained experts
  * qwen3-moe-235b   : 128 routed, top-8, no shared experts
  * jamba-v0.1-52b   : 16 routed, top-2, MoE every 2nd layer

Dispatch uses the einsum/one-hot form (t5x/MaxText style) *per token
group* of <= ``group_chunk`` tokens: capacity is group-local, so the
dispatch matmul costs t_g^2·k·cf·D per group (≈10-30% of expert FLOPs)
instead of the T^2 blow-up a global-capacity dispatch incurs — that
napkin-math result is logged in EXPERIMENTS.md §Perf.  With the expert
axis sharded over ``model`` GSPMD lowers dispatch/combine to the expected
all-to-all/all-gather collectives.  A Switch-style load-balance auxiliary
loss is returned alongside the output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import MoEConfig
from .layers import init_dense, init_swiglu, swiglu

GROUP_CHUNK = 2048  # tokens per dispatch group


def init_moe(key, d_model: int, m: MoEConfig, dtype) -> dict:
    ks = jax.random.split(key, 4)
    e = m.n_experts

    def stack_expert(k, fan_in, fan_out):
        kk = jax.random.split(k, e)
        return jnp.stack([init_dense(kk[i], fan_in, fan_out, dtype) for i in range(e)])

    p = {
        "router": init_dense(ks[0], d_model, e, jnp.float32),
        "w_gate": stack_expert(ks[1], d_model, m.d_expert),   # (E, D, F)
        "w_up": stack_expert(ks[2], d_model, m.d_expert),
        "w_down": jnp.swapaxes(stack_expert(ks[3], d_model, m.d_expert), 1, 2),  # (E, F, D)
    }
    if m.n_shared:
        p["shared"] = init_swiglu(jax.random.fold_in(key, 7), d_model,
                                  m.d_expert * m.n_shared, dtype)
    return p


def group_capacity(tokens_per_group: int, m: MoEConfig) -> int:
    return max(1, int(tokens_per_group * m.top_k / m.n_experts * m.capacity_factor))


def moe_ffn(x: jax.Array, p: dict, m: MoEConfig, *, shard_fn=None,
            group_chunk: int = GROUP_CHUNK) -> tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out (B,S,D), aux_loss scalar)."""
    sf = shard_fn or (lambda a, k: a)
    b, s, d = x.shape
    chunk = min(group_chunk, s)
    while s % chunk:
        chunk -= 1
    g = b * (s // chunk)
    xg = x.reshape(g, chunk, d)
    logits = xg.astype(jnp.float32) @ p["router"]               # (G,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, m.top_k)              # (G,T,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = group_capacity(chunk, m)
    onehot = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.int32)  # (G,T,k,E)
    flat = onehot.reshape(g, chunk * m.top_k, m.n_experts)
    pos = jnp.cumsum(flat, axis=1) * flat - 1                   # (G,T*k,E)
    pos = pos.reshape(g, chunk, m.top_k, m.n_experts)
    keep = (pos >= 0) & (pos < cap)
    # one live capacity slot per (token, expert): top-k experts are distinct,
    # so merging the k choices with max() is exact.
    slot = jnp.where(keep, pos, -1).max(2)                      # (G,T,E)
    disp = (jax.nn.one_hot(slot, cap, dtype=x.dtype)
            * keep.any(2)[..., None].astype(x.dtype))           # (G,T,E,C)
    expert_in = sf(jnp.einsum("gtd,gtec->gecd", xg, disp), "moe_experts")
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    expert_out = sf(jnp.einsum("gecf,efd->gecd", h, p["w_down"]), "moe_experts")
    gates_e = (gate_vals[..., None] * keep).max(2).astype(x.dtype)  # (G,T,E)
    combine = gates_e[..., None] * disp                         # (G,T,E,C)
    out = jnp.einsum("gtec,gecd->gtd", combine, expert_out)
    out = out.reshape(b, s, d)

    if m.n_shared:
        out = out + swiglu(x, p["shared"])

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx.reshape(-1, m.top_k)[:, 0], m.n_experts,
                       dtype=jnp.float32), axis=0)
    mean_probs = jnp.mean(probs.reshape(-1, m.n_experts), axis=0)
    aux = m.n_experts * jnp.sum(frac_tokens * mean_probs)
    return out, aux.astype(jnp.float32)
