"""RWKV-6 (Finch) blocks: time-mix with data-dependent decay + channel-mix.

Follows arXiv:2404.05892.  The WKV recurrence per head (k-dim x v-dim state):

    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T        w_t = exp(-exp(decay(x_t)))

with data-dependent token-shift interpolation (ddlerp) for r/k/v/g/w.  The
sequence form here is a plain ``lax.scan`` over time (the compiled body is a
single step, so lowering 4k..500k-step programs stays cheap); the Pallas
chunked kernel in ``repro.kernels.rwkv6_scan`` is the TPU hot path and is
validated against this reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_dense, rmsnorm

MIX_NAMES = ("w", "k", "v", "r", "g")


def init_time_mix(key, cfg) -> dict:
    d, lo = cfg.d_model, cfg.rwkv_lora_dim
    hd = cfg.rwkv_head_dim
    assert d % hd == 0
    ks = jax.random.split(key, 10)
    dt = cfg.jdtype
    return {
        "wr": init_dense(ks[0], d, d, dt),
        "wk": init_dense(ks[1], d, d, dt),
        "wv": init_dense(ks[2], d, d, dt),
        "wg": init_dense(ks[3], d, d, dt),
        "wo": init_dense(ks[4], d, d, dt),
        "maa_x": jnp.zeros((d,), jnp.float32) + 0.5,
        "maa_base": jnp.zeros((5, d), jnp.float32) + 0.5,
        "maa_w1": init_dense(ks[5], d, 5 * lo, jnp.float32),
        "maa_w2": (jax.random.normal(ks[6], (5, lo, d), jnp.float32) * 0.01),
        "decay_base": jnp.zeros((d,), jnp.float32) - 4.0,
        "dec_w1": init_dense(ks[7], d, lo, jnp.float32),
        "dec_w2": init_dense(ks[8], lo, d, jnp.float32) * 0.1,
        "bonus": jax.random.normal(ks[9], (d,), jnp.float32) * 0.1,
        "ln_x": jnp.ones((d,), jnp.float32),
    }


def init_channel_mix(key, cfg) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    dt = cfg.jdtype
    return {
        "maa_k": jnp.zeros((d,), jnp.float32) + 0.5,
        "maa_r": jnp.zeros((d,), jnp.float32) + 0.5,
        "w_k": init_dense(ks[0], d, cfg.d_ff, dt),
        "w_v": init_dense(ks[1], cfg.d_ff, d, dt),
        "w_r": init_dense(ks[2], d, d, dt),
    }


def _ddlerp(p, x, x_prev):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    dx = x_prev - x                                      # (B,S,D) or (B,D)
    xm = x + dx * p["maa_x"]
    lo = p["maa_w1"].shape[1] // 5
    t = jnp.tanh(xm.astype(jnp.float32) @ p["maa_w1"])   # (...,5*lo)
    t = t.reshape(t.shape[:-1] + (5, lo))
    deltas = jnp.einsum("...nl,nld->...nd", t, p["maa_w2"])  # (...,5,D)
    mix = p["maa_base"] + deltas                          # (...,5,D)
    out = x[..., None, :] + dx[..., None, :] * mix
    return tuple(out[..., i, :].astype(x.dtype) for i in range(5))


def _wkv_inputs(p, x, x_prev, cfg):
    xw, xk, xv, xr, xg = _ddlerp(p, x, x_prev)
    r = xr @ p["wr"]
    k = xk @ p["wk"]
    v = xv @ p["wv"]
    g = jax.nn.silu(xg @ p["wg"])
    w = jnp.exp(-jnp.exp(p["decay_base"] +
                         jnp.tanh(xw.astype(jnp.float32) @ p["dec_w1"]) @ p["dec_w2"]))
    return r, k, v, g, w


def _heads(x, hd):
    return x.reshape(x.shape[:-1] + (x.shape[-1] // hd, hd))


@jax.named_scope("wkv_scan")
def wkv_scan(r, k, v, w, u, state, *, chunk: int = 64, shard_fn=None):
    """Sequence WKV. r,k,v,w: (B,S,H,hd) float32; u: (H,hd); state: (B,H,hd,hd).

    Chunked two-level scan: ``jax.checkpoint`` at chunk boundaries keeps the
    backward pass from saving a (B,H,hd,hd) carry per timestep (which is
    what sinks a plain 4k-step scan; EXPERIMENTS.md §Perf).
    Returns (out (B,S,H,hd), final_state).
    """
    sf = shard_fn or (lambda a, k: a)
    b, s = r.shape[0], r.shape[1]
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    nc = s // chunk
    state = sf(state, "wkv_state")

    def step(st, inp):
        rt, kt, vt, wt = inp                             # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]         # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", rt, st + u[..., None] * kv)
        st = sf(wt[..., None] * st + kv, "wkv_state")
        return st, out

    def chunk_body(st, inp):
        xs = tuple(jnp.moveaxis(a, 1, 0) for a in inp)
        st, out = jax.lax.scan(step, st, xs)
        return sf(st, "wkv_state"), jnp.moveaxis(out, 0, 1)

    def split_chunks(a):
        return jnp.moveaxis(a.reshape(b, nc, chunk, *a.shape[2:]), 1, 0)

    xs = tuple(split_chunks(a) for a in (r, k, v, w))
    state, out = jax.lax.scan(jax.checkpoint(chunk_body), state, xs)
    return jnp.moveaxis(out, 0, 1).reshape(r.shape), state


def time_mix(p, x, x_prev, state, cfg, shard_fn=None):
    """x: (B,S,D); x_prev: (B,D) last token of previous chunk.

    Returns (out (B,S,D), new_x_prev (B,D), new_state).
    """
    sf = shard_fn or (lambda a, k: a)
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    r, k, v, g, w = _wkv_inputs(p, x, shifted, cfg)
    rh, kh, vh = (sf(_heads(a.astype(jnp.float32), hd), "heads")
                  for a in (r, k, v))
    wh = sf(_heads(w, hd), "heads")
    u = p["bonus"].reshape(d // hd, hd)
    out, state = wkv_scan(rh, kh, vh, wh, u, state, shard_fn=shard_fn)
    out = out.reshape(b, s, d)
    # per-head groupnorm (ln_x): normalise within each head
    oh = out.reshape(b, s, d // hd, hd)
    oh = (oh - oh.mean(-1, keepdims=True)) * jax.lax.rsqrt(oh.var(-1, keepdims=True) + 1e-5)
    out = oh.reshape(b, s, d) * p["ln_x"]
    out = (out.astype(x.dtype) * g) @ p["wo"]
    return out, x[:, -1, :], state


def channel_mix(p, x, x_prev):
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    dx = shifted - x
    xk = x + dx * p["maa_k"].astype(x.dtype)
    xr = x + dx * p["maa_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"]), x[:, -1, :]


def init_state(cfg, batch, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.rwkv_head_dim
    return {
        "tm_prev": jnp.zeros((batch, d), dtype),
        "cm_prev": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, d // hd, hd, hd), jnp.float32),
    }


def block(p, x, state, cfg, norm_eps):
    """One full RWKV block over a sequence chunk. state may be None (train)."""
    b = x.shape[0]
    st = state if state is not None else init_state(cfg, b, x.dtype)
    h = rmsnorm(x, p["norm1"], norm_eps)
    att, tm_prev, wkv = time_mix(p["tm"], h, st["tm_prev"].astype(x.dtype), st["wkv"], cfg)
    x = x + att
    h = rmsnorm(x, p["norm2"], norm_eps)
    ffn, cm_prev = channel_mix(p["cm"], h, st["cm_prev"].astype(x.dtype))
    x = x + ffn
    return x, {"tm_prev": tm_prev, "cm_prev": cm_prev, "wkv": wkv}
