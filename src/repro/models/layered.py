"""``LayeredModel``: the per-layer view of a network that split computing
operates on.

Split-Et-Impera's pipeline (saliency -> CS curve -> candidate cuts ->
head/bottleneck/tail) needs a model expressed as an ordered list of layers
with observable intermediate activations.  VGG16 is defined natively this
way; the transformer zoo exposes the same interface through
``transformer_as_layered`` (one layer per block), so the paper's technique
applies to every assigned architecture.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Layer:
    name: str
    kind: str                       # 'conv' | 'relu' | 'pool' | 'linear' | ...
    init: Callable[[Any], Any]      # key -> params (possibly {})
    apply: Callable[[Any, jax.Array], jax.Array]
    splittable: bool = True         # is a cut *after* this layer legal?
    # optional mult-add counter ``(params, in_shape, out_shape) -> int`` for
    # layers whose cost the generic conv/linear rules in ``core.stats``
    # cannot see (transformer blocks close over their params)
    mult_adds: Callable[[Any, tuple, tuple], int] = None


@dataclass
class LayeredModel:
    name: str
    layers: List[Layer]
    input_shape: tuple              # without batch dim
    n_classes: int

    def init(self, key) -> list:
        ks = jax.random.split(key, len(self.layers))
        return [l.init(k) for l, k in zip(self.layers, ks)]

    def apply(self, params: list, x: jax.Array) -> jax.Array:
        for l, p in zip(self.layers, params):
            x = l.apply(p, x)
        return x

    def apply_capture(self, params: list, x: jax.Array) -> tuple:
        """Returns (logits, [activation after each layer])."""
        acts = []
        for l, p in zip(self.layers, params):
            x = l.apply(p, x)
            acts.append(x)
        return x, acts

    def apply_with_taps(self, params: list, x: jax.Array, taps: list) -> jax.Array:
        """Forward where ``taps[i]`` is added to layer i's output.

        Differentiating w.r.t. zero taps yields d(output)/d(activation_i) for
        every layer in a single backward pass (the saliency trick).
        """
        for l, p, t in zip(self.layers, params, taps):
            x = l.apply(p, x) + t
        return x

    def apply_range(self, params: list, x: jax.Array, start: int, stop: int) -> jax.Array:
        """Run layers [start, stop)."""
        for l, p in zip(self.layers[start:stop], params[start:stop]):
            x = l.apply(p, x)
        return x

    def cut_points(self) -> list[int]:
        """Indices i such that a cut after layer i is legal."""
        return [i for i, l in enumerate(self.layers) if l.splittable and i < len(self.layers) - 1]

    def activation_shapes(self, params: list, batch: int = 1, *,
                          sample=None) -> list[tuple]:
        """Per-layer output shapes (leading ``batch`` dim included).

        ``sample``: an example input (array or pytree, e.g. a transformer
        batch dict) to derive shapes from when ``input_shape`` alone
        cannot describe the input; its own leading dim wins over
        ``batch``.
        """
        x = sample if sample is not None else jax.ShapeDtypeStruct(
            (batch,) + tuple(self.input_shape), jnp.float32)
        _, acts = jax.eval_shape(self.apply_capture, params, x)
        return [a.shape for a in acts]


def transformer_as_layered(cfg, params) -> LayeredModel:
    """Per-block LayeredModel view of a zoo model (for saliency/splitting).

    Cuts are only legal at block boundaries: a cut can never land inside an
    expert dispatch (MoE), a recurrence (SSM/Mamba) or an attention op —
    this is the family-specific legality rule from DESIGN.md §4.
    Layer 0 is the embedding (+frontend); the head/final-norm stay fused
    with the last block (a cut there is RC-equivalent).
    """
    from . import transformer as T

    descs, n_groups = block_structure_cached(cfg)
    layers = [Layer(
        name="embed", kind="embed",
        init=lambda k: {},
        apply=lambda p, batch: T.embed_inputs(params, cfg, batch)[0],
        splittable=True,
        mult_adds=lambda p, ish, osh: 0)]     # table lookup, no matmul

    def make_block(g, j, desc):
        lp = jax.tree.map(lambda a: a[g], params["layers"][f"l{j}"])
        # matmul cost per token ~ the block's weight count (x @ W costs
        # prod(W.shape) mult-adds per token for every 2-D weight)
        w_elems = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(lp)
                      if getattr(a, "ndim", 0) >= 2)

        def apply(p, x):
            positions = jnp.arange(x.shape[1])
            y, _, _ = T.apply_layer_seq(lp, desc, x, cfg, positions,
                                        causal=True, window=cfg.sliding_window)
            return y
        return Layer(name=f"block{g * len(descs) + j}", kind="block",
                     init=lambda k: {}, apply=apply, splittable=True,
                     mult_adds=lambda p, ish, osh: w_elems * osh[0] * osh[1])

    for g in range(n_groups):
        for j, desc in enumerate(descs):
            layers.append(make_block(g, j, desc))

    def head_apply(p, x):
        x = T._apply_norm(params["final_norm"], x, cfg)
        return T.logits_from_x(params, cfg, x)

    layers.append(Layer(name="head", kind="head", init=lambda k: {},
                        apply=head_apply, splittable=False,
                        mult_adds=lambda p, ish, osh:
                            cfg.d_model * int(np.prod(osh[:-1])) * osh[-1]))
    return LayeredModel(name=cfg.name, layers=layers,
                        input_shape=(), n_classes=cfg.vocab)


def block_structure_cached(cfg):
    from .transformer import block_structure
    return block_structure(cfg)
