"""Unified model definition covering all six assigned families.

One pair of entry points serves every architecture:

* ``forward(params, cfg, batch)``      — full-sequence (train / prefill)
* ``serve_step(params, cfg, cache,…)`` — one-token decode against a cache

Layers are *group-stacked*: the repeating period of the architecture (1 for
uniform stacks, 8 for Jamba's 1-attn:7-mamba interleave) is described by
``block_structure`` and scanned with ``jax.lax.scan`` + ``jax.checkpoint``,
so a 94-layer model compiles one block body.  Heterogeneous sublayers inside
a period are unrolled inside the scanned body.

The ``shard_fn`` hook lets the launcher pin the inter-layer residual stream
(sequence-parallel) and other activations without the model knowing about
meshes; it defaults to identity.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import mamba as mamba_mod
from . import rwkv as rwkv_mod
from .common import ModelConfig
from .layers import (apply_rope, attention, decode_attention, dense, gelu_mlp,
                     init_attn, init_dense, init_gelu_mlp, init_swiglu,
                     layernorm, rmsnorm, rope_tables, swiglu)
from .moe import init_moe, moe_ffn


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    mixer: str        # 'attn' | 'mamba' | 'rwkv'
    ffn: str          # 'dense' | 'moe' | 'none'
    cross: bool = False


def block_structure(cfg: ModelConfig) -> tuple[list[LayerDesc], int]:
    """(descs for one period, n_groups)."""
    if cfg.family == "ssm":
        return [LayerDesc("rwkv", "none")], cfg.n_layers
    period = cfg.attn_period if cfg.attn_period > 0 else 1
    if cfg.moe is not None:
        period = max(period, cfg.moe.moe_every)
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    descs = []
    for j in range(period):
        mixer = "attn" if cfg.is_attn_layer(j) else "mamba"
        ffn = "moe" if cfg.is_moe_layer(j) else "dense"
        descs.append(LayerDesc(mixer, ffn, cross=cfg.family == "encdec"))
    return descs, cfg.n_layers // period


def _norm_params(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _apply_norm(p, x, cfg):
    if cfg.family == "encdec":
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["w"], cfg.norm_eps)


# ------------------------------------------------------------------ init ----
def init_layer(key, desc: LayerDesc, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 6)
    d, dt = cfg.d_model, cfg.jdtype
    p = {"norm1": _norm_params(d, dt), "norm2": _norm_params(d, dt)}
    if desc.mixer == "attn":
        p["attn"] = init_attn(ks[0], cfg)
    elif desc.mixer == "mamba":
        p["mamba"] = mamba_mod.init_mamba(ks[0], cfg)
    else:  # rwkv
        p["tm"] = rwkv_mod.init_time_mix(ks[0], cfg)
        p["cm"] = rwkv_mod.init_channel_mix(ks[1], cfg)
    if desc.cross:
        p["norm_cross"] = _norm_params(d, dt)
        p["cross"] = init_attn(ks[2], cfg, with_bias=True, cross=True)
    if desc.ffn == "dense":
        p["ffn"] = (init_gelu_mlp(ks[3], d, cfg.d_ff, dt) if cfg.family == "encdec"
                    else init_swiglu(ks[3], d, cfg.d_ff, dt))
    elif desc.ffn == "moe":
        p["ffn"] = init_moe(ks[3], d, cfg.moe, dt)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    descs, n_groups = block_structure(cfg)
    ks = jax.random.split(key, 8)
    d, dt = cfg.d_model, cfg.jdtype

    def one_group(gk):
        gks = jax.random.split(gk, len(descs))
        return {f"l{j}": init_layer(gks[j], descs[j], cfg) for j in range(len(descs))}

    gkeys = jax.random.split(ks[0], n_groups)
    groups = jax.tree.map(lambda *xs: jnp.stack(xs), *[one_group(k) for k in gkeys])
    if n_groups == 1:  # keep the leading group axis for a uniform layout
        groups = jax.tree.map(lambda x: x, groups)
    params = {
        "embed": (jax.random.normal(ks[1], (cfg.vocab, d), jnp.float32) * 0.02).astype(dt),
        "final_norm": _norm_params(d, dt),
        "layers": groups,
    }
    if not cfg.tie_embeddings:
        params["head"] = init_dense(ks[2], d, cfg.vocab, dt)
    if cfg.family == "encdec":
        eks = jax.random.split(ks[3], cfg.n_enc_layers)
        enc_desc = LayerDesc("attn", "dense")
        enc_layers = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[init_layer(k, enc_desc, cfg) for k in eks])
        params["enc"] = {
            "proj": init_dense(ks[4], cfg.d_frontend, d, dt),
            "pos": (jax.random.normal(ks[5], (cfg.n_frames, d), jnp.float32) * 0.01).astype(dt),
            "layers": enc_layers,
            "final_norm": _norm_params(d, dt),
        }
    if cfg.family == "vlm":
        params["projector"] = {
            "w1": init_dense(ks[4], cfg.d_frontend, d, dt),
            "b1": jnp.zeros((d,), dt),
            "w2": init_dense(ks[5], d, d, dt),
            "b2": jnp.zeros((d,), dt),
        }
    return params


# ----------------------------------------------------------- full-seq fwd ----
def _qkv(p, x, cfg, cross_src=None):
    b, s, _ = x.shape
    hd = cfg.hd
    src = x if cross_src is None else cross_src
    kh = cfg.n_heads if cross_src is not None else cfg.n_kv_heads
    q = dense(x, p["wq"], p.get("bq")).reshape(b, s, cfg.n_heads, hd)
    k = dense(src, p["wk"], p.get("bk")).reshape(b, src.shape[1], kh, hd)
    v = dense(src, p["wv"], p.get("bv")).reshape(b, src.shape[1], kh, hd)
    return q, k, v


def _attn_seq(p, x, cfg, positions, *, causal, window, cross_src=None,
              shard_fn=None):
    sf = shard_fn or (lambda a, k: a)
    q, k, v = _qkv(p, x, cfg, cross_src)
    q = sf(q, "heads")      # (B,S,H,hd): heads over 'model'
    k = sf(k, "heads")      # dropped automatically when K < model-axis
    v = sf(v, "heads")
    if cross_src is None:  # rope only for self-attention
        cos, sin = rope_tables(positions, cfg.hd, cfg.rope_theta)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    out = sf(attention(q, k, v, causal=causal, window=window), "heads")
    b, s = x.shape[0], x.shape[1]
    return dense(out.reshape(b, s, cfg.n_heads * cfg.hd), p["wo"]), (k, v)


def apply_layer_seq(p, desc: LayerDesc, x, cfg, positions, *, causal=True,
                    window=None, enc_out=None, shard_fn=None, collect_cache=False):
    """One sublayer over a full sequence.  Returns (x, aux, cache_entry)."""
    sf = shard_fn or (lambda a, k: a)
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    h = _apply_norm(p["norm1"], x, cfg)
    if desc.mixer == "attn":
        att, (k, v) = _attn_seq(p["attn"], h, cfg, positions, causal=causal,
                                window=window, shard_fn=shard_fn)
        if collect_cache:
            cache["k"], cache["v"] = k, v
    elif desc.mixer == "mamba":
        att, state = mamba_mod.mamba_seq(p["mamba"], h, cfg, shard_fn=shard_fn)
        if collect_cache:
            cache["conv"], cache["ssm"] = state
    else:  # rwkv: norm1 -> time-mix
        st = rwkv_mod.init_state(cfg, x.shape[0], x.dtype)
        att, tm_prev, wkv = rwkv_mod.time_mix(p["tm"], h, st["tm_prev"],
                                              st["wkv"], cfg, shard_fn=shard_fn)
        if collect_cache:
            cache["tm_prev"], cache["wkv"] = tm_prev, wkv
    x = sf(x + att, "residual")
    if desc.cross and enc_out is not None:
        h = _apply_norm(p["norm_cross"], x, cfg)
        catt, (ck, cv) = _attn_seq(p["cross"], h, cfg, positions,
                                   causal=False, window=None, cross_src=enc_out,
                                   shard_fn=shard_fn)
        if collect_cache:
            cache["ck"], cache["cv"] = ck, cv
        x = x + catt
    h = _apply_norm(p["norm2"], x, cfg)
    if desc.ffn == "dense":
        if cfg.family == "encdec":
            f = gelu_mlp(h, p["ffn"])
        else:
            g = sf(jax.nn.silu(dense(h, p["ffn"]["w_gate"]))
                   * dense(h, p["ffn"]["w_up"]), "ffn_hidden")
            f = dense(g, p["ffn"]["w_down"])
    elif desc.ffn == "moe":
        f, aux = moe_ffn(h, p["ffn"], cfg.moe, shard_fn=shard_fn)
    else:  # rwkv channel mix
        f, cm_prev = rwkv_mod.channel_mix(p["cm"], h, jnp.zeros_like(h[:, 0]))
        if collect_cache:
            cache["cm_prev"] = cm_prev
    x = sf(x + f, "residual")
    return x, aux, cache


def _encoder(params, cfg, frames, shard_fn):
    """Whisper-style encoder on stub frame embeddings (B, F, d_frontend)."""
    x = frames @ params["enc"]["proj"] + params["enc"]["pos"][None]
    desc = LayerDesc("attn", "dense")
    positions = jnp.arange(frames.shape[1])

    def body(x, lp):
        x, _, _ = apply_layer_seq(lp, desc, x, cfg, positions, causal=False,
                                  shard_fn=shard_fn)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc"]["layers"])
    return _apply_norm(params["enc"]["final_norm"], x, cfg)


def embed_inputs(params, cfg, batch):
    """Token (+frontend) embedding -> (x (B,S,D), positions (S,), enc_out)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    enc_out = None
    if cfg.family == "vlm":
        pe = batch["patch_embeds"]
        pj = params["projector"]
        patches = jnp.tanh(pe @ pj["w1"] + pj["b1"]) @ pj["w2"] + pj["b2"]
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])
    return x, positions, enc_out


def forward(params, cfg: ModelConfig, batch: dict, *, shard_fn=None,
            collect_cache=False, logit_chunk: int = 512):
    """Full-sequence forward.

    batch: tokens (B,S_text) [+ patch_embeds (B,P,df) | frames (B,F,df)].
    Returns dict(logits=(B,S,V) [unless chunked loss is used downstream],
    aux=scalar, cache=group-stacked cache or None, x_final).
    """
    descs, n_groups = block_structure(cfg)
    x, positions, _ = embed_inputs(params, cfg, batch)
    enc_out = (_encoder(params, cfg, batch["frames"], shard_fn)
               if cfg.family == "encdec" else None)
    sf = shard_fn or (lambda a, k: a)
    x = sf(x, "residual")
    window = cfg.sliding_window

    def body(carry, group_p):
        x, aux = carry
        caches = {}
        for j, desc in enumerate(descs):
            x, a, c = apply_layer_seq(group_p[f"l{j}"], desc, x, cfg, positions,
                                      causal=True, window=window, enc_out=enc_out,
                                      shard_fn=shard_fn, collect_cache=collect_cache)
            aux = aux + a
            caches[f"l{j}"] = c
        return (x, aux), caches

    wrapped = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), caches = jax.lax.scan(wrapped, (x, jnp.zeros((), jnp.float32)),
                                    params["layers"])
    x = _apply_norm(params["final_norm"], x, cfg)
    return {"x": x, "aux": aux, "cache": caches if collect_cache else None,
            "positions": positions}


def logits_from_x(params, cfg, x):
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return x @ head


def loss_fn(params, cfg: ModelConfig, batch: dict, *, shard_fn=None,
            chunk: int = 512, aux_weight: float = 0.01):
    """Chunked softmax cross-entropy (never materialises (B,S,V) in f32)."""
    out = forward(params, cfg, batch, shard_fn=shard_fn)
    x, aux = out["x"], out["aux"]
    labels = batch["labels"]
    if cfg.family == "vlm":  # patch positions carry no next-token loss
        x = x[:, -labels.shape[1]:, :]
    b, s, d = x.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    sf = shard_fn or (lambda a, k: a)

    def ce(xc, lc):
        logits = sf((xc @ head).astype(jnp.float32), "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return (lse - gold).sum()

    def step(tot, inp):
        xc, lc = inp
        return tot + ce(xc, lc), None

    xs = (x.reshape(b, s // chunk, chunk, d).swapaxes(0, 1),
          labels.reshape(b, s // chunk, chunk).swapaxes(0, 1))
    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), xs)
    ntok = b * s
    return total / ntok + aux_weight * aux, {"ce": total / ntok, "aux": aux}


# ------------------------------------------------------------------ cache ----
def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None) -> dict:
    """Empty decode cache (group-stacked leading dim)."""
    descs, n_groups = block_structure(cfg)
    dt = dtype or cfg.jdtype
    sc = cache_len_for(cfg, seq_len)
    hd = cfg.hd

    def per_layer(desc: LayerDesc):
        c = {}
        if desc.mixer == "attn":
            c["k"] = jnp.zeros((n_groups, batch, sc, cfg.n_kv_heads, hd), dt)
            c["v"] = jnp.zeros((n_groups, batch, sc, cfg.n_kv_heads, hd), dt)
            c["kv_pos"] = jnp.full((n_groups, batch, sc), -1, jnp.int32)
        elif desc.mixer == "mamba":
            di, ds, dc = mamba_mod.d_inner(cfg), cfg.mamba_d_state, cfg.mamba_d_conv
            c["conv"] = jnp.zeros((n_groups, batch, dc - 1, di), jnp.float32)
            c["ssm"] = jnp.zeros((n_groups, batch, di, ds), jnp.float32)
        else:  # rwkv
            nh = cfg.d_model // cfg.rwkv_head_dim
            c["tm_prev"] = jnp.zeros((n_groups, batch, cfg.d_model), jnp.float32)
            c["cm_prev"] = jnp.zeros((n_groups, batch, cfg.d_model), jnp.float32)
            c["wkv"] = jnp.zeros(
                (n_groups, batch, nh, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                jnp.float32)
        if desc.cross:
            c["ck"] = jnp.zeros((n_groups, batch, cfg.n_frames, cfg.n_heads, hd), dt)
            c["cv"] = jnp.zeros((n_groups, batch, cfg.n_frames, cfg.n_heads, hd), dt)
        return c

    return {f"l{j}": per_layer(d) for j, d in enumerate(descs)}


def _attn_decode(p, h, cfg, cache_l, pos, window):
    """h: (B,1,D); cache_l: {'k','v','kv_pos'} (B,Sc,K,hd)."""
    b = h.shape[0]
    hd = cfg.hd
    q = dense(h, p["wq"], p.get("bq")).reshape(b, 1, cfg.n_heads, hd)
    k = dense(h, p["wk"], p.get("bk")).reshape(b, 1, cfg.n_kv_heads, hd)
    v = dense(h, p["wv"], p.get("bv")).reshape(b, 1, cfg.n_kv_heads, hd)
    cos, sin = rope_tables(pos[None], hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    sc = cache_l["k"].shape[1]
    slot = pos % sc
    kc = jax.lax.dynamic_update_slice(cache_l["k"], k, (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache_l["v"], v, (0, slot, 0, 0))
    kv_pos = jax.lax.dynamic_update_slice(
        cache_l["kv_pos"], jnp.full((b, 1), pos, jnp.int32), (0, slot))
    q_pos = jnp.full((b,), pos, jnp.int32)
    out = decode_attention(q, kc, vc, kv_pos, q_pos, window)
    out = dense(out.reshape(b, 1, cfg.n_heads * hd), p["wo"])
    return out, {"k": kc, "v": vc, "kv_pos": kv_pos}


def apply_layer_decode(p, desc: LayerDesc, x, cfg, cache_l, pos, window):
    cache_new = dict(cache_l)
    h = _apply_norm(p["norm1"], x, cfg)
    if desc.mixer == "attn":
        att, upd = _attn_decode(p["attn"], h, cfg, cache_l, pos, window)
        cache_new.update(upd)
    elif desc.mixer == "mamba":
        att, (conv, ssm) = mamba_mod.mamba_step(
            p["mamba"], h, (cache_l["conv"], cache_l["ssm"]), cfg)
        cache_new["conv"], cache_new["ssm"] = conv, ssm
    else:
        att, tm_prev, wkv = rwkv_mod.time_mix(
            p["tm"], h, cache_l["tm_prev"].astype(h.dtype), cache_l["wkv"], cfg)
        cache_new["tm_prev"], cache_new["wkv"] = tm_prev.astype(jnp.float32), wkv
    x = x + att
    if desc.cross:
        h = _apply_norm(p["norm_cross"], x, cfg)
        b = h.shape[0]
        q = dense(h, p["cross"]["wq"], p["cross"].get("bq")).reshape(b, 1, cfg.n_heads, cfg.hd)
        f = cache_l["ck"].shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))
        catt = decode_attention(q, cache_l["ck"], cache_l["cv"], kv_pos,
                                jnp.full((b,), f, jnp.int32), None)
        x = x + dense(catt.reshape(b, 1, cfg.n_heads * cfg.hd), p["cross"]["wo"])
    h = _apply_norm(p["norm2"], x, cfg)
    if desc.ffn == "dense":
        f = gelu_mlp(h, p["ffn"]) if cfg.family == "encdec" else swiglu(h, p["ffn"])
    elif desc.ffn == "moe":
        f, _ = moe_ffn(h, p["ffn"], cfg.moe)
    else:
        f, cm_prev = rwkv_mod.channel_mix(p["cm"], h, cache_l["cm_prev"].astype(h.dtype))
        cache_new["cm_prev"] = cm_prev.astype(jnp.float32)
    return x + f, cache_new


def serve_step(params, cfg: ModelConfig, cache: dict, token: jax.Array,
               pos: jax.Array, *, shard_fn=None):
    """One decode step.  token: (B,1) int32; pos: scalar int32 position.

    Returns (logits (B,V), new cache).
    """
    descs, _ = block_structure(cfg)
    sf = shard_fn or (lambda a, k: a)
    x = params["embed"][token]
    window = cfg.sliding_window

    def body(x, inp):
        group_p, cache_g = inp
        new_g = {}
        for j, desc in enumerate(descs):
            x, new_g[f"l{j}"] = apply_layer_decode(group_p[f"l{j}"], desc, x, cfg,
                                                   cache_g[f"l{j}"], pos, window)
        x = sf(x, "decode_residual")
        return x, new_g

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = _apply_norm(params["final_norm"], x, cfg)
    logits = logits_from_x(params, cfg, x)[:, 0, :]
    return sf(logits.astype(jnp.float32), "decode_logits"), new_cache


def prefill(params, cfg: ModelConfig, batch: dict, cache_seq_len: int, *, shard_fn=None):
    """Run the full prompt, build a decode cache of ``cache_seq_len`` slots.

    Returns (last-token logits (B,V), cache, next_pos).
    """
    out = forward(params, cfg, batch, shard_fn=shard_fn, collect_cache=True)
    x = out["x"]
    s_in = x.shape[1]
    logits = logits_from_x(params, cfg, x[:, -1:, :])[:, 0, :]
    raw = out["cache"]
    descs, n_groups = block_structure(cfg)
    b = x.shape[0]
    cache = init_cache(cfg, b, cache_seq_len)
    sc = cache_len_for(cfg, cache_seq_len)

    for j, desc in enumerate(descs):
        cj, rj = cache[f"l{j}"], raw[f"l{j}"]
        if desc.mixer == "attn":
            k, v = rj["k"], rj["v"]  # (G,B,S,K,hd)
            take = min(sc, s_in)
            src_pos = jnp.arange(s_in - take, s_in)
            slots = src_pos % sc
            cj["k"] = cj["k"].at[:, :, slots].set(k[:, :, s_in - take:])
            cj["v"] = cj["v"].at[:, :, slots].set(v[:, :, s_in - take:])
            cj["kv_pos"] = cj["kv_pos"].at[:, :, slots].set(
                jnp.broadcast_to(src_pos, (n_groups, b, take)).astype(jnp.int32))
        elif desc.mixer == "mamba":
            cj["conv"], cj["ssm"] = raw[f"l{j}"]["conv"], raw[f"l{j}"]["ssm"]
        else:
            cj["tm_prev"] = rj["tm_prev"].astype(jnp.float32)
            cj["cm_prev"] = rj["cm_prev"].astype(jnp.float32)
            cj["wkv"] = rj["wkv"]
        if desc.cross:
            cj["ck"], cj["cv"] = rj["ck"], rj["cv"]
    return logits, cache, jnp.asarray(s_in, jnp.int32)
