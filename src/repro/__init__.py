"""Split-Et-Impera in JAX.

Public API entry points:

    from repro.configs import get_config
    from repro.core import saliency, split, bottleneck, qos
    from repro.netsim.simulator import ApplicationSimulator, NetworkConfig
    from repro.models import transformer
    from repro.launch.mesh import make_production_mesh
"""

__version__ = "0.1.0"
