"""Split-Et-Impera in JAX.

The one-stop entry point is the ``repro.api`` facade:

    from repro.api import Study, QoSRequirements, Channel

    best = Study("vgg16", data=(xs, ys)).profile().candidates() \\
        .simulate().suggest(QoSRequirements(max_latency_s=0.05))

The subsystems underneath remain importable directly:

    from repro.configs import get_config
    from repro.core import saliency, split, bottleneck, qos
    from repro.netsim.simulator import ApplicationSimulator, NetworkConfig
    from repro.models import transformer
    from repro.launch.mesh import make_production_mesh
"""

__version__ = "0.1.0"
