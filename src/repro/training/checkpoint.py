"""Flat-npz checkpointing for arbitrary pytrees (no external deps)."""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _to_numpy(x):
    a = np.asarray(x)
    if a.dtype.kind not in "fiub" or str(a.dtype) == "bfloat16":
        a = a.astype(np.float32)   # bf16 etc: no native numpy representation
    return a


def save(path: str, tree) -> None:
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": _to_numpy(l) for i, l in enumerate(leaves)}
    arrays["__treedef__"] = np.frombuffer(str(treedef).encode(), dtype=np.uint8)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def restore(path: str, like):
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    import jax.numpy as jnp
    with np.load(path) as data:
        leaves, treedef = _flatten(like)
        out = []
        for i, ref in enumerate(leaves):
            arr = data[f"leaf_{i}"]
            assert arr.shape == ref.shape, (arr.shape, ref.shape)
            out.append(jnp.asarray(arr).astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
