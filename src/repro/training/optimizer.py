"""Adam (+ optional weight decay) on arbitrary pytrees.

Two flavours:
  * ``adam_*``       — simple fp32 Adam used by the paper-core experiments
                       (VGG/bottleneck training on CPU, §V hyperparams).
  * ``AdamWState``   — mixed-precision trainer for the big zoo: bf16 params,
                       configurable moment dtype (bf16 moments keep the
                       qwen3-235B optimizer state inside v5e HBM, DESIGN §6).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


# ------------------------------------------------------------ simple Adam ----
def adam_init(params):
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                     state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                     state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    upd = jax.tree.map(lambda m, v: m / bc1 / (jnp.sqrt(v / bc2) + eps), m, v)
    params = jax.tree.map(lambda p, u: (p.astype(jnp.float32) - lr * u).astype(p.dtype),
                          params, upd)
    return params, {"m": m, "v": v, "t": t}


# -------------------------------------------------- mixed-precision AdamW ----
@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    moment_dtype: str = "float32"      # "bfloat16" halves optimizer HBM
    master_fp32: bool = False          # fp32 master copy of bf16 params
    grad_clip: Optional[float] = 1.0


def adamw_init(params, cfg: OptConfig):
    md = jnp.dtype(cfg.moment_dtype)
    st = {"m": jax.tree.map(lambda p: jnp.zeros_like(p, md), params),
          "v": jax.tree.map(lambda p: jnp.zeros_like(p, md), params),
          "t": jnp.zeros((), jnp.int32)}
    if cfg.master_fp32:
        st["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return st


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: OptConfig):
    if cfg.grad_clip is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    t = state["t"] + 1
    md = jnp.dtype(cfg.moment_dtype)
    m = jax.tree.map(lambda m, g: (cfg.b1 * m.astype(jnp.float32)
                                   + (1 - cfg.b1) * g.astype(jnp.float32)).astype(md),
                     state["m"], grads)
    v = jax.tree.map(lambda v, g: (cfg.b2 * v.astype(jnp.float32)
                                   + (1 - cfg.b2) * jnp.square(g.astype(jnp.float32))).astype(md),
                     state["v"], grads)
    tf = t.astype(jnp.float32)
    bc1, bc2 = 1 - cfg.b1 ** tf, 1 - cfg.b2 ** tf

    def upd(m, v, p):
        u = (m.astype(jnp.float32) / bc1) / (jnp.sqrt(v.astype(jnp.float32) / bc2) + cfg.eps)
        if cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return u

    src = state.get("master", params)
    new_master = jax.tree.map(lambda p, m_, v_: p.astype(jnp.float32)
                              - cfg.lr * upd(m_, v_, p), src, m, v)
    new_params = jax.tree.map(lambda p, nm: nm.astype(p.dtype), params, new_master)
    new_state = {"m": m, "v": v, "t": t}
    if cfg.master_fp32:
        new_state["master"] = new_master
    return new_params, new_state
