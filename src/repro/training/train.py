"""Train-step factory for the zoo: loss -> grads -> AdamW, all shardable."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.common import ModelConfig
from .optimizer import OptConfig, adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, *, shard_fn=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def train_step(params, opt_state, batch):
        def lf(p):
            return T.loss_fn(p, cfg, batch, shard_fn=shard_fn)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, opt_cfg: OptConfig):
    params = T.init_params(key, cfg)
    return params, adamw_init(params, opt_cfg)


def train_state_struct(cfg: ModelConfig, opt_cfg: OptConfig):
    """Abstract (no-allocation) train state for dry-runs."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(functools.partial(init_train_state, cfg=cfg,
                                            opt_cfg=opt_cfg), key)
