"""Saliency-based split-point search (paper §III).

Generalized Grad-CAM over a :class:`LayeredModel`:

  1. one forward pass capturing every layer activation F^i,
  2. one backward pass (the *tap* trick: taps[i] added to each activation,
     vjp w.r.t. zero taps) yielding dy_c/dF^i for every layer at once,
  3. per layer: alpha_ch = mean_spatial(dy_c/dF_ch)   (Eq. 1; "spatial" =
     all non-batch, non-channel dims, so 1-D signals work — claim ii),
     m_i = sum_ch alpha_ch * F_ch, resized to a common grid,
  4. cumulative map  M_i = ReLU(sum_{k>=i} m_k)  (Eq. 2),
     per-layer scalar CS_i = mean_batch sum(M_i),
  5. average over inputs of all classes, normalise -> the CS curve.

Candidate split points = plateau-tolerant local maxima of CS restricted to
legal cut points.  See DESIGN.md §7 for the formula interpretation.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layered import LayeredModel


def _spatial_axes(shape) -> tuple:
    """Axes between batch (0) and channel (-1)."""
    return tuple(range(1, len(shape) - 1))


def _weighted_map(act: jax.Array, grad: jax.Array) -> jax.Array:
    """alpha-weighted, channel-summed map m_i: (B, *spatial) (spatial may be ())."""
    sp = _spatial_axes(act.shape)
    alpha = grad.mean(axis=sp) if sp else grad          # (B, C)
    alpha = alpha.reshape(alpha.shape[0], *([1] * len(sp)), alpha.shape[-1])
    return (alpha * act).sum(axis=-1)                   # (B, *spatial)


def _resize_to(m: jax.Array, target_spatial: tuple) -> jax.Array:
    """Resize (B, *spatial) map to (B, *target_spatial); scalars broadcast."""
    b = m.shape[0]
    if m.ndim == 1:                                      # no spatial dims
        return jnp.broadcast_to(m.reshape((b,) + (1,) * len(target_spatial)),
                                (b,) + target_spatial)
    if m.shape[1:] == target_spatial:
        return m
    return jax.image.resize(m, (b,) + target_spatial, method="bilinear")


def layer_saliency_maps(model: LayeredModel, params, x: jax.Array,
                        labels: jax.Array) -> list:
    """Per-layer alpha-weighted maps m_i resized to a common grid.

    Works on raw arrays or on model-specific input pytrees (the first
    layer of transformer LayeredModels consumes a batch dict).
    """
    zero_taps = None

    def fwd(taps):
        return model.apply_with_taps(params, x, taps)

    # build zero taps with the right shapes via a capture pass
    logits, acts = model.apply_capture(params, x)
    zero_taps = [jnp.zeros_like(a) for a in acts]
    logits, vjp = jax.vjp(fwd, zero_taps)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    (grads,) = vjp(onehot)

    # common grid = spatial shape of the largest feature map
    spatial_shapes = [a.shape[1:-1] for a in acts]
    ranked = sorted((s for s in spatial_shapes if s), key=np.prod, reverse=True)
    target = ranked[0] if ranked else ()
    maps = []
    for a, g in zip(acts, grads):
        m = _weighted_map(a.astype(jnp.float32), g.astype(jnp.float32))
        maps.append(_resize_to(m, tuple(target)) if target else m)
    return maps


def cumulative_saliency(model: LayeredModel, params, x: jax.Array,
                        labels: jax.Array,
                        layer_idx: Optional[Sequence[int]] = None) -> np.ndarray:
    """The CS curve over ``layer_idx`` (default: all layers)."""
    maps = layer_saliency_maps(model, params, x, labels)
    if layer_idx is not None:
        maps = [maps[i] for i in layer_idx]
    stack = jnp.stack(maps)                              # (L, B, *spatial)
    # cumulative from the back: M_i = sum_{k>=i} m_k
    cum = jnp.flip(jnp.cumsum(jnp.flip(stack, 0), axis=0), 0)
    cs = jax.nn.relu(cum).sum(axis=tuple(range(2, cum.ndim))).mean(axis=1)
    cs = np.asarray(cs, np.float64)
    rng = cs.max() - cs.min()
    return (cs - cs.min()) / (rng if rng > 0 else 1.0)


def batched_cs(model: LayeredModel, params, data_iter, n_batches: int,
               layer_idx=None) -> np.ndarray:
    """Average the CS curve over several batches (all classes into play)."""
    acc = None
    for _ in range(n_batches):
        x, y = next(data_iter)
        cs = cumulative_saliency(model, params, x, y, layer_idx)
        acc = cs if acc is None else acc + cs
    return acc / n_batches


def local_maxima(curve: np.ndarray, *, tol: float = 1e-9) -> list[int]:
    """Plateau-tolerant local maxima indices (endpoints excluded)."""
    peaks = []
    n = len(curve)
    i = 1
    while i < n - 1:
        j = i
        while j + 1 < n and abs(curve[j + 1] - curve[j]) <= tol:
            j += 1  # walk plateaus
        if curve[i] > curve[i - 1] + tol and (j + 1 < n and curve[j] > curve[j + 1] + tol):
            peaks.append((i + j) // 2)
            i = j + 1
        else:
            i += 1
    return peaks


def candidate_split_points(model: LayeredModel, cs: np.ndarray,
                           layer_idx: Sequence[int],
                           top_n: int = 5) -> list[int]:
    """Local CS maxima mapped back to legal model cut points, best first."""
    legal = set(model.cut_points())
    peaks = [layer_idx[p] for p in local_maxima(cs) if layer_idx[p] in legal]
    peaks.sort(key=lambda li: -cs[list(layer_idx).index(li)])
    return peaks[:top_n]
