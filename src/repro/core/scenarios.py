"""Computation-platform models and the LC / RC / SC scenario definitions
(paper §II-A).

The paper's simulator composes three timing sources: computation on the
edge device, computation on the server, and transmission.  This container
has no TPU/GPU wall-clock, so compute latencies come from an analytic
platform model (FLOPs / effective throughput) — recorded as a changed
assumption in DESIGN.md §3.  Transmission timing comes from
``repro.netsim`` (discrete-event TCP/UDP).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax

from repro.core import stats as S
from repro.core.split import SplitPlan, hop_payload_bytes


@dataclass(frozen=True)
class PlatformProfile:
    """Effective (not peak) throughput of a compute platform."""
    name: str
    flops_per_s: float

    def compute_time(self, flops: float) -> float:
        return flops / self.flops_per_s


# Representative profiles; effective throughput ~ 30-50% of peak.
PLATFORMS = {
    "mcu": PlatformProfile("mcu", 2e9),
    "edge-embedded": PlatformProfile("edge-embedded", 0.5e12),   # Nano-class
    "edge-accelerator": PlatformProfile("edge-accelerator", 5e12),  # Orin-class
    "server-gpu": PlatformProfile("server-gpu", 60e12),
    "tpu-v5e-chip": PlatformProfile("tpu-v5e-chip", 0.4 * 197e12),
}

# Sensing-side platforms a deployed fleet is made of (``repro.fleet`` draws
# its heterogeneous device mix from these).
EDGE_PLATFORM_NAMES = ("mcu", "edge-embedded", "edge-accelerator")


def edge_platform(name: str) -> PlatformProfile:
    """Resolve an edge platform by name with a diagnosable failure."""
    if name not in PLATFORMS:
        raise KeyError(f"unknown platform {name!r}; known: {sorted(PLATFORMS)}")
    if name not in EDGE_PLATFORM_NAMES:
        raise KeyError(f"{name!r} is a server platform, not an edge device "
                       f"class; edge classes: {EDGE_PLATFORM_NAMES}")
    return PLATFORMS[name]


class HILPlatform:
    """Hardware-in-the-loop platform (paper §IV): instead of the analytic
    FLOPs/throughput model, computation time is *measured* by executing the
    (jitted) segment on the attached hardware — here the host CPU; on a
    real deployment the same interface wraps the edge device.

    ``compute_time(flops)`` falls back to the analytic model when no
    measurement has been registered for that segment."""

    def __init__(self, name: str, fallback_flops_per_s: float = 50e9):
        self.name = name
        self.flops_per_s = fallback_flops_per_s
        self._measured = {}

    def measure(self, key: str, fn, *args, iters: int = 3) -> float:
        import time as _t
        jax.block_until_ready(fn(*args))          # compile + warm
        t0 = _t.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(*args))
        dt = (_t.perf_counter() - t0) / iters
        self._measured[key] = dt
        return dt

    def compute_time(self, flops: float, key: str = None) -> float:
        if key is not None and key in self._measured:
            return self._measured[key]
        return flops / self.flops_per_s


@dataclass(frozen=True)
class Scenario:
    """One design point: where does the computation run, what crosses the net."""
    kind: str                      # 'LC' | 'RC' | 'SC'
    split_plan: Optional[SplitPlan] = None   # SC only
    edge: PlatformProfile = PLATFORMS["edge-embedded"]
    server: PlatformProfile = PLATFORMS["server-gpu"]

    def label(self) -> str:
        if self.kind == "SC":
            return f"SC@{self.split_plan.split_layer}"
        return self.kind


def scenario_times_and_payload(scenario: Scenario, model, params,
                               input_bytes: int, batch: int = 1, *,
                               sample=None) -> dict:
    """(edge_time, server_time, wire_bytes) for one inference frame.

    ``sample``: example input (array or pytree) for models whose
    ``input_shape`` alone cannot describe the input.  FLOPs are counted
    at the sample's own leading dim and rescaled linearly to ``batch``.
    """
    scale = _sample_scale(batch, sample)
    total_flops = S.total_flops(model, params, batch, sample=sample) * scale
    if scenario.kind == "LC":
        return {"edge_s": scenario.edge.compute_time(total_flops),
                "server_s": 0.0, "wire_bytes": 0}
    if scenario.kind == "RC":
        return {"edge_s": 0.0,
                "server_s": scenario.server.compute_time(total_flops),
                "wire_bytes": input_bytes}
    plan = scenario.split_plan
    tiers = (scenario.edge,) + (scenario.server,) * len(plan.splits)
    st = stage_times_and_payloads(model, params, plan, tiers, batch,
                                  sample=sample)
    return {"edge_s": st["stage_s"][0],
            "server_s": sum(st["stage_s"][1:]),
            "wire_bytes": sum(st["hop_bytes"])}


def cut_payload_bytes_lut(model, params, batch: int = 1, *,
                          compression: float = 0.5,
                          wire_dtype_bytes: int = 4,
                          sample=None) -> np.ndarray:
    """Wire payload (bytes per ``batch`` frames) for a cut after *every*
    layer, as one array indexed by layer — the batched counterpart of
    pricing each cut's activation separately, so the vectorized planner
    screen gathers ``(n_combos, K)`` hop tensors with one fancy index.
    Rides the ``stats.summary`` cache; illegal cuts simply carry the
    payload their activation would have."""
    import numpy as np
    from repro.core import bottleneck as B
    rows = S.summary(model, params, batch, sample=sample)
    scale = _sample_scale(batch, sample)
    return np.array(
        [int(round(r.output_shape[0] * scale))
         * B.payload_bytes(r.output_shape[1:], compression, wire_dtype_bytes)
         if len(r.output_shape) > 1 else 0.0
         for r in rows], dtype=float)


def _sample_scale(batch: int, sample) -> float:
    """FLOPs are counted at the sample's own leading dim and rescaled
    linearly to ``batch``."""
    if sample is None:
        return 1.0
    import jax
    return batch / int(jax.tree.leaves(sample)[0].shape[0])


def stage_times_and_payloads(model, params, plan: SplitPlan, tiers,
                             batch: int = 1, *, sample=None) -> dict:
    """Per-stage compute times and per-hop payloads of a K-cut plan.

    ``tiers`` is the K+1 platform chain (device, ..., server) the stages
    run on; hop k carries the (compressed) activation after cut
    ``plan.splits[k]``.  This is the multi-tier generalisation of the
    SC branch of :func:`scenario_times_and_payload`, which delegates here
    with the 2-platform (edge, server) chain — the analytic stage/hop
    numbers ``netsim.simulator.measure_flow`` prices a ``NetworkPath``
    with.
    """
    cuts = plan.splits
    if len(tiers) != len(cuts) + 1:
        raise ValueError(f"{len(cuts)} cuts need {len(cuts) + 1} tiers, "
                         f"got {len(tiers)}")
    scale = _sample_scale(batch, sample)
    stage_f = S.flops_stages(model, params, cuts, batch, sample=sample)
    hop_bytes = hop_payload_bytes(model, params, plan, batch, sample=sample)
    return {"stage_s": [t.compute_time(f * scale)
                        for t, f in zip(tiers, stage_f)],
            "hop_bytes": hop_bytes}
