"""Undercomplete autoencoder bottleneck (paper §III, Eqs. 3-4).

The bottleneck sits after target layer ``T^i``: encoder F (edge side)
compresses the feature map channel-wise to ``rate`` of its channels,
decoder G (server side) reconstructs it.  Channel-wise projection works
for any signal layout (B, *spatial, C) — conv maps and token streams alike.

Training recipe, faithful to the paper:
  stage 1 — train the AE alone with the reconstruction loss L_AE (Eq. 3),
            the backbone frozen (50 epochs, lr 5e-4, Adam in §V);
  stage 2 — fine-tune end-to-end with the task loss L_task (Eq. 4; the
            paper uses an MSE-to-target form, we default to it and also
            provide cross-entropy).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layered import LayeredModel
from repro.models.layers import init_dense


def latent_channels(c: int, rate: float) -> int:
    return max(1, int(round(c * rate)))


def init_bottleneck(key, feat_shape: tuple, rate: float = 0.5,
                    dtype=jnp.float32) -> dict:
    """feat_shape: activation shape sans batch, channels last."""
    c = feat_shape[-1]
    cl = latent_channels(c, rate)
    k1, k2 = jax.random.split(key)
    return {
        "enc": {"w": init_dense(k1, c, cl, dtype), "b": jnp.zeros((cl,), dtype)},
        "dec": {"w": init_dense(k2, cl, c, dtype), "b": jnp.zeros((c,), dtype)},
    }


def encode(ae: dict, f: jax.Array) -> jax.Array:
    return jax.nn.relu(f @ ae["enc"]["w"] + ae["enc"]["b"])


def decode(ae: dict, z: jax.Array) -> jax.Array:
    return z @ ae["dec"]["w"] + ae["dec"]["b"]


def reconstruct(ae: dict, f: jax.Array) -> jax.Array:
    return decode(ae, encode(ae, f))


def encode_wire(ae: dict, f: jax.Array, scale: float = 127.0) -> tuple:
    """Encoder + symmetric int8 wire quantisation (what the Pallas
    ``bottleneck_compress`` kernel fuses on TPU).  Returns (int8, scales)."""
    z = encode(ae, f.astype(jnp.float32))
    amax = jnp.max(jnp.abs(z), axis=-1, keepdims=True)
    s = jnp.where(amax > 0, amax / scale, 1.0)
    q = jnp.clip(jnp.round(z / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def decode_wire(ae: dict, q: jax.Array, s: jax.Array) -> jax.Array:
    """Dequantise + decoder (what the Pallas ``bottleneck_decompress``
    kernel fuses on TPU; the runtime routes through
    ``kernels.bottleneck_decompress.bottleneck_decompress_any``)."""
    return decode(ae, q.astype(jnp.float32) * s)


def ae_loss(ae: dict, feats: jax.Array) -> jax.Array:
    """L_AE (Eq. 3): mean squared reconstruction error."""
    r = reconstruct(ae, feats.astype(jnp.float32))
    return jnp.mean(jnp.square(r - feats.astype(jnp.float32)))


def payload_bytes(feat_shape: tuple, rate: float, wire_dtype_bytes: int = 4) -> int:
    """Bytes/frame crossing the wire after compression (netsim input)."""
    import numpy as np
    cl = latent_channels(feat_shape[-1], rate)
    return int(np.prod(feat_shape[:-1])) * cl * wire_dtype_bytes


# -------------------------------------------------- split-model execution ----
def head_forward(model: LayeredModel, params, ae: Optional[dict], split: int,
                 x: jax.Array) -> jax.Array:
    """Edge side: layers [0, split] then the encoder. Returns the wire z."""
    f = model.apply_range(params, x, 0, split + 1)
    return encode(ae, f) if ae is not None else f


def tail_forward(model: LayeredModel, params, ae: Optional[dict], split: int,
                 z: jax.Array) -> jax.Array:
    """Server side: decoder then layers (split, end)."""
    f = decode(ae, z) if ae is not None else z
    return model.apply_range(params, f, split + 1, len(model.layers))


def split_forward(model: LayeredModel, params, ae: Optional[dict], split: int,
                  x: jax.Array, corrupt_mask: Optional[jax.Array] = None) -> jax.Array:
    """Full SC inference; ``corrupt_mask`` (broadcastable to z, 1=keep 0=lost)
    models UDP packet loss zeroing wire chunks (netsim feeds this in)."""
    z = head_forward(model, params, ae, split, x)
    if corrupt_mask is not None:
        z = z * corrupt_mask.astype(z.dtype)
    return tail_forward(model, params, ae, split, z)


def task_loss(model: LayeredModel, params, ae: Optional[dict], split: int,
              x: jax.Array, labels: jax.Array, kind: str = "mse") -> jax.Array:
    """L_task (Eq. 4). kind='mse' (paper) or 'ce'."""
    logits = (split_forward(model, params, ae, split, x)
              if ae is not None else model.apply(params, x))
    if kind == "mse":
        onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
        return jnp.mean(jnp.square(logits.astype(jnp.float32) - onehot))
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[:, None], 1)[:, 0]
    return jnp.mean(lse - gold)


def train_bottleneck(model: LayeredModel, params, split: int, data_iter,
                     steps: int, lr: float = 5e-4, rate: float = 0.5,
                     seed: int = 0) -> tuple:
    """Stage 1 (Eq. 3): Adam on the AE only, backbone frozen."""
    from repro.training.optimizer import adam_init, adam_update

    x0, _ = next(data_iter)
    f0 = model.apply_range(params, x0, 0, split + 1)
    ae = init_bottleneck(jax.random.PRNGKey(seed), f0.shape[1:], rate)
    opt = adam_init(ae)

    @jax.jit
    def step(ae, opt, feats):
        loss, g = jax.value_and_grad(ae_loss)(ae, feats)
        ae, opt = adam_update(ae, g, opt, lr)
        return ae, opt, loss

    head = jax.jit(lambda x: model.apply_range(params, x, 0, split + 1))
    losses = []
    for _ in range(steps):
        x, _ = next(data_iter)
        ae, opt, loss = step(ae, opt, head(x))
        losses.append(float(loss))
    return ae, losses


def finetune(model: LayeredModel, params, ae: dict, split: int, data_iter,
             steps: int, lr: float = 5e-4, loss_kind: str = "mse") -> tuple:
    """Stage 2 (Eq. 4): end-to-end fine-tune of backbone + AE."""
    from repro.training.optimizer import adam_init, adam_update

    state = {"params": params, "ae": ae}
    opt = adam_init(state)

    @jax.jit
    def step(state, opt, x, y):
        def lf(st):
            return task_loss(model, st["params"], st["ae"], split, x, y, loss_kind)
        loss, g = jax.value_and_grad(lf)(state)
        state, opt = adam_update(state, g, opt, lr)
        return state, opt, loss

    losses = []
    for _ in range(steps):
        x, y = next(data_iter)
        state, opt, loss = step(state, opt, x, y)
        losses.append(float(loss))
    return state["params"], state["ae"], losses
