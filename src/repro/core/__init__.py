"""The paper's contribution: saliency-driven split-point selection,
head/bottleneck/tail partitioning, QoS matching, model statistics."""
from . import bottleneck, qos, saliency, scenarios, split, stats  # noqa: F401
