"""Model partitioning: head / bottleneck / tail (paper §III) and the TPU
multi-pod adaptation (DESIGN.md §3).

Two execution mappings of the same split:

* **edge/server** (paper-faithful): `head_forward` on the sensing device,
  payload over the simulated network (``repro.netsim``), `tail_forward` on
  the server — see ``repro.core.bottleneck`` for the pieces.
* **multi-pod pipeline** (TPU adaptation): the cut becomes a cross-pod
  stage boundary; ``multipod_split_step`` runs a 2-stage microbatched
  pipeline under ``shard_map`` where the inter-stage hop is a
  ``lax.ppermute`` over the ``pod`` axis carrying the bottleneck-compressed
  activation — the paper's head/AE/tail triple with the TCP channel
  replaced by the pod-to-pod link.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layered import LayeredModel
from repro.models import transformer as T
from repro.core import bottleneck as B

if hasattr(jax, "shard_map"):
    _shard_map, _SMAP_KW = jax.shard_map, {"check_vma": False}
else:  # jax <= 0.4.x keeps it in experimental, with check_rep
    from jax.experimental.shard_map import shard_map as _shard_map
    _SMAP_KW = {"check_rep": False}


@dataclass(frozen=True)
class SplitPlan:
    """A concrete SC design point: one or more ordered cuts.

    The portable form of an SC candidate (``repro.api.types.SplitCandidate``
    carries one of these as its executable payload via ``.plan()``).
    ``splits`` is the canonical ordered cut list; the historical scalar
    ``split_layer`` stays as the first (edge-side) cut, so every 1-cut
    consumer keeps working unchanged — ``SplitPlan(4)`` and
    ``SplitPlan(4, splits=(4,))`` are the same design point.
    """
    split_layer: int              # first cut (after this layer index)
    compression: float = 0.5      # bottleneck rate (paper: 50%)
    wire_dtype_bytes: int = 4
    splits: tuple = None          # full ordered cut list; (split_layer,) if None

    def __post_init__(self):
        if self.splits is None:
            cuts = () if self.split_layer is None else (int(self.split_layer),)
        else:
            cuts = normalize_cuts(self.splits)
        object.__setattr__(self, "splits", cuts)
        if self.split_layer is None and cuts:
            object.__setattr__(self, "split_layer", cuts[0])

    @property
    def n_stages(self) -> int:
        return len(self.splits) + 1

    def describe(self, model: LayeredModel) -> str:
        """Human-readable stage layout of this plan on ``model``
        (legality-checked through :func:`validate_cuts`)."""
        cuts = validate_cuts(model, self.splits)
        if len(cuts) == 1:
            return (f"head=[0..{self.split_layer}] "
                    f"bottleneck(rate={self.compression}) "
                    f"tail=[{self.split_layer + 1}..{len(model.layers) - 1}]")
        bounds = (0,) + tuple(c + 1 for c in cuts) + (len(model.layers),)
        stages = " | ".join(f"stage{i}=[{a}..{b - 1}]"
                            for i, (a, b) in enumerate(zip(bounds, bounds[1:])))
        return f"{stages} bottleneck(rate={self.compression})"


def legal_cuts(model: LayeredModel) -> list[int]:
    """All legal cut indices of ``model`` (ascending layer order)."""
    return model.cut_points()


def validate_cut(model: LayeredModel, split_layer: int) -> int:
    """Check a cut index against the model's legality rule.

    Single authority for "is this split executable" — the runtime partition,
    the planner and the examples all route through here so an illegal cut
    fails loudly with the legal alternatives instead of silently producing a
    head/tail pair that can never run.
    """
    cuts = model.cut_points()
    if split_layer not in cuts:
        raise ValueError(
            f"cut after layer {split_layer} is not legal for {model.name!r}; "
            f"legal cuts: {cuts}")
    return split_layer


def normalize_cuts(splits) -> tuple:
    """Coerce a scalar cut or an iterable of cuts into the canonical
    ordered cut tuple (the ``splits`` convention: ints, ascending).

    Strict monotonicity is enforced here, at the point every cut list is
    constructed (``SplitPlan``, ``SplitCandidate``, the planners), so a
    shuffled or duplicated list fails loudly instead of silently pricing
    empty/overlapping stages; per-cut *legality* against a model stays
    with :func:`validate_cuts`.
    """
    if not hasattr(splits, "__iter__"):
        return (int(splits),)
    cuts = tuple(int(s) for s in splits)
    if any(b <= a for a, b in zip(cuts, cuts[1:])):
        raise ValueError(f"cut list {cuts} must be strictly increasing "
                         f"(every stage needs at least one layer)")
    return cuts


def validate_cuts(model: LayeredModel, splits) -> tuple:
    """Check an ordered cut list against the model's legality rule.

    The multi-cut extension of :func:`validate_cut` and, like it, the
    single legality authority: a legal cut list is non-empty, strictly
    increasing (each stage runs at least one layer — enforced by
    :func:`normalize_cuts`), and every cut is individually legal.
    Returns the normalised tuple.
    """
    cuts = normalize_cuts(splits)
    if not cuts:
        raise ValueError(f"need at least one cut for {model.name!r}; "
                         f"legal cuts: {model.cut_points()}")
    for c in cuts:
        validate_cut(model, c)
    return cuts


def legal_cut_lists(model: LayeredModel, n_cuts: int) -> list:
    """Every legal ordered cut list with exactly ``n_cuts`` cuts.

    The K-way search space of the multi-tier planner: all strictly
    increasing ``n_cuts``-combinations of :func:`legal_cuts`.  The lists
    grow combinatorially and the planners enumerate them per search, so
    they are cached on the model instance (layer structure is immutable
    in practice) — treat the returned list as read-only.
    """
    import itertools
    if n_cuts < 1:
        raise ValueError(f"n_cuts must be >= 1, got {n_cuts}")
    cache = (model.__dict__.setdefault("_cut_lists_cache", {})
             if hasattr(model, "__dict__") else None)
    if cache is not None and n_cuts in cache:
        return cache[n_cuts]
    out = list(itertools.combinations(legal_cuts(model), n_cuts))
    if cache is not None:
        cache[n_cuts] = out
    return out


def wire_payload_bytes(model: LayeredModel, params, plan: SplitPlan,
                       batch: int = 1, *, sample=None) -> int:
    """Bytes crossing the first (edge-side) wire hop per ``batch`` frames
    under ``plan`` — see :func:`hop_payload_bytes` for the whole chain.

    ``sample``: example input (array or pytree) for models whose
    ``input_shape`` alone cannot describe the input — see
    ``LayeredModel.activation_shapes``.
    """
    return hop_payload_bytes(model, params, plan, batch, sample=sample)[0]


def hop_payload_bytes(model: LayeredModel, params, plan: SplitPlan,
                      batch: int = 1, *, sample=None) -> list:
    """Per-hop wire payloads (bytes per ``batch`` frames) of a K-cut plan.

    Hop k carries the activation after cut ``plan.splits[k]``, compressed
    at the plan's bottleneck rate (one AE per cut, same rate — the
    analytic counterpart of the runtime's per-hop codec).
    """
    shapes = model.activation_shapes(params, batch, sample=sample)
    return [batch * B.payload_bytes(shapes[c][1:], plan.compression,
                                    plan.wire_dtype_bytes)
            for c in plan.splits]


# ------------------------------------------------ multi-pod pipeline step ----
def _stack_stages(layer_params, n_groups: int, n_stages: int):
    """(G, ...) group-stacked params -> (n_stages, G/n_stages, ...)."""
    def re(x):
        return x.reshape((n_stages, n_groups // n_stages) + x.shape[1:])
    return jax.tree.map(re, layer_params)


def multipod_split_step(params, cfg, batch: dict, mesh, *, ae: Optional[dict],
                        n_micro: int = 4, shard_fn=None,
                        quantize_wire: bool = False):
    """2-stage pipelined forward across the ``pod`` mesh axis.

    Uniform-stack architectures only (period-1 block structure).  The head
    stage (pod 0) embeds + runs the first half of the blocks and *encodes*
    the residual stream with the bottleneck AE; the compressed latent
    crosses pods via ``ppermute``; the tail stage (pod 1) decodes and runs
    the rest + LM head.  Microbatches keep both pods busy (GPipe-style,
    bubble = 1/(n_micro+1)).

    Returns per-token logits of the last microbatch wave (B, S, V) — enough
    for validation; the training driver reduces a loss instead.
    """
    descs, n_groups = T.block_structure(cfg)
    assert len(descs) == 1, "pipeline demo supports uniform stacks"
    assert n_groups % 2 == 0
    stages = _stack_stages(params["layers"], n_groups, 2)
    tokens = batch["tokens"]
    bsz, seq = tokens.shape
    assert bsz % n_micro == 0
    mb = bsz // n_micro

    stage_spec = jax.tree.map(lambda _: P("pod"), stages)
    out_spec = P(None, None, None)

    def run_stage(stage_params, x):
        positions = jnp.arange(x.shape[1])

        def body(x, lp):
            y, _, _ = T.apply_layer_seq(lp["l0"], descs[0], x, cfg, positions,
                                        causal=True, window=cfg.sliding_window)
            return y, None

        x, _ = jax.lax.scan(body, x, stage_params)
        return x

    def pipeline(stages_local, tokens_all):
        # stages_local: (1, G/2, ...) — this pod's stage
        stage_id = jax.lax.axis_index("pod")
        my_stage = jax.tree.map(lambda x: x[0], stages_local)
        mbs = tokens_all.reshape(n_micro, mb, seq)
        # one extra drain wave so the last microbatch clears the tail stage
        mbs = jnp.concatenate([mbs, jnp.zeros((1, mb, seq), mbs.dtype)], 0)

        def wave(carry, mb_tokens):
            recv = carry  # latent arriving from the other pod (previous wave)
            x0 = params["embed"][mb_tokens]                    # head input
            if ae is None:
                x1 = recv
            elif quantize_wire:
                x1 = B.decode_wire(ae, *recv)
            else:
                x1 = B.decode(ae, recv)
            x = jnp.where(stage_id == 0, x0, x1.astype(x0.dtype))
            y = run_stage(my_stage, x)
            if ae is None:
                wire = y
            elif quantize_wire:  # int8 codes + per-token scales on the link
                wire = B.encode_wire(ae, y.astype(jnp.float32))
            else:
                wire = B.encode(ae, y.astype(jnp.float32))
            sent = jax.tree.map(
                lambda t: jax.lax.ppermute(t, "pod", [(0, 1), (1, 0)]), wire)
            return sent, y

        latent_c = (B.latent_channels(cfg.d_model, 0.5) if ae is not None
                    else cfg.d_model)
        if ae is None:
            init = jnp.zeros((mb, seq, latent_c), cfg.jdtype)
        elif quantize_wire:
            init = (jnp.zeros((mb, seq, latent_c), jnp.int8),
                    jnp.ones((mb, seq, 1), jnp.float32))
        else:
            init = jnp.zeros((mb, seq, latent_c), jnp.float32)
        _, ys = jax.lax.scan(wave, init, mbs)
        # wave i's tail output (valid on pod 1) is microbatch i-1
        tail_out = ys[1:]                                      # (n_micro, mb, S, D)
        x = T._apply_norm(params["final_norm"], tail_out, cfg)
        logits = T.logits_from_x(params, cfg, x)
        logits = logits.reshape(bsz, seq, -1)
        # pod 0 holds head garbage; zero it and share pod 1's result
        valid = jnp.where(stage_id == 1, 1.0, 0.0).astype(logits.dtype)
        return jax.lax.psum(logits * valid, "pod")

    f = _shard_map(pipeline, mesh=mesh,
                   in_specs=(stage_spec, P()), out_specs=out_spec,
                   **_SMAP_KW)
    return f(stages, tokens)
