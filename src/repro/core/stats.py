"""Neural-network statistics reports (paper §V-D, Tables I and II).

Per-layer summary (type, output shape, #params) and model totals (total /
trainable params, total mult-adds, forward/backward pass size, estimated
total size) — the torchinfo-style report the paper prints for VGG16.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layered import LayeredModel


@dataclass
class LayerRow:
    name: str
    kind: str
    output_shape: tuple
    n_params: int
    mult_adds: int


def _layer_mult_adds(layer, p, in_shape, out_shape) -> int:
    if layer.mult_adds is not None:      # layer-provided counter wins
        return int(layer.mult_adds(p, in_shape, out_shape))
    if layer.kind == "conv":
        kh, kw, cin, cout = p["w"].shape
        b, h, w, _ = out_shape
        return b * h * w * kh * kw * cin * cout
    if layer.kind == "linear":
        fin, fout = p["w"].shape
        return int(np.prod(out_shape[:-1])) * fin * fout
    return 0


def _shape_sig(tree) -> tuple:
    """Leaf-shape signature of a pytree — what ``summary`` actually
    depends on (it runs under ``jax.eval_shape``; values never matter)."""
    if tree is None:
        return None
    return tuple(tuple(leaf.shape) for leaf in jax.tree.leaves(tree))


def summary(model: LayeredModel, params, batch: int = 16, *,
            sample=None) -> list:
    """Table I: one row per layer.

    ``sample``: example input (array or pytree) for models whose
    ``input_shape`` alone cannot describe the input (transformer layered
    views consume a batch dict); its leading dim wins over ``batch``.

    Rows are cached on the model instance per (param shapes, batch,
    sample shapes) key — the planners walk this table once per design
    *study*, not once per design *point* — so treat the returned list as
    read-only.
    """
    cache = None
    if hasattr(model, "__dict__"):
        cache = model.__dict__.setdefault("_summary_cache", {})
        # batch is shadowed by the sample's own leading dim when given
        key = (_shape_sig(params), None if sample is not None else batch,
               _shape_sig(sample))
        if key in cache:
            return cache[key]
    x = sample if sample is not None else jax.ShapeDtypeStruct(
        (batch,) + tuple(model.input_shape), jnp.float32)
    _, acts = jax.eval_shape(model.apply_capture, params, x)
    rows = []
    in_shape = None if sample is not None else x.shape
    for l, p, a in zip(model.layers, params, acts):
        n = sum(int(np.prod(leaf.shape)) for leaf in jax.tree.leaves(p))
        rows.append(LayerRow(l.name, l.kind, tuple(a.shape), n,
                             _layer_mult_adds(l, p, in_shape, a.shape)))
        in_shape = a.shape
    if cache is not None:
        cache[key] = rows
    return rows


def totals(model: LayeredModel, params, batch: int = 16,
           param_bytes: int = 4, act_bytes: int = 4) -> dict:
    """Table II: aggregate statistics (torchinfo conventions)."""
    rows = summary(model, params, batch)
    n_params = sum(r.n_params for r in rows)
    mult_adds = sum(r.mult_adds for r in rows)
    # forward/backward pass size, torchinfo convention (sum of layer output
    # bytes; reproduces the paper's 1735.26 MB within 1%)
    fwd_bwd = sum(int(np.prod(r.output_shape)) for r in rows) * act_bytes
    input_size = batch * int(np.prod(model.input_shape)) * act_bytes
    return {
        "total_params": n_params,
        "trainable_params": n_params,
        "mult_adds_G": mult_adds / 1e9,
        "fwd_bwd_MB": fwd_bwd / 2 ** 20,
        "input_MB": input_size / 2 ** 20,
        "params_MB": n_params * param_bytes / 2 ** 20,
        "total_MB": (fwd_bwd + input_size + n_params * param_bytes) / 2 ** 20,
    }


def total_flops(model: LayeredModel, params, batch: int = 1, *,
                sample=None) -> float:
    """Whole-model forward FLOPs (2x mult-adds) — the single counting
    convention shared by the scenario timing model and the serving cost
    model."""
    return sum(r.mult_adds
               for r in summary(model, params, batch, sample=sample)) * 2


def flops_split(model: LayeredModel, params, split_layer: int,
                batch: int = 1, *, sample=None) -> tuple:
    """(head_flops, tail_flops) for a cut after ``split_layer`` (2x mult-adds)."""
    head, tail = flops_stages(model, params, (split_layer,), batch,
                              sample=sample)
    return head, tail


def flops_prefix(model: LayeredModel, params, batch: int = 1, *,
                 sample=None) -> np.ndarray:
    """Cumulative forward FLOPs (2x mult-adds) at every layer boundary:
    entry ``i`` is the cost of layers ``[0, i)``, so any stage of any cut
    list prices as one subtraction — the surface the vectorized planner
    screen scores ``(n_combos, K+1)`` stage tensors from.  Rides the
    :func:`summary` cache."""
    rows = summary(model, params, batch, sample=sample)
    return np.concatenate(
        ([0.0], np.cumsum([2.0 * r.mult_adds for r in rows])))


def flops_stages(model: LayeredModel, params, cuts, batch: int = 1, *,
                 sample=None) -> list:
    """Per-stage forward FLOPs for an ordered cut list (2x mult-adds).

    ``cuts = (c1, .., cK)`` yields K+1 stage costs: layers ``[0, c1]``,
    ``(c1, c2]``, ..., ``(cK, end)`` — the multi-tier generalisation of
    :func:`flops_split` (which delegates here for the 1-cut case).
    """
    rows = summary(model, params, batch, sample=sample)
    bounds = [0] + [c + 1 for c in cuts] + [len(rows)]
    return [sum(r.mult_adds for r in rows[a:b]) * 2
            for a, b in zip(bounds, bounds[1:])]


def format_table(rows: list, max_rows: int = 0) -> str:
    out = [f"{'Layer (type)':<24s}{'Output Shape':<26s}{'Param #':>14s}"]
    shown = rows if not max_rows else rows[:max_rows]
    for r in shown:
        out.append(f"{r.name + ' (' + r.kind + ')':<24s}"
                   f"{str(list(r.output_shape)):<26s}{r.n_params:>14,d}")
    return "\n".join(out)
