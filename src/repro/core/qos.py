"""QoS matching: rank candidate configurations, suggest the best design
(paper §IV outputs i and ii).

Output i  — *suggested configurations*: SC candidates ranked by the CS value
            at their split point (the paper's accuracy proxy), plus LC/RC.
Output ii — *simulation verdicts*: after `repro.netsim` simulates the chosen
            subset, pick the best design meeting the application
            constraints (e.g. 20 FPS conveyor belt + accuracy floor).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.api.types import SplitCandidate

# Deprecated alias: the candidate type now lives in ``repro.api.types``
# (one design-point type from profiling to deployment).  Constructor and
# field names are unchanged — ``Candidate(label, split_layer,
# accuracy_proxy)`` keeps working — but new code should import
# ``SplitCandidate`` from ``repro.api``.
Candidate = SplitCandidate


@dataclass(frozen=True)
class QoSRequirements:
    max_latency_s: float            # e.g. 0.05 (20 FPS conveyor belt, §V-B)
    min_accuracy: float = 0.0


@dataclass
class SimVerdict:
    candidate: Candidate
    latency_s: float
    accuracy: float
    meta: dict = field(default_factory=dict)

    def satisfies(self, qos: QoSRequirements) -> bool:
        return (self.latency_s <= qos.max_latency_s
                and self.accuracy >= qos.min_accuracy)


def rank_candidates(cs_curve, layer_idx: Sequence[int],
                    split_points: Sequence[int],
                    include_lc_rc: bool = True) -> list[SplitCandidate]:
    """Output i: candidates ordered by presumed accuracy (CS at the cut)."""
    pos = {sp: i for i, sp in enumerate(layer_idx)}
    missing = [sp for sp in split_points if sp not in pos]
    if missing:
        raise ValueError(
            f"split points {missing} have no CS value: not in layer_idx "
            f"{sorted(pos)} — pass the layer_idx the curve was computed over")
    cands = [SplitCandidate.sc(sp, float(cs_curve[pos[sp]]))
             for sp in split_points]
    cands.sort(key=lambda c: -c.accuracy_proxy)
    if include_lc_rc:
        # RC preserves full accuracy (proxy 1.0 by definition); LC runs the
        # lightweight local model (proxy below any SC cut).
        cands = [SplitCandidate.rc()] + cands + [SplitCandidate.lc()]
    return cands


def suggest(verdicts: Sequence[SimVerdict], qos: QoSRequirements) -> Optional[SimVerdict]:
    """Output ii: best feasible design — max accuracy, then min latency."""
    ok = [v for v in verdicts if v.satisfies(qos)]
    if not ok:
        return None
    return max(ok, key=lambda v: (v.accuracy, -v.latency_s))


def pareto(verdicts: Sequence[SimVerdict]) -> list:
    """Accuracy/latency Pareto frontier over simulated designs."""
    keyed = [(v, (v.latency_s, -v.accuracy)) for v in verdicts]
    front = [v for v, _ in pareto_nd(keyed)]
    return sorted(front, key=lambda v: v.latency_s)


def pareto_nd(items: Sequence[tuple]) -> list:
    """N-objective Pareto filter over ``(payload, objectives)`` pairs.

    Every objective is minimised (negate the ones you maximise).  An item
    survives unless some other item is <= on every objective and strictly
    < on at least one.  Duplicated objective vectors all survive.
    """
    out = []
    for i, (_, obj) in enumerate(items):
        dominated = False
        for j, (_, other) in enumerate(items):
            if j == i:
                continue
            if (all(o <= s for o, s in zip(other, obj))
                    and any(o < s for o, s in zip(other, obj))):
                dominated = True
                break
        if not dominated:
            out.append(items[i])
    return out
