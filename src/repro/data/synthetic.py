"""Synthetic data: procedural "conveyor-belt toys" images + token streams.

No datasets ship offline, so the paper's CIFAR10/ICE-Lab images are stood
in for by a *learnable* procedural shape-classification task (the paper's
own task is classifying toy shapes on a conveyor belt, §V): each class is
a geometric silhouette (disk, square, cross, ring, triangle, stripes, ...)
rendered at random position/scale with noise and background clutter.  A
VGG reaches >90% on it within a few hundred CPU steps, which is what the
accuracy-vs-split experiments need.

Token streams for LM training are Zipf-sampled with a deterministic
next-token structure so cross-entropy visibly falls.
"""
from __future__ import annotations

import numpy as np

N_TOY_CLASSES = 8


def _render(cls: int, hw: int, rng: np.random.Generator) -> np.ndarray:
    img = rng.normal(0.0, 0.15, (hw, hw, 3)).astype(np.float32)
    yy, xx = np.mgrid[0:hw, 0:hw]
    cy, cx = rng.integers(hw // 4, 3 * hw // 4, 2)
    r = rng.integers(hw // 6, hw // 3)
    color = rng.uniform(0.6, 1.0, 3).astype(np.float32)
    if cls == 0:    # disk
        m = (yy - cy) ** 2 + (xx - cx) ** 2 <= r * r
    elif cls == 1:  # square
        m = (np.abs(yy - cy) <= r) & (np.abs(xx - cx) <= r)
    elif cls == 2:  # ring
        d2 = (yy - cy) ** 2 + (xx - cx) ** 2
        m = (d2 <= r * r) & (d2 >= (r // 2) ** 2)
    elif cls == 3:  # cross
        m = (np.abs(yy - cy) <= r // 3) | (np.abs(xx - cx) <= r // 3)
        m &= (np.abs(yy - cy) <= r) & (np.abs(xx - cx) <= r)
    elif cls == 4:  # triangle
        m = (yy - cy >= -r) & (yy - cy <= r) & (np.abs(xx - cx) <= (yy - cy + r) // 2)
    elif cls == 5:  # horizontal stripes
        m = ((yy // max(2, r // 2)) % 2 == 0) & (np.abs(yy - cy) <= r) & (np.abs(xx - cx) <= r)
    elif cls == 6:  # diamond
        m = np.abs(yy - cy) + np.abs(xx - cx) <= r
    else:           # checker
        m = (((yy // max(2, r // 2)) + (xx // max(2, r // 2))) % 2 == 0)
        m &= (np.abs(yy - cy) <= r) & (np.abs(xx - cx) <= r)
    img[m] = img[m] * 0.2 + color
    return np.clip(img, -1.0, 2.0)


def toy_images(n: int, hw: int = 32, seed: int = 0,
               n_classes: int = N_TOY_CLASSES) -> tuple:
    rng = np.random.default_rng(seed)
    ys = rng.integers(0, n_classes, n)
    xs = np.stack([_render(int(c), hw, rng) for c in ys])
    return xs.astype(np.float32), ys.astype(np.int32)


def toy_image_iter(batch: int, hw: int = 32, seed: int = 0,
                   n_classes: int = N_TOY_CLASSES):
    i = 0
    while True:
        xs, ys = toy_images(batch, hw, seed + i, n_classes)
        yield xs, ys
        i += 1


def token_batch(batch: int, seq: int, vocab: int, seed: int = 0) -> dict:
    """Zipf-ish stream with learnable bigram structure: next = (5*t+7) % V
    half the time, noise otherwise."""
    rng = np.random.default_rng(seed)
    toks = np.empty((batch, seq + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, batch)
    noise = rng.random((batch, seq))
    rand = rng.integers(0, vocab, (batch, seq))
    for t in range(seq):
        det = (5 * toks[:, t] + 7) % vocab
        toks[:, t + 1] = np.where(noise[:, t] < 0.8, det, rand[:, t])
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def token_iter(batch: int, seq: int, vocab: int, seed: int = 0):
    i = 0
    while True:
        yield token_batch(batch, seq, vocab, seed + i)
        i += 1
