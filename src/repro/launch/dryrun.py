import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) combination, build the production
mesh, attach the sharding rules, ``jit(...).lower(...).compile()`` the
right step function (train_step / prefill / serve_step), and record
``memory_analysis`` + ``cost_analysis`` + the collective schedule parsed
from the post-SPMD HLO.  Results land as JSON under
``results/dryrun/<mesh>/<arch>__<shape>.json`` (incremental: existing
files are skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, input_specs, params_struct, variant_for_shape
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.sharding import rules
from repro.training.optimizer import OptConfig
from repro.training.train import make_train_step, train_state_struct

def build_case(arch: str, shape_name: str, mesh, *, opt_overrides=None,
               optimized: bool = False):
    """Returns (fn, args tuple, in_shardings tuple).

    ``optimized=True`` applies the §Perf hillclimb changes: head->seq
    sharding fallback and the inference weight-sharding profile for decode.
    """
    cfg = variant_for_shape(get_config(arch), SHAPES[shape_name])
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    pstruct = params_struct(cfg)
    profile = "inference" if (optimized and shape.kind == "decode") else "train"
    pspec = rules.param_specs(pstruct, mesh, profile=profile)
    shard_fn = rules.make_shard_fn(mesh, head_seq_fallback=optimized)

    if shape.kind == "train":
        opt_cfg = OptConfig(moment_dtype="bfloat16", master_fp32=False,
                            **(opt_overrides or {}))
        _, ostruct = train_state_struct(cfg, opt_cfg)
        ospec = {"m": pspec, "v": pspec,
                 "t": jax.sharding.PartitionSpec()}
        step = make_train_step(cfg, opt_cfg, shard_fn=shard_fn)
        bspec = rules.batch_specs(specs["batch"], mesh)
        return (step, (pstruct, ostruct, specs["batch"]),
                (pspec, ospec, bspec), (pspec, ospec, None))

    if shape.kind == "prefill":
        def step(params, batch):
            logits, cache, pos = T.prefill(params, cfg, batch, shape.seq_len,
                                           shard_fn=shard_fn)
            return logits, cache
        bspec = rules.batch_specs(specs["batch"], mesh)
        return step, (pstruct, specs["batch"]), (pspec, bspec), None

    # decode
    def step(params, cache, token, pos):
        return T.serve_step(params, cfg, cache, token, pos, shard_fn=shard_fn)
    cspec = rules.cache_specs(specs["cache"], mesh)
    P = jax.sharding.PartitionSpec
    tspec, posspec = rules.batch_specs(specs["token"], mesh), P()
    return (step, (pstruct, specs["cache"], specs["token"], specs["pos"]),
            (pspec, cspec, tspec, posspec), None)


def run_case(arch: str, shape_name: str, *, multi_pod: bool = False,
             outdir: str = "results/dryrun", force: bool = False,
             save_hlo: bool = False, builder=build_case,
             optimized: bool = False) -> dict:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(f"{outdir}/{mesh_tag}", exist_ok=True)
    path = f"{outdir}/{mesh_tag}/{arch}__{shape_name}.json"
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, in_sh, out_sh = (lambda r: (r + (None,) * (4 - len(r))))(
        builder(arch, shape_name, mesh, optimized=optimized))
    in_shardings = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), in_sh,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    out_shardings = None
    if out_sh is not None:
        out_shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), out_sh,
            is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_shardings,
                         out_shardings=out_shardings)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    from repro.launch.hlo_cost import HloCost
    hc = HloCost(hlo)
    by_op = hc.collective_summary()
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "devices": int(len(mesh.devices.flatten())),
        "time_lower_s": round(t_lower, 2), "time_compile_s": round(t_compile, 2),
        # trip-count-corrected per-device costs (see hlo_cost.py; XLA's own
        # cost_analysis counts while bodies once)
        "flops_per_device": hc.flops,
        "bytes_per_device": hc.bytes,
        "xla_flops_per_device_raw": ca.get("flops"),
        "xla_bytes_accessed_raw": ca.get("bytes accessed"),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": (ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
        },
        "collectives": by_op,
        "collective_wire_bytes_total": sum(d["wire_bytes"] for d in by_op.values()),
        "n_collective_sites": len(hc.collectives),
        # HBM bytes inside named kernel-replaceable scopes (flash_attention,
        # wkv_scan): the Pallas kernels keep this traffic in VMEM on TPU
        "scope_bytes": hc.scope_bytes,
    }
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    if save_hlo:
        with open(path.replace(".json", ".hlo.txt"), "w") as f:
            f.write(hlo)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--opt", action="store_true",
                    help="apply §Perf optimizations (writes to --outdir; "
                         "use a distinct outdir to keep baselines)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]
    failures = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{'2x16x16' if mp else '16x16'} {arch:22s} {shape:12s}"
                try:
                    r = run_case(arch, shape, multi_pod=mp, force=args.force,
                                 outdir=args.outdir, save_hlo=args.save_hlo,
                                 optimized=args.opt)
                    print(f"OK   {tag} compile={r['time_compile_s']:7.1f}s "
                          f"flops/dev={r['flops_per_device']:.3e} "
                          f"peak={r['memory']['peak_estimate_bytes']/2**30:.2f}GiB "
                          f"wire={r['collective_wire_bytes_total']/2**20:.1f}MiB",
                          flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
