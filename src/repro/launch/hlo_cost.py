"""Post-SPMD HLO cost analyzer with while-loop trip-count accounting.

XLA's ``compiled.cost_analysis()`` on the host backend counts each while
*body once* (verified empirically — a 10-iteration scan reports 1/10th of
the unrolled FLOPs), which silently destroys the roofline for scanned-layer
models.  This module re-derives per-device costs from ``compiled.as_text()``:

  * builds the computation call graph (while/fusion/reduce/sort/...),
  * multiplies every computation's cost by the product of enclosing while
    trip counts (XLA annotates ``backend_config={"known_trip_count"...}``),
  * FLOPs: dot ops = 2 * |result| * contracted extent (plus a small
    elementwise allowance), convolutions approximated from kernel size,
  * bytes: per top-level instruction, operands + result (fusion interiors
    excluded — a reasonable HBM-traffic proxy, same convention XLA uses),
  * collectives: op, buffer bytes, replica-group size and ring-model wire
    bytes — each multiplied by loop multiplicity.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CALLS_RE = re.compile(
    r"(?:condition|body|calls|to_apply|select|scatter|update_computation)="
    r"%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GRP_EXPL = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GRP_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = DTYPE_BYTES.get(dtype)
    if n is None:
        return 0
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class Instr:
    name: str
    text: str           # full RHS text
    op: str
    result_dtype: str
    result_dims: str
    calls: list = field(default_factory=list)
    trip: int = 1


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)


def parse_module(hlo: str) -> tuple:
    """Returns (computations, entry_name, symtab name->(dtype, dims))."""
    comps = {}
    symtab = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line)
        if mc and not line.startswith("  "):
            cur = Computation(mc.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, rhs = mi.groups()
        # result type is the first shape on the RHS (tuples: take op anyway)
        ms = _SHAPE_RE.search(rhs)
        rdtype, rdims = (ms.group(1), ms.group(2)) if ms else ("", "")
        # op = first identifier immediately followed by '(' (dtypes/layouts
        # never are)
        mop = re.search(r"([a-z][\w\-]*)\(", rhs)
        op = mop.group(1) if mop else "unknown"
        ins = Instr(name, rhs, op, rdtype, rdims)
        ins.calls = _CALLS_RE.findall(rhs)
        mt = _TRIP_RE.search(rhs)
        if mt:
            ins.trip = int(mt.group(1))
        cur.instrs.append(ins)
        symtab[name] = (rdtype, rdims)
    return comps, entry, symtab


_OPND_RE = re.compile(r"%([\w.\-]+)")


def _operand_shapes(ins: Instr, symtab: dict) -> list:
    """(dtype, dims) of each %name operand inside the op's parens."""
    m = re.search(r"[a-z][\w\-]*\((.*)\)", ins.text)
    if not m:
        return []
    args = m.group(1)
    # cut off trailing attrs that sneak into the greedy group
    args = args.split("), ")[0] if ")," in args and "=%" not in args else args
    out = []
    for name in _OPND_RE.findall(args):
        if name in symtab:
            out.append(symtab[name])
    return out


def _dot_flops(ins: Instr, symtab: dict) -> float:
    """2 * |result| * contracted extent, operand shapes via symbol table."""
    opnds = _operand_shapes(ins, symtab)
    if not opnds:
        return 0.0
    lhs_dims = [int(d) for d in opnds[0][1].split(",") if d]
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.text)
    contracted = 1
    if mc and mc.group(1):
        for i in mc.group(1).split(","):
            contracted *= lhs_dims[int(i)]
    return 2.0 * _shape_elems(ins.result_dims) * contracted


def _conv_flops(ins: Instr, symtab: dict) -> float:
    opnds = _operand_shapes(ins, symtab)
    if len(opnds) < 2:
        return 0.0
    rhs_dims = [int(d) for d in opnds[1][1].split(",") if d]
    out = _shape_elems(ins.result_dims)
    # per output element: prod(kernel)/out_channels MACs; assume last kernel
    # dim is the output-feature dim (HWIO default)
    k = 1
    for d in rhs_dims[:-1]:
        k *= d
    return 2.0 * out * k


def _instr_bytes(ins: Instr, symtab: dict) -> float:
    """operands + result bytes (symbol-table resolved)."""
    total = _shape_bytes(ins.result_dtype, ins.result_dims)
    for dt, dims in _operand_shapes(ins, symtab):
        total += _shape_bytes(dt, dims)
    return float(total)


def _wire_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-gather":
        return (n - 1) / n
    if op == "reduce-scatter":
        return float(n - 1)
    if op == "all-reduce":
        return 2 * (n - 1) / n
    if op == "all-to-all":
        return (n - 1) / n
    return 1.0


def _group_size(ins: Instr) -> int:
    me = _GRP_EXPL.search(ins.text)
    if me:
        return len(me.group(1).split(","))
    mi = _GRP_IOTA.search(ins.text)
    if mi:
        return int(mi.group(2))
    return 1


class HloCost:
    def __init__(self, hlo: str):
        self.comps, self.entry, self.symtab = parse_module(hlo)
        self.instr_text = {}
        for c in self.comps.values():
            for i in c.instrs:
                self.instr_text[i.name] = i.text
        self._memo = {}
        self.collectives = []        # filled during analyze
        self.flop_sites = []         # (flops*mult, op_name) per dot site
        # HBM bytes attributed to jax.named_scope regions (e.g. the
        # "flash_attention" fallback whose traffic a Pallas kernel removes)
        self.scope_bytes = {}
        self._analyze()

    def top_flop_sites(self, n: int = 20) -> list:
        """Heaviest matmul sites (flops incl. loop multiplicity, op_name)."""
        return sorted(self.flop_sites, key=lambda t: -t[0])[:n]

    SCOPES = ("flash_attention", "wkv_scan", "mamba_scan")

    def _note_scope(self, ins: Instr, nbytes: float):
        text = ins.text
        if 'op_name="' not in text:
            # metadata-less fusions (e.g. wrapped_reduce-window): inherit
            # the scope of their first scoped operand (one hop)
            for opnd in _OPND_RE.findall(text)[:4]:
                t = self.instr_text.get(opnd, "")
                if 'op_name="' in t:
                    text = t
                    break
        for sc in self.SCOPES:
            if sc in text:
                self.scope_bytes[sc] = self.scope_bytes.get(sc, 0.0) + nbytes
                return

    def _comp_cost(self, name: str, mult: float,
                   inside_fusion: bool = False) -> tuple:
        """(flops, bytes) of computation ``name`` executed ``mult`` times.
        Collectives are appended with their total multiplicity.
        ``inside_fusion``: byte side-effects (scope notes) are suppressed —
        fusion interiors contribute flops only."""
        comp = self.comps.get(name)
        if comp is None:
            return 0.0, 0.0
        flops = bytes_ = 0.0
        for ins in comp.instrs:
            base_op = ins.op.replace("-start", "")
            if ins.op.endswith("-done") or base_op in ("parameter", "constant",
                                                       "tuple", "get-tuple-element",
                                                       "bitcast", "iota"):
                continue
            if base_op == "dot":
                f = _dot_flops(ins, self.symtab)
                flops += f
                b = _instr_bytes(ins, self.symtab)
                bytes_ += b
                if not inside_fusion:
                    self._note_scope(ins, b * mult)
                mo = re.search(r'op_name="([^"]*)"', ins.text)
                self.flop_sites.append((f * mult, mo.group(1) if mo else ins.name))
            elif base_op == "convolution":
                flops += _conv_flops(ins, self.symtab)
                bytes_ += _instr_bytes(ins, self.symtab)
            elif base_op == "while":
                f, b = 0.0, 0.0
                for callee in ins.calls:
                    cf, cb = self._comp_cost(callee, mult * ins.trip)
                    f, b = f + cf, b + cb
                flops += f * ins.trip
                bytes_ += b * ins.trip
                continue
            elif base_op in ("fusion", "call", "conditional", "async-start"):
                for callee in ins.calls:
                    cf, _ = self._comp_cost(callee, mult, inside_fusion=True)
                    flops += cf
                # layout-only fusions (transpose/copy/convert chains) fold
                # into dots or fuse away on the TPU target; the CPU backend
                # materialises them as copies — charging them would
                # overstate TPU HBM traffic (DESIGN.md par.9)
                mo = re.search(r'op_name="([^"]*)"', ins.text)
                last = (mo.group(1).split("/")[-1] if mo else ins.name)
                if last.startswith(("transpose", "convert", "copy")):
                    continue
                b = _instr_bytes(ins, self.symtab)
                bytes_ += b
                if not inside_fusion:
                    self._note_scope(ins, b * mult)
            elif base_op in COLLECTIVES:
                nb = _shape_bytes(ins.result_dtype, ins.result_dims)
                gs = _group_size(ins)
                if base_op == "collective-permute":
                    gs = 2
                self.collectives.append({
                    "op": base_op, "bytes": nb, "group_size": gs,
                    "mult": mult,
                    "wire_bytes": nb * _wire_factor(base_op, gs) * mult,
                })
                bytes_ += _instr_bytes(ins, self.symtab)
            elif base_op in ("gather", "scatter", "dynamic-slice",
                             "dynamic-update-slice", "sort", "reduce",
                             "reduce-window", "concatenate", "pad"):
                # data-movement ops that stay memory ops on TPU
                b = _instr_bytes(ins, self.symtab)
                bytes_ += b
                if not inside_fusion:
                    self._note_scope(ins, b * mult)
            else:
                # elementwise / convert / copy / transpose / broadcast: on
                # the TPU target these fuse into neighbouring dots/fusions,
                # so they contribute flops (1/elem) but no extra HBM trips.
                # (The CPU backend leaves them unfused; charging their
                # buffers would overstate TPU HBM traffic ~100x.)
                if base_op in ("add", "multiply", "subtract", "divide",
                               "exponential", "tanh", "maximum", "minimum",
                               "rsqrt", "power", "log", "select"):
                    flops += _shape_elems(ins.result_dims)
        return flops, bytes_

    def _analyze(self):
        # fusion interiors must not double-count bytes: handled by only
        # charging called-computation *flops* for fusions.  While bodies get
        # both flops and bytes (they run from HBM each iteration).
        self.flops, self.bytes = self._comp_cost(self.entry, 1.0)

    def collective_summary(self) -> dict:
        by_op = {}
        for c in self.collectives:
            d = by_op.setdefault(c["op"], {"count": 0.0, "bytes": 0.0,
                                           "wire_bytes": 0.0})
            d["count"] += c["mult"]
            d["bytes"] += c["bytes"] * c["mult"]
            d["wire_bytes"] += c["wire_bytes"]
        return by_op

    def report(self) -> dict:
        by_op = self.collective_summary()
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes,
            "collectives": by_op,
            "collective_wire_bytes_total": sum(d["wire_bytes"]
                                               for d in by_op.values()),
            "n_collective_sites": len(self.collectives),
        }
