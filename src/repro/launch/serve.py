"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Batched greedy decoding through the ServingEngine (prefill + KV-cache
decode) on a reduced config; --full-size targets a real slice.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.common import reduced
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.batch)]
    engine = ServingEngine(cfg, params,
                           cache_slots=args.prompt_len + args.max_new + 8)
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    for r in done[:4]:
        print(f"req {r.rid}: {r.out}")
    print(f"{total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s on host CPU)")


if __name__ == "__main__":
    main()
