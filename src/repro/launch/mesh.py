"""Production mesh builders.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    """``axis_types`` only exists on newer jax; older versions (<=0.4.x)
    default to auto sharding anyway, so omit it there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh_compat(shape: tuple, axes: tuple):
    """``jax.make_mesh`` with Auto axis types where the jax version has
    them — use this instead of calling ``jax.make_mesh`` directly.  Falls
    back to ``mesh_utils`` + ``Mesh`` on jax versions predating
    ``jax.make_mesh``."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))
    from jax.experimental import mesh_utils
    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)


def make_production_mesh(*, multi_pod: bool = False, model_axis: int = 16):
    """Single pod: (data, model) with data*model = 256 chips (v5e pod).
    Multi-pod prepends pod=2 (512 chips).

    ``model_axis`` is a per-architecture profile knob: the default 16 suits
    128-head-multiple models; archs whose head count is 8-divisible but not
    16-divisible (llama3.2-3b: 24 heads, whisper-tiny: 6) want
    ``model_axis=8`` — on llama3.2-3b x train_4k this cuts per-device peak
    HBM 8.2x and the memory term 13x (EXPERIMENTS.md §Perf iter 6)."""
    assert 256 % model_axis == 0
    data = 256 // model_axis
    shape = (2, data, model_axis) if multi_pod else (data, model_axis)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"), **_mesh_kwargs(2))
