"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On the CPU container this runs reduced configs end-to-end (synthetic token
stream, AdamW, checkpointing); on a real TPU slice the same driver scales
to the production mesh via --mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import token_iter
from repro.models.common import reduced
from repro.sharding import rules
from repro.training import checkpoint
from repro.training.optimizer import OptConfig
from repro.training.train import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (TPU slice) instead of reduced")
    ap.add_argument("--mesh", default=None, choices=[None, "pod", "multipod"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    mesh = None
    shard_fn = None
    if args.mesh:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")
        shard_fn = rules.make_shard_fn(mesh)

    oc = OptConfig(lr=args.lr)
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg, oc)
    step = jax.jit(make_train_step(cfg, oc, shard_fn=shard_fn))
    it = token_iter(args.batch, args.seq, cfg.vocab, seed=0)
    t0 = time.time()
    ctx = mesh or _nullcontext()
    with ctx:
        for i in range(args.steps):
            b = next(it)
            params, opt, m = step(params, opt,
                                  {k: jnp.asarray(v) for k, v in b.items()})
            if i % args.log_every == 0:
                print(f"step {i:5d} loss {float(m['loss']):.4f} "
                      f"({(time.time() - t0):.1f}s)", flush=True)
    print(f"final loss {float(m['loss']):.4f}")
    if args.ckpt:
        checkpoint.save(args.ckpt, params)
        print("saved", args.ckpt)


class _nullcontext:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
