"""Pallas TPU kernel for the RWKV-6 (Finch) WKV recurrence.

Grid: (B*H, n_chunks) with the chunk dimension innermost ("arbitrary"):
the (D_k x D_v) decay state lives in VMEM scratch across chunks, and the
per-timestep recurrence runs as a ``fori_loop`` over the chunk.  Memory
traffic is therefore one read of r/k/v/w and one write of out per token —
the state never visits HBM (the lax.scan reference spills it every step
on the XLA side unless fused).

Head dims are VPU-lane-aligned (64).  Validated against
``ref.rwkv6_scan_ref`` in interpret mode over shape and chunk sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compiler_params():
    cp = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    return cp(dimension_semantics=("parallel", "arbitrary"))


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, sf_ref, state, *,
            chunk: int, n_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    u = u_ref[0]                                 # (D,)

    def step(t, s):
        rt = r_ref[0, t].astype(jnp.float32)     # (D,)
        kt = k_ref[0, t].astype(jnp.float32)
        vt = v_ref[0, t].astype(jnp.float32)
        wt = w_ref[0, t].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]           # (Dk, Dv)
        out = jnp.sum(rt[:, None] * (s + u[:, None] * kv), axis=0)
        o_ref[0, t] = out.astype(o_ref.dtype)
        return wt[:, None] * s + kv

    state[...] = jax.lax.fori_loop(0, chunk, step, state[...])

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        sf_ref[0] = state[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,w: (B,S,H,D) f32; u: (H,D). Returns (out (B,S,H,D), state (B,H,D,D))."""
    b, s, h, d = r.shape
    chunk_ = min(chunk, s)
    assert s % chunk_ == 0
    nc = s // chunk_

    def bh(x):  # (B,S,H,D) -> (B*H, S, D)
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    uu = jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, d)
    kernel = functools.partial(_kernel, chunk=chunk_, n_chunks=nc)
    out, state = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk_, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk_, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk_, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk_, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, d), lambda i, c: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk_, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, d, d), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, d), r.dtype),
            jax.ShapeDtypeStruct((b * h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(bh(r), bh(k), bh(v), bh(w), uu)
    return (out.reshape(b, h, s, d).transpose(0, 2, 1, 3),
            state.reshape(b, h, d, d))
