"""Jit'd dispatch wrappers: Pallas kernel on TPU, reference elsewhere.

The models call these ops; on the CPU container the reference (pure-jnp)
path runs and the Pallas bodies are exercised via ``interpret=True`` in
tests.  ``force`` overrides for testing ('pallas-interpret' runs the real
kernel body emulated on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax

from . import ref
from .bottleneck_compress import bottleneck_compress
from .flash_attention import flash_attention
from .mamba_scan import mamba_scan
from .rwkv6_scan import rwkv6_scan


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def attention_op(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                 force: Optional[str] = None):
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window)
    if mode == "pallas-interpret":
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=True)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def compress_op(f, w, b, *, force: Optional[str] = None):
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "pallas":
        return bottleneck_compress(f, w, b)
    if mode == "pallas-interpret":
        return bottleneck_compress(f, w, b, interpret=True)
    return ref.bottleneck_compress_ref(f, w, b)


def decompress_op(q, s):
    return ref.bottleneck_decompress_ref(q, s)


def wkv_op(r, k, v, w, u, *, chunk: int = 64, force: Optional[str] = None):
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "pallas":
        return rwkv6_scan(r, k, v, w, u, chunk=chunk)
    if mode == "pallas-interpret":
        return rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    import jax.numpy as jnp
    b, _, h, d = r.shape
    return ref.rwkv6_scan_ref(r, k, v, w, u, jnp.zeros((b, h, d, d), jnp.float32))


def mamba_scan_op(dt, b, c, x, a, *, force=None):
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "pallas":
        return mamba_scan(dt, b, c, x, a)
    if mode == "pallas-interpret":
        return mamba_scan(dt, b, c, x, a, interpret=True)
    return ref.mamba_scan_ref(dt, b, c, x, a)
