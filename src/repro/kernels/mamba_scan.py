"""Pallas TPU kernel for the Mamba (S6) selective-state-space scan.

The XLA fallback materialises the discretised operands dA = exp(dt*A) and
dBx = dt*B*x as (B, chunk, d_inner, d_state) tensors in HBM per chunk —
the dominant memory term of jamba-v0.1-52b in the roofline table.  This
kernel fuses discretisation + recurrence: it reads only dt (B,S,di),
B/C (B,S,ds), x (B,S,di) and A (di,ds) from HBM, keeps the (bd, ds) state
and all discretised quantities in VMEM, and writes y (B,S,di) — HBM
traffic drops from O(S·di·ds) to O(S·(di+ds)), a ~d_state (16x) cut.

Grid: (batch, di_blocks, chunks) with chunks innermost ("arbitrary") so
the state scratch persists; di is blocked to keep (bd, ds) + operand
tiles inside VMEM (bd=512 -> ~0.6 MB scratch at ds=16).

Validated against ``ref.mamba_scan_ref`` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compiler_params():
    cp = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    return cp(dimension_semantics=("parallel", "parallel", "arbitrary"))


def _kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, y_ref, state, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    a = a_ref[...]                                   # (bd, ds)

    def step(t, h):
        dt = dt_ref[0, t].astype(jnp.float32)        # (bd,)
        bt = b_ref[0, t].astype(jnp.float32)         # (ds,)
        ct = c_ref[0, t].astype(jnp.float32)         # (ds,)
        xt = x_ref[0, t].astype(jnp.float32)         # (bd,)
        dA = jnp.exp(dt[:, None] * a)                # (bd, ds) — in VMEM only
        h = dA * h + (dt * xt)[:, None] * bt[None, :]
        y_ref[0, t] = jnp.sum(h * ct[None, :], axis=1).astype(y_ref.dtype)
        return h

    state[...] = jax.lax.fori_loop(0, chunk, step, state[...])


@functools.partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def mamba_scan(dt: jax.Array, b: jax.Array, c: jax.Array, x: jax.Array,
               a: jax.Array, *, chunk: int = 128, bd: int = 512,
               interpret: bool = False) -> jax.Array:
    """Selective scan: y[t] = C[t]·h[t],  h[t] = exp(dt[t]A)h[t-1] + dt[t]B[t]x[t].

    dt, x: (B,S,di) f32;  b, c: (B,S,ds) f32;  a: (di,ds) f32 (negative).
    Returns y (B,S,di) f32.  (The D-skip and gating stay outside — they are
    elementwise and fuse on their own.)
    """
    bsz, s, di = dt.shape
    ds = b.shape[-1]
    bd_ = min(bd, di)
    assert di % bd_ == 0
    chunk_ = min(chunk, s)
    assert s % chunk_ == 0
    nd, nc = di // bd_, s // chunk_

    kernel = functools.partial(_kernel, chunk=chunk_)
    y = pl.pallas_call(
        kernel,
        grid=(bsz, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk_, bd_), lambda i, j, k: (i, k, j)),   # dt
            pl.BlockSpec((1, chunk_, ds), lambda i, j, k: (i, k, 0)),    # B
            pl.BlockSpec((1, chunk_, ds), lambda i, j, k: (i, k, 0)),    # C
            pl.BlockSpec((1, chunk_, bd_), lambda i, j, k: (i, k, j)),   # x
            pl.BlockSpec((bd_, ds), lambda i, j, k: (j, 0)),             # A
        ],
        out_specs=pl.BlockSpec((1, chunk_, bd_), lambda i, j, k: (i, k, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd_, ds), jnp.float32)],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(dt, b, c, x, a)
    return y
