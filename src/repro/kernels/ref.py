"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,Sk,K,D) GQA. Plain softmax attention."""
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    qp = jnp.arange(sq)[:, None] + (sk - sq)   # aligned last positions
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def bottleneck_compress_ref(f, w, b, *, scale: float = 127.0):
    """Fused encoder projection + symmetric int8 wire quantisation.

    f: (N, C) activations; w: (C, L); b: (L,).
    Returns (q_int8 (N, L), per_row_scale (N, 1) f32).
    """
    z = jax.nn.relu(f.astype(jnp.float32) @ w.astype(jnp.float32)
                    + b.astype(jnp.float32))
    amax = jnp.max(jnp.abs(z), axis=-1, keepdims=True)
    s = jnp.where(amax > 0, amax / scale, 1.0)
    q = jnp.clip(jnp.round(z / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def bottleneck_decompress_ref(q, s):
    return q.astype(jnp.float32) * s


def bottleneck_decode_ref(q, s, w, b):
    """Fused wire dequantisation + AE-decoder projection (the mirror of
    :func:`bottleneck_compress_ref` on the receiving stage).

    q: (N, L) int8 wire codes; s: (N, 1) f32 row scales; w: (L, C); b: (C,).
    Returns the reconstructed boundary activation f32 (N, C).
    """
    z = q.astype(jnp.float32) * s.astype(jnp.float32)
    return z @ w.astype(jnp.float32) + b.astype(jnp.float32)


def rwkv6_scan_ref(r, k, v, w, u, state):
    """Sequential WKV-6 recurrence (B,S,H,D) f32; u (H,D); state (B,H,D,D).

    out_t = r_t . (S + u*k_t v_t^T);  S <- diag(w_t) S + k_t v_t^T
    """
    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[..., None] * kv)
        s = wt[..., None] * s + kv
        return s, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1), state


def mamba_scan_ref(dt, b, c, x, a):
    """Sequential selective scan (B,S,di)/(B,S,ds) f32 -> y (B,S,di)."""
    bsz, s, di = dt.shape
    ds = b.shape[-1]

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp
        dA = jnp.exp(dt_t[..., None] * a)                    # (B,di,ds)
        h = dA * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (dt, b, c, x))
    h0 = jnp.zeros((bsz, di, ds), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)
