"""Pallas TPU kernel for the split-point wire decompression — the
stage-prologue mirror of ``bottleneck_compress``.

On the receiving stage the int8 wire payload must become the boundary
activation again: dequantise (per-row scale) and apply the bottleneck
AE-decoder projection.  Run eagerly that is two dispatches with an f32
latent round-tripping through HBM between them; fused, the latent lives
only in VMEM and the kernel writes the reconstructed activation directly
— which lets ``runtime.partition`` compose it with the next stage's
layers into one jitted callable (decode as the stage prologue).

Grid: (n_tiles, c_tiles) over the *output* (N, C); the contraction over
the latent L is undercomplete by construction (L = rate * C, rate <= 1)
so a whole (L, bc) decoder slab fits in VMEM and each block is one
dequant + one MXU matmul — no accumulation scratch needed.  Tiles are
MXU-aligned (128).

Validated against ``ref.bottleneck_decode_ref`` in interpret mode; the
backend contract (auto -> kernel on TPU, pure-JAX ref elsewhere) is
shared with the compress side via ``bottleneck_compress.resolve_backend``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bottleneck_compress import _compiler_params, _pad_to, resolve_backend


def _kernel(q_ref, s_ref, w_ref, b_ref, o_ref):
    z = q_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    o_ref[...] = (jax.lax.dot(z, w_ref[...].astype(jnp.float32))
                  + b_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("bn", "bc", "interpret"))
def bottleneck_decompress(q: jax.Array, s: jax.Array, w: jax.Array,
                          b: jax.Array, *, bn: int = 128, bc: int = 512,
                          interpret: bool = False) -> jax.Array:
    """q: (N, L) int8 codes; s: (N, 1) f32 row scales; w: (L, C); b: (C,).

    Returns the reconstructed f32 boundary activation (N, C).
    """
    n, l = q.shape
    c = w.shape[1]
    bn_, bc_ = min(bn, n), min(bc, c)
    assert n % bn_ == 0 and c % bc_ == 0
    nn, nc = n // bn_, c // bc_

    return pl.pallas_call(
        _kernel,
        grid=(nn, nc),
        in_specs=[
            pl.BlockSpec((bn_, l), lambda i, j: (i, 0)),
            pl.BlockSpec((bn_, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((l, bc_), lambda i, j: (0, j)),
            pl.BlockSpec((bc_,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bn_, bc_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.float32),
        compiler_params=_compiler_params(("parallel", "parallel")),
        interpret=interpret,
    )(q, s, w, b)


def bottleneck_decompress_any(q: jax.Array, s: jax.Array, w: jax.Array,
                              b: jax.Array, *, backend: str | None = None,
                              bn: int = 128, bc: int = 512) -> jax.Array:
    """Shape-flexible, backend-routed decode: the runtime's entry point.

    Accepts codes with any leading dims ``(..., L)`` and scales
    ``(..., 1)``; pads N up to the kernel's row-tile multiple (zero rows
    decode to the bias and are dropped) and the output channels C up to
    the lane tile (extra decoder columns are zero and sliced off), and
    routes per :func:`resolve_backend` — the Pallas kernel on TPU, the
    jnp reference otherwise — so the exact same activation is
    reconstructed on every host.

    Returns the boundary activation f32 ``(..., C)``.
    """
    from . import ref as _ref

    lead = q.shape[:-1]
    l = q.shape[-1]
    c = w.shape[1]
    q2 = q.reshape(-1, l)
    s2 = s.reshape(-1, 1)
    n = q2.shape[0]
    mode = resolve_backend(backend)
    if mode == "ref":
        f = _ref.bottleneck_decode_ref(q2, s2, w, b)
    else:
        np_ = _pad_to(n, bn) if n > bn and n % bn else n
        cp = _pad_to(c, bc) if c > bc and c % bc else c
        qp = jnp.zeros((np_, l), q2.dtype).at[:n].set(q2)
        sp = jnp.ones((np_, 1), jnp.float32).at[:n].set(s2)
        wp = jnp.zeros((l, cp), w.dtype).at[:, :c].set(w)
        bp = jnp.zeros((cp,), b.dtype).at[:c].set(b)
        f = bottleneck_decompress(qp, sp, wp, bp, bn=bn, bc=bc,
                                  interpret=(mode == "interpret"))
        f = f[:n, :c]
    return f.reshape(lead + (c,))
