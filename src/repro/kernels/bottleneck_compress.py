"""Pallas TPU kernel for the split-point wire compression (DESIGN.md §3).

This is Split-Et-Impera's hot op: at the head/tail boundary the bottleneck
encoder projects the activation to the undercomplete latent and the result
is quantised to int8 for the wire (edge->server network, or the cross-pod
``ppermute`` in the multi-pod mapping).  Fusing projection + ReLU +
per-row amax + quantisation in one kernel means the f32 latent never
round-trips through HBM — only the int8 payload and one scale per row
leave VMEM.

Grid: (n_tiles, c_tiles); the contraction over input channels C is the
innermost ("arbitrary") dimension accumulating into a VMEM f32 scratch;
the final contraction step applies ReLU, computes the row-wise amax and
writes the int8 block.  Tiles are MXU-aligned (128).

Validated against ``ref.bottleneck_compress_ref`` in interpret mode.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def tpu_available() -> bool:
    """True when the default backend is a real TPU (not interpret mode)."""
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


def resolve_backend(backend: str | None = None) -> str:
    """Pick the execution path for the compress op.

    ``backend``: 'auto' | 'kernel' | 'interpret' | 'ref' (or None = env
    ``REPRO_BOTTLENECK_BACKEND``, default 'auto').  'auto' compiles the
    Pallas kernel on TPU and uses the pure-JAX reference everywhere else,
    so the runtime/CI can call this op on any host; 'interpret' forces the
    Pallas interpreter (kernel-logic validation on CPU).
    """
    backend = backend or os.environ.get("REPRO_BOTTLENECK_BACKEND", "auto")
    if backend not in ("auto", "kernel", "interpret", "ref"):
        raise ValueError(f"unknown bottleneck backend {backend!r}")
    if backend == "auto":
        return "kernel" if tpu_available() else "ref"
    return backend


def _compiler_params(semantics: tuple = ("parallel", "arbitrary")):
    cp = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    return cp(dimension_semantics=semantics)


def _kernel(f_ref, w_ref, b_ref, q_ref, s_ref, acc, *, nc: int, scale: float):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    f = f_ref[...].astype(jnp.float32)          # (bn, bc)
    w = w_ref[...].astype(jnp.float32)          # (bc, L)
    acc[...] += jax.lax.dot(f, w)

    @pl.when(ic == nc - 1)
    def _finish():
        z = jax.nn.relu(acc[...] + b_ref[...].astype(jnp.float32))
        amax = jnp.max(jnp.abs(z), axis=1, keepdims=True)
        s = jnp.where(amax > 0, amax / scale, 1.0)
        q_ref[...] = jnp.clip(jnp.round(z / s), -127, 127).astype(jnp.int8)
        s_ref[...] = s.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bn", "bc", "interpret"))
def bottleneck_compress(f: jax.Array, w: jax.Array, b: jax.Array, *,
                        bn: int = 128, bc: int = 512,
                        interpret: bool = False):
    """f: (N, C) activations; w: (C, L); b: (L,).

    Returns (q int8 (N, L), row scales f32 (N, 1)) — the wire payload.
    """
    n, c = f.shape
    l = w.shape[1]
    bn_, bc_ = min(bn, n), min(bc, c)
    assert n % bn_ == 0 and c % bc_ == 0
    nn, nc = n // bn_, c // bc_

    kernel = functools.partial(_kernel, nc=nc, scale=127.0)
    q, s = pl.pallas_call(
        kernel,
        grid=(nn, nc),
        in_specs=[
            pl.BlockSpec((bn_, bc_), lambda i, j: (i, j)),
            pl.BlockSpec((bc_, l), lambda i, j: (j, 0)),
            pl.BlockSpec((l,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn_, l), lambda i, j: (i, 0)),
            pl.BlockSpec((bn_, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, l), jnp.int8),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bn_, l), jnp.float32)],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(f, w, b)
    return q, s


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def bottleneck_compress_any(f: jax.Array, w: jax.Array, b: jax.Array, *,
                            backend: str | None = None,
                            bn: int = 128, bc: int = 512):
    """Shape-flexible, backend-routed compress: the runtime's entry point.

    Accepts activations with any leading dims ``(..., C)``; pads N/C up to
    the kernel's tile multiples (zero rows quantise to zero and are
    dropped), and routes per :func:`resolve_backend` — the Pallas kernel on
    TPU, the jnp reference otherwise — so the exact same int8 wire payload
    is produced on every host.

    Returns ``(q int8 (..., L), scales f32 (..., 1))``.
    """
    from . import ref as _ref

    lead = f.shape[:-1]
    c = f.shape[-1]
    l = w.shape[1]
    f2 = f.reshape(-1, c)
    n = f2.shape[0]
    mode = resolve_backend(backend)
    if mode == "ref":
        q, s = _ref.bottleneck_compress_ref(f2, w, b)
    else:
        np_, cp = n, c
        if n > bn and n % bn:
            np_ = _pad_to(n, bn)
        if c > bc and c % bc:
            cp = _pad_to(c, bc)
        fp = jnp.zeros((np_, cp), f2.dtype).at[:n, :c].set(f2)
        wp = jnp.zeros((cp, l), w.dtype).at[:c].set(w)
        q, s = bottleneck_compress(fp, wp, b, bn=bn, bc=bc,
                                   interpret=(mode == "interpret"))
        q, s = q[:n], s[:n]
    return q.reshape(lead + (l,)), s.reshape(lead + (1,))
