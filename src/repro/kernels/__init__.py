"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles."""
from . import ops, ref                               # noqa: F401
