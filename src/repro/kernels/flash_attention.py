"""Pallas TPU flash-attention kernel (causal / sliding-window, GQA).

Grid: (batch*heads, q_blocks, kv_blocks) with the kv dimension innermost
("arbitrary" semantics) so the online-softmax state (m, l, acc) lives in
VMEM scratch across kv steps.  Blocks are MXU-aligned (multiples of 128 in
the seq dims, head_dim 64/128).  Fully-masked kv blocks are skipped with
``pl.when`` — on TPU this converts causal masking into a real 2x FLOP
saving, which the pure-jnp flash path in ``repro.models.layers`` does not
get (see EXPERIMENTS.md §Perf).

Validated in interpret mode against ``ref.flash_attention_ref`` over shape,
dtype, GQA-ratio and window sweeps (tests/test_kernels_flash.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _compiler_params():
    cp = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    return cp(dimension_semantics=("parallel", "parallel", "arbitrary"))


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            bq: int, bk: int, sq: int, sk: int, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    iq = pl.program_id(1)
    # absolute positions; queries occupy the LAST sq slots of the sk range
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level skip: is any (q,k) pair in this tile live?
    lo_q, hi_q = iq * bq + (sk - sq), iq * bq + bq - 1 + (sk - sq)
    lo_k = ik * bk
    live = True
    if causal:
        live = jnp.asarray(lo_k <= hi_q)
    if window is not None:
        live = jnp.logical_and(live, jnp.asarray(lo_k + bk - 1 > lo_q - window))

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                    # (bk, d)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,Sk,K,D) with H % K == 0. Returns (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    bq_, bk_ = min(bq, sq), min(bk, sk)
    assert sq % bq_ == 0 and sk % bk_ == 0
    nq, nk = sq // bq_, sk // bk_
    scale = 1.0 / math.sqrt(d)

    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kh, sk, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kh, sk, d)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, bq=bq_, bk=bk_, sq=sq, sk=sk,
                               nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk_, d), lambda bh, iq, ik: (bh // g, ik, 0)),
            pl.BlockSpec((1, bk_, d), lambda bh, iq, ik: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, d), jnp.float32),
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
