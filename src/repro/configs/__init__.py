"""Config registry: ``get_config("<arch-id>")`` for the 10 assigned archs."""
from __future__ import annotations

import importlib

ARCHS = {
    "llama3.2-3b": "llama3_2_3b",
    "command-r-35b": "command_r_35b",
    "internvl2-76b": "internvl2_76b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-tiny": "whisper_tiny",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen2-72b": "qwen2_72b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama3-8b": "llama3_8b",
}


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return mod.CONFIG


def all_configs() -> dict:
    return {name: get_config(name) for name in ARCHS}
