"""whisper-tiny [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a stub: ``input_specs``
provides precomputed frame embeddings (B, 1500, 384).  Encoder (4L,
learned positions) + decoder (4L, self-attn KV cache + cross-attn cache)
are fully implemented.  Assigned decode seq-lens exceed Whisper's real
448-token context; the backbone honours them (DESIGN.md §4).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    head_dim=64, d_ff=1536, vocab=51865,
    rope_theta=10000.0, qkv_bias=True,
    n_enc_layers=4, n_frames=1500, d_frontend=384,
    source="arXiv:2212.04356",
)
