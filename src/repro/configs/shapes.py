"""The four assigned input shapes + ShapeDtypeStruct stand-ins for dry-runs.

``input_specs`` builds allocation-free inputs for every (arch x shape)
combination — the same pattern the brief describes: weak-type-correct,
shardable, no device memory touched.  Decode shapes produce the arguments
of ``serve_step`` (one token + a seq_len KV cache); train/prefill produce
full-sequence batches.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ShapeConfig
from repro.models import transformer as T

SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode"),
}

# Beyond-paper variant that makes long_500k runnable for full-attention
# families (DESIGN.md §4): ring-buffer sliding-window attention.
LONG_CONTEXT_WINDOW = 8192


def variant_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Architecture variant actually lowered for this shape."""
    if shape.name == "long_500k" and cfg.family not in ("ssm",):
        # hybrid keeps full attention on its 4 attn layers (native-ish long
        # context); all pure-attention families get the sliding window.
        if cfg.family != "hybrid":
            return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """VLM splits the sequence budget between patches and text."""
    if cfg.family == "vlm":
        return seq_len - cfg.n_patches
    return seq_len


def batch_struct(cfg: ModelConfig, shape: ShapeConfig, *, with_labels: bool) -> dict:
    b, s = shape.global_batch, shape.seq_len
    st = text_len(cfg, s)
    batch = {"tokens": _sds((b, st), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = _sds((b, cfg.n_patches, cfg.d_frontend), cfg.jdtype)
    if cfg.family == "encdec":
        batch["frames"] = _sds((b, cfg.n_frames, cfg.d_frontend), cfg.jdtype)
    if with_labels:
        batch["labels"] = _sds((b, st), jnp.int32)
    return batch


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for the step function selected by ``shape.kind``.

    train  -> {"batch": {...}}                              (train_step)
    prefill-> {"batch": {...}}                              (prefill_step)
    decode -> {"cache": ..., "token": ..., "pos": ...}      (serve_step)
    """
    cfg = variant_for_shape(cfg, shape)
    if shape.kind == "train":
        return {"batch": batch_struct(cfg, shape, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": batch_struct(cfg, shape, with_labels=False)}
    # decode: cache at seq_len occupancy, one new token
    b = shape.global_batch
    cache = jax.eval_shape(lambda: T.init_cache(cfg, b, shape.seq_len))
    return {
        "cache": cache,
        "token": _sds((b, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def params_struct(cfg: ModelConfig) -> dict:
    """Abstract parameter pytree (no allocation)."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: T.init_params(k, cfg), key)
