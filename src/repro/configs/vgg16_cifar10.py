"""The paper's own model: VGG16 (+ reduced CPU-trainable variant).

Not a transformer config — exposes the LayeredModel builders used by the
Split-Et-Impera core experiments (Figs. 2-4, Tables I-II).
"""
from repro.models.vgg import build_vgg, vgg16, vgg_cifar  # noqa: F401

# Paper training hyperparameters (§V)
TRAIN = dict(epochs=20, lr=5e-3, optimizer="adam")
BOTTLENECK_TRAIN = dict(epochs=50, lr=5e-4, optimizer="adam", compression=0.5)
