"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts
[arXiv:2401.06066].  Deviation: the reference model's first layer is a dense
FFN; we keep all 28 layers MoE for a uniform scan stack (the 2 shared
experts provide the dense path) — noted in DESIGN.md.
"""
from repro.models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    head_dim=128, d_ff=1408, vocab=102400,
    rope_theta=10000.0, qkv_bias=False,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
    source="arXiv:2401.06066",
)
