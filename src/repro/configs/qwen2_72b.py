"""qwen2-72b [dense] — GQA with QKV bias [arXiv:2407.10671]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    head_dim=128, d_ff=29568, vocab=152064,
    rope_theta=1000000.0, qkv_bias=True,
    source="arXiv:2407.10671",
)
