"""internvl2-76b [vlm] — InternViT(stub) + InternLM2 backbone [arXiv:2404.16821].

The InternViT-6B vision tower is a stub per the brief: ``input_specs``
delivers pre-extracted patch embeddings (B, 256, 3200); the 2-layer MLP
projector + 80-layer language decoder are fully implemented.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    head_dim=128, d_ff=28672, vocab=128256,
    rope_theta=1000000.0, qkv_bias=False,
    n_patches=256, d_frontend=3200,
    source="arXiv:2404.16821",
)
