"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
every 2nd layer [arXiv:2403.19887]."""
from repro.models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    head_dim=128, d_ff=14336, vocab=65536,
    qkv_bias=False, rope_theta=10000.0,
    attn_period=8, attn_index=4,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336, moe_every=2),
    source="arXiv:2403.19887",
)
