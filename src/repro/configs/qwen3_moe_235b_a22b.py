"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family]."""
from repro.models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    head_dim=128, d_ff=1536, vocab=151936,
    rope_theta=1000000.0, qkv_bias=False,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536),
    source="hf:Qwen/Qwen3-30B-A3B (235B-A22B sibling)",
)
