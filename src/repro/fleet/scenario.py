"""Drift and fault injection for the adaptive control loop.

A :class:`RegimeChangeTrace` is a fleet workload whose statistics *move*:
phases of different arrival rates/patterns spliced into one
:class:`~repro.fleet.traffic.Trace` (``Trace.concat`` / ``Trace.slice``
do the splicing with provenance preserved), plus scheduled faults —
link degradations (the channel a device class sits behind is replaced at
a simulated time, via ``netsim.channel.ChannelSchedule``) and replica
fail/recover events (the serving pool shrinks and grows).

The scenario is pure data: the adaptive controller
(``fleet.controller``) consumes it with either cluster engine, and
``schedule_faults`` wires the same events onto a live ``ClusterSim``'s
event queue for event-engine studies (``ClusterSim.set_replicas`` applies
replica events in place; link changes fire a callback).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from repro.fleet.traffic import DeviceClass, Trace, generate_trace
from repro.netsim.channel import ChannelSchedule, degrade


@dataclass(frozen=True)
class Phase:
    """One stationary stretch of a regime-change workload."""
    duration_s: float
    rate_hz: float
    pattern: str = "poisson"
    kw: tuple = ()                   # pattern kwargs as sorted items

    def kwargs(self) -> dict:
        return dict(self.kw)


@dataclass(frozen=True)
class LinkDegradation:
    """At ``t_s`` the named device class's channel degrades (or is
    restored: factors of 1.0 / loss_add 0.0 with a later event).
    ``device=None`` applies to every class."""
    t_s: float
    capacity_factor: float = 1.0
    latency_factor: float = 1.0
    loss_add: float = 0.0
    device: Optional[str] = None


@dataclass(frozen=True)
class ReplicaEvent:
    """At ``t_s`` the serving pool gains (``delta > 0``, recovery) or
    loses (``delta < 0``, failure) replicas."""
    t_s: float
    delta: int


@dataclass(frozen=True)
class RegimeChangeTrace:
    """A spliced multi-phase trace plus its scheduled faults.

    ``boundaries`` holds each phase's start time (first is 0.0);
    ``replica_pool`` is the total replicas physically available before
    any failure (``None`` = unconstrained).
    """
    trace: Trace
    mix: tuple                       # DeviceClass population
    boundaries: tuple = (0.0,)
    link_events: tuple = ()          # LinkDegradation, sorted by t_s
    replica_events: tuple = ()       # ReplicaEvent, sorted by t_s
    replica_pool: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "link_events",
                           tuple(sorted(self.link_events,
                                        key=lambda e: e.t_s)))
        object.__setattr__(self, "replica_events",
                           tuple(sorted(self.replica_events,
                                        key=lambda e: e.t_s)))

    @property
    def horizon_s(self) -> float:
        return self.trace.horizon_s

    @classmethod
    def from_phases(cls, mix: Sequence[DeviceClass],
                    phases: Sequence[Phase], *, seed: int = 0,
                    link_events=(), replica_events=(),
                    replica_pool: Optional[int] = None
                    ) -> "RegimeChangeTrace":
        """Build the spliced trace: one ``generate_trace`` per phase
        (seeded ``seed + i`` so phases are independently reproducible),
        sliced to the phase duration and concatenated in order."""
        if not phases:
            raise ValueError("need at least one phase")
        parts, bounds, t = [], [], 0.0
        for i, ph in enumerate(phases):
            # overdraw ~25% so the generated horizon covers duration_s,
            # then cut exactly at the boundary
            n = max(1, int(ph.rate_hz * ph.duration_s * 1.25) + 8)
            part = generate_trace(mix, n, ph.rate_hz, pattern=ph.pattern,
                                  seed=seed + i, **ph.kwargs())
            parts.append(part.slice(0.0, ph.duration_s))
            bounds.append(t)
            t += ph.duration_s
        trace = parts[0]
        for p in parts[1:]:
            trace = trace.concat(p)
        return cls(trace, tuple(mix), tuple(bounds), tuple(link_events),
                   tuple(replica_events), replica_pool)

    # ----------------------------------------------------- link regimes ----
    def channel_schedule(self, device: DeviceClass) -> ChannelSchedule:
        """The device's channel as a time-indexed schedule: each
        matching :class:`LinkDegradation` replaces the channel with a
        degraded copy *of the base channel* (events are absolute
        regimes, so a later event with unit factors restores the
        link)."""
        events = []
        for ev in self.link_events:
            if ev.device is not None and ev.device != device.name:
                continue
            events.append((ev.t_s, degrade(
                device.channel, capacity_factor=ev.capacity_factor,
                latency_factor=ev.latency_factor, loss_add=ev.loss_add)))
        return ChannelSchedule(device.channel, tuple(events))

    def devices_at(self, t: float) -> tuple:
        """The device mix with each class behind its channel regime
        active at simulated time ``t``."""
        from dataclasses import replace as _replace
        out = []
        for d in self.mix:
            ch = self.channel_schedule(d).at(t)
            out.append(d if ch is d.channel else _replace(d, channel=ch))
        return tuple(out)

    def available_replicas(self, t: float,
                           initial: Optional[int] = None) -> Optional[int]:
        """Replicas physically available at ``t``: the pool plus every
        fail/recover delta so far (``None`` = unconstrained and no
        failure ever applies a cap)."""
        pool = self.replica_pool if initial is None else initial
        if pool is None:
            return None
        for ev in self.replica_events:
            if ev.t_s <= t:
                pool += ev.delta
        return max(1, pool)

    def events_between(self, t0: float, t1: float) -> list:
        """All fault events with ``t0 < t_s <= t1``, time-ordered — what
        the controller sees when it wakes at ``t1`` having last looked
        at ``t0``."""
        evs = [e for e in self.link_events if t0 < e.t_s <= t1]
        evs += [e for e in self.replica_events if t0 < e.t_s <= t1]
        return sorted(evs, key=lambda e: e.t_s)


def schedule_faults(scenario: RegimeChangeTrace, sim,
                    on_link_change=None) -> list:
    """Wire the scenario's faults onto a live ``ClusterSim``: replica
    events apply in place via ``sim.set_replicas`` as the queue reaches
    them, link changes invoke ``on_link_change(t, device_name, channel)``
    (the cluster itself never prices wires — the embedder re-prices its
    flows).  Returns the scheduled event handles."""
    handles = []
    for ev in scenario.replica_events:
        def _apply(delta=ev.delta):
            sim.set_replicas(max(1, sim.n_replicas + delta))
        handles.append(sim.q.schedule_named(ev.t_s, _apply,
                                            "replica-event"))
    if on_link_change is not None:
        for d in scenario.mix:
            sched = scenario.channel_schedule(d)
            handles += sched.schedule_on(
                sim.q, lambda t, ch, name=d.name: on_link_change(t, name, ch))
    return handles
