"""Vectorized arrival-level cluster engine — the fleet-scale fast path.

``repro.fleet.cluster.ClusterSim`` steps one Python event per request,
which is the right *semantic authority* but tops out around thousands of
clients.  This module replays the exact same admission-queue + dynamic-
batching + replica state machine over whole NumPy arrival arrays:

* arrivals are sorted once and admitted in *runs* (every arrival between
  two state-changing events is one ``searchsorted`` slice, with drops
  decided by a queue-headroom count, not per-request branches);
* replica availability is a running k-server assignment (a k-entry heap
  of done-times — never one heap entry per request);
* the saturated regime (window overdue, all replicas busy, a full batch
  plus spare waiting) collapses to a closed form: dispatch times follow
  the max-plus cadence ``d_j = h_sorted[j mod k] + floor(j/k) * svc_B``,
  per-arrival dispatch counts broadcast over the k arithmetic
  progressions, and the drop decision ``A_{i+1} = min(A_i + 1, H_i)``
  (``H_i`` = queue headroom, a non-decreasing prefix quantity) is solved
  loop-free with ``np.minimum.accumulate`` — the queue-depth prefix
  scan.  Whole saturated stretches commit in O(arrivals/CHUNK) python
  iterations.

With the stock :class:`~repro.serving.engine.BatchCostModel` service
times are a deterministic function of batch size, so the replay is
*exact*: identical drop decisions, batch boundaries, and dispatch/done
times (modulo float accumulation order — see :data:`PCTL_RTOL`).  The
``check_event_engine=True`` path re-runs the event engine on the same
offers and asserts drop counts / batch counts match exactly and latency
percentiles agree within the documented tolerance.  That is the PR-5
screen/refine contract applied to the cluster: this engine screens,
``ClusterSim`` stays the single semantic authority and refines
survivors (see ``DeploymentPlanner.search(engine=...)``).

For the overload regime where per-request identity stops mattering,
:func:`fluid_cluster_stats` integrates a mean-field fluid (binned
Lindley) recurrence instead — O(n_bins) memory and time, approximate by
construction, selected by ``mode="auto"`` only under gross sustained
overload.

Stats come back on the same ``ClusterStats`` surface (``percentile``,
``drop_fraction``, ``mean_batch``, ``utilization``) as
:class:`VectorClusterStats` (per-request NumPy arrays, offer order) or,
with ``streaming=True``, :class:`StreamingClusterStats` — a fixed-bucket
histogram instead of per-request records, so retained memory stays
O(histogram) at 10^6+ requests.  When an enabled recorder is passed the
windowed ``fleet.*`` time series (see CONTRIBUTING's reference table)
are reconstructed from the arrays, so PR 6's observability works at
scale without per-event spans.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.fleet.cluster import (ClusterConfig, ClusterSim, RequestRecord)
from repro.obs import NULL
from repro.obs.metrics import Histogram
from repro.serving.engine import BatchCostModel

INF = float("inf")

#: Documented agreement tolerance for latency percentiles between the
#: vectorized and event engines.  Both compute the same real numbers;
#: they differ only in float accumulation order (the event engine chains
#: ``now + svc`` additively, the closed form multiplies out the cadence),
#: so the relative gap is bounded by accumulated rounding, far below
#: this.  Drop / batch / served counts carry no tolerance: they must be
#: exact in the deterministic-service case.
PCTL_RTOL = 1e-6
PCTL_ATOL = 1e-9

#: ``mode="auto"`` falls back to the mean-field fluid model only when the
#: run is big enough that per-request identity is unaffordable AND the
#: offered load exceeds capacity by this factor (deep overload: the
#: queue pegs at its limit and latency saturates, which is exactly where
#: the fluid limit is accurate).
FLUID_OVERLOAD_FACTOR = 3.0
FLUID_MIN_REQUESTS = 200_000

# Saturated-stretch lookahead (arrivals per closed-form commit).  Bounds
# the wasted work when a stretch breaks early; large stretches re-enter
# the fast path immediately, so throughput is O(n / CHUNK) commits.
_CHUNK = 8192

# Cap on the number of windowed telemetry samples reconstructed from a
# vectorized run (the event engine emits one sample per window *event*;
# at mega-fleet horizons that would itself be millions of rows).
_MAX_WINDOWS = 20_000


# ======================================================================
# stats surfaces
# ======================================================================

class VectorClusterStats:
    """``ClusterStats`` read surface over per-request NumPy arrays.

    Arrays are in *offer order* (the order requests were offered, which
    is also rid order when rids were auto-assigned).  ``t_dispatch`` /
    ``t_done`` are ``-1.0`` for dropped requests.
    """

    def __init__(self, rids, t_offer, t_dispatch, t_done, drop_mask,
                 batches: int, busy_s: float):
        self.rids = rids
        self.t_offer = t_offer
        self.t_dispatch = t_dispatch
        self.t_done = t_done
        self.drop_mask = drop_mask
        self.dropped = int(drop_mask.sum())
        self.batches = batches
        self.busy_s = busy_s

    # -------------------------------------------------- ClusterStats API
    @property
    def n_served(self) -> int:
        return len(self.t_offer) - self.dropped

    def latencies(self) -> np.ndarray:
        m = ~self.drop_mask
        return self.t_done[m] - self.t_offer[m]

    def waits(self) -> np.ndarray:
        m = ~self.drop_mask
        return self.t_dispatch[m] - self.t_offer[m]

    def percentile(self, p: float) -> float:
        lat = self.latencies()
        return float(np.percentile(lat, p)) if len(lat) else float("nan")

    def drop_fraction(self) -> float:
        n = len(self.t_offer)
        return self.dropped / n if n else 0.0

    def mean_batch(self) -> float:
        return self.n_served / self.batches if self.batches \
            else float("nan")

    def utilization(self, n_replicas: int, horizon_s: float) -> float:
        return self.busy_s / (n_replicas * horizon_s) if horizon_s > 0 \
            else 0.0

    @property
    def served(self) -> list:
        """Materialized ``RequestRecord`` list (event-engine compat).

        O(n) python objects — debugging/refinement aid, never built on
        the mega-fleet path."""
        m = ~self.drop_mask
        return [RequestRecord(int(r), float(t), float(d), float(o))
                for r, t, d, o in zip(self.rids[m], self.t_offer[m],
                                      self.t_dispatch[m], self.t_done[m])]

    def __repr__(self):
        return (f"VectorClusterStats(n={len(self.t_offer)}, "
                f"served={self.n_served}, dropped={self.dropped}, "
                f"batches={self.batches})")


class StreamingClusterStats:
    """``ClusterStats`` surface with O(histogram) memory: latency
    quantiles come from a streaming fixed-bucket histogram (the same
    :class:`repro.obs.metrics.Histogram` the windowed sampler uses), not
    from retained per-request records.

    Percentiles interpolate within log-spaced buckets, so they carry the
    standard telemetry quantile error (one bucket ratio, ~29% worst
    case at 9 buckets/decade) on top of :data:`PCTL_RTOL`; counts
    (served / dropped / batches) remain exact when produced by the exact
    engine, approximate when produced by the fluid model.
    """

    def __init__(self, hist: Histogram, n_served: int, dropped: int,
                 batches: int, busy_s: float):
        self.hist = hist
        self.n_served = n_served
        self.dropped = dropped
        self.batches = batches
        self.busy_s = busy_s

    def latencies(self) -> np.ndarray:
        raise RuntimeError(
            "StreamingClusterStats keeps no per-request records; use "
            "percentile()/mean_latency_s(), or rerun without "
            "streaming=True")

    def mean_latency_s(self) -> float:
        return self.hist.mean()

    def percentile(self, p: float) -> float:
        return self.hist.percentile(p)

    def drop_fraction(self) -> float:
        n = self.n_served + self.dropped
        return self.dropped / n if n else 0.0

    def mean_batch(self) -> float:
        return self.n_served / self.batches if self.batches \
            else float("nan")

    def utilization(self, n_replicas: int, horizon_s: float) -> float:
        return self.busy_s / (n_replicas * horizon_s) if horizon_s > 0 \
            else 0.0

    def __repr__(self):
        return (f"StreamingClusterStats(served={self.n_served}, "
                f"dropped={self.dropped}, batches={self.batches})")


# ======================================================================
# the exact vectorized replay
# ======================================================================

def _service_lut(cost: BatchCostModel, max_batch: int) -> np.ndarray:
    return np.array([0.0] + [cost.service_time(b)
                             for b in range(1, max_batch + 1)])


def _simulate_sorted(t: np.ndarray, cost: BatchCostModel,
                     cfg: ClusterConfig):
    """Replay the ``ClusterSim`` state machine over sorted arrivals.

    Returns ``(t_dispatch, t_done, drop_mask, batches, busy_s,
    batch_t, batch_n)`` aligned with ``t`` (sorted order).  Exact twin
    of the event engine for deterministic service times; the per-event
    invariants mirrored here are spelled out next to each branch.
    """
    import heapq as hq

    n = len(t)
    B, L = cfg.max_batch, cfg.queue_limit
    k, wnd = cfg.n_replicas, cfg.batch_window_s
    svc = _service_lut(cost, B)
    svc_b = float(svc[B])

    disp = np.empty(n)       # dropped slots set to -1.0 on return
    done = np.empty(n)
    drop = np.zeros(n, bool)
    adm = np.empty(n, np.int64)      # admitted arrival indices, FIFO
    na = 0                           # tail of the admitted buffer
    h = 0                            # head: adm[h:na] is the queue
    heap: list = []                  # done-times of the busy replicas
    free = k
    timer = INF                      # live window deadline (INF = none)
    due = False                      # window expired with work waiting
    i = 0                            # next arrival (sorted order)
    batches = 0
    busy = 0.0
    bt: list = []                    # per-batch dispatch times
    bn: list = []                    # per-batch sizes
    # adaptive saturated-stretch lookahead: sized to the observed commit
    # length so the per-commit array work tracks arrivals committed, not
    # arrivals scanned
    bulk_chunk = min(_CHUNK, max(2 * B * k, 1024))
    ar_buf = np.arange(min(n + 1, bulk_chunk + 1))   # grown on demand

    # python-float service LUT: the per-event fallback path below stays
    # numpy-free per iteration (no scalar boxing)
    svc_f = svc.tolist()

    def dispatch_ready(now: float):
        # mirror of ClusterSim._dispatch_ready: start batches while a
        # replica is free and one is ready (full, or window overdue).
        # disp/done times are not written here: batches consume adm[]
        # contiguously, so one np.repeat pass at the end covers every
        # dispatch in FIFO order.
        nonlocal free, h, batches, busy, due, timer
        while free and na > h and (due or na - h >= B):
            b = min(B, na - h)
            s = svc_f[b]
            hq.heappush(heap, now + s)
            free -= 1
            h += b
            batches += 1
            busy += s
            bt.append(now)
            bn.append(b)
        if na == h:                  # queue drained: window moot
            due = False
            timer = INF

    while i < n or h < na or heap:
        next_arr = t[i] if i < n else INF
        next_done = heap[0] if heap else INF

        # ---------------------------------------- saturated fast path --
        # Window overdue + every replica busy + at least one full batch
        # and one spare waiting: every done-event dispatches a full
        # batch, so dispatch times follow the k-server max-plus cadence
        # and whole stretches commit in closed form.
        if due and free == 0 and na - h >= B + 1:
            w0 = na - h
            hs = np.sort(np.asarray(heap))
            m_all = n - i
            m_c = min(m_all, bulk_chunk)
            ta = t[i:i + m_c]
            # Dispatch cadence: every done-event redispatches its
            # replica, so replica c's done-times form the arithmetic
            # progression h_c + m * svc_B.  Every running batch was
            # dispatched before the next event and finishes after it,
            # so the k done-times span less than one svc_B — which
            # makes the round-robin merge d_j globally sorted, i.e. the
            # true time-ordered dispatch schedule.  j range is supply-
            # bounded (admissions <= arrivals), so the stretch-break
            # test below is guaranteed to fail inside it.
            j_hi = (w0 + m_c) // B + 2
            jj = np.arange(j_hi + k)
            d = hs[jj % k] + (jj // k) * svc_b
            p_at_d = np.searchsorted(ta, d[:j_hi], side="right")
            if m_c:
                if len(ar_buf) < m_c + 1:
                    ar_buf = np.arange(min(n, 2 * m_c) + 1)
                ar = ar_buf[:m_c]
                idx1 = ar_buf[1:m_c + 1]
                # dispatches strictly before each arrival, from the
                # monotone inverse already in hand: p_at_d maps each
                # dispatch to its arrival position, so a bincount +
                # cumsum recovers the per-arrival dispatch count without
                # an O(m log j) search (d_j < ta_i <=> p_at_d[j] <= i)
                d_cnt = np.cumsum(np.bincount(
                    p_at_d, minlength=m_c + 1))[:m_c]
                # queue-depth prefix scan: admissions A satisfy
                # A_{i+1} = A_i + [A_i < H_i] with headroom
                # H_i = L - W0 + B * D_i non-decreasing, which unrolls
                # to A_i = min(i, min_{j<i} H_j + i - 1 - j)
                head = d_cnt * B
                head += (L - w0) - ar
                m_run = np.minimum.accumulate(head)
                m_run += idx1
                acum = np.empty(m_c + 1, np.int64)
                acum[0] = 0
                np.minimum(idx1, m_run - 1, out=acum[1:])
            else:
                ta = np.empty(0)
                acum = np.zeros(1, np.int64)
                p_at_d = np.zeros(j_hi, np.int64)
            w_at_d = w0 + acum[p_at_d] - B * jj[:j_hi]
            # stretch holds while each dispatch is full AND leaves work
            # (>= B+1 waiting), so `due` never resets mid-stretch
            ok = w_at_d >= B + 1
            if m_c < m_all:
                ok &= d[:j_hi] <= ta[-1]   # arrivals past chunk unmodeled
            jstar = int(np.argmin(ok)) if not ok.all() else j_hi
            # ok[0] always holds (w0 >= B+1), so jstar >= 1: progress
            pstar = int(np.searchsorted(ta, d[jstar], side="right"))
            # stretch ended at the lookahead cap, not a real queue dip:
            # widen the next lookahead; otherwise track the commit size
            if jstar < j_hi and w_at_d[jstar] >= B + 1:
                bulk_chunk = min(bulk_chunk * 4, 1 << 20)
            else:
                bulk_chunk = min(1 << 20, max(2 * B * k, 1024,
                                              pstar + (pstar >> 1)))
            n_new = int(acum[pstar])
            if pstar:
                admitted = acum[1:pstar + 1] > acum[:pstar]
                adm[na:na + n_new] = i + np.nonzero(admitted)[0]
                na += n_new
                drop[i:i + pstar] = ~admitted
                i += pstar
            h += B * jstar
            batches += jstar
            busy += jstar * svc_b
            bt.extend(d[:jstar].tolist())
            bn.extend([B] * jstar)
            # outstanding done-times after j* dispatches are exactly the
            # next k cadence entries (d_{j+k} = d_j + svc_B)
            heap[:] = d[jstar:jstar + k].tolist()
            continue

        # ------------------------------------------------- arrivals ----
        # Arrival events were all scheduled before run(), so they carry
        # the lowest sequence numbers and win every time tie.
        if i < n and next_arr <= next_done and next_arr <= timer:
            if na == h:
                # empty queue (=> no live timer, not due).  Mirrors
                # _on_arrival: drop check, append, then either the full-
                # batch dispatch branch (B == 1) or arm the window.
                now = float(next_arr)
                if L < 1:
                    drop[i] = True
                    i += 1
                    continue
                adm[na] = i
                na += 1
                i += 1
                if na - h >= B:
                    dispatch_ready(now)
                else:
                    timer = now + wnd
                continue
            # queue non-empty: admit a whole run of arrivals up to the
            # next state-changing event.  Arrivals at exactly t_stop are
            # included (they outrank the timer/done event in seq order).
            t_stop = min(timer, next_done)
            j_stop = i + int(np.searchsorted(t[i:], t_stop, side="right"))
            m = j_stop - i
            if due or free == 0:
                # nothing can dispatch on arrival (due => all busy; all
                # busy => the >=B dispatch branch is a no-op) and no new
                # timers are armed: pure admit/drop counting
                room = max(L - (na - h), 0)
                n_adm = min(m, room)
                if n_adm:
                    adm[na:na + n_adm] = np.arange(i, i + n_adm)
                    na += n_adm
                if n_adm < m:
                    drop[i + n_adm:j_stop] = True
                i = j_stop
                continue
            # free > 0, not due => waiting < B (else it would have
            # dispatched) and a timer is live.  Admissions can trigger a
            # full-batch dispatch mid-run.
            room_drop = L - (na - h)
            room_disp = B - (na - h)
            if room_drop <= 0:           # L < B and queue pegged at L
                drop[i:j_stop] = True
                i = j_stop
                continue
            if m < min(room_disp, room_drop):
                adm[na:na + m] = np.arange(i, i + m)
                na += m
                i = j_stop
                continue
            if room_drop < room_disp:    # L < B: fill to L, drop rest
                adm[na:na + room_drop] = np.arange(i, i + room_drop)
                na += room_drop
                drop[i + room_drop:j_stop] = True
                i = j_stop
                continue
            # the (na-h+room_disp)-th admission completes a full batch
            adm[na:na + room_disp] = np.arange(i, i + room_disp)
            na += room_disp
            now = float(t[i + room_disp - 1])
            i += room_disp
            dispatch_ready(now)
            continue

        # ------------------------------------------- done / window -----
        if heap and next_done <= timer:
            now = hq.heappop(heap)
            free += 1
            dispatch_ready(now)
            continue
        if timer < INF:
            now = timer
            timer = INF
            due = True
            dispatch_ready(now)
            continue
        raise RuntimeError("vectorized cluster replay stalled "
                           "(invariant violation)")     # pragma: no cover

    # one deferred pass writes every dispatch/done time: the taken
    # prefix of adm[] is exactly the concatenation of all batches in
    # dispatch order
    bt_a = np.asarray(bt)
    bn_a = np.asarray(bn, np.int64)
    taken = adm[:h]
    disp[taken] = np.repeat(bt_a, bn_a)
    done[taken] = np.repeat(bt_a + svc[bn_a], bn_a)
    disp[drop] = -1.0
    done[drop] = -1.0
    return disp, done, drop, batches, busy, bt_a, bn_a


# ======================================================================
# public entry points
# ======================================================================

def simulate_cluster_vectorized(times, cost: BatchCostModel,
                                cfg: ClusterConfig, *, rids=None,
                                tx_s=None, tx_bytes=None, obs=None,
                                window_s=None, streaming: bool = False,
                                mode: str = "exact",
                                check_event_engine: bool = False,
                                pctl_rtol: float = PCTL_RTOL):
    """Run the vectorized cluster engine over an arrival-time array.

    ``times`` is per-request arrival times at the admission queue (any
    order; offer order is preserved on the stats arrays).  ``mode`` is
    ``"exact"`` (the replay), ``"fluid"`` (mean-field), or ``"auto"``
    (exact unless the run is both huge and deeply overloaded — see
    :data:`FLUID_OVERLOAD_FACTOR`).  ``tx_s`` / ``tx_bytes`` are the
    optional per-request wire metadata ``ClusterSim.offer`` takes; they
    feed the ``fleet.inflight_bytes`` series.  With
    ``check_event_engine=True`` the event engine re-runs the same offers
    and exact-count / percentile agreement is asserted.
    """
    obs = NULL if obs is None else obs
    times = np.asarray(times, float)
    n = len(times)
    rids_a = np.arange(n, dtype=np.int64) if rids is None \
        else np.asarray(rids, np.int64)

    if mode == "auto":
        mode = "fluid" if _deep_overload(times, cost, cfg) else "exact"
    if mode == "fluid":
        if check_event_engine:
            raise ValueError("check_event_engine requires mode='exact': "
                             "the fluid model is approximate by design")
        return fluid_cluster_stats(times, cost, cfg, obs=obs,
                                   window_s=window_s)
    if mode != "exact":
        raise ValueError(f"unknown mode {mode!r}")

    # trace generators emit sorted arrivals; skip the argsort round-trip
    presorted = n < 2 or bool((times[1:] >= times[:-1]).all())
    if presorted:
        ts = times
    else:
        order = np.argsort(times, kind="stable")   # stable = seq order
        ts = times[order]
    disp_s, done_s, drop_s, batches, busy, bt, bn = \
        _simulate_sorted(ts, cost, cfg)

    if presorted:
        disp, done, drop_mask = disp_s, done_s, drop_s
    else:
        inv = np.empty(n, np.int64)
        inv[order] = np.arange(n)
        disp, done = disp_s[inv], done_s[inv]
        drop_mask = drop_s[inv]
    stats = VectorClusterStats(rids_a, times, disp, done, drop_mask,
                               batches, busy)

    if obs.enabled and n:
        _emit_series(obs, window_s if window_s is not None
                     else obs.window_s, ts, done_s, drop_s, bt, bn,
                     cfg, _service_lut(cost, cfg.max_batch),
                     times, tx_s, tx_bytes,
                     disp_sorted_adm=disp_s[~drop_s])
    if check_event_engine:
        check_against_event_engine(times, cost, cfg, stats,
                                   rids=rids_a, pctl_rtol=pctl_rtol)
    if streaming:
        return _to_streaming(stats)
    return stats


def check_against_event_engine(times, cost: BatchCostModel,
                               cfg: ClusterConfig, vstats, *, rids=None,
                               pctl_rtol: float = PCTL_RTOL,
                               pctl_atol: float = PCTL_ATOL) -> None:
    """Assert the event engine agrees with a vectorized run.

    Drop / served / batch counts must match exactly (deterministic
    service); latency percentiles must agree within ``pctl_rtol`` /
    ``pctl_atol`` (float accumulation order only).  O(n log n) python
    events — meant for small fleets and CI, not the mega-fleet path.
    """
    times = np.asarray(times, float)
    rids = np.arange(len(times)) if rids is None else rids
    sim = ClusterSim(cost, cfg)
    for r, tt in zip(rids, times):
        sim.offer(int(r), float(tt))
    est = sim.run()
    if est.dropped != vstats.dropped:
        raise AssertionError(
            f"drop count mismatch: event={est.dropped} "
            f"vectorized={vstats.dropped}")
    if est.batches != vstats.batches:
        raise AssertionError(
            f"batch count mismatch: event={est.batches} "
            f"vectorized={vstats.batches}")
    if len(est.served) != vstats.n_served:
        raise AssertionError(
            f"served count mismatch: event={len(est.served)} "
            f"vectorized={vstats.n_served}")
    for p in (50.0, 95.0, 99.0):
        a, b = est.percentile(p), vstats.percentile(p)
        if np.isnan(a) and np.isnan(b):
            continue
        if abs(a - b) > pctl_atol + pctl_rtol * max(abs(a), abs(b)):
            raise AssertionError(
                f"p{p:g} mismatch beyond tolerance: event={a!r} "
                f"vectorized={b!r}")


def _deep_overload(times: np.ndarray, cost: BatchCostModel,
                   cfg: ClusterConfig) -> bool:
    n = len(times)
    if n < FLUID_MIN_REQUESTS:
        return False
    horizon = float(times.max() - min(float(times.min()), 0.0))
    if horizon <= 0:
        return False
    capacity = cfg.n_replicas * cfg.max_batch \
        / cost.service_time(cfg.max_batch)
    return (n / horizon) > FLUID_OVERLOAD_FACTOR * capacity


def _to_streaming(stats: VectorClusterStats) -> StreamingClusterStats:
    hist = Histogram("cluster.latency_s")
    lat = stats.latencies()
    if len(lat):
        idx = np.searchsorted(np.asarray(hist.bounds), lat, side="left")
        counts = np.bincount(idx, minlength=len(hist.counts))
        hist.counts = counts.tolist()
        hist.n = int(len(lat))
        hist.total = float(lat.sum())
        hist.vmin = float(lat.min())
        hist.vmax = float(lat.max())
    return StreamingClusterStats(hist, stats.n_served, stats.dropped,
                                 stats.batches, stats.busy_s)


# ======================================================================
# windowed decision signals (engine-agnostic)
# ======================================================================

def signals_at(t: float, *, t_offer, t_dispatch, t_done, drop_mask,
               window_s: float, t_prev: Optional[float] = None) -> dict:
    """The adaptive controller's decision signals at simulated time
    ``t``, computed from per-request arrays.

    Both cluster engines can produce these arrays (the vectorized stats
    carry them natively; event-engine records convert trivially), and
    because the vectorized replay is an exact twin of the event engine,
    every *count* here — arrivals, drops, queue depth — is identical
    whichever engine produced the arrays.  That is what makes controller
    decisions engine-independent: drift detection keys on the exact
    integer signals, never on float-accumulation-sensitive quantities.

    Windows: arrival-side signals count offers in ``(t - window_s, t]``;
    completion-side signals (the latency percentiles) take requests done
    in ``(t_prev, t]`` (``t_prev`` defaults to ``t - window_s``).
    Only offers with ``t_offer <= t`` are considered, so a prefix replay
    and an incrementally-run event engine agree by causality.
    """
    t_offer = np.asarray(t_offer, float)
    t_dispatch = np.asarray(t_dispatch, float)
    t_done = np.asarray(t_done, float)
    drop_mask = np.asarray(drop_mask, bool)
    t_lo = t - window_s
    t_prev = t_lo if t_prev is None else t_prev

    past = t_offer <= t
    in_win = past & (t_offer > t_lo)
    n_arr = int(in_win.sum())
    n_drop = int((in_win & drop_mask).sum())
    adm = past & ~drop_mask
    depth = int((adm & (t_dispatch > t)).sum())
    inflight = int((adm & (t_dispatch <= t) & (t_done > t)).sum())
    done_win = adm & (t_done > t_prev) & (t_done <= t)
    lat = t_done[done_win] - t_offer[done_win]
    return {
        "t": t,
        "arrivals": n_arr,
        "rate_hz": n_arr / window_s if window_s > 0 else 0.0,
        "drops": n_drop,
        "drop_fraction": n_drop / n_arr if n_arr else 0.0,
        "queue_depth": depth,
        "inflight": inflight,
        "n_done": int(done_win.sum()),
        "p50_s": float(np.percentile(lat, 50)) if len(lat) else float("nan"),
        "p99_s": float(np.percentile(lat, 99)) if len(lat) else float("nan"),
    }


# ======================================================================
# mean-field fluid fallback
# ======================================================================

def fluid_cluster_stats(times, cost: BatchCostModel, cfg: ClusterConfig,
                        *, obs=None, window_s=None,
                        n_bins: int = 2048) -> StreamingClusterStats:
    """Mean-field (binned Lindley) fluid model of the cluster.

    Arrivals are binned; each bin moves fluid through
    ``Q' = Q + A - served`` with ``served = min(mu * dt, Q + A)`` at full
    service rate ``mu = k * B / svc(B)``, and queue mass above
    ``queue_limit`` overflows as drops.  Per-bin latency is approximated
    as ``Q/mu + svc(b̂) + window/2`` with ``b̂`` the fluid batch size.
    O(n_bins) regardless of request count; accurate in deep sustained
    overload (where waits are queue-dominated and batches run full),
    approximate elsewhere — which is why ``mode="auto"`` only selects it
    there.  Counts are rounded fluid masses, not per-request decisions.
    """
    obs = NULL if obs is None else obs
    times = np.asarray(times, float)
    hist = Histogram("cluster.latency_s")
    n = len(times)
    if n == 0:
        return StreamingClusterStats(hist, 0, 0, 0, 0.0)

    k, big_b, wnd = cfg.n_replicas, cfg.max_batch, cfg.batch_window_s
    svc_b = cost.service_time(big_b)
    mu = k * big_b / svc_b                      # req/s, batches full
    t_lo = min(0.0, float(times.min()))
    t_hi = float(times.max()) + svc_b
    n_bins = max(8, min(n_bins, n))
    edges = np.linspace(t_lo, t_hi, n_bins + 1)
    dt = edges[1] - edges[0]
    arr = np.histogram(times, bins=edges)[0].astype(float)

    bounds = np.asarray(hist.bounds)
    counts = np.zeros(len(hist.counts))
    q = 0.0
    total_served = 0.0
    total_drop = 0.0
    total_busy = 0.0
    total_batches = 0.0
    total_lat = 0.0
    vmin, vmax = INF, -INF
    q_series = np.empty(n_bins)
    served_series = np.empty(n_bins)
    lat_series = np.empty(n_bins)
    for b in range(n_bins):
        supply = q + arr[b]
        served = min(mu * dt, supply)
        q_new = supply - served
        drop_b = max(0.0, q_new - cfg.queue_limit)
        q_new = min(q_new, float(cfg.queue_limit))
        q_mid = 0.5 * (q + q_new)
        rate = arr[b] / dt
        bhat = min(float(big_b),
                   max(1.0, rate * wnd, q_mid / max(k, 1)))
        wait = q_mid / mu + (0.5 * wnd if q_mid < big_b else 0.0)
        lat = wait + cost.service_time(bhat)
        if served > 0:
            counts[int(np.searchsorted(bounds, lat, side="left"))] \
                += served
            total_lat += served * lat
            vmin, vmax = min(vmin, lat), max(vmax, lat)
            total_busy += (served / bhat) * cost.service_time(bhat)
            total_batches += served / bhat
        q = q_new
        total_served += served
        total_drop += drop_b
        q_series[b] = q
        served_series[b] = served
        lat_series[b] = lat

    hist.counts = [int(round(c)) for c in counts]
    hist.n = int(round(total_served))
    hist.total = total_lat
    hist.vmin, hist.vmax = vmin, vmax
    stats = StreamingClusterStats(hist, int(round(total_served)),
                                  int(round(total_drop)),
                                  int(round(total_batches)), total_busy)
    if obs.enabled:
        m = obs.metrics
        m.counter("fleet.arrivals").inc(n)
        m.counter("fleet.drops").inc(stats.dropped)
        m.counter("fleet.batches").inc(stats.batches)
        m.counter("fleet.served").inc(stats.n_served)
        mid = edges[1:]
        for b in range(n_bins):
            tb = float(mid[b])
            m.record("fleet.arrival_rate_hz", tb, arr[b] / dt)
            m.record("fleet.queue_depth", tb, q_series[b])
            m.record("fleet.drop_fraction", tb,
                     1.0 - served_series[b] / arr[b] if arr[b] else 0.0)
            m.record("fleet.utilization", tb,
                     served_series[b] * svc_b / big_b / (k * dt))
            m.record("fleet.latency_p50_s", tb, lat_series[b])
            m.record("fleet.latency_p99_s", tb, lat_series[b])
        obs.tracer.add("cluster.fluid", t_lo, t_hi, clock="sim",
                       tid="cluster", cat="fleet",
                       args={"n": n, "bins": n_bins,
                             "dropped": stats.dropped})
    return stats


# ======================================================================
# windowed fleet.* reconstruction (the PR-6 series, from arrays)
# ======================================================================

def _emit_series(obs, window_s, ts, done_s, drop_s, bt, bn, cfg,
                 svc_lut, times_offer, tx_s, tx_bytes, disp_sorted_adm):
    """Reconstruct the windowed ``fleet.*`` time series of
    ``ClusterSim._sample_window`` from the result arrays.

    Same names, units, and window cadence; per-window latency quantiles
    go through the same streaming-histogram estimator.  Differences from
    the event sampler are documented in CONTRIBUTING: samples cover the
    whole run (the event chain stops at the last event), and the window
    width is widened when a run would exceed ``_MAX_WINDOWS`` samples.
    """
    m = obs.metrics
    n = len(ts)
    n_drop = int(drop_s.sum())
    m.counter("fleet.arrivals").inc(n)
    m.counter("fleet.drops").inc(n_drop)
    m.counter("fleet.batches").inc(len(bt))
    m.counter("fleet.served").inc(n - n_drop)

    t_end = float(ts[-1])
    if len(done_s) and (n - n_drop):
        t_end = max(t_end, float(done_s[~drop_s].max()))
    w = max(window_s, t_end / _MAX_WINDOWS if t_end > 0 else window_s)
    edges = np.arange(0.0, t_end + w, w)
    if len(edges) < 2:
        edges = np.array([0.0, w])
    t_samp = edges[1:]
    dt = np.diff(edges)

    arr_w = np.histogram(ts, bins=edges)[0]
    drop_w = np.histogram(ts[drop_s], bins=edges)[0]

    adm_t = ts[~drop_s]
    done_adm = done_s[~drop_s]
    # FIFO => dispatch times are non-decreasing in admission order
    depth = (np.searchsorted(adm_t, t_samp, side="right")
             - np.searchsorted(disp_sorted_adm, t_samp, side="right"))

    # utilization: service seconds attributed to the dispatch window
    svc_arr = svc_lut[bn] if len(bn) else np.empty(0)
    busy_w = np.histogram(bt, bins=edges, weights=svc_arr)[0]

    for name, vals in (("fleet.arrival_rate_hz", arr_w / dt),
                       ("fleet.queue_depth", depth.astype(float)),
                       ("fleet.drop_fraction",
                        np.divide(drop_w, arr_w,
                                  out=np.zeros(len(arr_w)),
                                  where=arr_w > 0)),
                       ("fleet.utilization",
                        busy_w / (cfg.n_replicas * dt))):
        for tb, v in zip(t_samp, vals):
            m.record(name, float(tb), float(v))

    # in-flight wire bytes at each sample instant
    if tx_s is not None and tx_bytes is not None:
        starts = np.asarray(times_offer, float) - np.asarray(tx_s, float)
        by = np.asarray(tx_bytes, float)
        so = np.argsort(starts, kind="stable")
        cum_start = np.concatenate(([0.0], np.cumsum(by[so])))
        ao = np.argsort(times_offer, kind="stable")
        cum_arr = np.concatenate(([0.0], np.cumsum(by[ao])))
        inflight = (cum_start[np.searchsorted(starts[so], t_samp,
                                              side="right")]
                    - cum_arr[np.searchsorted(
                        np.asarray(times_offer, float)[ao], t_samp,
                        side="right")])
    else:
        inflight = np.zeros(len(t_samp))
    for tb, v in zip(t_samp, inflight):
        m.record("fleet.inflight_bytes", float(tb), float(v))

    # per-window latency quantiles via the same streaming histogram
    lat = done_adm - adm_t
    order = np.argsort(done_adm, kind="stable")
    done_sorted = done_adm[order]
    lat_by_done = lat[order]
    cut = np.searchsorted(done_sorted, edges, side="right")
    hist = Histogram("fleet.window_latency_s")
    bounds = np.asarray(hist.bounds)
    for wi in range(len(t_samp)):
        seg = lat_by_done[cut[wi]:cut[wi + 1]]
        if not len(seg):
            continue
        idx = np.searchsorted(bounds, seg, side="left")
        hist.counts = np.bincount(
            idx, minlength=len(hist.counts)).tolist()
        hist.n = int(len(seg))
        hist.total = float(seg.sum())
        hist.vmin = float(seg.min())
        hist.vmax = float(seg.max())
        tb = float(t_samp[wi])
        m.record("fleet.latency_p50_s", tb, hist.percentile(50))
        m.record("fleet.latency_p99_s", tb, hist.percentile(99))
        hist.reset()

    obs.tracer.add("cluster.vectorized", 0.0, t_end, clock="sim",
                   tid="cluster", cat="fleet",
                   args={"n": n, "dropped": n_drop, "batches": len(bt)})


# ======================================================================
# ClusterSim-shaped wrapper
# ======================================================================

class VectorizedClusterSim:
    """Drop-in ``ClusterSim`` shape over the vectorized engine.

    Same constructor and ``offer`` / ``offer_trace`` / ``run`` surface,
    so planner code can swap engines behind one variable.  Offers are
    buffered as arrays; :meth:`run` simulates the whole horizon at once
    (``until`` must stay ``inf`` — partial-horizon replay is the event
    engine's job) and returns :class:`VectorClusterStats` (or
    :class:`StreamingClusterStats` with ``streaming=True``), cached on
    ``self.stats``.
    """

    def __init__(self, cost: BatchCostModel, cfg: ClusterConfig,
                 obs=None, window_s: Optional[float] = None,
                 streaming: bool = False):
        assert cfg.n_replicas >= 1 and cfg.max_batch >= 1
        self.cost, self.cfg = cost, cfg
        self.obs = NULL if obs is None else obs
        self.window_s = (window_s if window_s is not None
                         else self.obs.window_s)
        self.streaming = streaming
        self._rids: list = []
        self._times: list = []
        self._tx_s: list = []
        self._tx_bytes: list = []
        self._chunks: list = []      # (rids, times, tx_s, tx_bytes)
        self.stats = None

    # ------------------------------------------------------------ intake
    def offer(self, rid: int, t_arrival: float, *, tx_s: float = 0.0,
              tx_bytes: int = 0) -> None:
        self._rids.append(rid)
        self._times.append(t_arrival)
        self._tx_s.append(tx_s)
        self._tx_bytes.append(tx_bytes)

    def offer_trace(self, arrivals) -> None:
        """arrivals: iterable of ``(rid, t_arrival)`` or
        ``(rid, t_arrival, tx_s, tx_bytes)`` rows."""
        for row in arrivals:
            if len(row) == 2:
                self.offer(row[0], row[1])
            else:
                rid, t, tx_time, tx_b = row
                self.offer(rid, t, tx_s=tx_time, tx_bytes=tx_b)

    def offer_array(self, t_arrival, rids=None, tx_s=None,
                    tx_bytes=None) -> None:
        """Bulk intake: whole arrival arrays, no per-request python."""
        t_arrival = np.asarray(t_arrival, float)
        n = len(t_arrival)
        base = sum(len(c[1]) for c in self._chunks) + len(self._times)
        rids = (np.arange(base, base + n, dtype=np.int64)
                if rids is None else np.asarray(rids, np.int64))
        self._chunks.append((rids, t_arrival, tx_s, tx_bytes))

    # --------------------------------------------------------------- run
    def run(self, until: float = INF, mode: str = "exact",
            check_event_engine: bool = False):
        assert until == INF, \
            "vectorized engine runs whole horizons; use ClusterSim " \
            "for partial runs"
        rids, times, tx_s, tx_bytes = self._gather()
        self.stats = simulate_cluster_vectorized(
            times, self.cost, self.cfg, rids=rids, tx_s=tx_s,
            tx_bytes=tx_bytes, obs=self.obs, window_s=self.window_s,
            streaming=self.streaming, mode=mode,
            check_event_engine=check_event_engine)
        return self.stats

    def _gather(self):
        parts = list(self._chunks)
        if self._times:
            parts.append((np.asarray(self._rids, np.int64),
                          np.asarray(self._times, float),
                          np.asarray(self._tx_s, float),
                          np.asarray(self._tx_bytes, float)))
        if not parts:
            return (np.empty(0, np.int64), np.empty(0),
                    None, None)
        rids = np.concatenate([p[0] for p in parts])
        times = np.concatenate([p[1] for p in parts])
        have_tx = any(p[2] is not None and np.any(np.asarray(p[2]))
                      for p in parts)
        if have_tx:
            tx_s = np.concatenate(
                [np.zeros(len(p[1])) if p[2] is None
                 else np.broadcast_to(np.asarray(p[2], float),
                                      (len(p[1]),)).copy()
                 for p in parts])
            tx_bytes = np.concatenate(
                [np.zeros(len(p[1])) if p[3] is None
                 else np.broadcast_to(np.asarray(p[3], float),
                                      (len(p[1]),)).copy()
                 for p in parts])
        else:
            tx_s = tx_bytes = None
        return rids, times, tx_s, tx_bytes
