"""Fleet workload generation: who sends, and when.

The paper evaluates one sensing node on one link; a deployment serves a
*population* — heterogeneous device classes (``core.scenarios`` platform
profiles, each behind its own channel) firing requests under realistic
arrival processes.  Three processes cover the regimes that matter for
capacity planning:

* ``poisson`` — memoryless steady-state load (the M in M/D/c),
* ``bursty``  — a two-state Markov-modulated Poisson process (on/off
  bursts), same mean rate but heavy short-term contention,
* ``diurnal`` — sinusoidally modulated rate (day/night swing) realised by
  thinning a dominating Poisson process.

Everything is deterministic under a seed: the same ``(mix, pattern, rate,
n, seed)`` tuple always yields the identical trace.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Sequence

import numpy as np

from repro.core.scenarios import PlatformProfile, edge_platform
from repro.netsim.channel import Channel

ARRIVAL_PATTERNS = ("poisson", "bursty", "diurnal")


@dataclass(frozen=True)
class DeviceClass:
    """One slice of the fleet: a platform profile behind a channel."""
    name: str
    platform: PlatformProfile
    channel: Channel
    protocols: tuple = ("tcp", "udp")   # transports this class supports
    weight: float = 1.0                 # share of the request population

    @classmethod
    def make(cls, platform_name: str, channel: Channel, *,
             name: Optional[str] = None, protocols: tuple = ("tcp", "udp"),
             weight: float = 1.0) -> "DeviceClass":
        return cls(name or platform_name, edge_platform(platform_name),
                   channel, protocols, weight)


@dataclass(frozen=True)
class FleetRequest:
    rid: int
    t_arrival: float                    # seconds since trace start
    device: str                         # DeviceClass.name


@dataclass(frozen=True)
class Trace:
    requests: tuple                     # FleetRequest, sorted by t_arrival
    horizon_s: float
    pattern: str
    seed: Optional[int] = None          # provenance: the generating seed

    def __len__(self):
        return len(self.requests)

    def for_device(self, name: str) -> "Trace":
        sub = tuple(r for r in self.requests if r.device == name)
        return Trace(sub, self.horizon_s, self.pattern, self.seed)

    def mean_rate_hz(self) -> float:
        return len(self.requests) / self.horizon_s if self.horizon_s else 0.0

    def arrival_times(self) -> np.ndarray:
        """All arrival times as one float array (request order) — the
        form the vectorized cluster engine consumes."""
        return np.fromiter((r.t_arrival for r in self.requests), float,
                           len(self.requests))

    def slice(self, t0: float, t1: float) -> "Trace":
        """The sub-trace of arrivals in ``[t0, t1)``, re-based to start
        at 0 (horizon ``t1 - t0``).  Request ids and the generating seed
        are preserved — a slice is provenance-traceable back to the
        trace it was cut from.  The controller's observation windows and
        scenario splicing both live on this."""
        if t1 < t0:
            raise ValueError(f"empty slice window [{t0}, {t1})")
        sub = tuple(replace(r, t_arrival=r.t_arrival - t0)
                    for r in self.requests if t0 <= r.t_arrival < t1)
        return Trace(sub, t1 - t0, self.pattern, self.seed)

    def concat(self, other: "Trace") -> "Trace":
        """This trace followed by ``other`` time-shifted to start at
        this trace's horizon.  Request ids are renumbered sequentially
        (downstream consumers key on unique rids); the seed survives
        only when both parts carry the same one — a splice of two
        different generations has no single generating seed, and
        pretending otherwise would poison downstream provenance."""
        shift, n0 = self.horizon_s, len(self.requests)
        reqs = tuple(replace(r, rid=i) for i, r in enumerate(self.requests))
        reqs += tuple(replace(r, rid=n0 + i, t_arrival=r.t_arrival + shift)
                      for i, r in enumerate(other.requests))
        pattern = (self.pattern if self.pattern == other.pattern
                   else f"{self.pattern}+{other.pattern}")
        seed = self.seed if self.seed == other.seed else None
        return Trace(reqs, shift + other.horizon_s, pattern, seed)


# ------------------------------------------------------ arrival processes ----
def poisson_arrivals(rate_hz: float, n: int, rng: np.random.Generator) -> np.ndarray:
    """n exponential inter-arrival gaps at ``rate_hz``."""
    assert rate_hz > 0 and n > 0
    return np.cumsum(rng.exponential(1.0 / rate_hz, n))


def bursty_arrivals(rate_hz: float, n: int, rng: np.random.Generator, *,
                    burst_factor: float = 8.0, p_on: float = 0.2,
                    mean_run: int = 20) -> np.ndarray:
    """Two-state MMPP: bursts run ``burst_factor`` hotter than the quiet
    state; burst runs last ~``mean_run`` arrivals and hold ``p_on`` of all
    arrivals (exit/entry flip probabilities are balanced for that
    stationary split), so the long-run mean rate stays ``rate_hz``:
    E[gap] = p_on/r_on + (1-p_on)/r_off = 1/rate.
    """
    assert rate_hz > 0 and n > 0 and 0.0 < p_on < 1.0
    r_off = rate_hz * (p_on / burst_factor + (1.0 - p_on))
    r_on = burst_factor * r_off
    f_exit = 1.0 / mean_run                      # leave a burst
    f_enter = f_exit * p_on / (1.0 - p_on)       # enter a burst
    on = bool(rng.random() < p_on)
    # vectorized: the per-arrival state chain decomposes into alternating
    # runs with geometric lengths (flip checked after each arrival), so
    # draw run lengths in bulk, expand to a per-arrival state array, and
    # scale one block of unit exponentials — megafleet traces (10^6+)
    # generate in milliseconds instead of minutes
    lens, states, covered = [], [], 0
    while covered < n:
        m = int(np.ceil((n - covered) / (1.0 / f_exit + 1.0 / f_enter))) + 16
        pair_len = np.empty(2 * m, np.int64)
        pair_on = np.empty(2 * m, bool)
        first, second = (f_exit, f_enter) if on else (f_enter, f_exit)
        pair_len[0::2] = rng.geometric(first, m)
        pair_len[1::2] = rng.geometric(second, m)
        pair_on[0::2], pair_on[1::2] = on, not on
        lens.append(pair_len)
        states.append(pair_on)
        covered += int(pair_len.sum())
    on_arr = np.repeat(np.concatenate(states), np.concatenate(lens))[:n]
    gaps = rng.exponential(1.0, n) / np.where(on_arr, r_on, r_off)
    return np.cumsum(gaps)


def diurnal_arrivals(rate_hz: float, n: int, rng: np.random.Generator, *,
                     period_s: float = 60.0, depth: float = 0.8) -> np.ndarray:
    """Sinusoidal rate ``rate*(1 + depth*sin)`` via thinning: draw from the
    dominating Poisson process at the peak rate and keep each arrival with
    probability rate(t)/peak.
    """
    assert rate_hz > 0 and n > 0 and 0.0 <= depth < 1.0
    peak = rate_hz * (1.0 + depth)
    # vectorized thinning: draw the dominating process and the accept
    # coins in chunks (mean accept ratio 1/(1+depth), so overdraw by
    # that factor plus slack), keep going until n survive
    out = np.empty(n)
    t0, k = 0.0, 0
    while k < n:
        m = int((n - k) * (1.0 + depth) * 1.2) + 64
        t = t0 + np.cumsum(rng.exponential(1.0 / peak, m))
        r_t = rate_hz * (1.0 + depth * np.sin(2.0 * np.pi * t / period_s))
        acc = t[rng.random(m) * peak < r_t]
        take = min(n - k, len(acc))
        out[k:k + take] = acc[:take]
        k += take
        t0 = float(t[-1])
    return out


_PROCESSES = {"poisson": poisson_arrivals, "bursty": bursty_arrivals,
              "diurnal": diurnal_arrivals}


def generate_trace(mix: Sequence[DeviceClass], n_requests: int,
                   rate_hz: float, *, pattern: str = "poisson",
                   seed: int = 0, **pattern_kw) -> Trace:
    """A deterministic fleet trace: arrival times from the chosen process,
    device classes drawn independently with probability ∝ weight.  The
    seed is recorded on the returned :class:`Trace` so downstream
    artifacts (exported telemetry, CI trace diffs) carry their own
    provenance."""
    if pattern not in _PROCESSES:
        raise ValueError(f"unknown pattern {pattern!r}; "
                         f"choose from {ARRIVAL_PATTERNS}")
    if not mix:
        raise ValueError("device mix is empty")
    rng = np.random.default_rng(seed)
    times = _PROCESSES[pattern](rate_hz, n_requests, rng, **pattern_kw)
    w = np.array([d.weight for d in mix], float)
    assert (w > 0).all(), "device weights must be positive"
    picks = rng.choice(len(mix), size=n_requests, p=w / w.sum())
    reqs = tuple(FleetRequest(i, float(times[i]), mix[picks[i]].name)
                 for i in range(n_requests))
    return Trace(reqs, float(times[-1]), pattern, seed)
