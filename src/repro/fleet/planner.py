"""QoS-aware deployment planning over a fleet.

Answers "which splits do I deploy for this *population* of clients", not
"which split for this one client".  The search space is

    split point x protocol x channel x batch size x replica count,

pruned with ``core.qos.rank_candidates`` (CS-curve accuracy proxy), costed
per flow with ``netsim`` (edge compute + simulated transfers + measured
accuracy under loss) and per deployment with ``fleet.cluster`` (queueing +
dynamic batching on the ``serving.engine`` replica cost model).  Output is
a Pareto front over (p99 latency, accuracy, server FLOPs/s) and a
``suggest(qos, fleet)`` API that picks one plan per device class.

Beyond the single device->server link, :func:`plan_tiers` searches
multi-tier chains (:class:`TierTopology`: device -> edge -> cloud):
cut-list x stage->tier assignment, each design point priced sequentially
and as a pipelined microbatched schedule.

Both searches are two-phase ("screen fast, verify exact"): the whole
combinatorial space is scored with the vectorized closed-form engine in
``netsim.analytic`` — exhaustively, as array operations — and only the
Pareto-front + top-K shortlist is re-priced by the discrete-event engine
(``netsim.simulator.simulate_pipeline`` / ``measure_flow``), which stays
the single semantic authority: refinement asserts the closed form agrees
to 1e-9 relative on loss-free paths.

The *cluster* leg follows the same contract: ``search`` and
:func:`simulate_deployment` take ``engine="event"|"vectorized"|"auto"``
— the arrival-level NumPy engine (``fleet.vectorized``) prices megafleet
traces orders of magnitude faster, the event engine remains the
authority, and any Pareto-front point screened vectorized is re-priced
exactly before it can be chosen.
"""
from __future__ import annotations

import itertools
import warnings
from dataclasses import dataclass, replace
from typing import Optional, Sequence

import numpy as np

from repro.api.types import SplitCandidate, legal_split_candidates
from repro.core import stats as S
from repro.core.qos import QoSRequirements, pareto_nd, rank_candidates
from repro.core.scenarios import (PLATFORMS, PlatformProfile, Scenario,
                                  cut_payload_bytes_lut,
                                  scenario_times_and_payload)
from repro.core.split import legal_cut_lists, legal_cuts
from repro.fleet.cluster import ClusterConfig, ClusterSim
from repro.fleet.traffic import DeviceClass, Trace
from repro.fleet.vectorized import simulate_cluster_vectorized
from repro.netsim import analytic
from repro.netsim.channel import Channel, compose_channels
from repro.netsim.protocols import RetryBudgetExceeded
from repro.netsim.simulator import (ApplicationSimulator, NetworkConfig,
                                    NetworkPath, measure_flow,
                                    simulate_pipeline)
from repro.obs import NULL
from repro.serving.engine import BatchCostModel


# requests per cluster above which engine="auto" switches from the exact
# event engine to the vectorized arrival-level engine (below it the event
# engine is both authoritative and fast enough)
AUTO_VECTORIZE_MIN = 20_000

CLUSTER_ENGINES = ("event", "vectorized", "auto")


def _resolve_engine(engine: str, n_requests: int) -> str:
    """'event' or 'vectorized' for a concrete run of ``n_requests``."""
    if engine not in CLUSTER_ENGINES:
        raise ValueError(f"engine must be one of {CLUSTER_ENGINES}, "
                         f"got {engine!r}")
    if engine == "auto":
        return ("vectorized" if n_requests >= AUTO_VECTORIZE_MIN
                else "event")
    return engine


@dataclass(frozen=True)
class SearchSpace:
    split_points: tuple              # legal cut layers to consider
    protocols: tuple = ("tcp", "udp")
    batch_sizes: tuple = (1, 8)
    replica_counts: tuple = (1, 2)
    batch_window_s: float = 2e-3
    top_k_splits: int = 2            # CS-ranked prune before simulation
    include_rc: bool = True
    include_lc: bool = False


# ------------------------------------------------------- tier topologies ----
@dataclass(frozen=True)
class Tier:
    """One compute tier of a multi-hop deployment chain.

    ``uplink`` is the physical link toward the next tier (None for the
    last); ``platform`` may be a ``core.scenarios`` profile name.
    """
    name: str
    platform: PlatformProfile
    uplink: Optional[Channel] = None
    protocol: str = "tcp"

    def __post_init__(self):
        if isinstance(self.platform, str):
            if self.platform not in PLATFORMS:
                raise KeyError(f"unknown platform {self.platform!r}; "
                               f"known: {sorted(PLATFORMS)}")
            object.__setattr__(self, "platform", PLATFORMS[self.platform])


@dataclass(frozen=True)
class TierTopology:
    """An ordered device -> edge -> ... -> cloud tier chain.

    The search space of :func:`plan_tiers`: stages of a cut list are
    assigned to an increasing subsequence of these tiers (sensing always
    on tier 0), the payload store-and-forwards through any skipped tier.
    """
    tiers: tuple

    def __post_init__(self):
        tiers = tuple(self.tiers)
        object.__setattr__(self, "tiers", tiers)
        if len(tiers) < 2:
            raise ValueError("a topology needs at least 2 tiers")
        missing = [t.name for t in tiers[:-1] if t.uplink is None]
        if missing:
            raise ValueError(f"tiers {missing} have no uplink toward the "
                             f"next tier")

    def __len__(self):
        return len(self.tiers)

    def __iter__(self):
        return iter(self.tiers)

    def __getitem__(self, i) -> Tier:
        return self.tiers[i]

    @property
    def platforms(self) -> tuple:
        return tuple(t.platform for t in self.tiers)

    def path(self) -> NetworkPath:
        """The full physical link chain as a :class:`NetworkPath`."""
        return NetworkPath(tuple(NetworkConfig(t.protocol, t.uplink)
                                 for t in self.tiers[:-1]))


@dataclass(frozen=True)
class TierPlan:
    """One evaluated (cut list, stage->tier assignment) design point."""
    splits: tuple                    # ordered cut list (K cuts)
    stage_tiers: tuple               # tier names, one per stage (K+1)
    tier_index: tuple                # tier indices, one per stage (K+1)
    latency_s: float                 # pipelined one-sample makespan
    sequential_s: float              # no-overlap reference
    n_micro: int
    stage_s: tuple                   # per physical tier (pass-throughs 0)
    hop_bytes: tuple                 # per physical link
    accuracy_proxy: float = 0.0      # min CS over the cuts (weakest stage)
    refined: bool = False            # latency re-priced by the event engine

    @property
    def speedup(self) -> float:
        return self.sequential_s / self.latency_s if self.latency_s else 1.0

    def satisfies(self, qos: QoSRequirements) -> bool:
        return (self.latency_s <= qos.max_latency_s
                and self.accuracy_proxy >= qos.min_accuracy)

    def runtime_path(self, topology: TierTopology) -> list:
        """One :class:`NetworkConfig` per *logical* wire hop, for a
        ``runtime.SplitRuntime`` executing this plan.  A logical hop that
        store-and-forwards through skipped tiers is priced over the
        composed effective channel (``netsim.channel.compose_channels``).
        """
        out = []
        for j in range(len(self.splits)):
            a, b = self.tier_index[j], self.tier_index[j + 1]
            links = [topology[t] for t in range(a, b)]
            out.append(NetworkConfig(
                links[0].protocol,
                compose_channels([t.uplink for t in links])))
        return out


def _screen_combos(model, topology: TierTopology, pool, cut_counts) -> list:
    """Materialize the (cut list, assignment) candidate set as per-k
    NumPy blocks: ``(cuts (N,k), assigns (N,k))`` index arrays."""
    n_links = len(topology) - 1
    blocks = []
    for k in (cut_counts or range(1, n_links + 1)):
        if k > n_links or k > len(pool):
            continue
        # enumeration routes through the legality authority, restricted
        # to the pool — never a locally re-derived cut set
        cut_lists = [cl for cl in legal_cut_lists(model, k)
                     if all(c in pool for c in cl)]
        assigns = list(itertools.combinations(range(1, n_links + 1), k))
        if not cut_lists or not assigns:
            continue
        blocks.append((np.repeat(np.asarray(cut_lists, int),
                                 len(assigns), axis=0),
                       np.tile(np.asarray(assigns, int),
                               (len(cut_lists), 1))))
    return blocks


def _pareto2_indices(plans: Sequence[TierPlan]) -> list:
    """Indices of the (latency, -accuracy_proxy) Pareto front of a list
    already sorted by (latency, -proxy) — one linear sweep, no O(N^2)."""
    out, best = [], -np.inf
    for i, p in enumerate(plans):
        if not out or p.accuracy_proxy > best:
            out.append(i)
            best = max(best, p.accuracy_proxy)
    return out


def plan_tiers(model, params, topology: TierTopology, *,
               n_micro: int = 4, cs_curve=None, layer_idx=None,
               compression: float = 0.5, wire_dtype_bytes: int = 4,
               batch: int = 1, sample=None, cut_pool=None,
               cut_counts=None, max_evals: int = 2048,
               refine: int = 8, obs=None) -> list:
    """Search cut-list x stage->tier assignment over ``topology``.

    Every legal cut list of each considered length (default: 1 up to the
    number of links) is combined with every increasing assignment of its
    stages onto the tier chain (stage 0 always on tier 0 — the sensing
    node; skipped tiers forward the payload without computing, ending
    early is allowed).  The search is two-phase:

    1. **screen** — the *whole* combo set is priced with the vectorized
       closed-form engine (``netsim.analytic``): per-layer FLOPs prefix
       sums and per-cut payloads are computed once, every combination's
       sequential and ``n_micro``-pipelined makespan as array ops.  The
       screen is exhaustive — no combination is ever dropped.
    2. **refine** — the (latency, accuracy-proxy) Pareto front plus the
       ``refine`` fastest survivors are re-priced exactly by the event
       engine (``netsim.simulator.simulate_pipeline``), with a built-in
       assertion that the closed form agrees to 1e-9 relative on
       loss-free paths (``TierPlan.refined`` marks them).  On lossy
       links the screen is loss-free-optimistic, so refinement iterates
       to a fixpoint: the front and top-``refine`` of the *final*
       ordering are guaranteed event-priced (the QoS winner
       ``suggest_tier_plan`` picks is always on that front); plans
       outside the shortlist keep the screen price.

    Returns :class:`TierPlan`\\ s for **all** combos, sorted by
    (pipelined latency, -accuracy proxy).  ``cut_pool`` restricts the
    cuts considered (e.g. a CS shortlist); ``max_evals`` bounds only the
    exact-refinement stage (never the sweep) — a shortlist longer than
    ``max_evals`` warns and refines its head.  ``refine=0`` skips
    refinement entirely (pure closed-form screen).

    ``obs`` (a ``repro.obs.Recorder``): the two phases become wall-clock
    spans — ``planner.screen`` with the swept combo count,
    ``planner.refine`` with the event-engine re-pricing count and
    fixpoint rounds — plus ``planner.screen_combos`` /
    ``planner.refined_plans`` counters, so the screen/refine split is
    *visible* in the exported trace rather than asserted by a benchmark.
    """
    from repro.core.scenarios import _sample_scale
    obs = NULL if obs is None else obs
    t_screen0 = obs.tracer.wall_now()
    scale = _sample_scale(batch, sample)
    prefix = S.flops_prefix(model, params, batch, sample=sample) * scale
    pay = cut_payload_bytes_lut(model, params, batch,
                                compression=compression,
                                wire_dtype_bytes=wire_dtype_bytes,
                                sample=sample)
    pos = ({sp: i for i, sp in enumerate(layer_idx)}
           if cs_curve is not None else {})
    pool = set(legal_cuts(model))
    if cut_pool is not None:
        pool &= set(cut_pool)
    if cs_curve is not None:
        pool &= set(pos)

    platforms = topology.platforms
    n_tiers, n_links = len(topology), len(topology) - 1
    full_path = topology.path()
    pp = analytic.path_params(full_path)
    cs_lut = np.zeros(len(pay))
    if cs_curve is not None:
        for sp, i in pos.items():
            cs_lut[sp] = float(cs_curve[i])

    plans = []
    for cuts_arr, asg_arr in _screen_combos(model, topology, pool,
                                            cut_counts):
        N, k = cuts_arr.shape
        rows_ix = np.arange(N)[:, None]
        # (n_combos, K+1) stage-time tensor: prefix-sum differences over
        # the stage bounds, scattered onto the assigned physical tiers
        bounds = np.concatenate([np.zeros((N, 1), int), cuts_arr + 1,
                                 np.full((N, 1), len(pay), int)], axis=1)
        stage_f = prefix[bounds[:, 1:]] - prefix[bounds[:, :-1]]
        tier_idx = np.concatenate([np.zeros((N, 1), int), asg_arr], axis=1)
        stage_t = np.zeros((N, n_tiers))
        # pricing routes through each platform's compute_time (the single
        # compute-pricing authority), one vectorized call per tier
        for t in range(n_tiers):
            r, c = np.nonzero(tier_idx == t)
            if len(r):
                stage_t[r, t] = platforms[t].compute_time(stage_f[r, c])
        # (n_combos, K) hop-byte tensor: link l carries the payload of
        # logical hop j = #{assigned tiers <= l}; links past the last
        # assigned tier are unused
        cov = (asg_arr[:, :, None]
               <= np.arange(n_links)[None, None, :]).sum(1)
        used = cov < k
        hop_b = np.where(
            used, pay[cuts_arr[rows_ix, np.clip(cov, 0, k - 1)]], 0.0)

        pipe_s, seq_s = analytic.pipeline_makespan_s(stage_t, hop_b, pp,
                                                     n_micro, hop_mask=used)
        # microbatching is a choice: where packetisation overhead beats
        # the overlap, the plan ships unchopped (n_micro 1)
        lat = np.minimum(pipe_s, seq_s)
        n_eff = np.where(seq_s < pipe_s, 1, n_micro)
        proxy = (cs_lut[cuts_arr].min(axis=1) if cs_curve is not None
                 else np.zeros(N))
        for i in range(N):
            idx = tuple(tier_idx[i])
            last = idx[-1]
            plans.append(TierPlan(
                tuple(int(c) for c in cuts_arr[i]),
                tuple(topology[t].name for t in idx), idx,
                float(lat[i]), float(seq_s[i]), int(n_eff[i]),
                tuple(float(s) for s in stage_t[i, :last + 1]),
                tuple(int(b) for b in hop_b[i, :last]),
                float(proxy[i])))

    if obs.enabled:
        obs.tracer.add("planner.screen", t_screen0, obs.tracer.wall_now(),
                       clock="wall", tid="planner", cat="planner",
                       args={"n_combos": len(plans), "n_micro": n_micro,
                             "n_tiers": n_tiers})
        obs.metrics.counter("planner.screen_combos").inc(len(plans))

    order = lambda p: (p.latency_s, -p.accuracy_proxy)  # noqa: E731
    plans.sort(key=order)
    # fixpoint refinement: re-pricing a lossy shortlist moves it upward
    # (the screen is loss-free-optimistic for TCP), which can promote
    # un-refined plans into the front/top-K of the *new* ordering —
    # iterate until the final ordering's Pareto front and `refine`
    # fastest plans are all event-priced (one pass suffices on exact
    # paths: prices don't move).  The QoS winner downstream
    # (suggest_tier_plan) is always on that front, so it can never be a
    # screen price.  max_evals bounds the total event-engine calls.
    budget = max_evals if refine else 0
    t_refine0, n_refined, n_rounds = obs.tracer.wall_now(), 0, 0
    n_infeasible = 0
    while refine and plans:
        shortlist = sorted(set(_pareto2_indices(plans))
                           | set(range(min(refine, len(plans)))))
        todo = [i for i in shortlist if not plans[i].refined]
        if not todo:
            break
        capped = budget < len(todo)
        if capped:
            warnings.warn(
                f"plan_tiers screened all {len(plans)} (cut list, "
                f"assignment) combinations closed-form, but the event "
                f"engine re-priced only {max_evals} plans "
                f"(max_evals={max_evals}); {len(todo) - budget} "
                f"shortlisted plans keep screen latencies — exact on "
                f"loss-free paths, loss-free-optimistic otherwise",
                stacklevel=2)
            todo = todo[:budget]
        budget -= len(todo)
        for i in todo:
            p = plans[i]
            path = NetworkPath(full_path.hops[:p.tier_index[-1]])
            try:
                pipe = simulate_pipeline(list(p.stage_s), list(p.hop_bytes),
                                         path, n_micro=n_micro,
                                         check_closed_form=True)
            except RetryBudgetExceeded:
                # the event engine found a hop too lossy to deliver: the
                # plan is infeasible (inf latency fails every QoS bar),
                # the sweep continues
                n_infeasible += 1
                plans[i] = replace(p, latency_s=float("inf"),
                                   sequential_s=float("inf"), refined=True)
                continue
            n_eff, lat = n_micro, pipe.latency_s
            if pipe.sequential_s < lat:
                n_eff, lat = 1, pipe.sequential_s
            plans[i] = replace(p, latency_s=lat,
                               sequential_s=pipe.sequential_s,
                               n_micro=n_eff, refined=True)
        n_refined += len(todo)
        n_rounds += 1
        plans.sort(key=order)
        if capped:
            break
    if obs.enabled and refine:
        obs.tracer.add("planner.refine", t_refine0, obs.tracer.wall_now(),
                       clock="wall", tid="planner", cat="planner",
                       args={"n_refined": n_refined, "rounds": n_rounds,
                             "n_combos": len(plans),
                             "n_infeasible": n_infeasible})
        obs.metrics.counter("planner.refined_plans").inc(n_refined)
        if n_infeasible:
            obs.metrics.counter("planner.infeasible_plans").inc(n_infeasible)
    return plans


def suggest_tier_plan(plans: Sequence[TierPlan],
                      qos: QoSRequirements) -> Optional[TierPlan]:
    """The best QoS-feasible tier plan: max accuracy proxy, then min
    pipelined latency (None when nothing in ``plans`` satisfies).

    On a :func:`plan_tiers` result (``refine > 0``, ``max_evals`` not
    hit) the winner is guaranteed event-priced: it always lies on the
    (latency, -proxy) Pareto front, which refinement re-prices to a
    fixpoint — so a loss-blind screen latency can never be what clears
    the QoS bar here."""
    ok = [p for p in plans if p.satisfies(qos)]
    if not ok:
        return None
    return max(ok, key=lambda p: (p.accuracy_proxy, -p.latency_s))


@dataclass(frozen=True)
class PlanPoint:
    """One evaluated deployment option for one device class."""
    device: str
    label: str                       # 'SC@k' | 'RC' | 'LC'
    split_layer: Optional[int]
    protocol: Optional[str]
    max_batch: int
    n_replicas: int
    p50_s: float
    p99_s: float
    accuracy: float
    server_flops_per_s: float
    drop_fraction: float
    batch_window_s: float = 0.0      # window the point was simulated under
    engine: str = "event"            # cluster engine that priced this point

    def objectives(self) -> tuple:
        """Minimised objective vector for the Pareto filter."""
        return (self.p99_s, -self.accuracy, self.server_flops_per_s)

    def satisfies(self, qos: QoSRequirements) -> bool:
        return (self.p99_s <= qos.max_latency_s
                and self.accuracy >= qos.min_accuracy
                and self.drop_fraction == 0.0)


class DeploymentPlanner:
    """Searches deployments of ``model`` for a heterogeneous fleet.

    ``ae_map`` maps split layer -> trained bottleneck AE (splits without an
    entry ship the raw activation).  ``accuracy_fn(scenario, netcfg)``
    overrides the measured-accuracy path (tests / analytic proxies);
    without it, accuracy comes from ``ApplicationSimulator`` on
    ``eval_data`` — real forwards on loss-corrupted tensors.

    ``cost``: any :class:`repro.api.types.CostModel` pricing both the
    per-flow stage times and the server's batched service time; cells it
    can't price fall back to the analytic FLOPs model.  (The
    pre-``repro.api`` ``cost_source=``/``calibration=`` pair was removed
    after a deprecation cycle; ``cost=table`` is the spelling.)

    ``obs`` (a ``repro.obs.Recorder``): :meth:`search` emits wall-clock
    phase spans (one per device class, with leg/point counts) and
    ``planner.evaluated_points`` / ``planner.screened_legs`` counters.
    The throwaway grid-point cluster simulations are deliberately *not*
    traced (a full search would swamp the trace with dead design
    points); :func:`simulate_deployment` traces the chosen plans' shared
    clusters instead.
    """

    def __init__(self, model, params, *, cs_curve, layer_idx,
                 ae_map=None, eval_data=None, accuracy_fn=None,
                 lc_model=None, lc_params=None,
                 server_platform=PLATFORMS["server-gpu"],
                 input_bytes: Optional[int] = None, n_frames: int = 8,
                 cost=None, sample=None, obs=None):
        if accuracy_fn is None and eval_data is None:
            raise ValueError("need eval_data to measure accuracy "
                             "(or pass accuracy_fn)")
        if input_bytes is None and eval_data is None:
            raise ValueError("need input_bytes when no eval_data is given "
                             "(it is derived from the eval inputs otherwise)")
        self.model, self.params = model, params
        self.cs_curve, self.layer_idx = cs_curve, list(layer_idx)
        self.ae_map = dict(ae_map or {})
        self.eval_data = eval_data
        self.accuracy_fn = accuracy_fn
        self.lc_model, self.lc_params = lc_model, lc_params
        self.server_platform = server_platform
        if input_bytes is None:
            xs = eval_data[0]
            input_bytes = int(np.prod(xs.shape[1:])) * 4
        self.input_bytes = input_bytes
        self.n_frames = n_frames
        self.cost = cost
        # example input pytree for models whose input_shape cannot
        # describe the input (transformer layered views)
        self.sample = sample
        self.obs = NULL if obs is None else obs
        self._flow_cache = {}
        self._cost_cache = {}
        # design points whose wire pricing blew the TCP retry budget
        # (link infeasible at that loss rate): skipped, not crashed
        self.n_infeasible_legs = 0

    # ------------------------------------------------------- candidates ----
    def candidates(self, space: SearchSpace) -> list[SplitCandidate]:
        """CS-ranked SC cuts (pruned to top-k) plus RC/LC per the space
        flags — core.qos ranking reused as-is.  Elements are
        :class:`repro.api.types.SplitCandidate`\\ s (tuple-compatible with
        the historical ``(label, split_layer)`` shape)."""
        ranked = rank_candidates(self.cs_curve, self.layer_idx,
                                 space.split_points, include_lc_rc=False)
        out = list(ranked[:space.top_k_splits])
        if space.include_rc:
            out.append(SplitCandidate.rc())
        if space.include_lc and self.lc_model is not None:
            out.append(SplitCandidate.lc())
        return out

    def _scenario(self, device: DeviceClass, label: str,
                  split: Optional[int]) -> Scenario:
        cand = SplitCandidate.from_any((label, split))
        return cand.scenario(device.platform, self.server_platform)

    # ------------------------------------------------------ per-flow leg ----
    def _flow(self, device: DeviceClass, label: str, split: Optional[int],
              protocol: str) -> dict:
        """Edge compute, wire-time samples and accuracy for one
        (device class, candidate, protocol) leg — cached, since every
        (batch, replicas) point shares it."""
        key = (device.name, label, protocol)
        if key in self._flow_cache:
            return self._flow_cache[key]
        scenario = self._scenario(device, label, split)
        netcfg = NetworkConfig(protocol, device.channel)
        flow = measure_flow(scenario, netcfg, self.model, self.params,
                            self.input_bytes, n_frames=self.n_frames,
                            cost=self.cost, sample=self.sample)
        if self.accuracy_fn is not None:
            acc = float(self.accuracy_fn(scenario, netcfg))
        else:
            xs, ys = self.eval_data
            sim = ApplicationSimulator(
                self.model, self.params, netcfg, ae=self.ae_map.get(split),
                lc_model=self.lc_model, lc_params=self.lc_params)
            # reuse this leg's transfer draws — don't re-simulate them
            acc = sim.simulate(scenario, xs, ys, n_frames=self.n_frames,
                               flow=flow).accuracy
        flow["accuracy"] = acc
        self._flow_cache[key] = flow
        return flow

    def _cost_model(self, split: Optional[int]) -> BatchCostModel:
        if split not in self._cost_cache:
            cost = None
            if self.cost is not None and hasattr(self.cost, "server_cost"):
                # measured (or otherwise externally priced) server stage
                cost = self.cost.server_cost(split, self.server_platform)
            if cost is None:
                cost = BatchCostModel.for_split(
                    self.model, self.params, split, self.server_platform,
                    sample=self.sample)
            self._cost_cache[split] = cost
        return self._cost_cache[split]

    # ------------------------------------------------------- multi-tier ----
    def search_tiers(self, topology: TierTopology, *, n_micro: int = 4,
                     **kw) -> list:
        """Multi-tier search over ``topology``: cut-list x stage->tier
        assignment, priced sequentially and pipelined — the planner-bound
        spelling of :func:`plan_tiers` (CS curve, compression and sample
        wired from this planner's configuration)."""
        return plan_tiers(self.model, self.params, topology,
                          n_micro=n_micro, cs_curve=self.cs_curve,
                          layer_idx=self.layer_idx, sample=self.sample, **kw)

    def default_space(self) -> SearchSpace:
        """Every legal cut the CS curve covers, stock protocol/batch/replica
        grids — what ``suggest`` uses when no space is given.  Legality
        comes from ``api.types.legal_split_candidates`` (which routes
        through ``core.split.validate_cut``, the single authority)."""
        covered = {c.split_layer for c in legal_split_candidates(
            self.model, self.cs_curve, self.layer_idx)}
        sps = tuple(sp for sp in self.layer_idx if sp in covered)
        return SearchSpace(split_points=sps,
                           include_lc=self.lc_model is not None)

    # ---------------------------------------------------------- screening ----
    def _screen_leg(self, device: DeviceClass, label: str,
                    split: Optional[int], proto: str) -> float:
        """Closed-form per-frame flow latency (edge + zero-loss wire +
        server compute) of one (candidate, protocol) leg — the cheap
        stand-in for :meth:`_flow` the two-phase search screens with
        (``netsim.analytic``); no event simulation, no forwards.  Like
        ``measure_flow``, compute times come from the configured cost
        model when it prices the cell (so a calibrated planner screens
        with measured numbers), falling back to the analytic model."""
        scen = self._scenario(device, label, split)
        times = (self.cost.flow_times(scen.kind, split, batch=1)
                 if self.cost is not None else None)
        if times is None:
            times = scenario_times_and_payload(scen, self.model, self.params,
                                               input_bytes=self.input_bytes,
                                               sample=self.sample)
        wire = 0.0
        if times["wire_bytes"] > 0:
            pp = analytic.path_params(
                NetworkPath((NetworkConfig(proto, device.channel),)))
            wire = float(analytic.transfer_duration_s(
                np.array([times["wire_bytes"]]), pp)[0])
        return times["edge_s"] + wire + times["server_s"]

    def _screened_legs(self, device: DeviceClass, cands, space: SearchSpace,
                       refine: int) -> set:
        """Phase-1 screen of one device's (candidate, protocol) legs:
        keep the (closed-form latency, -accuracy proxy) Pareto front plus
        the ``refine`` fastest; returns the surviving ``{(label,
        protocol)}`` set.  LC legs are not screened (no wire, one
        point)."""
        legs = []
        for cand in cands:
            label, split = cand
            if label == "LC":
                continue
            for proto in space.protocols:
                if proto not in device.protocols:
                    continue
                legs.append((self._screen_leg(device, label, split, proto),
                             -float(cand.accuracy_proxy), label, proto))
        legs.sort(key=lambda t: (t[0], t[1]))
        keep, best = set(), -np.inf
        for rank, (lat, nproxy, label, proto) in enumerate(legs):
            if rank < refine or -nproxy > best:
                keep.add((label, proto))
            best = max(best, -nproxy)
        return keep

    # ------------------------------------------------------------ search ----
    def search(self, trace: Trace, devices: Sequence[DeviceClass],
               space: SearchSpace, *, refine: Optional[int] = None,
               engine: str = "event") -> list:
        """Evaluate the space; returns one PlanPoint per evaluated combo.

        ``refine=None`` (default) evaluates every combination exactly,
        as always.  ``refine=k`` makes the search two-phase: every
        (candidate, protocol) leg is first scored with the closed-form
        analytic flow model (:meth:`_screen_leg` — no event engine, no
        forwards), and only the per-device (latency, -accuracy-proxy)
        Pareto front plus the ``k`` fastest legs are evaluated exactly
        (event-engine transfer draws, measured accuracy, and the cluster
        queueing simulation over the full batch x replicas grid).  The
        screen is loss-blind, so on lossy channels prefer a ``k`` wide
        enough to keep the retransmission-sensitive alternatives in.

        ``engine`` picks the cluster simulator pricing each grid point:
        ``"event"`` (default — the exact discrete-event authority),
        ``"vectorized"`` (the arrival-level NumPy engine in
        ``fleet.vectorized``; bit-identical latencies under the
        deterministic service model, orders of magnitude faster on
        megafleet traces), or ``"auto"`` (vectorized above
        ``AUTO_VECTORIZE_MIN`` requests per cluster).  Under a
        non-event engine the search follows the repo's screen/refine
        contract: the whole grid is priced vectorized, then every
        point on the per-device Pareto front is re-priced by the event
        engine (``PlanPoint.engine`` records which simulator produced
        each number).
        """
        obs = self.obs
        points, recipes = [], []
        for device in devices:
            sub = trace.for_device(device.name)
            if not len(sub):
                continue
            t_dev0, n_before = obs.tracer.wall_now(), len(points)
            cands = self.candidates(space)
            allowed = (self._screened_legs(device, cands, space, refine)
                       if refine is not None else None)
            if obs.enabled and allowed is not None:
                obs.metrics.counter("planner.screened_legs").inc(len(allowed))
            for label, split in cands:
                if label == "LC":
                    points.append(self._lc_point(device, sub))
                    recipes.append(None)
                    continue
                for proto in space.protocols:
                    if proto not in device.protocols:
                        continue
                    if allowed is not None and (label, proto) not in allowed:
                        continue
                    try:
                        flow = self._flow(device, label, split, proto)
                    except RetryBudgetExceeded:
                        # the link is too lossy to deliver this leg's
                        # payload reliably: an infeasible design point,
                        # not a planner crash — skip it and count it
                        self.n_infeasible_legs += 1
                        if obs.enabled:
                            obs.metrics.counter(
                                "planner.infeasible_legs").inc()
                        continue
                    for b, r in itertools.product(space.batch_sizes,
                                                  space.replica_counts):
                        args = (device, sub, label, split, proto, flow,
                                b, r, space.batch_window_s)
                        points.append(self._cluster_point(*args,
                                                          engine=engine))
                        recipes.append(args)
            if obs.enabled:
                n_dev = len(points) - n_before
                obs.tracer.add(f"planner.search:{device.name}", t_dev0,
                               obs.tracer.wall_now(), clock="wall",
                               tid="planner", cat="planner",
                               args={"n_points": n_dev,
                                     "n_requests": len(sub),
                                     "screened": allowed is not None})
                obs.metrics.counter("planner.evaluated_points").inc(n_dev)
        if engine != "event":
            self._refine_front(points, recipes)
        return points

    def _refine_front(self, points: list, recipes: list) -> None:
        """Screen/refine contract for the cluster engine: re-price every
        vectorized-screened point on the per-device Pareto front with the
        exact event engine, in place.  (The vectorized engine replays the
        event semantics exactly under the deterministic service model, so
        this normally changes nothing — it is the standing guarantee that
        no plan is ever *chosen* on a fast-path price alone.)"""
        index = {id(p): i for i, p in enumerate(points)}
        n_ref = 0
        for p in self.pareto_front(points):
            i = index[id(p)]
            if recipes[i] is None or points[i].engine == "event":
                continue
            points[i] = self._cluster_point(*recipes[i], engine="event")
            n_ref += 1
        if self.obs.enabled and n_ref:
            self.obs.metrics.counter("planner.refined_points").inc(n_ref)

    def _lc_point(self, device: DeviceClass, sub: Trace) -> PlanPoint:
        """All-edge: no queueing, no server FLOPs, LC-model accuracy."""
        flow = self._flow(device, "LC", None, device.protocols[0])
        lat = flow["edge_s"]
        return PlanPoint(device.name, "LC", None, None, 0, 0,
                         lat, lat, flow["accuracy"], 0.0, 0.0)

    def _cluster_point(self, device: DeviceClass, sub: Trace, label: str,
                       split: Optional[int], proto: str, flow: dict,
                       max_batch: int, n_replicas: int, window_s: float,
                       engine: str = "event") -> PlanPoint:
        cost = self._cost_model(split)
        cfg = ClusterConfig(n_replicas, max_batch, window_s)
        engine = _resolve_engine(engine, len(sub))
        horizon = max(sub.horizon_s, 1e-9)
        if engine == "vectorized":
            # request i reaches the cluster after its edge compute + its
            # own transfer draw (frames cycled, matching the event path)
            t_arr = sub.arrival_times()
            wire = np.asarray(flow["wire_s"], float)
            pre = flow["edge_s"] + wire[np.arange(len(t_arr)) % len(wire)]
            vstats = simulate_cluster_vectorized(t_arr + pre, cost, cfg)
            keep = ~vstats.drop_mask
            lat = pre[keep] + (vstats.t_done[keep] - vstats.t_offer[keep])
            n_served, drop = vstats.n_served, vstats.drop_fraction()
        else:
            sim = ClusterSim(cost, cfg)
            wire = flow["wire_s"]
            t_server = {}
            for i, req in enumerate(sub.requests):
                pre = flow["edge_s"] + wire[i % len(wire)]
                t_server[req.rid] = pre
                sim.offer(req.rid, req.t_arrival + pre)
            stats = sim.run()
            lat = np.array([t_server[rec.rid] + rec.latency_s
                            for rec in stats.served])
            n_served, drop = len(stats.served), stats.drop_fraction()
        flops_rate = cost.flops_per_item * n_served / horizon
        return PlanPoint(
            device.name, label, split, proto, max_batch, n_replicas,
            float(np.percentile(lat, 50)) if len(lat) else float("inf"),
            float(np.percentile(lat, 99)) if len(lat) else float("inf"),
            flow["accuracy"], flops_rate, drop,
            batch_window_s=window_s, engine=engine)

    # ------------------------------------------------------------ output ----
    @staticmethod
    def pareto_front(points: Sequence[PlanPoint]) -> list:
        """Non-dominated set over (p99 latency, accuracy, server FLOPs/s),
        per device class.  Ties on the whole objective vector keep only the
        cheapest deployment (fewest replicas, then smallest batch)."""
        front = []
        for dev in sorted({p.device for p in points}):
            best = {}
            for p in points:
                if p.device != dev:
                    continue
                obj = p.objectives()
                cur = best.get(obj)
                if cur is None or (p.n_replicas, p.max_batch) < (cur.n_replicas,
                                                                 cur.max_batch):
                    best[obj] = p
            mine = [(p, obj) for obj, p in best.items()]
            front.extend(p for p, _ in pareto_nd(mine))
        return sorted(front, key=lambda p: (p.device, p.p99_s))

    def suggest(self, qos: QoSRequirements, fleet,
                space: Optional[SearchSpace] = None,
                points: Optional[Sequence[PlanPoint]] = None) -> dict:
        """Pick one deployment plan per device class.

        ``fleet`` is ``(trace, device_classes)``.  Returns
        ``{device_name: PlanPoint | None}`` — only QoS-feasible plans are
        ever returned; ``None`` marks a class no searched plan can serve
        (caller should relax QoS, add replicas, or change the network).
        Pass ``points`` from an earlier :meth:`search` to skip
        re-evaluating the space.
        """
        trace, devices = fleet
        if points is None:
            points = self.search(trace, devices,
                                 space if space is not None
                                 else self.default_space())
        plans = {}
        for d in devices:
            ok = [p for p in points if p.device == d.name and p.satisfies(qos)]
            # max accuracy, then min p99, then cheapest server
            plans[d.name] = (max(ok, key=lambda p: (p.accuracy, -p.p99_s,
                                                    -p.server_flops_per_s))
                             if ok else None)
        return plans


def simulate_deployment(plans: dict, trace: Trace,
                        devices: Sequence[DeviceClass],
                        planner: DeploymentPlanner, *, obs=None,
                        engine: str = "event",
                        check_event_engine: bool = False) -> dict:
    """Joint validation: run the chosen per-class plans against the *mixed*
    trace, sharing one cluster per (split, batch, replicas) group so device
    classes genuinely contend for the same replicas.  Each group runs under
    the batching window its plans were searched with.  Returns fleet-level
    p50/p99 per group (each row records the ``engine`` that produced it).

    ``engine``: ``"event"`` (default), ``"vectorized"``, or ``"auto"``
    (vectorized above ``AUTO_VECTORIZE_MIN`` requests per group) — the
    same knob as :meth:`DeploymentPlanner.search`.  With
    ``check_event_engine=True`` a vectorized group is additionally
    replayed by the event engine and asserted to agree (exact drop
    counts, percentiles within ``fleet.vectorized.PCTL_RTOL``).

    ``obs``: the shared clusters run fully traced — per-request lifecycle
    spans (wire -> queue wait -> service), per-replica batch tracks, and
    the windowed fleet time series (the vectorized engine feeds the same
    ``fleet.*`` series from its arrival arrays).  This is *the* fleet
    simulation ``Study.observe()`` exports: the deployment you actually
    chose, under the mixed trace."""
    obs = NULL if obs is None else obs
    by_dev = {d.name: d for d in devices}
    groups = {}
    for name, plan in plans.items():
        if plan is None or plan.label == "LC":
            continue
        groups.setdefault((plan.split_layer, plan.max_batch,
                           plan.n_replicas, plan.batch_window_s),
                          []).append(plan)
    out = {}
    for (split, b, r, window_s), members in groups.items():
        cost = planner._cost_model(split)
        cfg = ClusterConfig(r, b, window_s)
        n_group = sum(len(trace.for_device(p.device)) for p in members)
        eng = _resolve_engine(engine, n_group)
        if eng == "vectorized":
            t_parts, pre_parts, txs_parts, txb_parts = [], [], [], []
            for plan in members:
                device = by_dev[plan.device]
                flow = planner._flow(device, plan.label, plan.split_layer,
                                     plan.protocol)
                sub = trace.for_device(plan.device)
                t_arr = sub.arrival_times()
                wire = np.asarray(flow["wire_s"], float)
                wire = wire[np.arange(len(t_arr)) % len(wire)]
                t_parts.append(t_arr)
                pre_parts.append(flow["edge_s"] + wire)
                txs_parts.append(wire)
                txb_parts.append(np.full(len(t_arr),
                                         int(flow.get("wire_bytes", 0))))
            t_all = np.concatenate(t_parts)
            pre = np.concatenate(pre_parts)
            vstats = simulate_cluster_vectorized(
                t_all + pre, cost, cfg, tx_s=np.concatenate(txs_parts),
                tx_bytes=np.concatenate(txb_parts), obs=obs,
                check_event_engine=check_event_engine)
            keep = ~vstats.drop_mask
            lat = pre[keep] + (vstats.t_done[keep] - vstats.t_offer[keep])
            n_served, drop = vstats.n_served, vstats.drop_fraction()
            mean_batch = vstats.mean_batch()
            util = vstats.utilization(r, trace.horizon_s)
        else:
            sim = ClusterSim(cost, cfg, obs=obs)
            pre = {}
            for plan in members:
                device = by_dev[plan.device]
                flow = planner._flow(device, plan.label, plan.split_layer,
                                     plan.protocol)
                sub = trace.for_device(plan.device)
                wire_bytes = int(flow.get("wire_bytes", 0))
                for i, req in enumerate(sub.requests):
                    wire = flow["wire_s"][i % len(flow["wire_s"])]
                    head = flow["edge_s"] + wire
                    pre[req.rid] = head
                    sim.offer(req.rid, req.t_arrival + head,
                              tx_s=wire, tx_bytes=wire_bytes)
            stats = sim.run()
            lat = np.array([pre[rec.rid] + rec.latency_s
                            for rec in stats.served])
            n_served, drop = len(stats.served), stats.drop_fraction()
            mean_batch = stats.mean_batch()
            util = stats.utilization(r, trace.horizon_s)
        out[(split, b, r, window_s)] = {
            "devices": sorted(p.device for p in members),
            "n_served": n_served,
            "drop_fraction": drop,
            "p50_s": float(np.percentile(lat, 50)) if len(lat) else float("inf"),
            "p99_s": float(np.percentile(lat, 99)) if len(lat) else float("inf"),
            "mean_batch": mean_batch,
            "utilization": util,
            "engine": eng,
        }
    return out
