"""Online adaptive replanning: a drift-aware control loop over the fleet.

The planner (``fleet.planner``) answers "which deployment for *this*
workload" once, offline.  Real workloads move: arrival rates swing,
links degrade, replicas fail.  :class:`AdaptiveController` closes the
loop — it watches a running cluster's windowed decision signals, detects
regime changes, re-screens the candidate space, and switches plans live
through an explicit migration model.

The control loop, end to end::

      telemetry window        drift detection           re-screen
    ┌──────────────────┐   ┌──────────────────┐   ┌────────────────────┐
    │ rate, drops,     │──▶│ rate-drift / drop │──▶│ closed-form screen │
    │ queue depth, p99 │   │ / queue / fault   │   │ + vectorized price │
    └──────────────────┘   └──────────────────┘   └─────────┬──────────┘
              ▲                                             │ hysteresis
              │            ┌──────────────────┐             ▼
              └────────────│ era simulation   │◀── switch: drain old,
                           │ (either engine)  │     warm up new
                           └──────────────────┘

Design invariants:

* **Engine-matched decisions.**  Drift detection keys only on signals
  that are *exactly* identical across the event and vectorized cluster
  engines (arrival counts, drop counts, queue depth — integers the two
  engines agree on by construction), and every candidate is priced with
  the vectorized engine regardless of which engine runs the simulation.
  ``run(scenario, engine="event")`` and ``engine="vectorized"``
  therefore make *identical switch decisions*; only float-accumulation
  noise in reported percentiles differs (the standing ``PCTL_RTOL``
  contract of ``fleet.vectorized``).

* **Eras.**  A run is a sequence of plan eras.  Each era is a fresh
  cluster (on either engine); at a switch the old era *drains* — every
  request that arrived before the switch finishes (or drops) on the old
  plan — while the new era's early arrivals pay an explicit warm-up:
  their cluster offer time is clamped to ``t_switch + warmup_s``.  The
  number of requests delayed and the total added delay are the
  *migration disruption*, reported per switch and in aggregate.

* **Bounded flapping.**  A voluntary switch requires an improvement
  margin (``min_improvement``), respects a cooldown, and is refused
  outright once ``max_switches`` voluntary switches have happened — the
  bound is a hard guard on the switch path, so ``n_switches <=
  max_switches`` holds for every scenario by construction.  Forced
  reconfigurations (replica fail/recover capping the live pool) do not
  count against the bound: they are physics, not policy.

* **Static is the same machinery.**  :meth:`run_static` runs the
  identical era pipeline with replanning disabled, so "adaptive with no
  triggers" and "static" produce bit-identical latencies — the no-op
  property tests assert exact array equality, not approximate closeness.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.qos import QoSRequirements
from repro.fleet.cluster import ClusterConfig, ClusterSim
from repro.fleet.scenario import RegimeChangeTrace
from repro.fleet.traffic import Trace
from repro.fleet.vectorized import signals_at, simulate_cluster_vectorized
from repro.netsim import analytic
from repro.netsim.simulator import NetworkConfig, NetworkPath
from repro.obs import NULL
from repro.serving.engine import BatchCostModel


# ----------------------------------------------------------- candidates ----
@dataclass(frozen=True)
class CandidatePlan:
    """One switchable deployment: a split candidate fully configured.

    The controller's decision space is a *fixed grid* of these — built
    from a :class:`~repro.fleet.planner.DeploymentPlanner` search space
    via :meth:`AdaptiveController.from_planner`, or handed in directly
    (planner-free mode: property tests and benchmarks price candidates
    without a model in sight)."""
    key: str                         # unique id, e.g. "SC@3:tcp:b8:r2"
    label: str                       # 'SC@k' | 'RC'
    split: Optional[int]
    protocol: str
    max_batch: int
    n_replicas: int
    batch_window_s: float
    cost: BatchCostModel             # server-side batched service model
    queue_limit: int = 4096

    def cluster_cfg(self, k_eff: int) -> ClusterConfig:
        return ClusterConfig(k_eff, self.max_batch, self.batch_window_s,
                             self.queue_limit)

    def capacity_hz(self, avail: Optional[int] = None) -> float:
        """Closed-form saturation throughput: ``k * B / svc(B)``."""
        k = self.n_replicas if avail is None else min(self.n_replicas, avail)
        return k * self.max_batch / self.cost.service_time(self.max_batch)


@dataclass(frozen=True)
class ControllerConfig:
    """Control-loop tuning.  ``None`` disables the matching trigger;
    with every trigger disabled and a fault-free scenario the adaptive
    run is provably a no-op (exactly equal to the static run)."""
    control_period_s: float = 1.0    # decision (and signal-window) cadence
    drift_threshold: Optional[float] = 0.35   # |rate-ref|/ref to trigger
    drop_trigger: Optional[float] = 0.0       # window drop fraction >
    queue_trigger: Optional[int] = None       # queue depth >
    min_improvement: float = 0.10    # new p99 < (1-m) * incumbent p99
    cooldown_s: float = 0.0          # min spacing between switches
    warmup_s: float = 0.0            # new-plan offers clamped to t+warmup
    max_switches: int = 4            # hard cap on voluntary switches
    max_priced: int = 8              # shortlist size priced per replan
    fault_trigger: Optional[int] = None   # runtime fault reports/window >=


@dataclass
class SwitchRecord:
    """One plan transition (voluntary or forced), with its migration
    disruption filled in as the new era accumulates arrivals."""
    t_s: float
    from_key: str
    to_key: str
    reason: str     # rate-drift|drops|queue|fault|runtime-fault|replica-cap
    forced: bool = False
    predicted_p99_s: float = float("nan")   # priced p99 of the new plan
    incumbent_p99_s: float = float("nan")   # priced p99 of the old plan
    n_delayed: int = 0               # requests held back by warm-up
    added_delay_s: float = 0.0       # total seconds of warm-up delay


@dataclass(frozen=True)
class EraStats:
    """One plan era's outcome (arrivals in ``[t_start, t_end)``, drained
    to completion on that era's plan)."""
    key: str
    t_start: float
    t_end: float
    n_replicas: int
    n_offered: int
    n_served: int
    dropped: int
    p50_s: float
    p99_s: float
    forced: bool                     # era opened by a forced reconfig


@dataclass(frozen=True)
class AdaptiveRunResult:
    """Everything one adaptive (or static) run produced."""
    engine: str
    latencies: np.ndarray            # end-to-end seconds, served requests
    n_offered: int
    dropped: int
    eras: tuple                      # EraStats, time order
    switches: tuple                  # SwitchRecord, time order
    n_decisions: int                 # control ticks evaluated
    n_replans: int                   # re-screens actually computed
    n_suppressed: int                # triggers that did not switch

    @property
    def n_switches(self) -> int:
        """Voluntary switches — the quantity ``max_switches`` bounds."""
        return sum(1 for s in self.switches if not s.forced)

    @property
    def n_forced(self) -> int:
        return sum(1 for s in self.switches if s.forced)

    @property
    def plan_keys(self) -> tuple:
        return tuple(e.key for e in self.eras)

    @property
    def p50_s(self) -> float:
        return (float(np.percentile(self.latencies, 50))
                if len(self.latencies) else float("nan"))

    @property
    def p99_s(self) -> float:
        return (float(np.percentile(self.latencies, 99))
                if len(self.latencies) else float("nan"))

    @property
    def drop_fraction(self) -> float:
        return self.dropped / self.n_offered if self.n_offered else 0.0

    @property
    def migration(self) -> dict:
        """Aggregate migration disruption across every switch."""
        return {"n_delayed": sum(s.n_delayed for s in self.switches),
                "added_delay_s": sum(s.added_delay_s
                                     for s in self.switches)}


# ------------------------------------------------------------ era state ----
class _Era:
    """Mutable state of the currently-running plan era."""

    __slots__ = ("cand", "k_eff", "t_start", "warmup_end", "forced",
                 "switch", "t_arr", "offer", "count", "dev_pos", "sim",
                 "drops_mark", "served_mark")

    def __init__(self, cand: CandidatePlan, k_eff: int, t_start: float,
                 warmup_end: float, forced: bool,
                 switch: Optional[SwitchRecord]):
        self.cand, self.k_eff = cand, k_eff
        self.t_start, self.warmup_end = t_start, warmup_end
        self.forced, self.switch = forced, switch
        self.t_arr: list = []        # arrival-time chunks (np arrays)
        self.offer: list = []        # matching offer-time chunks
        self.count = 0
        self.dev_pos: dict = {}      # device name -> wire-draw cursor
        self.sim: Optional[ClusterSim] = None   # event engine only
        self.drops_mark = 0          # cumulative drops at last decision
        self.served_mark = 0         # served-list watermark

    def arrays(self):
        if not self.t_arr:
            return np.empty(0), np.empty(0)
        return np.concatenate(self.t_arr), np.concatenate(self.offer)


_ZERO_FLOW = {"edge_s": 0.0, "wire_s": np.zeros(1), "wire_bytes": 0,
              "accuracy": 1.0}


class AdaptiveController:
    """Drift-aware closed-loop replanner over a fixed candidate grid.

    ``flow_fn(device, cand, protocol) -> {"edge_s", "wire_s",
    "wire_bytes", "accuracy"}`` prices the per-device pre-cluster leg
    (edge compute + wire draws); ``None`` means a zero-cost leg
    (planner-free mode).  Flows are cached per (device, candidate label,
    protocol, link epoch) — a link degradation advances the epoch and
    forces a re-price, which is how degraded wires reach both the
    simulation and the replan pricing.
    """

    def __init__(self, candidates: Sequence[CandidatePlan], *,
                 qos: Optional[QoSRequirements] = None,
                 config: Optional[ControllerConfig] = None,
                 flow_fn: Optional[Callable] = None,
                 planner=None, obs=None):
        cands = tuple(candidates)
        if not cands:
            raise ValueError("need at least one CandidatePlan")
        keys = [c.key for c in cands]
        if len(set(keys)) != len(keys):
            raise ValueError("candidate keys must be unique")
        self.candidates = cands
        self.by_key = {c.key: c for c in cands}
        self.qos = qos
        self.config = config if config is not None else ControllerConfig()
        self.obs = NULL if obs is None else obs
        self._flow_fn = flow_fn
        self._planner = planner      # for epoch-keyed flow-cache clearing
        self._flow_cache: dict = {}
        self._planner_epochs = None
        self._scheds: dict = {}
        self._mix: dict = {}
        # runtime fault reports (t_s, n): the live runtime's recovery
        # counters, consumed by the fault_trigger rescue rule
        self._fault_reports: list = []

    def report_faults(self, t_s: float, n: int = 1) -> None:
        """Feed the controller the live runtime's fault counters (e.g.
        ``RuntimeResult.meta["recovery"]["retries"]`` or a
        ``runtime.fault.*`` telemetry sum) stamped at sim-time ``t_s``.
        With ``config.fault_trigger`` set, ``>= fault_trigger`` reported
        faults inside one control window trigger a replan (reason
        ``"runtime-fault"``) — the runtime's degradation becomes a
        rescue signal, not just a log line."""
        if n > 0:
            self._fault_reports.append((float(t_s), int(n)))

    def _runtime_faults_between(self, t0: float, t1: float) -> int:
        return sum(n for t, n in self._fault_reports if t0 < t <= t1)

    # ------------------------------------------------------ construction ----
    @classmethod
    def from_planner(cls, planner, space, *, qos=None, config=None,
                     obs=None) -> "AdaptiveController":
        """The controller's grid from a planner's search space: CS-ranked
        split candidates x protocol x batch x replicas, each priced by
        the planner's flow machinery (measured or analytic, whatever the
        planner was configured with)."""
        import itertools
        cands = []
        for sc in planner.candidates(space):
            label, split = sc
            if label == "LC":        # all-edge: nothing to re-plan
                continue
            for proto in space.protocols:
                for b, r in itertools.product(space.batch_sizes,
                                              space.replica_counts):
                    cands.append(CandidatePlan(
                        key=f"{label}:{proto}:b{b}:r{r}", label=label,
                        split=split, protocol=proto, max_batch=b,
                        n_replicas=r, batch_window_s=space.batch_window_s,
                        cost=planner._cost_model(split)))

        def flow_fn(device, cand, proto):
            return planner._flow(device, cand.label, cand.split, proto)

        return cls(cands, qos=qos, config=config, flow_fn=flow_fn,
                   planner=planner, obs=obs if obs is not None
                   else planner.obs)

    # ------------------------------------------------------------- flows ----
    def _flow_for(self, device, cand: CandidatePlan, epoch: int) -> dict:
        proto = (cand.protocol if cand.protocol in device.protocols
                 else device.protocols[0])
        key = (device.name, cand.label, proto, epoch)
        hit = self._flow_cache.get(key)
        if hit is not None:
            return hit
        if self._flow_fn is None:
            flow = _ZERO_FLOW
        else:
            if self._planner is not None and epoch != self._planner_epochs:
                # the planner caches flows per (device, label, protocol)
                # with no link-state key; a new epoch means those prices
                # are stale for the changed channel
                self._planner._flow_cache.clear()
                self._planner_epochs = epoch
            flow = self._flow_fn(device, cand, proto)
        self._flow_cache[key] = flow
        return flow

    def _device_at_epoch(self, name: str, epoch: int):
        d, sched = self._mix[name], self._scheds[name]
        if epoch == 0:
            return d
        return replace(d, channel=sched.events[epoch - 1][1])

    # ------------------------------------------------------- offer build ----
    def _offer_times(self, era: _Era, t_arr: np.ndarray,
                     dev: np.ndarray) -> np.ndarray:
        """Cluster offer times for arrivals joining ``era``: arrival +
        per-device pre-delay (edge compute + the device's wire draw,
        priced against the link regime active at the arrival), clamped
        to the era's warm-up end.  Clamped requests are the migration
        disruption, tallied onto the switch that opened the era."""
        offer = np.empty(len(t_arr))
        for name in np.unique(dev):
            idxs = np.nonzero(dev == name)[0]
            sched = self._scheds[name]
            pos0 = era.dev_pos.get(name, 0)
            era.dev_pos[name] = pos0 + len(idxs)
            ranks = pos0 + np.arange(len(idxs))
            ev_t = np.array([e[0] for e in sched.events])
            ep = (np.searchsorted(ev_t, t_arr[idxs], side="right")
                  if len(ev_t) else np.zeros(len(idxs), np.int64))
            for e in np.unique(ep):
                m = ep == e
                flow = self._flow_for(self._device_at_epoch(name, int(e)),
                                      era.cand, int(e))
                wire = np.asarray(flow["wire_s"], float)
                pre = flow["edge_s"] + wire[ranks[m] % len(wire)]
                raw = t_arr[idxs[m]] + pre
                clamped = np.maximum(raw, era.warmup_end)
                if era.switch is not None:
                    late = raw < era.warmup_end
                    era.switch.n_delayed += int(late.sum())
                    era.switch.added_delay_s += float(
                        (era.warmup_end - raw[late]).sum())
                offer[idxs[m]] = clamped
        return offer

    # --------------------------------------------------- screen + price ----
    def _screen_latency(self, cand: CandidatePlan, t_now: float) -> float:
        """Closed-form single-request latency proxy (``netsim.analytic``
        wire + edge compute + unbatched service), fleet-weighted — the
        cheap ordering the shortlist is cut with; never a price a switch
        is decided on."""
        num = den = 0.0
        for name, d in self._mix.items():
            epoch = self._scheds[name].epoch(t_now)
            dev = self._device_at_epoch(name, epoch)
            flow = self._flow_for(dev, cand, epoch)
            wire = 0.0
            if flow.get("wire_bytes", 0) > 0:
                proto = (cand.protocol if cand.protocol in dev.protocols
                         else dev.protocols[0])
                pp = analytic.path_params(
                    NetworkPath((NetworkConfig(proto, dev.channel),)))
                wire = float(analytic.transfer_duration_s(
                    np.array([flow["wire_bytes"]]), pp)[0])
            num += d.weight * (flow["edge_s"] + wire)
            den += d.weight
        return num / den + cand.cost.service_time(1)

    def _shortlist(self, rate_hz: float, t_now: float,
                   avail: Optional[int],
                   current: Optional[CandidatePlan]) -> list:
        """Capacity-feasible candidates, ordered by the closed-form
        latency screen, cut to ``max_priced`` (+ the incumbent, always,
        so hysteresis compares like for like)."""
        rows = [(c, c.capacity_hz(avail), self._screen_latency(c, t_now))
                for c in self.candidates]
        ok = [r for r in rows if r[1] > rate_hz]
        if not ok:                   # everything saturates: least-bad first
            ok = sorted(rows, key=lambda r: -r[1])
        short = [r[0] for r in
                 sorted(ok, key=lambda r: (r[2], r[0].key))]
        short = short[:self.config.max_priced]
        if current is not None and all(c.key != current.key for c in short):
            short.append(current)
        return short

    def _price(self, cand: CandidatePlan, window: Trace, t_now: float,
               avail: Optional[int]) -> dict:
        """Vectorized-engine price of one candidate on the lookback
        window — always the vectorized engine, whatever engine runs the
        simulation, so decisions are engine-independent."""
        k = (cand.n_replicas if avail is None
             else min(cand.n_replicas, avail))
        t_arr = window.arrival_times()
        if not len(t_arr):
            return {"p99_s": 0.0, "p50_s": 0.0, "drop_fraction": 0.0,
                    "accuracy": 1.0, "k": k, "n": 0}
        dev = np.array([r.device for r in window.requests])
        offer = np.empty(len(t_arr))
        acc = 1.0
        for name in np.unique(dev):
            idxs = np.nonzero(dev == name)[0]
            epoch = self._scheds[name].epoch(t_now)
            flow = self._flow_for(self._device_at_epoch(name, epoch),
                                  cand, epoch)
            acc = min(acc, float(flow.get("accuracy", 1.0)))
            wire = np.asarray(flow["wire_s"], float)
            pre = flow["edge_s"] + wire[np.arange(len(idxs)) % len(wire)]
            offer[idxs] = t_arr[idxs] + pre
        v = simulate_cluster_vectorized(offer, cand.cost,
                                        cand.cluster_cfg(k))
        keep = ~v.drop_mask
        lat = v.t_done[keep] - t_arr[keep]
        return {
            "p99_s": float(np.percentile(lat, 99)) if len(lat)
            else float("inf"),
            "p50_s": float(np.percentile(lat, 50)) if len(lat)
            else float("inf"),
            "drop_fraction": v.drop_fraction(),
            "accuracy": acc, "k": k, "n": len(t_arr),
        }

    def _choose(self, window: Trace, t_now: float, avail: Optional[int],
                current: Optional[CandidatePlan]):
        """Re-screen the space on the lookback window: closed-form
        shortlist, vectorized pricing, QoS-feasible-first selection.
        Returns ``(best, priced)`` with the incumbent always priced."""
        rate = len(window) / max(window.horizon_s, 1e-9)
        short = self._shortlist(rate, t_now, avail, current)
        priced = {c.key: self._price(c, window, t_now, avail)
                  for c in short}
        qos = self.qos

        def feasible(c):
            p = priced[c.key]
            if p["drop_fraction"] != 0.0:
                return False
            return qos is None or (p["p99_s"] <= qos.max_latency_s
                                   and p["accuracy"] >= qos.min_accuracy)

        pool = [c for c in short if feasible(c)] or short
        best = min(pool, key=lambda c: (priced[c.key]["drop_fraction"],
                                        priced[c.key]["p99_s"],
                                        c.n_replicas, c.max_batch, c.key))
        return best, priced

    # ----------------------------------------------------------- signals ----
    def _signals(self, era: _Era, t: float, t_prev: float,
                 engine: str) -> dict:
        win = t - t_prev
        t_a, off = era.arrays()
        if engine == "vectorized":
            if not len(off):
                return signals_at(t, t_offer=off, t_dispatch=off,
                                  t_done=off, drop_mask=off.astype(bool),
                                  window_s=win, t_prev=t_prev)
            v = simulate_cluster_vectorized(off, era.cand.cost,
                                            era.cand.cluster_cfg(era.k_eff))
            return signals_at(t, t_offer=v.t_offer,
                              t_dispatch=v.t_dispatch, t_done=v.t_done,
                              drop_mask=v.drop_mask, window_s=win,
                              t_prev=t_prev)
        # event engine: same quantities from the live simulation — every
        # count matches the vectorized prefix replay exactly (drops and
        # dispatches are decided at offer times, which are shared inputs)
        sim = era.sim
        past = int((off <= t).sum()) if len(off) else 0
        n_arr = int(((off > t_prev) & (off <= t)).sum()) if len(off) else 0
        drops_now = sim.stats.dropped
        n_drop = drops_now - era.drops_mark
        era.drops_mark = drops_now
        served = sim.stats.served
        new = served[era.served_mark:]
        era.served_mark = len(served)
        lat = np.array([r.latency_s for r in new], float)
        depth = sim.queue_depth
        return {
            "t": t, "arrivals": n_arr,
            "rate_hz": n_arr / win if win > 0 else 0.0,
            "drops": n_drop,
            "drop_fraction": n_drop / n_arr if n_arr else 0.0,
            "queue_depth": depth,
            "inflight": (past - drops_now) - len(served) - depth,
            "n_done": len(lat),
            "p50_s": float(np.percentile(lat, 50)) if len(lat)
            else float("nan"),
            "p99_s": float(np.percentile(lat, 99)) if len(lat)
            else float("nan"),
        }

    # --------------------------------------------------------- era admin ----
    def _open_era(self, cand: CandidatePlan, avail: Optional[int],
                  t: float, engine: str, *, forced: bool = False,
                  switch: Optional[SwitchRecord] = None,
                  warmup_s: float = 0.0) -> _Era:
        k = cand.n_replicas if avail is None else min(cand.n_replicas,
                                                      avail)
        era = _Era(cand, k, t, t + warmup_s, forced, switch)
        if engine == "event":
            era.sim = ClusterSim(cand.cost, cand.cluster_cfg(k))
        return era

    def _close_era(self, era: _Era, t_end: float, engine: str):
        """Drain the era to completion; returns (EraStats, latencies)."""
        t_a, off = era.arrays()
        if engine == "event":
            era.sim.run()            # drain: in-flight work finishes here
            st = era.sim.stats
            lat = np.array([rec.latency_s + (off[rec.rid] - t_a[rec.rid])
                            for rec in st.served])
            n_served, dropped = len(st.served), st.dropped
        elif len(off):
            v = simulate_cluster_vectorized(off, era.cand.cost,
                                            era.cand.cluster_cfg(era.k_eff))
            keep = ~v.drop_mask
            lat = v.t_done[keep] - t_a[keep]
            n_served, dropped = v.n_served, v.dropped
        else:
            lat = np.empty(0)
            n_served = dropped = 0
        stats = EraStats(
            era.cand.key, era.t_start, t_end, era.k_eff, era.count,
            n_served, dropped,
            float(np.percentile(lat, 50)) if len(lat) else float("nan"),
            float(np.percentile(lat, 99)) if len(lat) else float("nan"),
            era.forced)
        if self.obs.enabled:
            self.obs.tracer.add(
                f"era[{era.cand.key}]", era.t_start, t_end, clock="sim",
                tid="controller", cat="controller",
                args={"replicas": era.k_eff, "offered": era.count,
                      "dropped": dropped, "forced": era.forced})
        return stats, lat

    # --------------------------------------------------------- main loop ----
    def run(self, scenario: RegimeChangeTrace, *,
            initial: Optional[str] = None, engine: str = "vectorized",
            _static: bool = False) -> AdaptiveRunResult:
        """Run the closed loop over ``scenario`` on either cluster
        engine.  ``initial`` pins the starting plan by key; ``None``
        picks it online-realistically — priced on the *first* control
        window only, because at deploy time the controller can observe
        the current regime, not the future.  (A static planner sizing
        for the whole horizon is :meth:`best_static`.)"""
        if engine not in ("event", "vectorized"):
            raise ValueError(f"engine must be 'event' or 'vectorized', "
                             f"got {engine!r}")
        cfg, obs = self.config, self.obs
        trace = scenario.trace
        horizon = trace.horizon_s
        t_all = trace.arrival_times()
        dev_all = np.array([r.device for r in trace.requests])
        self._scheds = {d.name: scenario.channel_schedule(d)
                        for d in scenario.mix}
        self._mix = {d.name: d for d in scenario.mix}
        self._flow_cache.clear()
        self._planner_epochs = None

        avail = scenario.available_replicas(0.0)
        if initial is not None:
            cand0 = self.by_key[initial]
        else:
            window0 = trace.slice(0.0, min(cfg.control_period_s, horizon))
            if not len(window0):
                window0 = trace     # nothing observable yet: size for all
            cand0, _ = self._choose(window0, 0.0, avail, None)
        era = self._open_era(cand0, avail, 0.0, engine)
        eras, era_lats, switches = [], [], []
        n_decisions = n_replans = n_suppressed = 0
        last_switch_t = -float("inf")
        ref_rate: Optional[float] = None
        i = 0                        # arrival feed cursor
        t_prev = 0.0                 # previous decision tick

        ticks = [(float(k) * cfg.control_period_s, 1)
                 for k in range(1, int(np.ceil(horizon
                                               / cfg.control_period_s)))
                 if float(k) * cfg.control_period_s < horizon]
        ticks += [(ev.t_s, 0) for ev in scenario.replica_events
                  if 0.0 < ev.t_s < horizon]
        ticks.sort()                 # replica events first on tie (kind 0)

        def feed(until):
            nonlocal i
            j = int(np.searchsorted(t_all, until, side="right"))
            if j <= i:
                return
            t_arr = t_all[i:j]
            offer = self._offer_times(era, t_arr, dev_all[i:j])
            era.t_arr.append(t_arr)
            era.offer.append(offer)
            if engine == "event":
                base = era.count
                for p, off_t in enumerate(offer):
                    era.sim.offer(base + p, float(off_t))
            era.count += len(t_arr)
            i = j

        def close_and_open(cand, t, *, forced, switch, warmup_s=0.0):
            nonlocal era
            stats, lat = self._close_era(era, t, engine)
            eras.append(stats)
            era_lats.append(lat)
            era = self._open_era(cand, avail, t, engine, forced=forced,
                                 switch=switch, warmup_s=warmup_s)

        for t, kind in ticks:
            feed(t)
            if engine == "event":
                era.sim.run(until=t)
            if kind == 0:            # replica fail/recover (physics)
                avail = scenario.available_replicas(t)
                k_new = (era.cand.n_replicas if avail is None
                         else min(era.cand.n_replicas, avail))
                if k_new != era.k_eff:
                    sw = SwitchRecord(t, era.cand.key, era.cand.key,
                                      reason="replica-cap", forced=True)
                    switches.append(sw)
                    close_and_open(era.cand, t, forced=True, switch=sw)
                    if obs.enabled:
                        obs.tracer.instant("switch", t, clock="sim",
                                           tid="controller",
                                           cat="controller",
                                           args={"reason": "replica-cap",
                                                 "replicas": k_new})
                continue

            n_decisions += 1
            if _static:
                t_prev = t
                continue
            t_lo = max(era.t_start, t_prev)
            if t - t_lo <= 1e-12:
                t_prev = t
                continue
            sig = self._signals(era, t, t_lo, engine)
            if obs.enabled:
                m = obs.metrics
                m.record("controller.rate_hz", t, sig["rate_hz"])
                m.record("controller.queue_depth", t, sig["queue_depth"])
                m.record("controller.drop_fraction", t,
                         sig["drop_fraction"])
                if not np.isnan(sig["p99_s"]):
                    m.record("controller.window_p99_s", t, sig["p99_s"])

            faults = scenario.events_between(t_prev, t)
            trig = None
            if faults:
                trig = "fault"
            elif (cfg.fault_trigger is not None
                    and self._runtime_faults_between(t_prev, t)
                    >= cfg.fault_trigger):
                trig = "runtime-fault"
            elif (cfg.drop_trigger is not None
                    and sig["drop_fraction"] > cfg.drop_trigger):
                trig = "drops"
            elif (cfg.queue_trigger is not None
                    and sig["queue_depth"] > cfg.queue_trigger):
                trig = "queue"
            elif cfg.drift_threshold is not None:
                if ref_rate is None:
                    ref_rate = sig["rate_hz"]
                elif (abs(sig["rate_hz"] - ref_rate)
                        > cfg.drift_threshold * max(ref_rate, 1e-9)):
                    trig = "rate-drift"

            if trig is not None:
                n_voluntary = sum(1 for s in switches if not s.forced)
                if (n_voluntary >= cfg.max_switches
                        or t - last_switch_t < cfg.cooldown_s):
                    n_suppressed += 1
                else:
                    n_replans += 1
                    t0w = obs.tracer.wall_now()
                    window = trace.slice(max(0.0,
                                             t - cfg.control_period_s), t)
                    best, priced = self._choose(window, t, avail,
                                                era.cand)
                    cur, new = priced[era.cand.key], priced[best.key]
                    rescue = (cur["drop_fraction"] > 0.0
                              and new["drop_fraction"] == 0.0)
                    improve = (new["p99_s"] < (1.0 - cfg.min_improvement)
                               * cur["p99_s"])
                    if obs.enabled:
                        obs.metrics.counter("controller.replans").inc()
                        obs.tracer.add(
                            "replan", t0w, obs.tracer.wall_now(),
                            clock="wall", tid="controller",
                            cat="controller",
                            args={"t_sim": t, "trigger": trig,
                                  "chosen": best.key,
                                  "n_priced": len(priced)})
                    if best.key != era.cand.key and (rescue or improve):
                        sw = SwitchRecord(
                            t, era.cand.key, best.key, reason=trig,
                            predicted_p99_s=new["p99_s"],
                            incumbent_p99_s=cur["p99_s"])
                        switches.append(sw)
                        last_switch_t = t
                        close_and_open(best, t, forced=False, switch=sw,
                                       warmup_s=cfg.warmup_s)
                        if obs.enabled:
                            obs.metrics.counter(
                                "controller.switches").inc()
                            obs.tracer.add(
                                "switch", t, t + cfg.warmup_s,
                                clock="sim", tid="controller",
                                cat="controller",
                                args={"from": sw.from_key,
                                      "to": sw.to_key, "reason": trig})
                    else:
                        n_suppressed += 1
                ref_rate = sig["rate_hz"]
            t_prev = t

        feed(float("inf"))           # tail arrivals past the last tick
        stats, lat = self._close_era(era, horizon, engine)
        eras.append(stats)
        era_lats.append(lat)

        if obs.enabled:
            m = obs.metrics
            m.counter("controller.decisions").inc(n_decisions)
            m.counter("controller.forced").inc(
                sum(1 for s in switches if s.forced))
            m.counter("controller.suppressed").inc(n_suppressed)

        lat_all = (np.concatenate(era_lats) if era_lats
                   else np.empty(0))
        return AdaptiveRunResult(
            engine=engine, latencies=lat_all, n_offered=len(trace),
            dropped=sum(e.dropped for e in eras), eras=tuple(eras),
            switches=tuple(switches), n_decisions=n_decisions,
            n_replans=n_replans, n_suppressed=n_suppressed)

    def run_static(self, scenario: RegimeChangeTrace, key: str, *,
                   engine: str = "vectorized") -> AdaptiveRunResult:
        """The static baseline: one plan for the whole horizon, on the
        *same* era machinery (physical replica reconfigurations still
        apply — a failed replica is gone whether or not anyone adapts),
        so adaptive-with-no-triggers equals static exactly."""
        return self.run(scenario, initial=key, engine=engine,
                        _static=True)

    def best_static(self, scenario: RegimeChangeTrace,
                    engine: str = "vectorized") -> AdaptiveRunResult:
        """Every candidate run statically; the best by (drop fraction,
        p99) — the strongest fixed-plan baseline the grid offers."""
        runs = [self.run_static(scenario, c.key, engine=engine)
                for c in self.candidates]
        return min(runs, key=lambda r: (r.drop_fraction, r.p99_s))
