"""Discrete-event cluster model: admission queue, dynamic batching,
replicas.

One server deployment = ``n_replicas`` identical replicas, each costed by
the :class:`repro.serving.engine.BatchCostModel` (fixed per-batch
dispatch/prefill overhead + per-item FLOPs at the platform's effective
throughput).  Requests land in a bounded FIFO admission queue; a dynamic
batching window collects them — a batch dispatches the moment it is full
(the window timer is *cancelled*, exercising the shared engine's event
handles) or when the window expires with work waiting.

Runs on the same :class:`repro.netsim.events.EventQueue` the transport
models use — there is a single event-loop implementation in the repo, and
a cluster can be embedded in an outer simulation by passing its queue in.

Telemetry (``obs=``, a ``repro.obs.Recorder``): every served request
becomes a lifecycle span on the simulated clock (``request`` =
transfer + queue wait + service, with ``wire``/``queue_wait`` child
intervals), every dispatched batch a span on its replica's track, and a
windowed sampler records the fleet's live signals every
``obs.window_s`` simulated seconds — ``fleet.arrival_rate_hz``,
``fleet.queue_depth``, ``fleet.drop_fraction``, ``fleet.utilization``,
``fleet.inflight_bytes``, ``fleet.latency_p50_s`` / ``_p99_s`` (from a
per-window streaming histogram).  With the default null recorder every
telemetry branch is one ``enabled`` check.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.netsim.events import EventQueue
from repro.obs import NULL
from repro.serving.engine import BatchCostModel


@dataclass(frozen=True)
class ClusterConfig:
    n_replicas: int = 1
    max_batch: int = 8
    batch_window_s: float = 2e-3     # dynamic batching window
    queue_limit: int = 4096          # admission queue bound (then: drop)


@dataclass
class RequestRecord:
    rid: int
    t_offer: float                   # arrival at the admission queue
    t_dispatch: float = -1.0
    t_done: float = -1.0
    dropped: bool = False

    @property
    def latency_s(self) -> float:    # queue wait + batch service
        assert self.t_done >= 0, "request not served"
        return self.t_done - self.t_offer

    @property
    def wait_s(self) -> float:
        assert self.t_dispatch >= 0, "request not dispatched"
        return self.t_dispatch - self.t_offer


@dataclass
class ClusterStats:
    served: list = field(default_factory=list)    # RequestRecord
    dropped: int = 0
    batches: int = 0
    busy_s: float = 0.0

    def latencies(self) -> np.ndarray:
        return np.array([r.latency_s for r in self.served])

    def percentile(self, p: float) -> float:
        """``nan`` on an empty run (never raises from ``np.percentile``
        on a zero-length array)."""
        lat = self.latencies()
        return float(np.percentile(lat, p)) if len(lat) else float("nan")

    def drop_fraction(self) -> float:
        n = len(self.served) + self.dropped
        return self.dropped / n if n else 0.0

    def mean_batch(self) -> float:
        """Mean served batch size; ``nan`` when no batch ever ran (an
        empty run has no meaningful batch size — 0 would read as a
        real, catastrophic measurement)."""
        return len(self.served) / self.batches if self.batches \
            else float("nan")

    def utilization(self, n_replicas: int, horizon_s: float) -> float:
        return self.busy_s / (n_replicas * horizon_s) if horizon_s > 0 else 0.0


class ClusterSim:
    """Offer requests with :meth:`offer`, then :meth:`run` the queue."""

    def __init__(self, cost: BatchCostModel, cfg: ClusterConfig,
                 queue: Optional[EventQueue] = None, obs=None,
                 window_s: Optional[float] = None):
        assert cfg.n_replicas >= 1 and cfg.max_batch >= 1
        self.cost, self.cfg = cost, cfg
        self.obs = NULL if obs is None else obs
        self.q = queue if queue is not None else EventQueue(obs=self.obs)
        self.stats = ClusterStats()
        self._waiting = []           # RequestRecord FIFO
        # free replica *indices* (not a count), so batch spans land on a
        # stable per-replica track in the exported trace
        self._free = list(range(cfg.n_replicas))
        self._n_live = cfg.n_replicas   # live pool size (set_replicas)
        self._next_rid = cfg.n_replicas  # fresh track ids for grown pool
        self._retire = 0             # busy replicas to retire on _on_done
        self._window_timer = None    # live EventHandle or None
        self._due = False            # window expired with work still waiting
        # ------------------------------------------------- telemetry ----
        self.window_s = (window_s if window_s is not None
                         else self.obs.window_s)
        self._sampling = False
        self._win = {"t0": 0.0, "arrivals": 0, "drops": 0, "offered": 0,
                     "busy_s": 0.0}
        self._win_lat = self.obs.metrics.histogram("fleet.window_latency_s")
        self._inflight_bytes = 0
        self._pre = {}               # rid -> (t_tx_start, tx_bytes)

    # ---------------------------------------------------- live controls ----
    @property
    def queue_depth(self) -> int:
        """Requests admitted but not yet dispatched — the live signal
        the adaptive controller samples."""
        return len(self._waiting)

    @property
    def n_replicas(self) -> int:
        """Live replica-pool size (``set_replicas`` moves it; ``cfg``
        keeps the configured starting point)."""
        return self._n_live

    def set_replicas(self, k: int) -> None:
        """Resize the replica pool in place (fail/recover injection).

        Growth adds fresh replicas immediately (new trace track ids, so
        a recovered replica is visibly a different machine) and
        dispatches any ready work.  Shrinkage retires idle replicas
        first; busy ones finish their in-flight batch and then leave —
        graceful failover, a failure never kills a running batch.
        """
        assert k >= 1
        while k > self._n_live:
            if self._retire > 0:     # un-cancel a pending retirement
                self._retire -= 1
            else:
                self._free.append(self._next_rid)
                self._next_rid += 1
            self._n_live += 1
        while k < self._n_live:
            if self._free:
                self._free.pop()
            else:
                self._retire += 1    # consumed by the next _on_done
            self._n_live -= 1
        self._dispatch_ready()

    # ------------------------------------------------------------ intake ----
    def offer(self, rid: int, t_arrival: float, *, tx_s: float = 0.0,
              tx_bytes: int = 0) -> None:
        """Schedule one request's arrival at the admission queue.

        ``tx_s``/``tx_bytes`` describe the wire transfer that *precedes*
        the arrival (the request is in flight over the link during
        ``[t_arrival - tx_s, t_arrival]`` carrying ``tx_bytes``): purely
        telemetry — it feeds the ``fleet.inflight_bytes`` gauge and the
        per-request ``wire`` span, and changes nothing when tracing is
        off."""
        if self.obs.enabled and tx_bytes > 0:
            self._pre[rid] = (t_arrival - tx_s, tx_bytes)
            gauge = self.obs.metrics.gauge("fleet.inflight_bytes")
            self.q.schedule_named(max(0.0, t_arrival - tx_s),
                                  lambda b=tx_bytes: gauge.add(b),
                                  "tx-start")
        self.q.schedule_named(t_arrival, lambda r=rid: self._on_arrival(r),
                              "arrival")

    def offer_trace(self, arrivals) -> None:
        """arrivals: iterable of ``(rid, t_arrival)`` or
        ``(rid, t_arrival, tx_s, tx_bytes)`` rows.  The 4-field form
        forwards the wire metadata :meth:`offer` supports — without it,
        trace-driven runs silently lost the ``wire`` span and the
        ``fleet.inflight_bytes`` gauge."""
        for row in arrivals:
            if len(row) == 2:
                rid, t = row
                self.offer(rid, t)
            else:
                rid, t, tx_s, tx_bytes = row
                self.offer(rid, t, tx_s=tx_s, tx_bytes=int(tx_bytes))

    def run(self, until: float = float("inf")) -> ClusterStats:
        if self.obs.enabled and not self._sampling and not self.q.empty():
            self._sampling = True
            self._win["t0"] = self.q.now
            self.q.schedule_named(self.q.now + self.window_s,
                                  self._sample_window, "metrics-window")
        self.q.run(until=until)
        return self.stats

    # ------------------------------------------------------------ events ----
    def _on_arrival(self, rid: int) -> None:
        obs = self.obs
        if obs.enabled:
            self._win["offered"] += 1
            self._win["arrivals"] += 1
            obs.metrics.counter("fleet.arrivals").inc()
            if rid in self._pre:
                obs.metrics.gauge("fleet.inflight_bytes").add(
                    -self._pre[rid][1])
        if len(self._waiting) >= self.cfg.queue_limit:
            self.stats.dropped += 1
            if obs.enabled:
                self._win["drops"] += 1
                obs.metrics.counter("fleet.drops").inc()
                self._pre.pop(rid, None)
            return
        self._waiting.append(RequestRecord(rid, self.q.now))
        if len(self._waiting) >= self.cfg.max_batch:
            self._dispatch_ready()
        elif self._window_timer is None and not self._due:
            self._window_timer = self.q.schedule_named(
                self.q.now + self.cfg.batch_window_s, self._on_window,
                "batch-window")

    def _on_window(self) -> None:
        self._window_timer = None
        self._due = True
        self._dispatch_ready()

    def _dispatch_ready(self) -> None:
        """Start batches while a replica is free and a batch is ready
        (full, or the window has expired on a partial one)."""
        while (self._free and self._waiting
               and (self._due or len(self._waiting) >= self.cfg.max_batch)):
            batch = self._waiting[:self.cfg.max_batch]
            del self._waiting[:self.cfg.max_batch]
            replica = self._free.pop()
            svc = self.cost.service_time(len(batch))
            self.stats.batches += 1
            self.stats.busy_s += svc
            if self.obs.enabled:
                self._win["busy_s"] += svc
                self.obs.metrics.counter("fleet.batches").inc()
            for r in batch:
                r.t_dispatch = self.q.now
            self.q.schedule_named(self.q.now + svc,
                                  lambda b=batch, i=replica:
                                  self._on_done(b, i),
                                  "batch-done")
        if not self._waiting:
            self._due = False
            if self._window_timer is not None:
                self._window_timer.cancel()  # batch filled before the window
                self._window_timer = None
        # invariant: anything still waiting is covered by a live window
        # timer, by _due (window already expired), or is a full batch that
        # dispatches as soon as a replica frees up

    def _on_done(self, batch, replica: int) -> None:
        if self._retire > 0:         # deferred shrink: retire, don't free
            self._retire -= 1
        else:
            self._free.append(replica)
        for r in batch:
            r.t_done = self.q.now
        self.stats.served.extend(batch)
        if self.obs.enabled:
            self._record_batch(batch, replica)
        self._dispatch_ready()

    # --------------------------------------------------------- telemetry ----
    def _record_batch(self, batch, replica: int) -> None:
        tracer = self.obs.tracer
        t_dispatch, t_done = batch[0].t_dispatch, batch[0].t_done
        tracer.add(f"batch[n={len(batch)}]", t_dispatch, t_done,
                   clock="sim", tid=f"replica{replica}", cat="fleet",
                   args={"n": len(batch)})
        self.obs.metrics.counter("fleet.served").inc(len(batch))
        for r in batch:
            self._win_lat.observe(r.latency_s)
            pre = self._pre.pop(r.rid, None)
            t0 = pre[0] if pre is not None else r.t_offer
            root = tracer.add("request", t0, r.t_done, clock="sim",
                              tid="requests", cat="fleet",
                              args={"rid": r.rid, "wait_s": r.wait_s,
                                    "batch": len(batch)})
            if pre is not None:
                tracer.add("wire", t0, r.t_offer, clock="sim",
                           tid="requests", cat="fleet",
                           args={"bytes": pre[1]}, parent=root)
            if r.wait_s > 0:
                tracer.add("queue_wait", r.t_offer, r.t_dispatch,
                           clock="sim", tid="requests", cat="fleet",
                           parent=root)
            tracer.add("service", r.t_dispatch, r.t_done, clock="sim",
                       tid="requests", cat="fleet", parent=root)

    def _sample_window(self) -> None:
        """One windowed sample of the live fleet signals, self-scheduled
        every ``window_s`` while other events remain (the chain ends
        itself when the simulation drains, so ``run(until=inf)``
        terminates)."""
        m, t, w = self.obs.metrics, self.q.now, self._win
        dt = max(t - w["t0"], 1e-12)
        m.record("fleet.arrival_rate_hz", t, w["arrivals"] / dt)
        m.record("fleet.queue_depth", t, len(self._waiting))
        m.record("fleet.drop_fraction", t,
                 w["drops"] / w["offered"] if w["offered"] else 0.0)
        m.record("fleet.utilization", t,
                 w["busy_s"] / (self._n_live * dt))
        m.record("fleet.inflight_bytes", t,
                 m.gauge("fleet.inflight_bytes").value)
        if self._win_lat.n:
            m.record("fleet.latency_p50_s", t, self._win_lat.percentile(50))
            m.record("fleet.latency_p99_s", t, self._win_lat.percentile(99))
        self._win = {"t0": t, "arrivals": 0, "drops": 0, "offered": 0,
                     "busy_s": 0.0}
        self._win_lat.reset()
        if self.q.peek() < float("inf"):
            self.q.schedule_named(t + self.window_s, self._sample_window,
                                  "metrics-window")
        else:
            self._sampling = False
