"""Discrete-event cluster model: admission queue, dynamic batching,
replicas.

One server deployment = ``n_replicas`` identical replicas, each costed by
the :class:`repro.serving.engine.BatchCostModel` (fixed per-batch
dispatch/prefill overhead + per-item FLOPs at the platform's effective
throughput).  Requests land in a bounded FIFO admission queue; a dynamic
batching window collects them — a batch dispatches the moment it is full
(the window timer is *cancelled*, exercising the shared engine's event
handles) or when the window expires with work waiting.

Runs on the same :class:`repro.netsim.events.EventQueue` the transport
models use — there is a single event-loop implementation in the repo, and
a cluster can be embedded in an outer simulation by passing its queue in.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.netsim.events import EventQueue
from repro.serving.engine import BatchCostModel


@dataclass(frozen=True)
class ClusterConfig:
    n_replicas: int = 1
    max_batch: int = 8
    batch_window_s: float = 2e-3     # dynamic batching window
    queue_limit: int = 4096          # admission queue bound (then: drop)


@dataclass
class RequestRecord:
    rid: int
    t_offer: float                   # arrival at the admission queue
    t_dispatch: float = -1.0
    t_done: float = -1.0
    dropped: bool = False

    @property
    def latency_s(self) -> float:    # queue wait + batch service
        assert self.t_done >= 0, "request not served"
        return self.t_done - self.t_offer

    @property
    def wait_s(self) -> float:
        assert self.t_dispatch >= 0, "request not dispatched"
        return self.t_dispatch - self.t_offer


@dataclass
class ClusterStats:
    served: list = field(default_factory=list)    # RequestRecord
    dropped: int = 0
    batches: int = 0
    busy_s: float = 0.0

    def latencies(self) -> np.ndarray:
        return np.array([r.latency_s for r in self.served])

    def percentile(self, p: float) -> float:
        lat = self.latencies()
        return float(np.percentile(lat, p)) if len(lat) else float("nan")

    def drop_fraction(self) -> float:
        n = len(self.served) + self.dropped
        return self.dropped / n if n else 0.0

    def mean_batch(self) -> float:
        return len(self.served) / self.batches if self.batches else 0.0

    def utilization(self, n_replicas: int, horizon_s: float) -> float:
        return self.busy_s / (n_replicas * horizon_s) if horizon_s > 0 else 0.0


class ClusterSim:
    """Offer requests with :meth:`offer`, then :meth:`run` the queue."""

    def __init__(self, cost: BatchCostModel, cfg: ClusterConfig,
                 queue: Optional[EventQueue] = None):
        assert cfg.n_replicas >= 1 and cfg.max_batch >= 1
        self.cost, self.cfg = cost, cfg
        self.q = queue if queue is not None else EventQueue()
        self.stats = ClusterStats()
        self._waiting = []           # RequestRecord FIFO
        self._free = cfg.n_replicas
        self._window_timer = None    # live EventHandle or None
        self._due = False            # window expired with work still waiting

    # ------------------------------------------------------------ intake ----
    def offer(self, rid: int, t_arrival: float) -> None:
        self.q.schedule(t_arrival, lambda r=rid: self._on_arrival(r))

    def offer_trace(self, arrivals) -> None:
        """arrivals: iterable of (rid, t_arrival)."""
        for rid, t in arrivals:
            self.offer(rid, t)

    def run(self, until: float = float("inf")) -> ClusterStats:
        self.q.run(until=until)
        return self.stats

    # ------------------------------------------------------------ events ----
    def _on_arrival(self, rid: int) -> None:
        if len(self._waiting) >= self.cfg.queue_limit:
            self.stats.dropped += 1
            return
        self._waiting.append(RequestRecord(rid, self.q.now))
        if len(self._waiting) >= self.cfg.max_batch:
            self._dispatch_ready()
        elif self._window_timer is None and not self._due:
            self._window_timer = self.q.schedule(
                self.q.now + self.cfg.batch_window_s, self._on_window)

    def _on_window(self) -> None:
        self._window_timer = None
        self._due = True
        self._dispatch_ready()

    def _dispatch_ready(self) -> None:
        """Start batches while a replica is free and a batch is ready
        (full, or the window has expired on a partial one)."""
        while (self._free > 0 and self._waiting
               and (self._due or len(self._waiting) >= self.cfg.max_batch)):
            batch = self._waiting[:self.cfg.max_batch]
            del self._waiting[:self.cfg.max_batch]
            self._free -= 1
            svc = self.cost.service_time(len(batch))
            self.stats.batches += 1
            self.stats.busy_s += svc
            for r in batch:
                r.t_dispatch = self.q.now
            self.q.schedule(self.q.now + svc, lambda b=batch: self._on_done(b))
        if not self._waiting:
            self._due = False
            if self._window_timer is not None:
                self._window_timer.cancel()  # batch filled before the window
                self._window_timer = None
        # invariant: anything still waiting is covered by a live window
        # timer, by _due (window already expired), or is a full batch that
        # dispatches as soon as a replica frees up

    def _on_done(self, batch) -> None:
        self._free += 1
        for r in batch:
            r.t_done = self.q.now
        self.stats.served.extend(batch)
        self._dispatch_ready()
