"""Fleet-scale simulation and planning: traffic generation over a
heterogeneous device mix, a discrete-event serving cluster, a QoS-aware
deployment planner (which splits for this *population*), and an online
adaptive controller that re-plans splits live when the workload
drifts."""
from .traffic import (ARRIVAL_PATTERNS, DeviceClass, FleetRequest,  # noqa: F401
                      Trace, generate_trace)
from .cluster import ClusterConfig, ClusterSim, ClusterStats        # noqa: F401
from .vectorized import (PCTL_RTOL, StreamingClusterStats,          # noqa: F401
                         VectorClusterStats, VectorizedClusterSim,
                         fluid_cluster_stats, signals_at,
                         simulate_cluster_vectorized)
from .planner import (DeploymentPlanner, PlanPoint, SearchSpace,    # noqa: F401
                      Tier, TierPlan, TierTopology, plan_tiers,
                      simulate_deployment, suggest_tier_plan)
from .scenario import (LinkDegradation, Phase, RegimeChangeTrace,   # noqa: F401
                       ReplicaEvent, schedule_faults)
from .controller import (AdaptiveController, AdaptiveRunResult,     # noqa: F401
                         CandidatePlan, ControllerConfig, EraStats,
                         SwitchRecord)
