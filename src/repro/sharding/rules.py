"""Logical-axis sharding rules: param/batch/cache pytrees -> PartitionSpecs.

Scheme (DESIGN.md §6): 2D FSDP-style weight sharding over ("data","model"),
experts over "model", batch over ("pod","data"), sequence-parallel residual
stream (seq over "model"), decode KV caches sharded batch->data /
seq->model.  Every candidate axis is divisibility-checked against the mesh
and silently dropped when it does not divide (whisper-tiny's 6 heads,
long_500k's batch=1, ...), so one rule set serves all 40 combos.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# (path-regex, spec template). First match wins. Templates are tuples of
# mesh-axis names (or None); a leading "+G" marks group-stacked params.
PARAM_RULES = [
    (r"embed$", (None, "model")),
    (r"head$", (None, "model")),
    (r"(attn|cross)/w[qkv]$", ("data", "model")),
    (r"(attn|cross)/wo$", ("model", "data")),
    (r"(attn|cross)/b[qkv]$", ("model",)),
    (r"ffn/router$", (None, None)),
    (r"ffn/w_(gate|up)$", {2: ("data", "model"), 3: ("model", "data", None)}),
    (r"ffn/w_down$", {2: ("model", "data"), 3: ("model", None, "data")}),
    (r"ffn/shared/w_(gate|up)$", ("data", "model")),
    (r"ffn/shared/w_down$", ("model", "data")),
    (r"ffn/(w_in|b_in)$", {2: ("data", "model"), 1: ("model",)}),
    (r"ffn/w_out$", ("model", "data")),
    (r"mamba/in_proj$", ("data", "model")),
    (r"mamba/out_proj$", ("model", "data")),
    (r"mamba/conv$", (None, "model")),
    (r"mamba/conv_b$", ("model",)),
    (r"mamba/x_proj$", ("model", None)),
    (r"mamba/dt_proj$", (None, "model")),
    (r"mamba/(dt_bias|D)$", ("model",)),
    (r"mamba/A_log$", ("model", None)),
    (r"tm/w[rkvg]$", ("data", "model")),
    (r"tm/wo$", ("model", "data")),
    (r"cm/w_k$", ("data", "model")),
    (r"cm/w_v$", ("model", "data")),
    (r"cm/w_r$", ("data", "model")),
    (r"enc/proj$", (None, "model")),
    (r"enc/pos$", (None, "model")),
    (r"projector/w1$", (None, "model")),
    (r"projector/w2$", ("data", "model")),
]

CACHE_RULES = [
    (r"/(k|v)$", (None, "data", "model", None, None)),
    (r"/kv_pos$", (None, "data", "model")),
    (r"/(ck|cv)$", (None, "data", None, "model", None)),
    (r"/conv$", (None, "data", None, "model")),
    (r"/ssm$", (None, "data", "model", None)),
    (r"/(tm_prev|cm_prev)$", (None, "data", "model")),
    (r"/wkv$", (None, "data", "model", None, None)),
]


def _path_str(path) -> str:
    parts = []
    for pk in path:
        if hasattr(pk, "key"):
            parts.append(str(pk.key))
        elif hasattr(pk, "idx"):
            parts.append(str(pk.idx))
    return "/".join(parts)


def _sanitize(spec: tuple, shape: tuple, axis_sizes: dict) -> P:
    """Drop sharding on axes that do not divide the dim."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
        else:
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= axis_sizes[a]
            out.append(ax if dim % n == 0 else None)
    return P(*out)


def _resolve(rules, path: str, shape: tuple, axis_sizes: dict,
             stacked: bool) -> P:
    for pat, tmpl in rules:
        if re.search(pat, path):
            if isinstance(tmpl, dict):  # select by rank (sans group axis)
                tmpl = tmpl.get(len(shape) - (1 if stacked else 0))
                if tmpl is None:
                    return P()
            spec = ((None,) + tuple(tmpl)) if stacked else tuple(tmpl)
            if len(spec) != len(shape):  # rank mismatch -> replicate
                return P()
            return _sanitize(spec, shape, axis_sizes)
    return P()


def mesh_axis_sizes(mesh: Mesh) -> dict:
    d = dict(zip(mesh.axis_names, mesh.devices.shape))
    d.setdefault("pod", 1)
    return d


def batch_axes(mesh: Mesh):
    """The composite data-parallel axis: ("pod","data") on multi-pod."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def param_specs(params_tree, mesh: Mesh, profile: str = "train"):
    """PartitionSpec pytree matching a param (shape-)pytree.

    profile="train": 2D FSDP sharding over ("data","model").
    profile="inference": weights sharded over "model" only (replicated
    across "data") — kills the per-step weight all-gathers that dominate
    decode (§Perf hillclimb 2) at the cost of 16x weight HBM.
    """
    sizes = mesh_axis_sizes(mesh)

    def one(path, leaf):
        ps = _path_str(path)
        stacked = ps.startswith("layers/") or "/layers/" in ps
        spec = _resolve(PARAM_RULES, ps, leaf.shape, sizes, stacked)
        if profile == "inference":
            spec = P(*[None if ax == "data" else ax for ax in spec])
        return spec

    return jax.tree_util.tree_map_with_path(one, params_tree)


def cache_specs(cache_tree, mesh: Mesh):
    sizes = mesh_axis_sizes(mesh)

    def one(path, leaf):
        return _resolve(CACHE_RULES, _path_str(path), leaf.shape, sizes, False)

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def batch_specs(batch_tree, mesh: Mesh):
    """tokens/labels (B,S) -> batch over ("pod","data"); frontends likewise."""
    sizes = mesh_axis_sizes(mesh)
    dp = batch_axes(mesh)
    dp = dp[0] if len(dp) == 1 else dp

    def one(path, leaf):
        spec = (dp,) + (None,) * (len(leaf.shape) - 1)
        return _sanitize(spec, leaf.shape, sizes)

    return jax.tree_util.tree_map_with_path(one, batch_tree)


# Each kind maps to a list of candidate specs; the first whose sharded dims
# all divide is used ("heads" falls back to sequence sharding when the head
# count doesn't divide the model axis — llama3.2-3b's 24 heads, whisper's 6).
ACT_SPECS = {
    "residual": lambda dp: [P(dp, "model", None)],
    "heads": lambda dp: [P(dp, None, "model", None), P(dp, "model", None, None)],
    "ffn_hidden": lambda dp: [P(dp, None, "model")],
    "moe_experts": lambda dp: [P(dp, "model", None, None)],
    "mamba_inner": lambda dp: [P(dp, None, "model")],
    "mamba_state": lambda dp: [P(dp, "model", None)],
    "wkv_state": lambda dp: [P(dp, "model", None, None)],
    "logits": lambda dp: [P(dp, None, "model")],
    "decode_residual": lambda dp: [P("data", None, None)],
    "decode_logits": lambda dp: [P("data", "model")],
    # wire boundary tensors (runtime.partition fused segments): the int8
    # codes (N, L) and their (N, 1) row scales shard over the batch-row
    # axis only — the latent dim stays whole so a row's codes and its
    # scale land on the same shard and framing needs no gather beyond
    # the batch axis.  Rank-agnostic (trailing dims replicate), so the
    # same rule covers flattened (N, L) and full (B, *spatial, L) codes.
    "boundary_codes": lambda dp: [P(dp)],
    "boundary_scales": lambda dp: [P(dp)],
}


def _fits(spec, shape, sizes) -> bool:
    for dim, ax in zip(shape, spec):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= sizes[a]
        if dim % n:
            return False
    return True


def make_shard_fn(mesh: Optional[Mesh], *, head_seq_fallback: bool = False):
    """The shard_fn hook models accept: pins activation shardings.

    ``head_seq_fallback=True`` is the §Perf optimisation: when the head
    count doesn't divide the model axis, shard the attention *sequence*
    dim instead of leaving q/k/v effectively replicated (default False =
    the recorded baseline).
    """
    if mesh is None:
        return lambda x, kind: x
    sizes = mesh_axis_sizes(mesh)
    dp = batch_axes(mesh)
    dp = dp[0] if len(dp) == 1 else dp

    def shard_fn(x, kind):
        fn = ACT_SPECS.get(kind)
        if fn is None:
            return x
        candidates = fn(dp)
        if not head_seq_fallback:
            candidates = candidates[:1]
        for spec in candidates:
            if _fits(tuple(spec), x.shape, sizes):
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, spec))
        spec = _sanitize(tuple(candidates[0]), x.shape, sizes)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard_fn


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
