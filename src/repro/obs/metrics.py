"""Counters, gauges, streaming fixed-bucket histograms, and windowed
time series — the fleet simulator's live signals.

Instruments are registered by dotted name (``fleet.queue_depth``,
``planner.screen_combos``; see CONTRIBUTING "Metric naming") in a
:class:`MetricsRegistry`.  Besides the live instruments, the registry
holds *time series*: ``record(name, t, value)`` appends one sample at
simulated (or wall) time ``t`` — this is what the cluster model's
windowed sampler writes every ``window_s`` of simulated time, and what
``TelemetryReport.timeseries`` reads back as NumPy arrays.

The histogram is streaming and fixed-bucket: ``observe`` is O(log
n_buckets) with no per-sample allocation, percentiles interpolate
within the bucket — the standard telemetry trade (bounded memory, small
quantile error) rather than keeping every sample.
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Optional

import numpy as np


def labelled(base: str, **labels) -> str:
    """``labelled("runtime.stage_s", k=2)`` -> ``"runtime.stage_s{k=2}"``.

    The one canonical label spelling (sorted keys, no spaces), so
    subsystems registering the same logical metric collide on the same
    name instead of fragmenting the registry.
    """
    if not labels:
        return base
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{base}{{{inner}}}"


class Counter:
    """Monotonic float counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name, self.value = name, 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """Last-write-wins instantaneous value (may go up and down)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name, self.value = name, 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, v: float) -> None:
        self.value += v


def latency_buckets(lo: float = 1e-5, hi: float = 100.0,
                    per_decade: int = 9) -> tuple:
    """Log-spaced bucket upper bounds covering [lo, hi] seconds."""
    n_dec = np.log10(hi / lo)
    n = int(round(n_dec * per_decade)) + 1
    return tuple(float(b) for b in np.geomspace(lo, hi, n))


class Histogram:
    """Streaming fixed-bucket histogram (upper-bound buckets + +inf)."""

    __slots__ = ("name", "bounds", "counts", "n", "total", "vmin", "vmax")

    def __init__(self, name: str, bounds=None):
        self.name = name
        self.bounds = tuple(sorted(bounds)) if bounds else latency_buckets()
        self.counts = [0] * (len(self.bounds) + 1)   # last = overflow
        self.reset()

    def reset(self) -> None:
        for i in range(len(self.counts)):
            self.counts[i] = 0
        self.n, self.total = 0, 0.0
        self.vmin, self.vmax = float("inf"), float("-inf")

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.bounds, v)] += 1
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def mean(self) -> float:
        return self.total / self.n if self.n else float("nan")

    def percentile(self, p: float) -> float:
        """Quantile estimate: linear interpolation inside the bucket the
        target rank lands in, clamped to the observed [min, max]."""
        if not self.n:
            return float("nan")
        rank = (p / 100.0) * self.n
        cum = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if cum + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else min(self.vmin, 0.0)
                hi = (self.bounds[i] if i < len(self.bounds)
                      else self.vmax)
                frac = (rank - cum) / c
                est = lo + frac * (hi - lo)
                return float(min(max(est, self.vmin), self.vmax))
            cum += c
        return self.vmax


class MetricsRegistry:
    """Get-or-create instruments by name + append-only time series."""

    def __init__(self):
        self._instruments: dict = {}
        self._series: dict = {}

    def _get(self, name: str, cls, *args):
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = cls(name, *args)
        elif not isinstance(inst, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(inst).__name__}, not {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, bounds=None) -> Histogram:
        return self._get(name, Histogram, bounds)

    # ------------------------------------------------------ time series ----
    def record(self, name: str, t: float, value: float) -> None:
        self._series.setdefault(name, []).append((float(t), float(value)))

    def timeseries(self, name: str) -> tuple:
        """``(times, values)`` NumPy arrays (empty when never recorded)."""
        rows = self._series.get(name, ())
        if not rows:
            return np.empty(0), np.empty(0)
        a = np.asarray(rows)
        return a[:, 0], a[:, 1]

    def series_names(self) -> list:
        return sorted(self._series)

    def names(self) -> list:
        return sorted(self._instruments)

    def snapshot(self) -> dict:
        """Current value of every instrument (histograms report count)."""
        out = {}
        for name, inst in sorted(self._instruments.items()):
            out[name] = inst.n if isinstance(inst, Histogram) else inst.value
        return out

    def get(self, name: str) -> Optional[object]:
        return self._instruments.get(name)
