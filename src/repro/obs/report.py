"""``TelemetryReport`` — the read side of a :class:`repro.obs.Recorder`.

What ``Study.observe()`` hands back: one object that exports the
collected spans as a Perfetto-loadable Chrome trace, reads windowed
metric time series as NumPy arrays, and prints a text summary.  The
report is a *view*: it holds the live recorder, so it can be created
once and re-read as later pipeline stages add telemetry.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.obs.trace import write_chrome_trace


class TelemetryReport:
    """See module docstring.  Construct via ``Study.observe()`` or
    ``Recorder.report()``."""

    def __init__(self, recorder):
        self._rec = recorder

    @property
    def recorder(self):
        """The live recorder — hand it (``obs=report.recorder``) to
        simulators driven outside the Study so their telemetry lands in
        the same report."""
        return self._rec

    @property
    def spans(self) -> list:
        return self._rec.tracer.spans

    @property
    def metrics(self):
        return self._rec.metrics

    # ---------------------------------------------------------- export ----
    def to_chrome_trace(self, path: str, clock: str = "both",
                        metadata: Optional[dict] = None) -> str:
        """Write Chrome trace-event JSON; open the file at
        https://ui.perfetto.dev.  ``clock="sim"`` keeps only the
        simulated timeline (bit-reproducible under a seed — the CI
        artifact mode); ``"wall"`` only the host timeline; ``"both"``
        exports the two as separate Perfetto processes."""
        return write_chrome_trace(self.spans, path, clock=clock,
                                  metadata=metadata)

    def timeseries(self, name: str) -> tuple:
        """``(times, values)`` arrays of one windowed metric (e.g.
        ``"fleet.queue_depth"``); empty arrays when never recorded."""
        return self._rec.metrics.timeseries(name)

    def series_names(self) -> list:
        return self._rec.metrics.series_names()

    def counters(self) -> dict:
        return self._rec.metrics.snapshot()

    # --------------------------------------------------------- summary ----
    def summary(self, top: int = 8) -> str:
        """Span counts per category, instrument snapshot, and the
        recorded time series with their last sampled values."""
        lines = []
        by_cat: dict = {}
        for s in self.spans:
            by_cat[s.cat or s.clock] = by_cat.get(s.cat or s.clock, 0) + 1
        lines.append(f"telemetry: {len(self.spans)} spans"
                     + (" (" + ", ".join(f"{c}: {n}" for c, n in
                                         sorted(by_cat.items())) + ")"
                        if by_cat else ""))
        snap = self._rec.metrics.snapshot()
        if snap:
            lines.append("instruments:")
            for name, v in snap.items():
                lines.append(f"  {name:40s} {v:g}")
        names = self.series_names()
        if names:
            lines.append("time series (windowed):")
            for name in names:
                t, v = self.timeseries(name)
                lines.append(f"  {name:40s} {len(v):4d} samples, "
                             f"last {v[-1]:g} @ t={t[-1]:.3f}s")
        longest = sorted((s for s in self.spans if s.dur > 0),
                         key=lambda s: -s.dur)[:top]
        if longest:
            lines.append(f"longest spans (top {len(longest)}):")
            for s in longest:
                lines.append(f"  {s.name:32s} [{s.clock}] "
                             f"{s.dur * 1e3:10.3f} ms  {s.cat}")
        return "\n".join(lines)

    def __repr__(self):
        n_series = len(self.series_names())
        return (f"TelemetryReport({len(self.spans)} spans, "
                f"{len(self.counters())} instruments, "
                f"{n_series} time series)")

    # convenient aggregate used by tests and the example ------------------
    def span_total_s(self, name: str, clock: Optional[str] = None) -> float:
        """Summed duration of every span called ``name``."""
        return float(sum(s.dur for s in self.spans
                         if s.name == name
                         and (clock is None or s.clock == clock)))

    def window_percentile(self, name: str, p: float) -> float:
        """Percentile over a recorded time series' values (helper for
        quick assertions on windowed signals)."""
        _, v = self.timeseries(name)
        return float(np.percentile(v, p)) if len(v) else float("nan")
