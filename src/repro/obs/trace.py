"""Hierarchical spans with two clocks, exported as Chrome trace-event
JSON (loadable at https://ui.perfetto.dev).

A :class:`Span` is one named interval on one *clock*:

* ``clock="sim"`` — simulated seconds on the shared discrete-event
  engine's timeline (``repro.netsim.events.EventQueue.now``).  Sim spans
  are bit-reproducible across runs of the same seeded simulation, so a
  trace exported with ``clock="sim"`` is diffable in CI.
* ``clock="wall"`` — host seconds since the tracer's epoch
  (``time.perf_counter``-based), for the phases that really execute:
  planner screen/refine, runtime stage forwards, calibration sweeps.

The two timelines export as two Perfetto *processes* ("simulated clock"
pid 1, "wall clock" pid 2), each span's ``tid`` naming a track within
its process; span containment per track gives the hierarchy, so the
Chrome ``"X"`` complete-event encoding suffices (plus ``"i"`` instants
for zero-duration marks and ``"M"`` metadata naming the tracks).

Nothing here imports jax or any repro subsystem — the tracer must stay
importable from the innermost event loop.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional

CLOCKS = ("sim", "wall")
_PID = {"sim": 1, "wall": 2}
_PROCESS_NAME = {"sim": "simulated clock", "wall": "wall clock"}


@dataclass
class Span:
    """One named interval; ``t0 == t1`` marks an instant event."""
    name: str
    t0: float
    t1: float
    clock: str = "sim"
    tid: str = "main"
    cat: str = ""
    args: dict = field(default_factory=dict)
    children: list = field(default_factory=list)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def walk(self):
        """This span, then every descendant (pre-order)."""
        yield self
        for c in self.children:
            yield from c.walk()


class Tracer:
    """Collects spans; see the module docstring for the clock model.

    ``add``/``instant`` record on an explicit timeline (simulation code
    passes ``EventQueue.now``); the :meth:`span` context manager times a
    wall-clock phase.  ``to_chrome_trace`` writes the Perfetto-loadable
    JSON.
    """

    def __init__(self):
        self.spans: list[Span] = []
        self._epoch = time.perf_counter()
        self._stack: list[Span] = []     # open wall-clock span() nesting

    def wall_now(self) -> float:
        """Seconds since this tracer's epoch (the wall timeline)."""
        return time.perf_counter() - self._epoch

    # ---------------------------------------------------------- record ----
    def add(self, name: str, t0: float, t1: float, *, clock: str = "sim",
            tid: str = "main", cat: str = "",
            args: Optional[dict] = None,
            parent: Optional[Span] = None) -> Span:
        """Record one completed span; returns it (for arg updates)."""
        s = Span(name, float(t0), float(t1), clock, tid, cat,
                 dict(args) if args else {})
        if parent is not None:
            parent.children.append(s)
        self.spans.append(s)
        return s

    def instant(self, name: str, t: float, *, clock: str = "sim",
                tid: str = "main", cat: str = "",
                args: Optional[dict] = None) -> Span:
        return self.add(name, t, t, clock=clock, tid=tid, cat=cat, args=args)

    def extend(self, spans) -> None:
        """Adopt already-built spans (e.g. a runtime result's tree)."""
        self.spans.extend(spans)

    @contextmanager
    def span(self, name: str, *, tid: str = "main", cat: str = "",
             args: Optional[dict] = None):
        """Wall-clock phase timer; nests (children attach to the
        innermost open span on the same tracer)."""
        parent = self._stack[-1] if self._stack else None
        s = self.add(name, self.wall_now(), 0.0, clock="wall", tid=tid,
                     cat=cat, args=args, parent=parent)
        self._stack.append(s)
        try:
            yield s
        finally:
            self._stack.pop()
            s.t1 = self.wall_now()

    # ---------------------------------------------------------- export ----
    def chrome_events(self, clock: str = "both") -> list:
        return chrome_events(self.spans, clock=clock)

    def to_chrome_trace(self, path: str, clock: str = "both",
                        metadata: Optional[dict] = None) -> str:
        return write_chrome_trace(self.spans, path, clock=clock,
                                  metadata=metadata)


def chrome_events(spans, clock: str = "both") -> list:
    """Flatten spans to Chrome trace events (``clock`` filters to one
    timeline; ``"both"`` keeps the two as separate pids)."""
    if clock not in CLOCKS + ("both",):
        raise ValueError(f"clock must be one of {CLOCKS + ('both',)}, "
                         f"got {clock!r}")
    keep = [s for s in spans if clock == "both" or s.clock == clock]
    # stable integer tids per (pid, track name), in first-seen order
    tids: dict = {}
    for s in keep:
        tids.setdefault((_PID[s.clock], s.tid), len(tids) + 1)
    events = []
    for (pid, name), tid in tids.items():
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": _PROCESS_NAME[
                           "sim" if pid == _PID["sim"] else "wall"]}})
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": name}})
    # dedupe the repeated process_name rows
    seen, meta = set(), []
    for e in events:
        key = (e["name"], e["pid"], e["tid"])
        if key not in seen:
            seen.add(key)
            meta.append(e)
    events = meta
    for s in keep:
        pid, tid = _PID[s.clock], tids[(_PID[s.clock], s.tid)]
        ts = round(s.t0 * 1e6, 3)                 # Chrome wants microseconds
        e = {"name": s.name, "cat": s.cat or s.clock, "pid": pid,
             "tid": tid, "ts": ts}
        if s.t1 > s.t0:
            e["ph"] = "X"
            e["dur"] = round((s.t1 - s.t0) * 1e6, 3)
        else:
            e["ph"] = "i"
            e["s"] = "t"
        if s.args:
            e["args"] = s.args
        events.append(e)
    # deterministic ordering: metadata first, then by (pid, ts, tid, name)
    events.sort(key=lambda e: (e["ph"] != "M", e["pid"],
                               e.get("ts", -1.0), e["tid"], e["name"]))
    return events


def write_chrome_trace(spans, path: str, clock: str = "both",
                       metadata: Optional[dict] = None) -> str:
    """Write ``{"traceEvents": [...]}`` JSON; returns ``path``."""
    doc = {"traceEvents": chrome_events(spans, clock=clock),
           "displayTimeUnit": "ms"}
    if metadata:
        doc["metadata"] = metadata
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return path
