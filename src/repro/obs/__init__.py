"""``repro.obs`` — the unified telemetry layer: spans, fleet metrics,
Perfetto trace export.

Three pillars (see ISSUE/README "Observability"):

* :class:`Tracer` — hierarchical spans on two clocks (simulated and
  wall), exported as Chrome trace-event JSON for https://ui.perfetto.dev
  (``repro.obs.trace``).
* :class:`MetricsRegistry` — counters, gauges, streaming fixed-bucket
  histograms, and the windowed time series the fleet simulator samples
  (``repro.obs.metrics``).
* :data:`NULL` — the shared :class:`NullRecorder`: every instrumented
  hot path defaults to it, and guards with ``if obs.enabled:`` (or
  dispatches to an uninstrumented loop) so tracing costs nothing
  measurable when off.  ``benchmarks/bench_obs.py`` enforces the
  ceiling.

A :class:`Recorder` bundles one tracer + one registry; instrumented
subsystems (``netsim.events``, ``fleet.cluster``, ``runtime.engine``,
``fleet.planner``, ``fleet.controller`` — the adaptive control loop's
``controller.*`` series/counters and replan/switch/era spans) take
``obs=`` and a :class:`TelemetryReport` (``Study.observe()``) reads
everything back.

Deliberately zero-dependency beyond NumPy: importable from the innermost
event loop, no jax, no repro imports outward.
"""
from __future__ import annotations

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               labelled, latency_buckets)
from repro.obs.report import TelemetryReport
from repro.obs.trace import (Span, Tracer, chrome_events, write_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "labelled",
    "latency_buckets", "NULL", "NullRecorder", "Recorder", "Span",
    "TelemetryReport", "Tracer", "chrome_events", "write_chrome_trace",
]


class Recorder:
    """One tracer + one metrics registry; ``enabled`` is True.

    ``window_s`` is the default sampling window instrumented simulators
    use for windowed time series (``fleet.cluster`` reads it).
    """

    enabled = True

    def __init__(self, window_s: float = 0.05):
        self.tracer = Tracer()
        self.metrics = MetricsRegistry()
        self.window_s = window_s

    def report(self) -> TelemetryReport:
        return TelemetryReport(self)


# ------------------------------------------------------------- null path ----
class _NullSpan:
    """Inert span stand-in: context manager, ignores arg updates."""

    __slots__ = ("args",)

    def __init__(self):
        self.args = {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _NullTracer:
    enabled = False
    spans: tuple = ()

    def wall_now(self) -> float:
        return 0.0

    def add(self, *a, **kw):
        return _NULL_SPAN

    def instant(self, *a, **kw):
        return _NULL_SPAN

    def extend(self, spans) -> None:
        pass

    def span(self, *a, **kw):
        return _NULL_SPAN


class _NullInstrument:
    __slots__ = ()
    value = 0.0
    n = 0

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def add(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def reset(self) -> None:
        pass

    def percentile(self, p: float) -> float:
        return float("nan")

    def mean(self) -> float:
        return float("nan")


_NULL_INSTRUMENT = _NullInstrument()


class _NullMetrics:
    def counter(self, name):
        return _NULL_INSTRUMENT

    def gauge(self, name):
        return _NULL_INSTRUMENT

    def histogram(self, name, bounds=None):
        return _NULL_INSTRUMENT

    def record(self, name, t, value) -> None:
        pass

    def timeseries(self, name):
        import numpy as np
        return np.empty(0), np.empty(0)

    def series_names(self) -> list:
        return []

    def names(self) -> list:
        return []

    def snapshot(self) -> dict:
        return {}

    def get(self, name):
        return None


class NullRecorder:
    """The off switch: same surface as :class:`Recorder`, every method a
    no-op, ``enabled`` False.  Instrumented code holds the shared
    :data:`NULL` instance by default and never allocates on the hot
    path."""

    enabled = False
    window_s = 0.05

    def __init__(self):
        self.tracer = _NullTracer()
        self.metrics = _NullMetrics()

    def report(self) -> TelemetryReport:
        return TelemetryReport(self)


NULL = NullRecorder()
