"""Deterministic fault injection + recovery policy for the live runtime.

Split-Et-Impera's premise is that the cut crosses a real, unreliable
network (paper §IV: the saboteur, the TCP/UDP loss study) — yet a live
:class:`~repro.runtime.engine.SplitRuntime` with no fault model silently
assumes every transfer arrives intact and the tail server never dies.
This module is the runtime's half of the sim-vs-reality loop for
*failure*:

* :class:`FaultPlan` — a seeded, fully deterministic fault schedule
  (transfer loss spikes, frame corruption, tail-server blackouts and
  stragglers, stage exceptions).  Every decision is a pure function of
  ``(seed, request, hop/stage, attempt)`` — never of wall-clock time or
  execution order — so the same plan replays the identical fault
  sequence across runs, across ``fused=True/False``, and across hosts.
* :class:`RecoveryPolicy` — what the runtime *does* about it: per-hop
  timeouts derived from the netsim channel RTO (the same constant
  ``netsim.protocols.simulate_tcp`` arms its retransmission timers
  with), capped exponential backoff with deterministic jitter, a
  per-request deadline budget, and the two graceful-degradation rungs
  (codec downgrade, full local fallback).

Both objects are inert data: the recovery machinery itself lives in
``runtime.engine`` (``SplitRuntime(faults=..., recovery=...)``) and is
only entered when a plan is present — the zero-fault fast path is never
touched.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

# netsim's transport constants: the recovery timeout is derived from the
# same RTO formula the simulated TCP arms its retransmission timers with
from repro.netsim.protocols import MTU_BYTES

#: fault kinds a transfer attempt can draw (order fixes the rate bands)
TRANSFER_FAULTS = ("drop", "corrupt", "straggle")


class FaultError(RuntimeError):
    """An injected stage exception (the ``stage_fault_rate`` fault kind).

    Raised *inside* the stage execution wrapper so the recovery loop
    exercises real exception machinery, and typed so nothing but the
    fault layer is ever caught.
    """


class RecoveryExhausted(RuntimeError):
    """Recovery ran out of options: the hop exhausted its attempt budget
    (or the request its deadline) and the policy forbids local fallback."""


def _draw(seed: int, rid: int, idx: int, attempt: int, salt: int) -> float:
    """One uniform [0, 1) draw keyed purely on identity, never on order."""
    return float(np.random.default_rng(
        (seed, rid, idx, attempt, salt)).random())


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic, seeded fault schedule for one runtime.

    Rates are per *attempt*: each (request ``rid``, hop ``k``, attempt
    ``a``) transfer attempt draws one uniform number from
    ``rng((seed, rid, k, a))`` and maps it onto the ``drop`` /
    ``corrupt`` / ``straggle`` bands; stage executions draw the same way
    per (rid, stage, attempt).  ``blackouts`` are windows on the
    request's *virtual clock* (seconds since the request started —
    compute + wire + waits) during which the tail-server hop is down
    regardless of the rates.

    ``max_consecutive`` caps how many consecutive faulted attempts one
    (rid, hop/stage) may draw: past it the schedule stops injecting, so
    every fault burst is finite and a retrying runtime always terminates
    (blackout windows are finite by construction).  Set it high to force
    the degradation rungs instead.
    """
    seed: int = 0
    drop_rate: float = 0.0           # transfer attempt lost (timeout fires)
    corrupt_rate: float = 0.0        # frame delivered but corrupted (CRC)
    straggle_rate: float = 0.0       # tail-server straggler: late delivery
    straggle_s: float = 0.05         # extra seconds a straggler costs
    stage_fault_rate: float = 0.0    # stage raises FaultError
    blackouts: tuple = ()            # ((t0_s, t1_s), ...) virtual clock
    max_consecutive: int = 6

    def __post_init__(self):
        object.__setattr__(self, "blackouts",
                           tuple((float(a), float(b))
                                 for a, b in self.blackouts))
        for a, b in self.blackouts:
            if b <= a:
                raise ValueError(f"blackout window ({a}, {b}) is empty")

    @property
    def any_faults(self) -> bool:
        return bool(self.drop_rate or self.corrupt_rate
                    or self.straggle_rate or self.stage_fault_rate
                    or self.blackouts)

    # ------------------------------------------------------- decisions ----
    def transfer_fault(self, rid: int, hop: int,
                       attempt: int) -> Optional[str]:
        """Fate of transfer attempt ``attempt`` of hop ``hop``:
        ``'drop' | 'corrupt' | 'straggle' | None`` — deterministic."""
        if attempt >= self.max_consecutive:
            return None
        r = _draw(self.seed, rid, hop, attempt, salt=1)
        edge = 0.0
        for kind, rate in zip(TRANSFER_FAULTS, (self.drop_rate,
                                                self.corrupt_rate,
                                                self.straggle_rate)):
            edge += rate
            if r < edge:
                return kind
        return None

    def stage_fault(self, rid: int, stage: int, attempt: int) -> bool:
        """Does stage ``stage`` raise on execution attempt ``attempt``?"""
        if attempt >= self.max_consecutive:
            return False
        return _draw(self.seed, rid, stage, attempt,
                     salt=2) < self.stage_fault_rate

    def blackout_at(self, t: float) -> bool:
        """Is the tail server dark at virtual time ``t``?"""
        return any(a <= t < b for a, b in self.blackouts)

    def blackout_end(self, t: float) -> float:
        """End of the blackout window covering ``t`` (``t`` if none)."""
        for a, b in self.blackouts:
            if a <= t < b:
                return b
        return t

    # ------------------------------------------------------- corruption ----
    def corrupt_bytes(self, buf: bytes, rid: int, hop: int, attempt: int,
                      lo: int = 0) -> bytes:
        """A deterministically corrupted copy of ``buf``: 1-4 bytes in
        ``[lo, len)`` XOR-flipped (``lo`` lets the caller spare the
        header so the detection burden falls on the CRC, not the
        magic)."""
        if not buf:
            return buf
        lo = min(lo, len(buf) - 1)
        rng = np.random.default_rng((self.seed, rid, hop, attempt, 3))
        n = int(rng.integers(1, 5))
        offs = lo + rng.integers(0, len(buf) - lo, size=n)
        out = bytearray(buf)
        for o in offs:
            out[int(o)] ^= 0xFF
        return bytes(out)

    # ------------------------------------------------------- schedules ----
    def transfer_schedule(self, rid: int, hop: int, n: int) -> tuple:
        """The first ``n`` attempt fates of one hop — the determinism
        witness property tests compare across runs."""
        return tuple(self.transfer_fault(rid, hop, a) for a in range(n))


@dataclass(frozen=True)
class RecoveryPolicy:
    """What the runtime does when the wire (or a stage) misbehaves.

    * **Timeout** — a transfer attempt that never delivers is detected
      after :meth:`timeout_s`: the netsim channel's RTO (``2*RTT +
      serialization(MTU)``, exactly the constant
      ``netsim.protocols.simulate_tcp`` arms) plus the frame's own
      serialization time.  Unpriced hops (no channel) use
      ``default_timeout_s``.
    * **Backoff** — retries wait ``min(base * mult^attempt, cap)`` plus
      a *deterministic* jitter fraction drawn from ``(seed, rid, hop,
      attempt)`` — reproducible, but uncorrelated across requests so
      synchronized retry storms still decorrelate.  ``base_backoff_s``
      of ``None`` uses one hop RTO.
    * **Deadline** — the per-request budget on the virtual clock
      (compute + wire + waits).  When the next attempt could no longer
      fit, the request escalates to the degradation rungs instead of
      retrying forever.
    * **Degradation rungs** — (1) after ``downgrade_after`` corrupted
      frames on one hop the codec downgrades one rung
      (``ae8 -> int8 -> f32``, re-encoded locally from the original
      boundary activation); (2) when the server leg exhausts its attempt
      or deadline budget and ``local_fallback`` is set, the edge runs
      every remaining stage itself.  Both are explicitly flagged in
      ``RuntimeResult.meta`` and priced in the per-stage accounting.
    """
    max_attempts: int = 8            # per hop (timeouts + corruptions)
    base_backoff_s: Optional[float] = None   # None: one hop RTO
    backoff_mult: float = 2.0
    backoff_cap_s: float = 0.5
    jitter: float = 0.1              # fraction of the backoff, deterministic
    deadline_s: Optional[float] = None       # per-request virtual budget
    downgrade_after: int = 2         # corrupted frames before codec downgrade
    local_fallback: bool = True
    default_timeout_s: float = 0.05  # unpriced hops have no RTO to derive

    def rto_s(self, channel) -> float:
        """The netsim RTO of ``channel`` (``simulate_tcp``'s timer)."""
        if channel is None:
            return self.default_timeout_s
        return (2 * (2 * channel.latency_s)
                + channel.serialization_s(MTU_BYTES) + 1e-6)

    def timeout_s(self, channel, nbytes: int) -> float:
        """Loss-detection time of one ``nbytes`` transfer attempt."""
        if channel is None:
            return self.default_timeout_s
        return self.rto_s(channel) + channel.serialization_s(nbytes)

    def backoff_s(self, attempt: int, *, seed: int, rid: int,
                  hop: int, channel=None) -> float:
        """Capped exponential backoff with deterministic jitter."""
        base = (self.base_backoff_s if self.base_backoff_s is not None
                else self.rto_s(channel))
        raw = min(base * self.backoff_mult ** attempt, self.backoff_cap_s)
        return raw * (1.0 + self.jitter * _draw(seed, rid, hop, attempt,
                                                salt=4))


#: codec degradation ladders, strongest first.  Corruption on an 'ae8'
#: hop smears whole rows through the AE-decoder matmul; 'int8' localises
#: the damage to the flipped codes; 'f32' needs no scales at all and is
#: the last rung before local fallback.
DOWNGRADE_LADDER = {
    "ae8": ("ae8", "int8", "f32"),
    "int8": ("int8", "f32"),
    "f32": ("f32",),
}


def downgrade_ladder(kind: str) -> tuple:
    """The rung sequence for a hop whose nominal wire kind is ``kind``."""
    return DOWNGRADE_LADDER[kind]
