"""Executable stage chain of a :class:`LayeredModel` at a legal cut list.

This is the *live* counterpart of ``core.split``: where ``SplitPlan`` only
names a design point, a :class:`Partition` is a chain of K+1 jitted
callables that actually run the stages — the first on the "device"
process, the middle stages on intermediate tiers, the last on the
"server" process — with each inter-stage activation crossing between them
through the wire codec (``runtime.wire``).  Legality goes through
``core.split.validate_cuts`` so the runtime and the planner can never
disagree about which cut lists exist.

The historical 1-cut head/tail vocabulary is preserved exactly:
``head`` is stage 0 (layers ``[0, splits[0]]``) and ``tail`` is
everything after the first cut, so ``tail(head(x)) == apply(x)`` for any
number of cuts.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax

from repro.core import bottleneck as B
from repro.core.split import validate_cuts
from repro.models.layered import LayeredModel
from repro.runtime import wire as W


def _is_single_ae(ae: dict) -> bool:
    """One bottleneck AE ({'enc': .., 'dec': ..}) vs a cut -> AE map."""
    return "enc" in ae and "dec" in ae


@dataclass
class Partition:
    """Stage executables for an ordered cut list.

    ``split_layer`` accepts the historical scalar cut or a cut sequence;
    the normalised tuple lives in :attr:`splits` and the scalar field is
    rebound to the first (edge-side) cut.  ``stage(k)(x)`` runs stage k;
    ``head``/``tail`` keep the 1-cut vocabulary (stage 0 / everything
    after the first cut).  The bottleneck AEs (when present) live in the
    wire codec, not here — the partition is codec-agnostic so the same
    stage chain can ship f32, int8 or AE-compressed payloads.  ``ae`` may
    be a single AE dict (attached to the first cut) or a ``{cut: ae}``
    map; :attr:`ae_map` is the normalised form.
    """
    model: LayeredModel
    params: list
    split_layer: object              # int | ordered cut sequence
    ae: Optional[dict] = None
    _stages: list = field(default=None, repr=False)
    _tail: object = field(default=None, repr=False)
    _fused: dict = field(default_factory=dict, repr=False)

    def __post_init__(self):
        self.splits = validate_cuts(self.model, self.split_layer)
        self.split_layer = self.splits[0]
        if self.ae is None:
            self.ae_map = {}
        elif _is_single_ae(self.ae):
            self.ae_map = {self.splits[0]: self.ae}
        else:
            self.ae_map = dict(self.ae)
            self.ae = self.ae_map.get(self.splits[0])
        m, p = self.model, self.params
        bounds = (0,) + tuple(c + 1 for c in self.splits) + (len(m.layers),)
        self._stages = [
            jax.jit(lambda x, a=a, b=b: m.apply_range(p, x, a, b))
            for a, b in zip(bounds, bounds[1:])]
        self._tail = (self._stages[1] if len(self.splits) == 1 else
                      jax.jit(lambda f: m.apply_range(p, f, self.splits[0] + 1,
                                                      len(m.layers))))

    # ------------------------------------------------------------ stages ----
    @property
    def n_stages(self) -> int:
        return len(self.splits) + 1

    def stage(self, k: int):
        """The jitted stage-k callable (layers between cuts k-1 and k)."""
        return self._stages[k]

    def head(self, x: jax.Array) -> jax.Array:
        """Device side: layers [0, splits[0]] -> first boundary activation."""
        return self._stages[0](x)

    def tail(self, f: jax.Array) -> jax.Array:
        """Everything after the first cut: boundary activation -> logits."""
        return self._tail(f)

    def full(self, x: jax.Array) -> jax.Array:
        """Unsplit reference forward (equivalence oracle)."""
        return self.tail(self.head(x))

    def forward_stages(self, x: jax.Array) -> jax.Array:
        """Run the whole stage chain sequentially (no codec) — equal to
        :meth:`full` by construction; the multi-stage equivalence oracle."""
        for s in self._stages:
            x = s(x)
        return x

    # ----------------------------------------------------- fused boundary ----
    def wire_kinds(self, quantize: bool = True) -> tuple:
        """Per-hop payload kind ('f32' | 'int8' | 'ae8') — static given
        the AE map and the quantize flag."""
        return tuple(W.wire_kind(self.ae_map.get(c), quantize)
                     for c in self.splits)

    def fused_segments(self, *, quantize: bool = True,
                       backend: Optional[str] = None,
                       shard_fn=None) -> list:
        """K+1 wire-to-wire jitted callables: the fused-boundary runtime.

        Where :meth:`stage` callables map activation -> activation and
        leave the codec to the caller (the eager path, one host
        round-trip per leg), each fused segment runs its layers *and*
        the boundary codec in ONE jitted program:

        * segment 0: ``x -> (data, scales)`` — stage-0 layers with the
          hop-0 encode (projection + ReLU + per-row amax + int8 for
          'ae8') fused as the stage epilogue, so the f32 latent never
          leaves the device between the last layer and the quantiser;
        * middle segment k: ``(data, scales) -> (data, scales)`` — hop
          k-1 decode (dequantise + AE-decoder) as the stage prologue,
          the stage layers, then the hop-k encode epilogue;
        * last segment: ``(data, scales) -> logits``.

        Boundary inputs are **donated** (the int8 codes + scales buffers
        are dead once decoded, so XLA may reuse them) — a segment must
        therefore be fed freshly parsed arrays on every call.  Segments
        are cached per ``(quantize, backend)``; byte framing stays
        outside (``wire.frame_arrays`` writes the header around the
        kernel output).  ``fused == eager`` to int8 bit-identity is the
        contract tests enforce (see ``tests/test_fused_boundary.py``).

        ``shard_fn`` (a ``sharding.rules.make_shard_fn`` hook) pins the
        boundary tensors inside the jitted segments — kinds
        ``boundary_codes`` / ``boundary_scales``, batch-row sharded so a
        row's codes and its scale co-locate.
        """
        key = (quantize, backend, shard_fn)
        if key not in self._fused:
            self._fused[key] = self._build_fused(quantize, backend, shard_fn)
        return self._fused[key]

    def _build_fused(self, quantize: bool, backend: Optional[str],
                     shard_fn=None) -> list:
        m, p = self.model, self.params
        bounds = (0,) + tuple(c + 1 for c in self.splits) + (len(m.layers),)
        aes = [self.ae_map.get(c) for c in self.splits]
        kinds = self.wire_kinds(quantize)
        # Donation is a no-op on hosts without buffer aliasing (CPU XLA
        # warns and ignores it) — only request it where it can land.
        donate = (0,) if jax.devices()[0].platform != "cpu" else ()

        # The barrier pins the codec subgraph: XLA may not fold stage
        # layers into the quantiser's float math (or vice versa), which
        # is what keeps the payload bit-identical to the eager byte path
        # (wire._encode_jit / _decode_jit compile the same subgraph).
        def pin(data, scales):
            if shard_fn is None:
                return data, scales
            data = shard_fn(data, "boundary_codes")
            if scales is not None:
                scales = shard_fn(scales, "boundary_scales")
            return data, scales

        def enc(f, k):
            return pin(*W.encode_arrays(jax.lax.optimization_barrier(f),
                                        aes[k], quantize=quantize,
                                        backend=backend))

        def dec(boundary, k):
            data, scales = pin(*boundary)
            return jax.lax.optimization_barrier(
                W.decode_arrays(kinds[k], data, scales, aes[k],
                                backend=backend))

        n = len(self.splits)
        segs = [jax.jit(lambda x, b=bounds[1]:
                        enc(m.apply_range(p, x, 0, b), 0))]
        for k in range(1, n + 1):
            a, b = bounds[k], bounds[k + 1]
            if k < n:
                segs.append(jax.jit(
                    lambda bd, a=a, b=b, k=k:
                        enc(m.apply_range(p, dec(bd, k - 1), a, b), k),
                    donate_argnums=donate))
            else:
                segs.append(jax.jit(
                    lambda bd, a=a, b=b, k=k:
                        m.apply_range(p, dec(bd, k - 1), a, b),
                    donate_argnums=donate))
        return segs

    def fused_forward(self, x: jax.Array, *, quantize: bool = True,
                      backend: Optional[str] = None) -> jax.Array:
        """Run the whole fused segment chain (no byte framing) — the
        device-only equivalent of :meth:`forward_stages` on the fused
        path."""
        segs = self.fused_segments(quantize=quantize, backend=backend)
        cur = segs[0](x)
        for seg in segs[1:]:
            cur = seg(cur)
        return cur

    # ------------------------------------------------------------ shapes ----
    def boundary_shape(self, batch: int = 1, hop: int = 0) -> tuple:
        """Activation shape crossing wire hop ``hop`` (with batch dim)."""
        return tuple(self.model.activation_shapes(
            self.params, batch)[self.splits[hop]])

    def describe(self) -> str:
        m = self.model
        if len(self.splits) == 1:
            return (f"{m.name}: head=[0..{self.split_layer}] "
                    f"tail=[{self.split_layer + 1}..{len(m.layers) - 1}]"
                    f"{' +ae' if self.ae is not None else ''}")
        bounds = (0,) + tuple(c + 1 for c in self.splits) + (len(m.layers),)
        stages = " | ".join(f"stage{i}=[{a}..{b - 1}]"
                            for i, (a, b) in enumerate(zip(bounds, bounds[1:])))
        aes = sorted(self.ae_map)
        return f"{m.name}: {stages}{' +ae@' + str(aes) if aes else ''}"


def make_partition(model: LayeredModel, params, split_layer,
                   ae: Optional[dict] = None) -> Partition:
    """Build (and legality-check) a runnable partition at one cut (int)
    or an ordered cut list (sequence)."""
    return Partition(model, params, split_layer, ae)


def head_with_encoder(part: Partition, x: jax.Array) -> jax.Array:
    """Paper-faithful edge stage: head layers + AE encoder (f32 latent).

    Thin wrapper over ``core.bottleneck.head_forward`` kept for parity
    checks between the runtime path and the simulator's SC forward.
    """
    return B.head_forward(part.model, part.params, part.ae,
                          part.split_layer, x)
