"""Executable head/tail partition of a :class:`LayeredModel` at a legal cut.

This is the *live* counterpart of ``core.split``: where ``SplitPlan`` only
names a design point, a :class:`Partition` is a pair of jitted callables
that actually run the two sides — the head on the "edge" process, the tail
on the "server" process — with the activation crossing between them through
the wire codec (``runtime.wire``).  Legality goes through
``core.split.validate_cut`` so the runtime and the planner can never
disagree about which cuts exist.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax

from repro.core import bottleneck as B
from repro.core.split import validate_cut
from repro.models.layered import LayeredModel


@dataclass
class Partition:
    """Head/tail executables for a cut after ``split_layer``.

    ``head(x)`` runs layers ``[0, split]`` and returns the raw boundary
    activation; ``tail(f)`` runs layers ``(split, end)`` and returns the
    logits.  The bottleneck AE (when present) lives in the wire codec, not
    here — the partition is codec-agnostic so the same head/tail pair can
    ship f32, int8 or AE-compressed payloads.
    """
    model: LayeredModel
    params: list
    split_layer: int
    ae: Optional[dict] = None
    _head: object = field(default=None, repr=False)
    _tail: object = field(default=None, repr=False)

    def __post_init__(self):
        validate_cut(self.model, self.split_layer)
        m, p, k = self.model, self.params, self.split_layer
        self._head = jax.jit(lambda x: m.apply_range(p, x, 0, k + 1))
        self._tail = jax.jit(
            lambda f: m.apply_range(p, f, k + 1, len(m.layers)))

    # ------------------------------------------------------------ stages ----
    def head(self, x: jax.Array) -> jax.Array:
        """Edge side: layers [0, split] -> boundary activation."""
        return self._head(x)

    def tail(self, f: jax.Array) -> jax.Array:
        """Server side: boundary activation -> logits."""
        return self._tail(f)

    def full(self, x: jax.Array) -> jax.Array:
        """Unsplit reference forward (equivalence oracle)."""
        return self.tail(self.head(x))

    # ------------------------------------------------------------ shapes ----
    def boundary_shape(self, batch: int = 1) -> tuple:
        """Activation shape crossing the wire (with batch dim)."""
        return tuple(self.model.activation_shapes(
            self.params, batch)[self.split_layer])

    def describe(self) -> str:
        return (f"{self.model.name}: head=[0..{self.split_layer}] "
                f"tail=[{self.split_layer + 1}..{len(self.model.layers) - 1}]"
                f"{' +ae' if self.ae is not None else ''}")


def make_partition(model: LayeredModel, params, split_layer: int,
                   ae: Optional[dict] = None) -> Partition:
    """Build (and legality-check) a runnable partition."""
    return Partition(model, params, split_layer, ae)


def head_with_encoder(part: Partition, x: jax.Array) -> jax.Array:
    """Paper-faithful edge stage: head layers + AE encoder (f32 latent).

    Thin wrapper over ``core.bottleneck.head_forward`` kept for parity
    checks between the runtime path and the simulator's SC forward.
    """
    return B.head_forward(part.model, part.params, part.ae,
                          part.split_layer, x)
