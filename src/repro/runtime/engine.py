"""Live split-execution: head on the edge, tail on the server, the int8
wire in between.

Two drivers on top of :class:`repro.runtime.partition.Partition`:

* :class:`SplitRuntime` — one client end-to-end: head forward, wire
  encode -> bytes -> (netsim-priced transfer) -> decode, tail forward.
  Every stage is wall-clock timed (``jax.block_until_ready`` fences), so a
  run doubles as a measurement — this is what ``runtime.calibrate`` sweeps
  to build the simulator's measured cost tables.
* :class:`TailServer` — the server side under *many* clients: tail
  requests queue and are batched through a fixed
  :class:`repro.serving.continuous.SlotPool`, one jitted batched tail
  forward per step (the SplitNets-style partitioned serving discipline).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.netsim.channel import Channel
from repro.netsim.protocols import simulate_transfer
from repro.obs import NULL, Span, labelled
from repro.runtime import wire as W
from repro.runtime.faults import (FaultError, FaultPlan, RecoveryExhausted,
                                  RecoveryPolicy, downgrade_ladder)
from repro.runtime.partition import Partition, make_partition
from repro.serving.continuous import SlotPool


def timeit_blocked(fn, *args, iters: int = 3, warmup: int = 1) -> tuple:
    """(best seconds, last output) with compile excluded and device fences.

    Min-over-iterations, not mean: the repeatable cost of the stage.  On a
    loaded host the mean smears scheduler noise into the calibration
    tables; min is stable, and since the runtime and the calibrator both
    measure through here, simulated-vs-executed comparisons cancel the
    estimator choice.
    """
    out = None
    for _ in range(max(1, warmup)):
        out = jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best, out


@dataclass
class RuntimeResult:
    """One timed end-to-end split inference.

    The scalar fields keep the historical 1-cut decomposition for any
    number of cuts: ``head_s`` is stage 0, ``tail_s`` sums the later
    stages, and ``encode_s``/``transfer_s``/``decode_s``/``wire_bytes``
    sum over hops.  The per-stage / per-hop breakdown lives in
    ``stage_s`` and ``hops``, and ``trace`` holds the same decomposition
    as a span tree (``infer`` -> ``stage{k}`` -> ``encode``/``transfer``/
    ``decode`` per hop) on a reconstructed timeline, so an executed run
    and a simulated one are comparable span-by-span in Perfetto.

    ``total_s`` is **transfer-inclusive** — ``compute_s + transfer_s``,
    i.e. stages + codec + the netsim-priced wire time — and reconciles
    exactly with the root span of :func:`build_infer_spans` (pinned by a
    regression test; a device-only latency lives in ``compute_s``).
    """
    logits: np.ndarray
    split_layer: int                 # first (edge-side) cut
    head_s: float
    encode_s: float
    transfer_s: float                # netsim-priced wire time (0 w/o channel)
    decode_s: float
    tail_s: float
    wire_bytes: int
    meta: dict = field(default_factory=dict)
    splits: tuple = ()               # full ordered cut list
    stage_s: tuple = ()              # per-stage compute seconds (K+1)
    hops: tuple = ()                 # per-hop dicts: bytes/encode_s/...
    trace: Optional[Span] = None     # root span of the timing tree

    @property
    def compute_s(self) -> float:
        return self.head_s + self.encode_s + self.decode_s + self.tail_s

    @property
    def total_s(self) -> float:
        return self.compute_s + self.transfer_s


def build_infer_spans(stage_s, hops, splits, *, base: float = 0.0,
                      clock: str = "wall", tid: str = "runtime") -> Span:
    """The span tree of one timed split inference.

    The measured per-stage / per-hop durations are laid out back-to-back
    from ``base`` (a *reconstructed* timeline: ``timeit_blocked`` takes
    the min over iterations, so the stages were not literally contiguous
    on the host clock).  By construction the root span's duration equals
    the sum of its leaves — i.e. it reconciles exactly with
    ``RuntimeResult.total_s``.
    """
    total = sum(stage_s) + sum(h["encode_s"] + h["transfer_s"]
                               + h["decode_s"] for h in hops)
    root = Span("infer", base, base + total, clock, tid, "runtime",
                {"splits": list(splits)})
    t = base
    for k, s in enumerate(stage_s):
        root.children.append(Span(f"stage{k}", t, t + s, clock, tid,
                                  "runtime", {"k": k}))
        t += s
        if k >= len(hops):
            continue
        h = hops[k]
        hop = Span(f"hop{k}", t, t + h["encode_s"] + h["transfer_s"]
                   + h["decode_s"], clock, tid, "runtime",
                   {"cut": h["cut"], "bytes": h["bytes"]})
        root.children.append(hop)
        # recovery hops carry an event log (timeouts, backoffs, failed
        # parses, re-encodes...); its bucket sums ARE encode_s/transfer_s/
        # decode_s, so rendering per-event keeps the root reconciled
        events = h.get("events") or [(part, part, h[f"{part}_s"])
                                     for part in ("encode", "transfer",
                                                  "decode")]
        for name, _bucket, d in events:
            hop.children.append(Span(name, t, t + d, clock, tid, "runtime"))
            t += d
    return root


class SplitRuntime:
    """Execute a model split at ``split_layer`` (one cut or an ordered
    cut list) end-to-end on this host.

    The stages run as a chain: stage k computes, its boundary activation
    crosses hop k through the wire codec, stage k+1 continues — with
    per-stage and per-hop wall-clock timing.  ``channel``/``protocol``
    price the wire hops with the discrete-event transport models (the
    bytes are real, the network is simulated — the runtime runs in one
    process); a single channel prices every hop, a sequence of channels
    (or a ``netsim.simulator.NetworkPath``) prices hop k with entry k.
    ``wire_kind`` per hop: 'ae8' when that cut has an AE, else 'int8'
    ('f32' for the exactness oracle).  ``ae`` may be one AE dict (first
    cut) or a ``{cut: ae}`` map.

    ``fused=True`` switches the execution to the fused-boundary path
    (``Partition.fused_segments``): each leg is ONE jitted callable with
    the wire encode fused as the stage epilogue and the decode as the
    next stage's prologue, so the only host-side work per hop is the
    zero-copy byte framing and the parse.  The payload bytes are
    bit-identical to the eager path — ``fused`` changes where time goes
    (hop ``encode_s``/``decode_s`` shrink to framing/parse; the codec
    compute moves into ``stage_s``), never the numbers on the wire.
    """

    def __init__(self, model, params, split_layer, *,
                 ae: Optional[dict] = None,
                 channel=None, protocol: str = "tcp",
                 quantize: bool = True, backend: Optional[str] = None,
                 fused: bool = False, obs=None,
                 faults: Optional[FaultPlan] = None,
                 recovery: Optional[RecoveryPolicy] = None):
        self.part: Partition = make_partition(model, params, split_layer, ae)
        self.channel, self.protocol = channel, protocol
        self.quantize, self.backend = quantize, backend
        self.fused = fused
        self.hops = self._resolve_hops(channel, protocol)
        self.obs = NULL if obs is None else obs
        # fault injection + recovery: only consulted when a plan is
        # present — ``faults=None`` leaves the zero-fault fast path (and
        # its SEI1 byte streams) completely untouched
        self.faults = faults
        self.recovery = recovery if recovery is not None else RecoveryPolicy()

    def _resolve_hops(self, channel, protocol) -> list:
        """Per-hop (protocol, channel) pairs; None entries skip pricing."""
        n = len(self.part.splits)
        if channel is None:
            return [None] * n
        if isinstance(channel, Channel):
            return [(protocol, channel)] * n
        hops = []
        for h in channel:                    # NetworkPath | sequence
            if isinstance(h, Channel):
                hops.append((protocol, h))
            elif h is None:
                hops.append(None)
            else:                            # a NetworkConfig-shaped hop
                hops.append((h.protocol, h.channel))
        if len(hops) != n:
            raise ValueError(f"{n} cuts need {n} priced hops, got {len(hops)}")
        return hops

    # ------------------------------------------------------------ stages ----
    def _encode(self, f, ae):
        return W.encode_activation(f, ae, quantize=self.quantize,
                                   backend=self.backend)

    def _price_hop(self, k: int, nbytes: int, stream: int) -> tuple:
        """netsim-priced transfer of hop k: (transfer_s, transport meta)."""
        if self.hops[k] is None:
            return 0.0, {}
        proto, ch = self.hops[k]
        tr = simulate_transfer(proto, nbytes, ch, stream=stream + 137 * k)
        return tr.duration_s, {"n_packets": tr.n_packets,
                               "n_transmissions": tr.n_transmissions,
                               "loss_fraction": tr.loss_fraction}

    @staticmethod
    def _parse(buf: bytes) -> tuple:
        """Wire bytes -> boundary pytree, rebuilt per call: the fused
        segments donate their boundary input, so a parse is single-use."""
        return W.parse_arrays(buf)

    def infer(self, x, *, iters: int = 3, stream: int = 0,
              rid: int = 0) -> RuntimeResult:
        """Timed stage -> wire -> stage ... execution of one input batch.

        ``rid`` is the request id the fault plan keys its deterministic
        draws on (ignored when no plan is installed).
        """
        if self.faults is not None:
            logits, stage_s, hops, extra = self._run_recovering(
                x, iters=iters, stream=stream, rid=rid)
            return self._package(logits, stage_s, hops, extra)
        if self.fused:
            logits, stage_s, hops = self._run_fused(x, iters=iters,
                                                    stream=stream)
        else:
            logits, stage_s, hops = self._run_eager(x, iters=iters,
                                                    stream=stream)
        return self._package(logits, stage_s, hops)

    def _run_eager(self, x, *, iters: int, stream: int) -> tuple:
        """Historical op-by-op path: stage jit, then codec on the host
        (the exactness + accounting oracle for the fused path)."""
        cur = jnp.asarray(x)
        stage_s, hops = [], []
        for k in range(self.part.n_stages):
            s, cur = timeit_blocked(self.part.stage(k), cur, iters=iters)
            stage_s.append(s)
            if k >= len(self.part.splits):
                break
            ae_k = self.part.ae_map.get(self.part.splits[k])
            encode_s, buf = timeit_blocked(
                lambda v: W.to_bytes(self._encode(v, ae_k)), cur, iters=iters)
            transfer_s, meta = self._price_hop(k, len(buf), stream)
            decode_s, cur = timeit_blocked(
                lambda b: W.decode_activation(W.from_bytes(b), ae_k),
                buf, iters=iters)
            hops.append({"cut": self.part.splits[k], "bytes": len(buf),
                         "encode_s": encode_s, "transfer_s": transfer_s,
                         "decode_s": decode_s, **meta})
        return cur, stage_s, hops

    def _run_fused(self, x, *, iters: int, stream: int) -> tuple:
        """Fused-boundary path: one jitted wire-to-wire segment per leg.

        Accounting: the codec compute is inside the segments, so
        ``stage_s[k]`` absorbs it; hop ``encode_s`` is just the zero-copy
        framing and ``decode_s`` just the byte parse.  The middle/last
        legs are timed as ``seg(parse(buf))`` (fresh boundary arrays per
        call — the segments donate their input) and the parse time is
        measured separately and subtracted, so the split between
        ``decode_s`` and ``stage_s`` stays honest.
        """
        segs = self.part.fused_segments(quantize=self.quantize,
                                        backend=self.backend)
        kinds = self.part.wire_kinds(self.quantize)
        stage_s, hops = [], []
        s0, out = timeit_blocked(segs[0], jnp.asarray(x), iters=iters)
        stage_s.append(s0)
        for k in range(len(self.part.splits)):
            encode_s, buf = timeit_blocked(
                lambda d, s, kk=k: W.frame_arrays(kinds[kk], d, s),
                out[0], out[1], iters=iters)
            transfer_s, meta = self._price_hop(k, len(buf), stream)
            parse_s, _ = timeit_blocked(self._parse, buf, iters=iters)
            leg_s, out = timeit_blocked(
                lambda b, kk=k: segs[kk + 1](self._parse(b)),
                buf, iters=iters)
            stage_s.append(max(0.0, leg_s - parse_s))
            hops.append({"cut": self.part.splits[k], "bytes": len(buf),
                         "encode_s": encode_s, "transfer_s": transfer_s,
                         "decode_s": parse_s, **meta})
        return out, stage_s, hops

    # ------------------------------------------------------- recovery ----
    def _encode_rung(self, f, ae_k, kind: str) -> bytes:
        """Encode the boundary activation at one degradation rung, as a
        checksummed (SEI2) frame.  Rung 0 is the hop's nominal codec;
        lower rungs re-encode locally from the same activation
        (ae8 -> int8 -> f32), so a downgrade never needs a round-trip."""
        if kind == "ae8":
            pkt = W.encode_activation(f, ae_k, quantize=True,
                                      backend=self.backend)
        else:
            pkt = W.encode_activation(f, None, quantize=(kind == "int8"),
                                      backend=self.backend)
        return W.to_bytes(pkt, checksum=True)

    @staticmethod
    def _payload_lo(buf: bytes) -> int:
        """First payload byte of an SEI2 frame (corruption is aimed past
        the header so detection falls on the CRC, not the magic)."""
        return 6 + 4 * buf[5] + 8

    def _run_stage_faulted(self, k: int, cur, *, iters, rid, plan,
                           counts, rec):
        """Stage k under injected stage exceptions: retry until the plan
        stops faulting (bounded by ``max_consecutive``), charging one
        stage execution per crashed attempt."""
        attempt = 0
        while True:
            try:
                if plan.stage_fault(rid, k, attempt):
                    raise FaultError(
                        f"injected fault in stage {k} (attempt {attempt})")
                s, out = timeit_blocked(self.part.stage(k), cur, iters=iters)
                break
            except FaultError:
                counts["stage"] += 1
                rec["retries"] += 1
                attempt += 1
        # every crashed attempt ran the stage up to the fault: charge a
        # full execution each so the accounting prices the retries
        return s * (1 + attempt), out

    def _recover_hop(self, k: int, cur, *, iters, stream, rid,
                     counts, rec, t: float):
        """Hop k under the fault plan: attempt loop with RTO-derived
        timeouts, backoff, codec downgrade, and local-fallback
        escalation.  Returns ``(boundary, hop_dict, t, fell_back)``."""
        plan, pol = self.faults, self.recovery
        cut = self.part.splits[k]
        ae_k = self.part.ae_map.get(cut)
        ladder = downgrade_ladder(W.wire_kind(ae_k, self.quantize))
        ch_k = None if self.hops[k] is None else self.hops[k][1]
        last_hop = k == len(self.part.splits) - 1
        events, tmeta = [], {}
        rung, corruptions, attempt = 0, 0, 0
        fell_back = False

        def encode(rung_kind):
            return timeit_blocked(
                lambda v: self._encode_rung(v, ae_k, rung_kind), cur,
                iters=iters)

        enc_s, buf = encode(ladder[rung])
        events.append(("encode", "encode", enc_s))
        t += enc_s
        while True:
            if attempt >= pol.max_attempts or (
                    pol.deadline_s is not None and t >= pol.deadline_s):
                # budget exhausted: degrade to running the rest locally
                if not pol.local_fallback:
                    raise RecoveryExhausted(
                        f"hop {k}: {attempt} attempts, "
                        f"t={t:.3f}s of budget {pol.deadline_s}")
                rec["local_fallback"] = True
                fell_back = True
                break
            fate = plan.transfer_fault(rid, k, attempt)
            if last_hop and plan.blackout_at(t):
                fate = "blackout"     # server leg is dark: attempt times out
            if fate in ("drop", "blackout"):
                counts[fate] += 1
                lost_s = pol.timeout_s(ch_k, len(buf))
                back = pol.backoff_s(attempt, seed=plan.seed, rid=rid,
                                     hop=k, channel=ch_k)
                events.append((f"{fate}-timeout", "transfer", lost_s))
                events.append(("backoff", "transfer", back))
                t += lost_s + back
                rec["timeouts"] += 1
                rec["backoff_s"] += back
                rec["retries"] += 1
                attempt += 1
                continue
            transfer_s, tmeta = self._price_hop(k, len(buf),
                                                stream + 7919 * attempt)
            if fate == "corrupt":
                counts["corrupt"] += 1
                events.append(("transfer", "transfer", transfer_s))
                t += transfer_s
                bad = plan.corrupt_bytes(buf, rid, k, attempt,
                                         lo=self._payload_lo(buf))
                try:
                    W.from_bytes(bad)
                    raise AssertionError(
                        "corrupted SEI2 frame decoded cleanly")
                except W.WireError as e:
                    rec["log"].append(
                        {"event": "corrupt", "hop": k, "attempt": attempt,
                         "error": str(e)})
                corruptions += 1
                back = pol.backoff_s(attempt, seed=plan.seed, rid=rid,
                                     hop=k, channel=ch_k)
                events.append(("backoff", "transfer", back))
                t += back
                rec["backoff_s"] += back
                rec["retries"] += 1
                if corruptions >= pol.downgrade_after \
                        and rung + 1 < len(ladder):
                    rung += 1
                    corruptions = 0
                    rec["downgrades"].append(
                        {"hop": k, "to": ladder[rung], "attempt": attempt})
                    enc_s, buf = encode(ladder[rung])
                    events.append(("re-encode", "encode", enc_s))
                    t += enc_s
                attempt += 1
                continue
            # delivered — possibly late (straggling tail server)
            if fate == "straggle":
                counts["straggle"] += 1
                events.append(("straggle", "transfer", plan.straggle_s))
                t += plan.straggle_s
            events.append(("transfer", "transfer", transfer_s))
            t += transfer_s
            dec_s, cur = timeit_blocked(
                lambda b, kk=ladder[rung]: W.decode_activation(
                    W.from_bytes(b), ae_k if kk == "ae8" else None),
                buf, iters=iters)
            events.append(("decode", "decode", dec_s))
            t += dec_s
            break
        hop = {"cut": cut, "bytes": len(buf),
               "encode_s": sum(d for _, b, d in events if b == "encode"),
               "transfer_s": sum(d for _, b, d in events if b == "transfer"),
               "decode_s": sum(d for _, b, d in events if b == "decode"),
               "attempts": attempt + (0 if fell_back else 1),
               "kind": ladder[rung], "delivered": not fell_back,
               "events": events, **tmeta}
        return cur, hop, t, fell_back

    def _run_recovering(self, x, *, iters: int, stream: int,
                        rid: int) -> tuple:
        """The faulted/recovery execution: the eager stage chain wrapped
        in the retry/backoff/degradation machinery of
        :class:`~repro.runtime.faults.RecoveryPolicy`.

        Runs eagerly even under ``fused=True`` (recorded as
        ``meta["recovery"]["exec"]``): codec downgrade re-encodes from
        the raw boundary activation, which fused segments never expose —
        and since fused==eager bit-identity is an enforced invariant,
        outputs and payload bytes are identical either way.  Frames ship
        as SEI2 (CRC32-checksummed), so corruption is detected, never
        decoded; zero-fault runs (``faults=None``) never enter here.
        """
        plan = self.faults
        counts = {"drop": 0, "corrupt": 0, "straggle": 0, "stage": 0,
                  "blackout": 0}
        rec = {"retries": 0, "timeouts": 0, "backoff_s": 0.0,
               "downgrades": [], "local_fallback": False, "exec": "eager",
               "log": []}
        t = 0.0
        cur = jnp.asarray(x)
        stage_s, hops = [], []
        for k in range(self.part.n_stages):
            s, cur = self._run_stage_faulted(k, cur, iters=iters, rid=rid,
                                             plan=plan, counts=counts,
                                             rec=rec)
            stage_s.append(s)
            t += s
            if k >= len(self.part.splits):
                break
            cur, hop, t, fell_back = self._recover_hop(
                k, cur, iters=iters, stream=stream, rid=rid,
                counts=counts, rec=rec, t=t)
            hops.append(hop)
            if fell_back:
                # the server leg is unreachable within budget: the edge
                # runs every remaining stage itself (codec skipped — the
                # exact boundary activation feeds the next stage)
                for j in range(k + 1, self.part.n_stages):
                    s, cur = self._run_stage_faulted(
                        j, cur, iters=iters, rid=rid, plan=plan,
                        counts=counts, rec=rec)
                    stage_s.append(s)
                    t += s
                break
        rec["t_virtual_s"] = t
        obs = self.obs
        if obs.enabled:
            now = obs.tracer.wall_now()
            for name, v in counts.items():
                if v:
                    obs.metrics.counter(f"runtime.fault.{name}").inc(v)
            obs.metrics.counter("runtime.retry.attempts").inc(rec["retries"])
            obs.metrics.counter("runtime.retry.timeouts").inc(rec["timeouts"])
            obs.metrics.counter("runtime.retry.backoff_s").inc(
                rec["backoff_s"])
            obs.metrics.counter("runtime.retry.downgrades").inc(
                len(rec["downgrades"]))
            if rec["local_fallback"]:
                obs.metrics.counter("runtime.retry.local_fallback").inc()
            obs.metrics.record("runtime.retry.t_virtual_s", now, t)
        extra = {"degraded": bool(rec["downgrades"]) or rec["local_fallback"],
                 "local_fallback": rec["local_fallback"],
                 "recovery": {**rec, "faults": counts}}
        return cur, stage_s, hops, extra

    def _package(self, logits, stage_s, hops,
                 extra_meta: Optional[dict] = None) -> RuntimeResult:
        result = RuntimeResult(
            np.asarray(logits), self.part.split_layer,
            stage_s[0],
            sum(h["encode_s"] for h in hops),
            sum(h["transfer_s"] for h in hops),
            sum(h["decode_s"] for h in hops),
            sum(stage_s[1:]),
            sum(h["bytes"] for h in hops),
            {**(dict(hops[0]) if len(hops) == 1 else {"hops": hops}),
             "fused": self.fused, **(extra_meta or {})},
            splits=self.part.splits, stage_s=tuple(stage_s),
            hops=tuple(hops))
        obs = self.obs
        if obs.enabled:
            # anchor the reconstructed timeline so successive infers on
            # one recorder don't overlap (the real elapsed time, warmup
            # included, always exceeds the min-estimator total)
            end = obs.tracer.wall_now()
            result.trace = build_infer_spans(
                stage_s, hops, self.part.splits,
                base=max(0.0, end - result.total_s))
            obs.tracer.extend(result.trace.walk())
            for k, s in enumerate(stage_s):
                obs.metrics.record(labelled("runtime.stage_s", k=k), end, s)
            for k, h in enumerate(hops):
                obs.metrics.record(labelled("runtime.hop_bytes", k=k), end,
                                   h["bytes"])
        else:
            result.trace = build_infer_spans(stage_s, hops, self.part.splits)
        return result

    def reference(self, x) -> np.ndarray:
        """Unsplit forward of the same params (equivalence oracle)."""
        return np.asarray(self.part.full(jnp.asarray(x)))


# -------------------------------------------------------- multi-client ----
@dataclass
class TailRequest:
    client_id: int
    payload: bytes                   # serialized wire packet
    t_submit: float = 0.0


class TailServer:
    """Server side of the split runtime under N edge clients.

    Requests (wire byte strings) queue; each :meth:`step` admits up to
    ``n_slots`` of them into the slot pool, decodes, and runs **one**
    batched tail forward for the whole pool (empty slots padded with
    zeros, their outputs discarded).  The tail is jitted once for the pool
    shape — batch composition changes per step without recompiling, the
    same discipline ``ContinuousBatcher`` applies to decode streams.
    """

    def __init__(self, part: Partition, *, n_slots: int = 4,
                 client_batch: int = 1,
                 faults: Optional[FaultPlan] = None):
        self.part = part
        self.pool = SlotPool(n_slots)
        self.queue: deque = deque()
        self.client_batch = client_batch
        self._feat = part.boundary_shape(client_batch)[1:]
        self.n_batches = 0
        self.n_served = 0
        self.occupancy: list = []
        # fault plan: integrity-check admissions, honour blackout windows
        self.faults = faults
        self.n_rejected = 0
        self.rejected: list = []
        self.n_blackout_steps = 0

    def submit(self, client_id: int, payload: bytes, t: float = 0.0) -> bool:
        """Queue one wire payload.  With a fault plan installed the frame
        is integrity-checked on admission (corrupted frames are rejected
        and counted — the client's retry loop re-sends, the server never
        decodes garbage).  Returns whether the request was accepted."""
        if self.faults is not None:
            try:
                W.from_bytes(payload)
            except W.WireError:
                self.n_rejected += 1
                self.rejected.append(client_id)
                return False
        self.queue.append(TailRequest(client_id, payload, t))
        return True

    def step(self, now: Optional[float] = None) -> dict:
        """Serve up to ``n_slots`` queued requests in one batched forward.

        Returns ``{client_id: logits}`` for the requests served this step.
        ``now`` (a virtual-clock timestamp) lets a fault plan's blackout
        windows apply: a step inside a window serves nothing.
        """
        if (self.faults is not None and now is not None
                and self.faults.blackout_at(now)):
            self.n_blackout_steps += 1
            return {}
        while self.queue and self.pool.free_slots():
            self.pool.admit(self.queue.popleft())
        active = self.pool.occupied()
        if not active:
            return {}
        fb = jnp.zeros((len(self.pool), self.client_batch) + self._feat,
                       jnp.float32)
        for slot, req in active:
            f = W.decode_activation(W.from_bytes(req.payload), self.part.ae)
            fb = fb.at[slot].set(f.astype(jnp.float32))
        # one jitted tail forward for the whole pool (shape is static:
        # n_slots * client_batch), reusing the partition's compiled stage
        logits = self.part.tail(
            fb.reshape((len(self.pool) * self.client_batch,) + self._feat))
        logits = np.asarray(logits).reshape(
            (len(self.pool), self.client_batch) + logits.shape[1:])
        out = {}
        for slot, req in active:
            out[req.client_id] = logits[slot]
            self.pool.release(slot)
        self.n_batches += 1
        self.n_served += len(active)
        self.occupancy.append(len(active))
        return out

    def drain(self) -> dict:
        """Step until the queue and pool are empty; merged results."""
        results = {}
        while self.queue or self.pool.any_active():
            results.update(self.step())
        return results


def run_clients(model, params, split_layer: int, client_inputs, *,
                ae: Optional[dict] = None, n_slots: int = 4,
                quantize: bool = True) -> tuple:
    """Convenience driver: N clients each run the head locally, their wire
    payloads are served by one TailServer.  Returns
    ``({client_id: logits}, server)``.
    """
    part = make_partition(model, params, split_layer, ae)
    xs = [jnp.asarray(x) for x in client_inputs]
    bsz = xs[0].shape[0]
    server = TailServer(part, n_slots=n_slots, client_batch=bsz)
    for cid, x in enumerate(xs):
        f = part.head(x)
        pkt = W.encode_activation(f, ae, quantize=quantize)
        server.submit(cid, W.to_bytes(pkt))
    return server.drain(), server
