"""Measured calibration: run the split runtime over a (config, split)
grid and emit cost tables the simulators consume.

The analytic models in ``core.scenarios`` (FLOPs / effective throughput)
are guesses; this module replaces them with *measurements* taken by
executing the real head/tail stages and the real wire codec on the
attached hardware — the paper §IV hardware-in-the-loop methodology (see
``core.scenarios.HILPlatform``), extended to a whole grid of cuts.

The table implements the :class:`repro.api.types.CostModel` protocol:
``netsim.simulator.measure_flow(..., cost=table)`` and
``fleet.planner.DeploymentPlanner(cost=table)`` look entries up by
``(scenario kind, split layer)`` and fall back to the analytic model for
cells the grid didn't cover.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.split import validate_cut
from repro.runtime import wire as W
from repro.runtime.engine import timeit_blocked
from repro.runtime.partition import make_partition


@dataclass(frozen=True)
class CalEntry:
    """Measured costs of one (scenario kind, split) cell.

    Times and bytes are for one forward of the *calibration batch*
    (``CalibrationTable.batch`` frames); consumers that need a different
    batch size scale linearly (``measure_flow`` does this) or divide by
    the table batch for per-frame costs (the planner does).
    """
    head_s: float                    # edge-side stage compute
    tail_s: float                    # server-side stage compute
    wire_bytes: int                  # actual serialized payload size
    encode_s: float = 0.0            # edge-side codec
    decode_s: float = 0.0            # server-side codec
    fused_edge_s: float = 0.0        # fused seg0 + framing (calibrate(fused=True))
    fused_server_s: float = 0.0      # parse + fused decode/tail segment
    use_fused: bool = False          # quote fused costs from edge_s/server_s

    @property
    def edge_s(self) -> float:
        """Edge wall clock as the planner prices it: the fused-boundary
        measurement when ``use_fused`` (one jitted leg + framing), else
        head compute + eager codec."""
        if self.use_fused:
            return self.fused_edge_s
        return self.head_s + self.encode_s

    @property
    def server_s(self) -> float:
        if self.use_fused:
            return self.fused_server_s
        return self.decode_s + self.tail_s


@dataclass
class CalibrationTable:
    """(kind, split) -> :class:`CalEntry`, JSON-serialisable."""
    model_name: str
    batch: int
    entries: dict = field(default_factory=dict)
    meta: dict = field(default_factory=dict)

    @staticmethod
    def key(kind: str, split: Optional[int]) -> str:
        return kind if split is None else f"{kind}@{split}"

    def put(self, kind: str, split: Optional[int], entry: CalEntry):
        self.entries[self.key(kind, split)] = entry

    def lookup(self, kind: str, split: Optional[int] = None) -> Optional[CalEntry]:
        return self.entries.get(self.key(kind, split))

    def flow_times(self, kind: str, split: Optional[int] = None,
                   batch: Optional[int] = None) -> Optional[dict]:
        """The measured replacement for
        ``core.scenarios.scenario_times_and_payload`` — same keys, plus the
        provenance marker.  None when the cell wasn't calibrated.

        With ``batch``, times quoted at the table's calibration batch are
        rescaled linearly to ``batch`` frames (first-order model;
        re-calibrate at the serving batch for exact numbers).  This is
        the :class:`repro.api.types.CostModel` flow interface.
        """
        e = self.lookup(kind, split)
        if e is None:
            return None
        if kind == "LC":
            times = {"edge_s": e.head_s, "server_s": 0.0, "wire_bytes": 0,
                     "cost_source": "measured"}
        elif kind == "RC":
            times = {"edge_s": 0.0, "server_s": e.tail_s,
                     "wire_bytes": e.wire_bytes, "cost_source": "measured"}
        else:
            times = {"edge_s": e.edge_s, "server_s": e.server_s,
                     "wire_bytes": e.wire_bytes, "cost_source": "measured"}
        if batch is not None:
            from repro.api.types import scale_flow_times
            times = scale_flow_times(times, self.batch or batch, batch)
        return times

    def server_cost(self, split: Optional[int], platform):
        """Measured per-replica service-time model of the server stage
        (the :class:`repro.api.types.CostModel` server interface): the
        wall clock of the executed tail stage, normalised to one request.
        None when the cell wasn't calibrated.
        """
        from repro.serving.engine import BatchCostModel
        entry = self.lookup("SC" if split is not None else "RC", split)
        if entry is None:
            return None
        per_item = entry.server_s / max(1, self.batch)
        return BatchCostModel.from_measured(per_item, platform.flops_per_s)

    def splits(self) -> list:
        return sorted(int(k.split("@")[1]) for k in self.entries
                      if "@" in k)

    # -------------------------------------------------------- persistence ----
    def to_json(self, path: str):
        doc = {"model_name": self.model_name, "batch": self.batch,
               "meta": self.meta,
               "entries": {k: asdict(e) for k, e in self.entries.items()}}
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=1)

    @classmethod
    def from_json(cls, path: str) -> "CalibrationTable":
        with open(path) as fh:
            doc = json.load(fh)
        t = cls(doc["model_name"], doc["batch"], meta=doc.get("meta", {}))
        for k, e in doc["entries"].items():
            t.entries[k] = CalEntry(**e)
        return t


def calibrate(model, params, splits: Sequence[int], *,
              ae_map: Optional[dict] = None, batch: int = 1,
              x: Optional[np.ndarray] = None, iters: int = 3,
              quantize: bool = True, include_rc: bool = True,
              include_lc: bool = True, fused: bool = False,
              seed: int = 0) -> CalibrationTable:
    """Measure per-stage compute and wire payload over a split grid.

    Runs on this host (HIL: the measured hardware stands in for both edge
    and server — scale or re-measure per platform for heterogeneous
    deployments).  ``ae_map``: split -> trained bottleneck AE; splits
    without an entry ship the raw int8 activation.

    ``fused=True`` additionally measures the fused-boundary execution
    (``Partition.fused_segments``: codec fused into the stage jit, only
    framing/parse on the host) and marks the entries ``use_fused``, so
    ``edge_s``/``server_s`` — and every planner/simulator consuming this
    table through the CostModel protocol — price the fused runtime.  The
    eager per-component times are always kept alongside.

    ``x`` may be any input pytree the model consumes (a transformer
    layered view takes a batch dict); the calibration batch is its
    leading dim.
    """
    ae_map = dict(ae_map or {})
    if x is None:
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((batch,) + tuple(model.input_shape)
                                ).astype(np.float32)
    x = jax.tree.map(jnp.asarray, x)
    leaves = jax.tree.leaves(x)
    batch = int(leaves[0].shape[0])  # the table's batch is x's, always
    table = CalibrationTable(model.name, batch,
                             meta={"iters": iters, "quantize": quantize,
                                   "fused": fused,
                                   "n_splits": len(splits)})

    full_s, _ = timeit_blocked(lambda v: model.apply(params, v), x,
                               iters=iters)
    if include_lc:
        table.put("LC", None, CalEntry(full_s, 0.0, 0))
    if include_rc:
        input_bytes = sum(leaf.size * leaf.dtype.itemsize for leaf in leaves)
        table.put("RC", None, CalEntry(0.0, full_s, input_bytes))

    for split in splits:
        validate_cut(model, split)
        ae = ae_map.get(split)
        part = make_partition(model, params, split, ae)
        head_s, f = timeit_blocked(part.head, x, iters=iters)
        enc_s, pkt = timeit_blocked(
            lambda v: W.encode_activation(v, ae, quantize=quantize), f,
            iters=iters, warmup=1)
        buf = W.to_bytes(pkt)
        dec_s, f_hat = timeit_blocked(
            lambda b: W.decode_activation(W.from_bytes(b), ae), buf,
            iters=iters, warmup=1)
        tail_s, _ = timeit_blocked(part.tail, f_hat, iters=iters)
        extra = {}
        if fused:
            segs = part.fused_segments(quantize=quantize)
            kind = part.wire_kinds(quantize)[0]
            seg0_s, out = timeit_blocked(segs[0], x, iters=iters)
            frame_s, fbuf = timeit_blocked(
                lambda d, s: W.frame_arrays(kind, d, s), out[0], out[1],
                iters=iters)
            # the server leg re-parses per call (the segment donates its
            # boundary input); parse + decode + tail is one measurement —
            # exactly the wall clock a fused server spends per request
            leg_s, _ = timeit_blocked(
                lambda b: segs[1](W.parse_arrays(b)), fbuf, iters=iters)
            if len(fbuf) != len(buf):
                raise AssertionError(
                    f"fused wire framing diverged from eager at split "
                    f"{split}: {len(fbuf)} vs {len(buf)} bytes")
            extra = {"fused_edge_s": seg0_s + frame_s,
                     "fused_server_s": leg_s, "use_fused": True}
        table.put("SC", split,
                  CalEntry(head_s, tail_s, len(buf), enc_s, dec_s, **extra))
    return table
