"""The split-point wire format: what actually crosses the network.

Three payload kinds, all self-describing byte strings (header + payload)
so the tail server can decode without out-of-band shape agreement:

* ``f32``  — raw float32 activation (debug / exactness oracle);
* ``int8`` — symmetric per-row int8 quantisation of the raw activation
             (+ one f32 scale per row), no AE;
* ``ae8``  — bottleneck-AE encoder projection fused with the int8
             quantisation — the Pallas ``bottleneck_compress`` path,
             routed through the pure-JAX reference on hosts without a TPU
             (``kernels.bottleneck_compress.resolve_backend``).

Decoding reverses the chain on the server: parse -> dequantise -> (AE
decoder) -> boundary activation for ``Partition.tail``.

The codec exists at two altitudes:

* **array layer** (:func:`encode_arrays` / :func:`decode_arrays`) —
  pure-JAX, jittable transforms between the boundary activation and the
  device-resident wire tensors ``(data, scales)``.  This is what
  ``Partition.fused_segments`` closes over so encode fuses into the tail
  of a stage and decode into the head of the next.
* **byte layer** (:func:`frame_arrays` / :func:`to_bytes` /
  :func:`from_bytes`) — the self-describing framing.  ``frame_arrays``
  is the zero-copy path: the header is written *around* the kernel's
  int8 + scales output (one ``b"".join`` over buffer views, no
  intermediate numpy copies), and ``from_bytes`` parses into views over
  the received buffer.

Two frame versions share one parser:

* ``SEI1`` — the original header (magic | kind u8 | ndim u8 | dims
  u32*).  The default everywhere; byte streams are bit-identical to
  what earlier revisions shipped.
* ``SEI2`` — the checksummed header (``checksum=True``): identical
  layout plus two u32 CRC32s (data, scales) between the dims and the
  payload, so in-flight corruption is *detected* — a typed
  :class:`WireError`, never a garbage decode.  The fault-injection
  runtime ships SEI2 on faulted paths only.

Every malformed input — bad magic, unknown kind, truncation at any
field boundary, CRC mismatch — raises :class:`WireError` (a
``ValueError``) naming the offset it died at.
"""
from __future__ import annotations

import functools
import struct
import zlib
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bottleneck_compress import bottleneck_compress_any
from repro.kernels.bottleneck_decompress import bottleneck_decompress_any

MAGIC = b"SEI1"
MAGIC2 = b"SEI2"   # checksummed frames: dims are followed by 2 u32 CRC32s
_KINDS = ("f32", "int8", "ae8")

_KIND_DTYPE = {"f32": np.float32, "int8": np.int8, "ae8": np.int8}


class WireError(ValueError):
    """Malformed or corrupted wire bytes: bad magic, unknown kind,
    truncation at a field boundary, or a CRC32 mismatch.  Subclasses
    ``ValueError`` so pre-existing ``except ValueError`` sites keep
    working; the message carries the offset where parsing failed."""


def wire_kind(ae: Optional[dict], quantize: bool = True) -> str:
    """The payload kind one hop ships: 'ae8' when the cut has an AE,
    else 'int8' ('f32' with ``quantize=False``)."""
    if ae is not None:
        return "ae8"
    return "int8" if quantize else "f32"


@dataclass(frozen=True)
class WirePacket:
    """Decoded in-memory form of one wire transfer."""
    kind: str                        # 'f32' | 'int8' | 'ae8'
    shape: tuple                     # payload tensor shape (B, *spatial, L)
    data: np.ndarray                 # f32 (kind f32) or int8 codes
    scales: Optional[np.ndarray]     # f32 (N, 1) row scales (int8 kinds)
    checksum: bool = False           # SEI2 frame (per-array CRC32s)

    @property
    def nbytes(self) -> int:
        """Serialized size: header (6 + 4*ndim [+ 8 CRC]) + payload
        [+ scales]."""
        n = 6 + 4 * len(self.shape) + (8 if self.checksum else 0)
        n += self.data.nbytes
        return n + (self.scales.nbytes if self.scales is not None else 0)


# ------------------------------------------------------------ array layer ----
def encode_arrays(f: jax.Array, ae: Optional[dict] = None, *,
                  quantize: bool = True,
                  backend: Optional[str] = None) -> tuple:
    """Jittable edge-side codec core: activation -> ``(data, scales)``.

    The wire tensors stay on device: int8 codes + f32 ``(N, 1)`` row
    scales for the quantised kinds, ``(f32 data, None)`` for 'f32'.  The
    kind itself is a static function of ``(ae, quantize)`` —
    :func:`wire_kind` — so a jitted closure over fixed ``ae`` traces one
    payload layout.
    """
    if ae is not None:
        q, s = bottleneck_compress_any(
            jnp.asarray(f, jnp.float32), ae["enc"]["w"], ae["enc"]["b"],
            backend=backend)
        return q, s.reshape(-1, 1)
    if not quantize:
        return jnp.asarray(f, jnp.float32), None
    q, s = _quantize_rows(jnp.asarray(f, jnp.float32))
    return q, s.reshape(-1, 1)


def decode_arrays(kind: str, data: jax.Array, scales: Optional[jax.Array],
                  ae: Optional[dict] = None, *,
                  backend: Optional[str] = None) -> jax.Array:
    """Jittable server-side codec core: ``(data, scales)`` -> activation.

    'ae8' routes dequantise + AE-decoder through the fused
    ``bottleneck_decompress`` kernel path (pure-JAX reference off-TPU),
    so composing this with the next stage's layers under one ``jit``
    keeps the f32 latent in VMEM.
    """
    if kind == "f32":
        return jnp.asarray(data)
    shape = tuple(data.shape)
    if kind == "ae8":
        if ae is None:
            raise ValueError("ae8 payload needs the bottleneck AE to decode")
        return bottleneck_decompress_any(
            jnp.asarray(data), jnp.asarray(scales).reshape(-1, 1),
            ae["dec"]["w"], ae["dec"]["b"], backend=backend)
    z = (jnp.asarray(data).reshape(-1, shape[-1]).astype(jnp.float32)
         * jnp.asarray(scales).reshape(-1, 1))
    return z.reshape(shape)


# The byte path runs the SAME compiled codec math as the fused segments.
# This is what makes ``fused == eager`` hold to the bit: op-by-op dispatch
# and XLA compile constant divisions differently (1-ulp scale drift), so
# both paths must go through one jitted core.  ``ae`` is a pytree argument
# (no retrace per table entry); ``quantize``/``backend`` are static.
@functools.partial(jax.jit, static_argnames=("quantize", "backend"))
def _encode_jit(f, ae, *, quantize: bool, backend: Optional[str]):
    return encode_arrays(f, ae, quantize=quantize, backend=backend)


@functools.partial(jax.jit, static_argnames=("kind", "backend"))
def _decode_jit(kind: str, data, scales, ae, *, backend: Optional[str]):
    return decode_arrays(kind, data, scales, ae, backend=backend)


# ----------------------------------------------------------- encode side ----
def encode_activation(f: jax.Array, ae: Optional[dict] = None, *,
                      quantize: bool = True,
                      backend: Optional[str] = None) -> WirePacket:
    """Edge-side codec: boundary activation -> wire packet.

    ``ae`` present: AE-encoder + int8 (kind ``ae8``, the compressed wire of
    paper §III with DESIGN.md §3's quantisation).  ``ae`` absent: raw int8
    (kind ``int8``) or raw f32 when ``quantize=False``.
    """
    kind = wire_kind(ae, quantize)
    data, scales = _encode_jit(f, ae, quantize=quantize, backend=backend)
    return WirePacket(kind, tuple(data.shape), np.asarray(data),
                      None if scales is None else np.asarray(scales))


def _quantize_rows(f: jax.Array, scale: float = 127.0) -> tuple:
    """Symmetric per-row int8 over the channel axis (no projection).

    Returns ``(q int8 shaped like f, scales f32 (N, 1))``.
    """
    f2 = f.reshape(-1, f.shape[-1])
    amax = jnp.max(jnp.abs(f2), axis=-1, keepdims=True)
    s = jnp.where(amax > 0, amax / scale, 1.0)
    q = jnp.clip(jnp.round(f2 / s), -127, 127).astype(jnp.int8)
    return q.reshape(f.shape), s


# ----------------------------------------------------------- byte format ----
def _header(kind: str, shape: tuple, *, crcs: Optional[tuple] = None) -> bytes:
    magic = MAGIC if crcs is None else MAGIC2
    head = magic + struct.pack("<BB", _KINDS.index(kind), len(shape))
    head += struct.pack(f"<{len(shape)}I", *shape)
    if crcs is not None:
        head += struct.pack("<II", *crcs)
    return head


def _buffer_view(a, dtype) -> memoryview:
    """A C-contiguous byte view over ``a`` without copying when possible
    (device arrays on CPU backends and contiguous numpy arrays alias)."""
    arr = np.asarray(a, dtype)
    if not arr.flags.c_contiguous:
        arr = np.ascontiguousarray(arr)
    return memoryview(arr).cast("B", (arr.nbytes,))


def frame_arrays(kind: str, data, scales=None, *, checksum: bool = False) -> bytes:
    """Zero-copy framing of the jitted path's wire tensors.

    Writes the self-describing header *around* the kernel's
    ``(data, scales)`` output: the only copy is the single ``join`` into
    the outgoing buffer — no intermediate ``WirePacket`` and no numpy
    detour.  ``to_bytes(encode_activation(f, ...))`` and
    ``frame_arrays(kind, *encode_arrays(f, ...))`` produce identical
    bytes.

    ``checksum=True`` emits an SEI2 frame: two u32 CRC32s (data, scales
    — 0 when there are no scales) follow the dims, so the receiver can
    reject in-flight corruption.  The default stays SEI1, bit-identical
    to the historical framing.
    """
    dview = _buffer_view(data, _KIND_DTYPE[kind])
    sview = None if scales is None else _buffer_view(scales, np.float32)
    crcs = None
    if checksum:
        crcs = (zlib.crc32(dview), 0 if sview is None else zlib.crc32(sview))
    parts = [_header(kind, tuple(data.shape), crcs=crcs), dview]
    if sview is not None:
        parts.append(sview)
    return b"".join(parts)


def to_bytes(pkt: WirePacket, *, checksum: Optional[bool] = None) -> bytes:
    """Serialise: MAGIC | kind u8 | ndim u8 | dims u32* [| crc u32 x2]
    | payload [| scales].  ``checksum`` defaults to the packet's own
    flag (``False`` for packets built by :func:`encode_activation`)."""
    if checksum is None:
        checksum = pkt.checksum
    return frame_arrays(pkt.kind, pkt.data, pkt.scales, checksum=checksum)


def parse_arrays(buf: bytes) -> tuple:
    """Wire bytes -> device-resident ``(data, scales)`` boundary pytree —
    the input of a fused segment.  The mirror of :func:`frame_arrays`;
    callers must re-parse per call when feeding donating segments (the
    arrays are consumed)."""
    pkt = from_bytes(buf)
    return (jnp.asarray(pkt.data),
            None if pkt.scales is None else jnp.asarray(pkt.scales))


def _need(buf, end: int, what: str, off: int):
    if len(buf) < end:
        raise WireError(
            f"truncated frame: {what} at offset {off} needs {end} bytes, "
            f"buffer has {len(buf)}")


def from_bytes(buf: bytes) -> WirePacket:
    """Parse one frame (either version).  Raises :class:`WireError` on
    bad magic, unknown kind id, truncation at any field boundary, or —
    for SEI2 frames — a per-array CRC32 mismatch."""
    magic = bytes(buf[:4])
    if magic not in (MAGIC, MAGIC2):
        raise WireError("not a split-wire payload (bad magic)")
    checksum = magic == MAGIC2
    _need(buf, 6, "kind/ndim header", 4)
    kind_id, ndim = struct.unpack_from("<BB", buf, 4)
    if kind_id >= len(_KINDS):
        raise WireError(f"unknown wire kind id {kind_id} at offset 4")
    kind = _KINDS[kind_id]
    _need(buf, 6 + 4 * ndim, f"{ndim} u32 dims", 6)
    shape = struct.unpack_from(f"<{ndim}I", buf, 6)
    off = 6 + 4 * ndim
    crcs = None
    if checksum:
        _need(buf, off + 8, "CRC32 pair", off)
        crcs = struct.unpack_from("<II", buf, off)
        off += 8
    n_elems = int(np.prod(shape, dtype=np.int64))
    itemsize = np.dtype(_KIND_DTYPE[kind]).itemsize
    _need(buf, off + n_elems * itemsize, f"{kind} payload", off)
    data = np.frombuffer(buf, _KIND_DTYPE[kind], n_elems, off).reshape(shape)
    if crcs is not None and zlib.crc32(buf[off:off + n_elems * itemsize]) \
            != crcs[0]:
        raise WireError(f"CRC mismatch in data array at offset {off}")
    if kind == "f32":
        return WirePacket(kind, shape, data, None, checksum)
    s_off = off + n_elems * itemsize
    n_rows = n_elems // shape[-1] if ndim and shape[-1] else 0
    _need(buf, s_off + 4 * n_rows, f"{n_rows} f32 row scales", s_off)
    scales = np.frombuffer(buf, np.float32, n_rows,
                           s_off).reshape(n_rows, 1)
    if crcs is not None and zlib.crc32(buf[s_off:s_off + 4 * n_rows]) \
            != crcs[1]:
        raise WireError(f"CRC mismatch in scales array at offset {s_off}")
    return WirePacket(kind, shape, data, scales, checksum)


# ----------------------------------------------------------- decode side ----
def decode_activation(pkt: WirePacket, ae: Optional[dict] = None,
                      corrupt_mask: Optional[np.ndarray] = None) -> jax.Array:
    """Server-side codec: wire packet -> boundary activation.

    ``corrupt_mask`` (flat, 1=keep) zeroes lost UDP chunks *on the wire
    representation* before dequantisation — same receiver semantics as
    ``netsim.simulator.chunk_mask_from_packets``.
    """
    data = pkt.data
    if corrupt_mask is not None:
        data = data * corrupt_mask.reshape(data.shape).astype(data.dtype)
    if pkt.kind == "ae8" and ae is None:
        raise ValueError("ae8 payload needs the bottleneck AE to decode")
    return _decode_jit(pkt.kind, jnp.asarray(data),
                       None if pkt.scales is None else jnp.asarray(pkt.scales),
                       ae, backend=None)


def roundtrip(f: jax.Array, ae: Optional[dict] = None, *,
              quantize: bool = True) -> jax.Array:
    """encode -> bytes -> parse -> decode (the full wire path, no network)."""
    return decode_activation(
        from_bytes(to_bytes(encode_activation(f, ae, quantize=quantize))), ae)
