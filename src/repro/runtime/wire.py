"""The split-point wire format: what actually crosses the network.

Three payload kinds, all self-describing byte strings (header + payload)
so the tail server can decode without out-of-band shape agreement:

* ``f32``  — raw float32 activation (debug / exactness oracle);
* ``int8`` — symmetric per-row int8 quantisation of the raw activation
             (+ one f32 scale per row), no AE;
* ``ae8``  — bottleneck-AE encoder projection fused with the int8
             quantisation — the Pallas ``bottleneck_compress`` path,
             routed through the pure-JAX reference on hosts without a TPU
             (``kernels.bottleneck_compress.resolve_backend``).

Decoding reverses the chain on the server: parse -> dequantise -> (AE
decoder) -> boundary activation for ``Partition.tail``.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bottleneck as B
from repro.kernels.bottleneck_compress import bottleneck_compress_any

MAGIC = b"SEI1"
_KINDS = ("f32", "int8", "ae8")


@dataclass(frozen=True)
class WirePacket:
    """Decoded in-memory form of one wire transfer."""
    kind: str                        # 'f32' | 'int8' | 'ae8'
    shape: tuple                     # payload tensor shape (B, *spatial, L)
    data: np.ndarray                 # f32 (kind f32) or int8 codes
    scales: Optional[np.ndarray]     # f32 (N, 1) row scales (int8 kinds)

    @property
    def nbytes(self) -> int:
        """Serialized size: header (6 + 4*ndim) + payload [+ scales]."""
        n = 6 + 4 * len(self.shape) + self.data.nbytes
        return n + (self.scales.nbytes if self.scales is not None else 0)


# ----------------------------------------------------------- encode side ----
def encode_activation(f: jax.Array, ae: Optional[dict] = None, *,
                      quantize: bool = True,
                      backend: Optional[str] = None) -> WirePacket:
    """Edge-side codec: boundary activation -> wire packet.

    ``ae`` present: AE-encoder + int8 (kind ``ae8``, the compressed wire of
    paper §III with DESIGN.md §3's quantisation).  ``ae`` absent: raw int8
    (kind ``int8``) or raw f32 when ``quantize=False``.
    """
    if ae is not None:
        q, s = bottleneck_compress_any(
            jnp.asarray(f, jnp.float32), ae["enc"]["w"], ae["enc"]["b"],
            backend=backend)
        return WirePacket("ae8", tuple(q.shape), np.asarray(q),
                          np.asarray(s).reshape(-1, 1))
    if not quantize:
        return WirePacket("f32", tuple(f.shape),
                          np.asarray(f, np.float32), None)
    q, s = _quantize_rows(jnp.asarray(f, jnp.float32))
    return WirePacket("int8", tuple(q.shape), np.asarray(q),
                      np.asarray(s).reshape(-1, 1))


def _quantize_rows(f: jax.Array, scale: float = 127.0) -> tuple:
    """Symmetric per-row int8 over the channel axis (no projection).

    Returns ``(q int8 shaped like f, scales f32 (N, 1))``.
    """
    f2 = f.reshape(-1, f.shape[-1])
    amax = jnp.max(jnp.abs(f2), axis=-1, keepdims=True)
    s = jnp.where(amax > 0, amax / scale, 1.0)
    q = jnp.clip(jnp.round(f2 / s), -127, 127).astype(jnp.int8)
    return q.reshape(f.shape), s


# ----------------------------------------------------------- byte format ----
def to_bytes(pkt: WirePacket) -> bytes:
    """Serialise: MAGIC | kind u8 | ndim u8 | dims u32* | payload [| scales]."""
    kind_id = _KINDS.index(pkt.kind)
    head = MAGIC + struct.pack("<BB", kind_id, len(pkt.shape))
    head += struct.pack(f"<{len(pkt.shape)}I", *pkt.shape)
    body = np.ascontiguousarray(pkt.data).tobytes()
    if pkt.scales is not None:
        body += np.ascontiguousarray(pkt.scales, np.float32).tobytes()
    return head + body


def from_bytes(buf: bytes) -> WirePacket:
    if buf[:4] != MAGIC:
        raise ValueError("not a split-wire payload (bad magic)")
    kind_id, ndim = struct.unpack_from("<BB", buf, 4)
    kind = _KINDS[kind_id]
    shape = struct.unpack_from(f"<{ndim}I", buf, 6)
    off = 6 + 4 * ndim
    n_elems = int(np.prod(shape))
    if kind == "f32":
        data = np.frombuffer(buf, np.float32, n_elems, off).reshape(shape)
        return WirePacket(kind, shape, data, None)
    data = np.frombuffer(buf, np.int8, n_elems, off).reshape(shape)
    n_rows = n_elems // shape[-1]
    scales = np.frombuffer(buf, np.float32, n_rows,
                           off + n_elems).reshape(n_rows, 1)
    return WirePacket(kind, shape, data, scales)


# ----------------------------------------------------------- decode side ----
def decode_activation(pkt: WirePacket, ae: Optional[dict] = None,
                      corrupt_mask: Optional[np.ndarray] = None) -> jax.Array:
    """Server-side codec: wire packet -> boundary activation.

    ``corrupt_mask`` (flat, 1=keep) zeroes lost UDP chunks *on the wire
    representation* before dequantisation — same receiver semantics as
    ``netsim.simulator.chunk_mask_from_packets``.
    """
    data = pkt.data
    if corrupt_mask is not None:
        data = data * corrupt_mask.reshape(data.shape).astype(data.dtype)
    if pkt.kind == "f32":
        return jnp.asarray(data)
    z2 = data.reshape(-1, pkt.shape[-1]).astype(np.float32) * pkt.scales
    z = jnp.asarray(z2.reshape(pkt.shape))
    if pkt.kind == "ae8":
        if ae is None:
            raise ValueError("ae8 payload needs the bottleneck AE to decode")
        return B.decode(ae, z)
    return z


def roundtrip(f: jax.Array, ae: Optional[dict] = None, *,
              quantize: bool = True) -> jax.Array:
    """encode -> bytes -> parse -> decode (the full wire path, no network)."""
    return decode_activation(
        from_bytes(to_bytes(encode_activation(f, ae, quantize=quantize))), ae)
