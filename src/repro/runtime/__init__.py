"""Live split-execution runtime: partition -> wire -> tail, measured.

The executable counterpart of the ``netsim``/``fleet`` simulators — and
the instrument that calibrates them: ``runtime.calibrate`` builds the
measured ``CalibrationTable`` that ``measure_flow``/``DeploymentPlanner``
(and the ``repro.api.Study`` facade) consume via ``cost=``.
"""
from .calibrate import CalEntry, CalibrationTable, calibrate       # noqa: F401
from .engine import (RuntimeResult, SplitRuntime, TailServer,      # noqa: F401
                     run_clients, timeit_blocked)
from .faults import (FaultError, FaultPlan, RecoveryExhausted,     # noqa: F401
                     RecoveryPolicy)
from .partition import Partition, make_partition                   # noqa: F401
from .wire import (WireError, WirePacket, decode_activation,       # noqa: F401
                   encode_activation, from_bytes, to_bytes)
