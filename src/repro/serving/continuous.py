"""Continuous-batching serving: slot-based scheduler over ``serve_step``.

Production-style decode loop: a fixed pool of batch slots; requests
arrive over (simulated) time, prefill runs per-request into its slot's
cache region, decode steps advance *all* active slots each tick, finished
slots are freed and refilled immediately.  This is the vLLM-style
iteration-level scheduling discipline on top of the zoo's KV cache —
batch composition changes every step without recompiling (static shapes:
the step function is jit-compiled once for the slot pool).

Per-slot positions: every slot tracks its own absolute position; the
one-token decode uses per-slot rope positions and cache slots, so mixed
progress across slots is exact (validated against single-request decode
in tests/test_continuous_batching.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.common import ModelConfig
from repro.models.layers import (apply_rope, decode_attention, dense,
                                 rope_tables)


class SlotPool:
    """Fixed pool of batch slots with iteration-level admit/release.

    The scheduling discipline both batched servers share: a static number
    of slots (so the jitted step compiles once), occupancy tracked per
    slot, freed slots refilled immediately.  ``ContinuousBatcher`` uses it
    for decode streams; ``repro.runtime.engine.TailServer`` uses it to
    batch split-runtime tail requests from many edge clients.
    """

    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n_slots = n_slots
        self.items: List[Optional[object]] = [None] * n_slots

    def free_slots(self) -> List[int]:
        return [i for i, it in enumerate(self.items) if it is None]

    def admit(self, item) -> int:
        """Place ``item`` in the first free slot; returns the slot index."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("slot pool full")
        self.items[free[0]] = item
        return free[0]

    def release(self, slot: int):
        item, self.items[slot] = self.items[slot], None
        return item

    def occupied(self) -> List[tuple]:
        """(slot, item) pairs for every active slot."""
        return [(i, it) for i, it in enumerate(self.items) if it is not None]

    def any_active(self) -> bool:
        return any(it is not None for it in self.items)

    def __len__(self) -> int:
        return self.n_slots


@dataclass
class StreamRequest:
    rid: int
    prompt: np.ndarray
    max_new: int = 16
    arrival: int = 0                  # tick at which the request arrives
    out: List[int] = field(default_factory=list)
    done: bool = False


def _attn_decode_multi(p, h, cfg, cache_l, pos, window):
    """Like transformer._attn_decode but with per-slot positions pos (B,)."""
    b = h.shape[0]
    hd = cfg.hd
    q = dense(h, p["wq"], p.get("bq")).reshape(b, 1, cfg.n_heads, hd)
    k = dense(h, p["wk"], p.get("bk")).reshape(b, 1, cfg.n_kv_heads, hd)
    v = dense(h, p["wv"], p.get("bv")).reshape(b, 1, cfg.n_kv_heads, hd)
    cos, sin = rope_tables(pos[:, None], hd, cfg.rope_theta)   # (B,1,hd/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    sc = cache_l["k"].shape[1]
    slot = (pos % sc).astype(jnp.int32)                        # (B,)
    bidx = jnp.arange(b)
    kc = cache_l["k"].at[bidx, slot].set(k[:, 0])
    vc = cache_l["v"].at[bidx, slot].set(v[:, 0])
    kv_pos = cache_l["kv_pos"].at[bidx, slot].set(pos.astype(jnp.int32))
    out = decode_attention(q, kc, vc, kv_pos, pos, window)
    out = dense(out.reshape(b, 1, cfg.n_heads * hd), p["wo"])
    return out, {"k": kc, "v": vc, "kv_pos": kv_pos}


def serve_step_multi(params, cfg: ModelConfig, cache, token, pos):
    """Decode with per-slot positions. token (B,1); pos (B,) int32."""
    descs, _ = T.block_structure(cfg)
    x = params["embed"][token]
    window = cfg.sliding_window

    def body(x, inp):
        group_p, cache_g = inp
        new_g = {}
        for j, desc in enumerate(descs):
            p = group_p[f"l{j}"]
            cl = cache_g[f"l{j}"]
            new_l = dict(cl)
            h = T._apply_norm(p["norm1"], x, cfg)
            if desc.mixer == "attn":
                att, upd = _attn_decode_multi(p["attn"], h, cfg, cl, pos, window)
                new_l.update(upd)
            elif desc.mixer == "mamba":
                from repro.models import mamba as M
                att, (conv, ssm) = M.mamba_step(p["mamba"], h,
                                                (cl["conv"], cl["ssm"]), cfg)
                new_l["conv"], new_l["ssm"] = conv, ssm
            else:
                from repro.models import rwkv as R
                att, tm_prev, wkv = R.time_mix(p["tm"], h,
                                               cl["tm_prev"].astype(h.dtype),
                                               cl["wkv"], cfg)
                new_l["tm_prev"] = tm_prev.astype(jnp.float32)
                new_l["wkv"] = wkv
            x = x + att
            h = T._apply_norm(p["norm2"], x, cfg)
            if desc.ffn == "dense":
                from repro.models.layers import swiglu
                f = swiglu(h, p["ffn"])
            elif desc.ffn == "moe":
                from repro.models.moe import moe_ffn
                f, _ = moe_ffn(h, p["ffn"], cfg.moe)
            else:
                from repro.models import rwkv as R
                f, cm_prev = R.channel_mix(p["cm"], h,
                                           cl["cm_prev"].astype(h.dtype))
                new_l["cm_prev"] = cm_prev.astype(jnp.float32)
            x = x + f
            new_g[f"l{j}"] = new_l
        return x, new_g

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = T._apply_norm(params["final_norm"], x, cfg)
    logits = T.logits_from_x(params, cfg, x)[:, 0, :]
    return logits.astype(jnp.float32), new_cache


class ContinuousBatcher:
    """Fixed slot pool; iteration-level scheduling."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 cache_len: int = 128):
        assert cfg.family in ("dense", "moe", "ssm"), \
            "continuous batching demo covers uniform-stack families"
        self.cfg, self.params = cfg, params
        self.n_slots, self.cache_len = n_slots, cache_len
        self.cache = T.init_cache(cfg, n_slots, cache_len)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.pool = SlotPool(n_slots)
        self.token = jnp.zeros((n_slots, 1), jnp.int32)
        self._step = jax.jit(lambda p, c, t, pos: serve_step_multi(
            p, cfg, c, t, pos))

    @property
    def active(self) -> List[Optional[StreamRequest]]:
        return self.pool.items

    def _slot_cache(self, fn):
        """Apply fn(leaf)->leaf to the cache pytree."""
        self.cache = jax.tree.map(fn, self.cache)

    def _admit(self, req: StreamRequest, slot: int):
        """Prefill the request into ``slot`` (single-request prefill)."""
        cfg = self.cfg
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        logits, rcache, pos = T.prefill(self.params, cfg, batch, self.cache_len)

        def put(pool, single):
            return pool.at[:, slot].set(single[:, 0])
        self.cache = jax.tree.map(put, self.cache, rcache)
        self.pos = self.pos.at[slot].set(int(pos))
        nxt = int(jnp.argmax(logits[0]))
        req.out.append(nxt)
        self.token = self.token.at[slot, 0].set(nxt)
        self.pool.items[slot] = req

    def run(self, requests: List[StreamRequest], max_ticks: int = 256):
        """Drive arrivals + decode until all requests finish."""
        pending = sorted(requests, key=lambda r: r.arrival)
        tick = 0
        finished = []
        while (pending or self.pool.any_active()) and tick < max_ticks:
            # admissions
            for slot in self.pool.free_slots():
                if pending and pending[0].arrival <= tick:
                    self._admit(pending.pop(0), slot)
            if self.pool.any_active():
                logits, self.cache = self._step(self.params, self.cache,
                                                self.token, self.pos)
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                self.pos = self.pos + jnp.asarray(
                    [1 if r is not None else 0 for r in self.active], jnp.int32)
                self.token = nxt[:, None]
                for slot, req in self.pool.occupied():
                    req.out.append(int(nxt[slot]))
                    if len(req.out) >= req.max_new:
                        req.done = True
                        finished.append(req)
                        self.pool.release(slot)
            tick += 1
        return finished
