"""Batched serving engine: prefill + decode over the zoo's ``serve_step``.

Used by the end-to-end serving example (the paper is an inference-serving
design framework, so the required end-to-end driver serves rather than
trains) and by the decode-shape dry-runs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.common import ModelConfig


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)


class ServingEngine:
    """Static-batch engine: pad prompts, prefill once, decode greedily."""

    def __init__(self, cfg: ModelConfig, params, *, cache_slots: int = 256,
                 shard_fn=None):
        self.cfg, self.params = cfg, params
        self.cache_slots = cache_slots
        self.shard_fn = shard_fn
        self._decode = jax.jit(
            lambda p, c, t, pos: T.serve_step(p, cfg, c, t, pos, shard_fn=shard_fn))

    def run(self, requests: List[Request]) -> List[Request]:
        cfg = self.cfg
        b = len(requests)
        max_prompt = max(len(r.prompt) for r in requests)
        # left-pad prompts so last token aligns (static batch)
        toks = np.zeros((b, max_prompt), np.int32)
        for i, r in enumerate(requests):
            toks[i, max_prompt - len(r.prompt):] = r.prompt
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros((b, cfg.n_patches, cfg.d_frontend), cfg.jdtype)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((b, cfg.n_frames, cfg.d_frontend), cfg.jdtype)
        logits, cache, pos = T.prefill(self.params, cfg, batch, self.cache_slots,
                                       shard_fn=self.shard_fn)
        max_new = max(r.max_new for r in requests)
        token = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for step in range(max_new):
            for i, r in enumerate(requests):
                if step < r.max_new:
                    r.out.append(int(token[i, 0]))
            logits, cache = self._decode(self.params, cache, token, pos + step)
            token = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return requests
