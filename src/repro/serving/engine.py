"""Batched serving engine: prefill + decode over the zoo's ``serve_step``.

Used by the end-to-end serving example (the paper is an inference-serving
design framework, so the required end-to-end driver serves rather than
trains) and by the decode-shape dry-runs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.common import ModelConfig


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)


@dataclass(frozen=True)
class BatchCostModel:
    """Analytic per-replica service-time model of the static-batch engine.

    One batch pays a fixed dispatch/prefill overhead, then per-item FLOPs at
    the platform's effective throughput — batching amortises the overhead,
    which is what the fleet's dynamic batching window exploits.  This is the
    capacity model ``repro.fleet.cluster`` runs its replicas on.
    """
    flops_per_item: float            # server-side FLOPs of one request
    flops_per_s: float               # replica effective throughput
    fixed_overhead_s: float = 2e-4   # dispatch + prefill per batch

    def service_time(self, batch_size: int) -> float:
        assert batch_size >= 1
        return (self.fixed_overhead_s
                + batch_size * self.flops_per_item / self.flops_per_s)

    def throughput(self, batch_size: int) -> float:
        """Requests/s one replica sustains at that batch size."""
        return batch_size / self.service_time(batch_size)

    @classmethod
    def for_split(cls, model, params, split_layer: Optional[int],
                  platform, *, fixed_overhead_s: float = 2e-4,
                  sample=None) -> "BatchCostModel":
        """Server-side cost of one request for a cut after ``split_layer``
        (``None`` = the server runs the whole model, i.e. scenario RC).

        ``sample``: example input pytree for models whose ``input_shape``
        cannot describe the input; FLOPs counted at its batch are
        normalised back to one request.
        """
        import jax

        from repro.core import stats as S
        n = 1
        if sample is not None:
            n = int(jax.tree.leaves(sample)[0].shape[0])
        if split_layer is None:
            flops = S.total_flops(model, params, batch=1, sample=sample)
        else:
            _, flops = S.flops_split(model, params, split_layer, batch=1,
                                     sample=sample)
        return cls(float(flops) / n, platform.flops_per_s,
                   fixed_overhead_s=fixed_overhead_s)

    @classmethod
    def from_measured(cls, seconds_per_item: float, flops_per_s: float, *,
                      fixed_overhead_s: float = 2e-4) -> "BatchCostModel":
        """Cost model anchored to a *measured* per-item service time
        (hardware-in-the-loop: the wall clock of the executed tail stage,
        see ``repro.runtime.calibrate``).  ``flops_per_item`` is
        back-derived so FLOPs-rate reporting stays meaningful."""
        assert seconds_per_item > 0
        return cls(seconds_per_item * flops_per_s, flops_per_s,
                   fixed_overhead_s=fixed_overhead_s)


class ServingEngine:
    """Static-batch engine: pad prompts, prefill once, decode greedily."""

    def __init__(self, cfg: ModelConfig, params, *, cache_slots: int = 256,
                 shard_fn=None):
        self.cfg, self.params = cfg, params
        self.cache_slots = cache_slots
        self.shard_fn = shard_fn
        self._decode = jax.jit(
            lambda p, c, t, pos: T.serve_step(p, cfg, c, t, pos, shard_fn=shard_fn))

    def run(self, requests: List[Request]) -> List[Request]:
        cfg = self.cfg
        b = len(requests)
        max_prompt = max(len(r.prompt) for r in requests)
        # left-pad prompts so last token aligns (static batch)
        toks = np.zeros((b, max_prompt), np.int32)
        for i, r in enumerate(requests):
            toks[i, max_prompt - len(r.prompt):] = r.prompt
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros((b, cfg.n_patches, cfg.d_frontend), cfg.jdtype)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((b, cfg.n_frames, cfg.d_frontend), cfg.jdtype)
        logits, cache, pos = T.prefill(self.params, cfg, batch, self.cache_slots,
                                       shard_fn=self.shard_fn)
        max_new = max(r.max_new for r in requests)
        token = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for step in range(max_new):
            for i, r in enumerate(requests):
                if step < r.max_new:
                    r.out.append(int(token[i, 0]))
            logits, cache = self._decode(self.params, cache, token, pos + step)
            token = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return requests
