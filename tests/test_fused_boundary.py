"""Fused-boundary runtime contract tests.

The fused path (``Partition.fused_segments`` / ``SplitRuntime(fused=True)``)
must be indistinguishable from the eager stage-then-codec path on the
wire: byte-for-byte identical payloads on every hop and bit-identical
logits — ``fused`` moves work between timing buckets, never changes the
numbers.  These tests pin that contract, the fused accounting, the
TailServer interop, the fused calibration fields, and the boundary-tensor
sharding hook.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bottleneck as B
from repro.runtime import wire as W
from repro.runtime.calibrate import CalibrationTable, calibrate
from repro.runtime.engine import SplitRuntime, TailServer
from repro.runtime.partition import make_partition


def _ae_for(model, params, cut, rate=0.5):
    shapes = model.activation_shapes(params, 1)
    return B.init_bottleneck(jax.random.PRNGKey(1), shapes[cut], rate=rate)


def _eager_chain(part, x, *, quantize=True):
    """The historical op-by-op wire path; returns (logits, per-hop bufs)."""
    cur, bufs = jnp.asarray(x), []
    for k, cut in enumerate(part.splits):
        cur = part.stage(k)(cur)
        ae_k = part.ae_map.get(cut)
        buf = W.to_bytes(W.encode_activation(cur, ae_k, quantize=quantize))
        bufs.append(buf)
        cur = W.decode_activation(W.from_bytes(buf), ae_k)
    return np.asarray(part.stage(len(part.splits))(cur)), bufs


def _fused_chain(part, x, *, quantize=True):
    """Fused segments + byte framing; returns (logits, per-hop bufs)."""
    segs = part.fused_segments(quantize=quantize)
    kinds = part.wire_kinds(quantize)
    out, bufs = segs[0](jnp.asarray(x)), []
    for k in range(1, len(segs)):
        bufs.append(W.frame_arrays(kinds[k - 1], out[0], out[1]))
        out = segs[k](W.parse_arrays(bufs[-1]))
    return np.asarray(out), bufs


@pytest.mark.parametrize("quantize", [True, False])
def test_fused_payloads_bit_identical_to_eager(vgg_small, toy_data, quantize):
    """Every hop's wire bytes and the final logits match exactly —
    across ae8 (first cut), int8 and f32 payload kinds."""
    model, params = vgg_small
    xs, _ = toy_data
    x = jnp.asarray(xs[:4])
    cuts = model.cut_points()
    c0, c1 = cuts[1], cuts[3]
    part = make_partition(model, params, (c0, c1),
                          ae={c0: _ae_for(model, params, c0)})
    y_eager, bufs_eager = _eager_chain(part, x, quantize=quantize)
    y_fused, bufs_fused = _fused_chain(part, x, quantize=quantize)
    for k, (a, b) in enumerate(zip(bufs_fused, bufs_eager)):
        assert a == b, f"hop {k} payload diverged ({len(a)} vs {len(b)} B)"
    np.testing.assert_array_equal(y_fused, y_eager)


def test_fused_forward_matches_segment_chain(vgg_small, toy_data):
    """fused_forward (device-only, no framing) equals the framed chain
    bit-for-bit: byte framing is lossless."""
    model, params = vgg_small
    xs, _ = toy_data
    x = jnp.asarray(xs[:2])
    c0 = model.cut_points()[2]
    part = make_partition(model, params, c0,
                          ae=_ae_for(model, params, c0))
    y_chain, _ = _fused_chain(part, x)
    np.testing.assert_array_equal(np.asarray(part.fused_forward(x)), y_chain)


def test_fused_runtime_matches_eager_runtime(vgg_small, toy_data):
    model, params = vgg_small
    xs, _ = toy_data
    x = np.asarray(xs[:4])
    c0 = model.cut_points()[1]
    ae = _ae_for(model, params, c0)
    r_eager = SplitRuntime(model, params, c0, ae=ae).infer(x, iters=1)
    r_fused = SplitRuntime(model, params, c0, ae=ae, fused=True).infer(
        x, iters=1)
    np.testing.assert_array_equal(r_fused.logits, r_eager.logits)
    assert r_fused.wire_bytes == r_eager.wire_bytes
    assert r_fused.meta["fused"] and not r_eager.meta["fused"]


def test_fused_runtime_accounting_reconciles(vgg_small, toy_data):
    """stage_s + hop encode/transfer/decode sums to total_s, and the
    span tree's root duration agrees — same invariant as eager."""
    from repro.netsim.channel import Channel
    model, params = vgg_small
    xs, _ = toy_data
    ch = Channel(latency_s=0.004, capacity_bps=20e6, interface_bps=100e6)
    cuts = model.cut_points()
    rt = SplitRuntime(model, params, (cuts[1], cuts[3]),
                      ae={cuts[1]: _ae_for(model, params, cuts[1])},
                      channel=ch, fused=True)
    res = rt.infer(np.asarray(xs[:2]), iters=1)
    parts = sum(res.stage_s) + sum(h["encode_s"] + h["transfer_s"]
                                   + h["decode_s"] for h in res.hops)
    assert res.transfer_s > 0
    assert abs(parts - res.total_s) < 1e-12
    assert abs(res.trace.dur - res.total_s) < 1e-9
    assert len(res.stage_s) == 3 and len(res.hops) == 2


def test_tail_server_serves_fused_payload(vgg_small, toy_data):
    """A payload framed from a fused segment is a normal wire payload:
    the (eager) TailServer decodes and serves it unchanged."""
    model, params = vgg_small
    xs, _ = toy_data
    x = jnp.asarray(xs[:2])
    c0 = model.cut_points()[2]
    ae = _ae_for(model, params, c0)
    part = make_partition(model, params, c0, ae=ae)
    segs = part.fused_segments()
    out = segs[0](x)
    buf = W.frame_arrays(part.wire_kinds()[0], out[0], out[1])
    server = TailServer(part, n_slots=2, client_batch=2)
    server.submit(0, buf)
    results = server.drain()
    eager_buf = W.to_bytes(W.encode_activation(part.head(x), ae))
    assert buf == eager_buf
    want = part.tail(W.decode_activation(W.from_bytes(eager_buf), ae))
    np.testing.assert_allclose(results[0], np.asarray(want), atol=1e-5)


def test_calibrate_fused_quotes_fused_costs(vgg_small, tmp_path):
    model, params = vgg_small
    c0 = model.cut_points()[1]
    ae = _ae_for(model, params, c0)
    t = calibrate(model, params, [c0], ae_map={c0: ae}, batch=2, iters=1,
                  fused=True)
    e = t.lookup("SC", c0)
    assert e.use_fused and e.fused_edge_s > 0 and e.fused_server_s > 0
    assert e.edge_s == e.fused_edge_s
    assert e.server_s == e.fused_server_s
    # eager component times are kept alongside for comparison
    assert e.head_s > 0 and e.encode_s > 0 and e.decode_s > 0
    # the CostModel flow interface quotes the fused numbers
    ft = t.flow_times("SC", c0)
    assert ft["edge_s"] == e.fused_edge_s
    # JSON round-trips the new fields; old entries without them load too
    p = tmp_path / "cal.json"
    t.to_json(str(p))
    assert CalibrationTable.from_json(str(p)).lookup("SC", c0) == e
    doc = json.loads(p.read_text())
    for entry in doc["entries"].values():
        for f in ("fused_edge_s", "fused_server_s", "use_fused"):
            entry.pop(f, None)
    p.write_text(json.dumps(doc))
    old = CalibrationTable.from_json(str(p)).lookup("SC", c0)
    assert not old.use_fused and old.edge_s == old.head_s + old.encode_s


def test_boundary_shard_fn_hook(vgg_small, toy_data):
    """Fused segments accept a sharding.rules shard_fn; on the host mesh
    the boundary pins are identity and results are unchanged."""
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import rules
    model, params = vgg_small
    xs, _ = toy_data
    x = jnp.asarray(xs[:2])
    c0 = model.cut_points()[2]
    part = make_partition(model, params, c0,
                          ae=_ae_for(model, params, c0))
    sf = rules.make_shard_fn(make_host_mesh())
    plain = np.asarray(part.fused_forward(x))
    segs = part.fused_segments(shard_fn=sf)
    cur = segs[0](x)
    for s in segs[1:]:
        cur = s(cur)
    np.testing.assert_array_equal(np.asarray(cur), plain)


def test_boundary_specs_shard_rows_only():
    """The boundary-tensor rules shard the batch-row axis and leave the
    latent dim whole, for both codes and scales, at any rank."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding import rules
    for kind in ("boundary_codes", "boundary_scales"):
        (spec,) = rules.ACT_SPECS[kind]("data")
        assert tuple(spec) == ("data",)
    (spec,) = rules.ACT_SPECS["boundary_codes"](("pod", "data"))
    assert tuple(spec) == (("pod", "data"),)
