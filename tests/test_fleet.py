"""Fleet subsystem tests: traffic determinism, cluster queueing behaviour,
planner feasibility, and the shared event engine."""
import numpy as np
import pytest

import repro.netsim.events as events
import repro.fleet.cluster as cluster_mod
from repro.core.qos import QoSRequirements
from repro.fleet import (ClusterConfig, ClusterSim, DeviceClass,
                         DeploymentPlanner, SearchSpace, generate_trace)
from repro.fleet.planner import simulate_deployment
from repro.netsim.channel import Channel
from repro.serving.engine import BatchCostModel


def _mix(loss=0.0):
    return [DeviceClass.make("mcu", Channel(1e-3, 1e6, 1e6, loss_rate=loss,
                                            seed=1), weight=1.0),
            DeviceClass.make("edge-embedded",
                             Channel(1e-4, 50e6, 50e6, loss_rate=loss, seed=2),
                             weight=2.0),
            DeviceClass.make("edge-accelerator",
                             Channel(1e-4, 1e9, 1e9, seed=3), weight=1.0)]


# ------------------------------------------------------------- traffic ----
@pytest.mark.parametrize("pattern", ["poisson", "bursty", "diurnal"])
def test_trace_deterministic_under_seed(pattern):
    mix = _mix()
    a = generate_trace(mix, 400, 100.0, pattern=pattern, seed=7)
    b = generate_trace(mix, 400, 100.0, pattern=pattern, seed=7)
    assert [(r.t_arrival, r.device) for r in a.requests] == \
           [(r.t_arrival, r.device) for r in b.requests]
    c = generate_trace(mix, 400, 100.0, pattern=pattern, seed=8)
    assert [r.t_arrival for r in a.requests] != [r.t_arrival for r in c.requests]
    # arrivals are sorted, strictly positive, and every class shows up
    ts = [r.t_arrival for r in a.requests]
    assert ts == sorted(ts) and ts[0] > 0
    assert {r.device for r in a.requests} == {d.name for d in mix}


@pytest.mark.parametrize("pattern,kw", [
    ("poisson", {}), ("bursty", {}),
    # mean rate only converges over whole periods: use a short one
    ("diurnal", {"period_s": 5.0}),
])
def test_trace_hits_requested_mean_rate(pattern, kw):
    tr = generate_trace(_mix(), 8000, 250.0, pattern=pattern, seed=0, **kw)
    assert abs(tr.mean_rate_hz() - 250.0) / 250.0 < 0.15


def test_bursty_is_burstier_than_poisson():
    def dispersion(tr, window=0.1):
        """Index of dispersion of counts — burstiness shows up in windowed
        count variance, not in the raw inter-arrival CV."""
        ts = np.array([r.t_arrival for r in tr.requests])
        counts, _ = np.histogram(ts, np.arange(0.0, ts[-1], window))
        return counts.var() / counts.mean()
    po = dispersion(generate_trace(_mix(), 4000, 100.0, pattern="poisson", seed=4))
    bu = dispersion(generate_trace(_mix(), 4000, 100.0, pattern="bursty", seed=4))
    assert po < 1.5                 # poisson: D ~= 1
    assert bu > po * 2.0, (po, bu)  # MMPP: overdispersed


def test_device_mix_follows_weights():
    tr = generate_trace(_mix(), 4000, 100.0, seed=2)
    n = {d: len(tr.for_device(d).requests)
         for d in ("mcu", "edge-embedded", "edge-accelerator")}
    assert abs(n["edge-embedded"] / 4000 - 0.5) < 0.05
    assert sum(n.values()) == 4000


def test_unknown_pattern_and_platform_raise():
    with pytest.raises(ValueError):
        generate_trace(_mix(), 10, 1.0, pattern="fractal")
    with pytest.raises(KeyError):
        DeviceClass.make("server-gpu", Channel(1e-4, 1e9, 1e9))


# -------------------------------------------------------- event engine ----
def test_fleet_and_netsim_share_one_event_queue_impl():
    assert cluster_mod.EventQueue is events.EventQueue


def test_event_handle_cancellation():
    q = events.EventQueue()
    seen = []
    h = q.schedule(1.0, lambda: seen.append("dead"))
    q.schedule(2.0, lambda: seen.append("live"))
    h.cancel()
    assert q.peek() == 2.0          # cancelled head is skipped
    q.run()
    assert seen == ["live"]
    assert q.empty()


# -------------------------------------------------------------- cluster ----
def _cost(service_s=1e-3):
    # max_batch=1 service time == fixed overhead => deterministic M/D/c
    return BatchCostModel(flops_per_item=0.0, flops_per_s=1e12,
                          fixed_overhead_s=service_s)


def test_queueing_latency_monotone_in_arrival_rate():
    mix = _mix()
    lats = []
    for rate in (300.0, 600.0, 900.0):     # capacity: 1000 req/s
        tr = generate_trace(mix, 1500, rate, seed=11)
        sim = ClusterSim(_cost(1e-3), ClusterConfig(
            n_replicas=1, max_batch=1, batch_window_s=0.0))
        sim.offer_trace((r.rid, r.t_arrival) for r in tr.requests)
        st = sim.run()
        assert len(st.served) == 1500 and st.dropped == 0
        lats.append(st.latencies().mean())
    assert lats[0] < lats[1] < lats[2], lats


def test_cluster_drops_when_admission_queue_full():
    tr = generate_trace(_mix(), 800, 5000.0, seed=3)   # 5x overload
    sim = ClusterSim(_cost(1e-3), ClusterConfig(
        n_replicas=1, max_batch=1, batch_window_s=0.0, queue_limit=16))
    sim.offer_trace((r.rid, r.t_arrival) for r in tr.requests)
    st = sim.run()
    assert st.dropped > 0
    assert len(st.served) + st.dropped == 800
    assert 0.0 < st.drop_fraction() < 1.0


def test_dynamic_batching_amortizes_and_respects_max_batch():
    tr = generate_trace(_mix(), 2000, 4000.0, seed=5)
    cfg = ClusterConfig(n_replicas=1, max_batch=8, batch_window_s=2e-3)
    sim = ClusterSim(_cost(1e-3), cfg)
    sim.offer_trace((r.rid, r.t_arrival) for r in tr.requests)
    st = sim.run()
    assert 1.0 < st.mean_batch() <= cfg.max_batch
    # every batch bounded by max_batch
    assert st.batches * cfg.max_batch >= len(st.served)
    # full batches dispatched early => their window timers were cancelled
    assert sim.q.n_cancelled > 0


def test_replicas_add_capacity():
    tr = generate_trace(_mix(), 1500, 1800.0, seed=9)  # 1 replica: overloaded
    waits = []
    for r in (1, 2):
        sim = ClusterSim(_cost(1e-3), ClusterConfig(
            n_replicas=r, max_batch=1, batch_window_s=0.0))
        sim.offer_trace((req.rid, req.t_arrival) for req in tr.requests)
        waits.append(sim.run().latencies().mean())
    assert waits[1] < waits[0] * 0.5


def test_embedded_cluster_uses_outer_queue():
    q = events.EventQueue()
    sim = ClusterSim(_cost(1e-3), ClusterConfig(1, 1, 0.0), queue=q)
    sim.offer(0, 0.5)
    q.run()
    assert len(sim.stats.served) == 1
    assert sim.q is q


# -------------------------------------------------------------- planner ----
@pytest.fixture(scope="module")
def planner(request):
    vgg_small = request.getfixturevalue("vgg_small")
    model, params = vgg_small
    from repro.models.vgg import feature_index
    fi = feature_index(model)
    cs = np.linspace(1.0, 0.2, len(fi))

    def accuracy_fn(scenario, netcfg):
        # analytic proxy: UDP loses accuracy with channel loss, TCP doesn't
        base = 0.9 if scenario.kind != "LC" else 0.6
        if netcfg.protocol == "udp":
            base -= netcfg.channel.loss_rate
        return base

    return DeploymentPlanner(model, params, cs_curve=cs, layer_idx=fi,
                             accuracy_fn=accuracy_fn,
                             input_bytes=16 * 16 * 3 * 4, n_frames=4)


@pytest.fixture(scope="module")
def space(planner):
    legal = set(planner.model.cut_points())
    sps = tuple(sp for sp in planner.layer_idx if sp in legal)[:3]
    return SearchSpace(split_points=sps, protocols=("tcp", "udp"),
                       batch_sizes=(1, 4), replica_counts=(1, 2),
                       top_k_splits=2)


def test_planner_suggest_returns_only_feasible(planner, space):
    mix = _mix(loss=0.1)
    trace = generate_trace(mix, 300, 150.0, seed=21)
    qos = QoSRequirements(max_latency_s=1.0, min_accuracy=0.5)
    plans = planner.suggest(qos, (trace, mix), space)
    assert set(plans) == {d.name for d in mix}
    assert any(p is not None for p in plans.values())
    for p in plans.values():
        if p is not None:
            assert p.satisfies(qos)
            assert p.p99_s <= qos.max_latency_s
            assert p.accuracy >= qos.min_accuracy


def test_planner_infeasible_qos_yields_none(planner, space):
    mix = _mix()
    trace = generate_trace(mix, 100, 50.0, seed=22)
    impossible = QoSRequirements(max_latency_s=1e-9, min_accuracy=0.999)
    plans = planner.suggest(impossible, (trace, mix), space)
    assert all(p is None for p in plans.values())


def test_pareto_front_is_nondominated(planner, space):
    mix = _mix(loss=0.05)
    trace = generate_trace(mix, 200, 100.0, seed=23)
    points = planner.search(trace, mix, space)
    front = planner.pareto_front(points)
    assert front
    for p in front:
        rivals = [o for o in points if o.device == p.device]
        for o in rivals:
            po, oo = p.objectives(), o.objectives()
            assert not (all(b <= a for a, b in zip(po, oo))
                        and any(b < a for a, b in zip(po, oo))), (p, o)


def test_planner_candidates_pruned_by_cs_ranking(planner, space):
    cands = planner.candidates(space)
    sc = [c for c in cands if c[0].startswith("SC")]
    assert len(sc) == space.top_k_splits
    # cs curve is decreasing, so the earliest cuts rank first
    proxies = [planner.cs_curve[planner.layer_idx.index(s)] for _, s in sc]
    assert proxies == sorted(proxies, reverse=True)
    assert ("RC", None) in cands


def test_joint_deployment_simulation(planner, space):
    mix = _mix()
    trace = generate_trace(mix, 300, 200.0, seed=24)
    qos = QoSRequirements(max_latency_s=1.0, min_accuracy=0.0)
    plans = planner.suggest(qos, (trace, mix), space)
    report = simulate_deployment(plans, trace, mix, planner)
    assert report
    total = sum(g["n_served"] for g in report.values())
    planned = sum(len(trace.for_device(d).requests) for d, p in plans.items()
                  if p is not None and p.label != "LC")
    assert total == planned
    for g in report.values():
        assert g["p99_s"] >= g["p50_s"] > 0
