"""Multi-pod split pipeline correctness (runs in a subprocess because the
device-count flag must be set before jax initialises)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_multipod_pipeline_example():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    script = os.path.join(os.path.dirname(__file__), "..", "examples",
                          "multipod_pipeline.py")
    out = subprocess.run([sys.executable, script], env=env, timeout=600,
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr[-2000:]
    # bitwise-identical on some jax versions; reassociation across
    # shard_map/scan can differ in the last float32 bits on others
    import re
    m = re.search(r"max err ([0-9.e+-]+)", out.stdout)
    assert m, out.stdout
    assert float(m.group(1)) <= 1e-4, out.stdout
