"""Adaptive replanning: the drift-aware control loop (fleet.controller)
and its scenario layer (fleet.scenario, ChannelSchedule, live replica
pool).  The load-bearing invariant: both cluster engines produce the
*same switch decisions* on the same scenario."""
import numpy as np
import pytest

from repro.fleet import (AdaptiveController, CandidatePlan, ClusterConfig,
                         ClusterSim, ControllerConfig, DeviceClass,
                         LinkDegradation, Phase, RegimeChangeTrace,
                         ReplicaEvent, generate_trace, schedule_faults)
from repro.netsim.channel import Channel, ChannelSchedule, degrade
from repro.serving.engine import BatchCostModel

COST = BatchCostModel(flops_per_item=1e7, flops_per_s=1e12,
                      fixed_overhead_s=2e-4)
# svc(1)=0.21ms (cap ~4.8k/s) ... svc(64)=0.84ms (cap ~76k/s): small
# batch is snappy at calm rates, big batch is the only rush survivor
CHANNEL = Channel(1e-4, 100e6, 100e6, seed=1)


def _cands():
    return [CandidatePlan("b1", "SC@3", 3, "tcp", 1, 1, 5e-3, COST),
            CandidatePlan("b8", "SC@3", 3, "tcp", 8, 1, 5e-3, COST),
            CandidatePlan("b64", "SC@3", 3, "tcp", 64, 1, 5e-3, COST)]


def _mix():
    return (DeviceClass.make("edge-embedded", CHANNEL),)


@pytest.fixture(scope="module")
def rush_calm():
    """Morning rush (only b64 keeps up) then a long calm tail where the
    big batch pays its batching window on every request."""
    return RegimeChangeTrace.from_phases(
        _mix(), [Phase(1.0, 20000.0), Phase(4.0, 1500.0)], seed=7)


@pytest.fixture(scope="module")
def controller():
    cfg = ControllerConfig(control_period_s=0.25, drift_threshold=0.3,
                           min_improvement=0.05, warmup_s=0.02,
                           max_switches=4)
    return AdaptiveController(_cands(), config=cfg)


# ---------------------------------------------------------- scenarios ----
def test_trace_slice_concat_provenance():
    mix = _mix()
    t = generate_trace(mix, 50, 100.0, seed=3)
    s = t.slice(0.1, 0.3)
    assert s.seed == 3 and s.horizon_s == pytest.approx(0.2)
    assert all(0.0 <= r.t_arrival < 0.2 for r in s.requests)
    u = generate_trace(mix, 30, 100.0, seed=4)
    c = t.concat(u)
    assert c.seed is None                      # different generations
    assert c.horizon_s == pytest.approx(t.horizon_s + u.horizon_s)
    assert [r.rid for r in c.requests] == list(range(len(c)))
    assert len(c) == 80
    same = t.concat(generate_trace(mix, 30, 100.0, seed=3))
    assert same.seed == 3                      # shared seed survives
    with pytest.raises(ValueError):
        t.slice(0.5, 0.1)


def test_from_phases_boundaries_and_rates():
    sc = RegimeChangeTrace.from_phases(
        _mix(), [Phase(2.0, 100.0), Phase(3.0, 1000.0)], seed=0)
    assert sc.boundaries == (0.0, 2.0)
    assert sc.horizon_s == pytest.approx(5.0)
    t = sc.trace.arrival_times()
    early = int((t < 2.0).sum())
    late = int((t >= 2.0).sum())
    assert 100 < early < 350 and late > 2000   # rates ~100 vs ~1000 Hz


def test_channel_schedule_epochs():
    base = CHANNEL
    bad = degrade(base, capacity_factor=0.1, latency_factor=4.0)
    sched = ChannelSchedule(base, ((2.0, bad), (5.0, base)))
    assert sched.at(1.0) is base and sched.epoch(1.0) == 0
    assert sched.at(2.0) is bad and sched.epoch(2.0) == 1
    assert sched.at(7.0) is base and sched.epoch(7.0) == 2
    assert bad.latency_s == pytest.approx(4e-4)
    assert bad.effective_bps == pytest.approx(10e6)
    with pytest.raises(ValueError):
        degrade(base, capacity_factor=0.0)
    with pytest.raises(ValueError):
        degrade(base, latency_factor=0.5)


def test_cluster_live_replica_pool():
    sim = ClusterSim(COST, ClusterConfig(n_replicas=2, max_batch=4,
                                         batch_window_s=1e-3))
    for i in range(40):
        sim.offer(i, 0.001 * i)
    sim.run(until=0.01)
    assert sim.n_replicas == 2
    sim.set_replicas(1)                        # graceful shrink mid-run
    assert sim.n_replicas == 1
    sim.set_replicas(3)                        # recovery grows the pool
    assert sim.n_replicas == 3
    stats = sim.run()
    assert len(stats.served) == 40 and stats.dropped == 0


def test_schedule_faults_on_live_cluster():
    sc = RegimeChangeTrace.from_phases(
        _mix(), [Phase(1.0, 200.0)], seed=2,
        replica_events=[ReplicaEvent(0.3, -1), ReplicaEvent(0.6, +1)],
        link_events=[LinkDegradation(0.5, capacity_factor=0.5)],
        replica_pool=2)
    sim = ClusterSim(COST, ClusterConfig(n_replicas=2, max_batch=4,
                                         batch_window_s=1e-3))
    seen = []
    schedule_faults(sc, sim, on_link_change=lambda t, name, ch:
                    seen.append((t, name, ch.capacity_bps)))
    for i, r in enumerate(sc.trace.requests):
        sim.offer(i, r.t_arrival)
    sim.run(until=0.4)
    assert sim.n_replicas == 1                 # failure applied in place
    sim.run()
    assert sim.n_replicas == 2                 # recovery applied
    assert seen == [(0.5, "edge-embedded", pytest.approx(50e6))]
    assert sc.available_replicas(0.4) == 1
    assert sc.available_replicas(0.7) == 2


# ----------------------------------------------- the control loop itself ----
def test_engines_make_identical_switch_decisions(rush_calm, controller):
    rv = controller.run(rush_calm, engine="vectorized")
    re = controller.run(rush_calm, engine="event")
    assert rv.plan_keys == re.plan_keys
    assert len(rv.plan_keys) >= 2              # it did adapt
    assert [(s.t_s, s.from_key, s.to_key, s.reason, s.forced)
            for s in rv.switches] == \
           [(s.t_s, s.from_key, s.to_key, s.reason, s.forced)
            for s in re.switches]
    assert rv.migration == re.migration
    assert rv.dropped == re.dropped
    assert (rv.n_decisions, rv.n_replans, rv.n_suppressed) == \
           (re.n_decisions, re.n_replans, re.n_suppressed)
    # latencies agree to the standing cross-engine percentile tolerance
    assert rv.p99_s == pytest.approx(re.p99_s, rel=1e-6)
    assert len(rv.latencies) == len(re.latencies)


def test_adaptive_beats_best_static(rush_calm, controller):
    adaptive = controller.run(rush_calm, engine="vectorized")
    static = controller.best_static(rush_calm)
    assert adaptive.drop_fraction == 0.0
    assert static.p99_s > 1.5 * adaptive.p99_s
    # the win comes from down-shifting after the rush, not from drops
    assert adaptive.plan_keys[0] in ("b8", "b64")
    assert adaptive.plan_keys[-1] == "b1"


def test_migration_disruption_is_explicit(rush_calm, controller):
    res = controller.run(rush_calm, engine="vectorized")
    sw = [s for s in res.switches if not s.forced]
    assert sw and res.migration["n_delayed"] > 0
    assert res.migration["added_delay_s"] > 0.0
    assert res.migration["n_delayed"] == sum(s.n_delayed for s in sw)
    # warm-up can never delay anyone longer than warmup_s each
    assert res.migration["added_delay_s"] <= \
        res.migration["n_delayed"] * controller.config.warmup_s + 1e-12
    # switches record the prices hysteresis compared
    assert sw[0].predicted_p99_s < sw[0].incumbent_p99_s


def test_no_warmup_no_disruption(rush_calm):
    cfg = ControllerConfig(control_period_s=0.25, drift_threshold=0.3,
                           min_improvement=0.05, warmup_s=0.0)
    ctl = AdaptiveController(_cands(), config=cfg)
    res = ctl.run(rush_calm, engine="vectorized")
    assert res.n_switches >= 1
    assert res.migration == {"n_delayed": 0, "added_delay_s": 0.0}


def test_max_switches_is_a_hard_cap():
    # hostile flapping workload: the rate alternates every second
    phases = [Phase(1.0, 20000.0 if i % 2 == 0 else 1500.0)
              for i in range(6)]
    sc = RegimeChangeTrace.from_phases(_mix(), phases, seed=11)
    cfg = ControllerConfig(control_period_s=0.25, drift_threshold=0.3,
                           min_improvement=0.0, max_switches=1)
    ctl = AdaptiveController(_cands(), config=cfg)
    res = ctl.run(sc, engine="vectorized")
    assert res.n_switches <= 1
    assert res.n_suppressed >= 1               # the cap visibly bit


def test_cooldown_spaces_switches():
    phases = [Phase(1.0, 20000.0 if i % 2 == 0 else 1500.0)
              for i in range(6)]
    sc = RegimeChangeTrace.from_phases(_mix(), phases, seed=11)
    cfg = ControllerConfig(control_period_s=0.25, drift_threshold=0.3,
                           min_improvement=0.0, cooldown_s=2.0,
                           max_switches=50)
    res = AdaptiveController(_cands(), config=cfg).run(sc)
    ts = [s.t_s for s in res.switches if not s.forced]
    assert all(b - a >= 2.0 for a, b in zip(ts, ts[1:]))


def test_disabled_triggers_make_adaptive_a_noop(rush_calm):
    cfg = ControllerConfig(control_period_s=0.25, drift_threshold=None,
                           drop_trigger=None, queue_trigger=None)
    ctl = AdaptiveController(_cands(), config=cfg)
    for engine in ("vectorized", "event"):
        a = ctl.run(rush_calm, initial="b64", engine=engine)
        s = ctl.run_static(rush_calm, "b64", engine=engine)
        assert np.array_equal(a.latencies, s.latencies)
        assert a.plan_keys == s.plan_keys == ("b64",)
        assert a.n_switches == 0 and a.n_replans == 0


def test_replica_failure_forces_reconfig_without_counting():
    sc = RegimeChangeTrace.from_phases(
        _mix(), [Phase(3.0, 2000.0)], seed=5,
        replica_events=[ReplicaEvent(1.0, -1), ReplicaEvent(2.0, +1)],
        replica_pool=2)
    cands = [CandidatePlan("b8r2", "SC@3", 3, "tcp", 8, 2, 5e-3, COST)]
    cfg = ControllerConfig(control_period_s=0.5, drift_threshold=None,
                           drop_trigger=None)
    ctl = AdaptiveController(cands, config=cfg)
    rv = ctl.run(sc, engine="vectorized")
    re = ctl.run(sc, engine="event")
    assert rv.plan_keys == re.plan_keys == ("b8r2",) * 3
    assert rv.n_forced == re.n_forced == 2
    assert rv.n_switches == 0                  # physics is not policy
    assert [e.n_replicas for e in rv.eras] == [2, 1, 2]
    assert [e.n_replicas for e in re.eras] == [2, 1, 2]
    assert all(s.forced for s in rv.switches)


def test_link_degradation_reprices_flows():
    # wire-aware flow: the pre-delay stretches when the link degrades
    def flow_fn(device, cand, proto):
        wire = device.channel.latency_s + \
            8000 * 8.0 / device.channel.effective_bps
        return {"edge_s": 1e-4, "wire_s": np.array([wire]),
                "wire_bytes": 8000, "accuracy": 0.95}

    sc = RegimeChangeTrace.from_phases(
        _mix(), [Phase(2.0, 500.0)], seed=9,
        link_events=[LinkDegradation(1.0, capacity_factor=0.05,
                                     latency_factor=10.0)])
    cfg = ControllerConfig(control_period_s=0.25, drift_threshold=None,
                           drop_trigger=None)
    ctl = AdaptiveController(_cands(), config=cfg, flow_fn=flow_fn)
    rv = ctl.run(sc, initial="b1", engine="vectorized")
    re = ctl.run(sc, initial="b1", engine="event")
    # the fault fired a replan on both engines
    assert rv.n_replans == re.n_replans >= 1
    assert rv.plan_keys == re.plan_keys
    # latency visibly jumps after the degradation: the per-arrival wire
    # pricing picked up the new regime
    t_cut = 1.0
    t_arr = sc.trace.arrival_times()
    n_before = int((t_arr < t_cut).sum())
    lat = rv.latencies
    assert len(lat) == len(t_arr)
    assert np.median(lat[n_before:]) > 4 * np.median(lat[:n_before])


def test_drop_trigger_rescues_an_overloaded_plan():
    # calm then rush, pinned to the small batch: queue overflows, the
    # drop trigger fires, and the controller escapes to the big batch
    sc = RegimeChangeTrace.from_phases(
        _mix(), [Phase(1.0, 1500.0), Phase(2.0, 20000.0)], seed=13)
    cands = [CandidatePlan("b1", "SC@3", 3, "tcp", 1, 1, 5e-3, COST,
                           queue_limit=256),
             CandidatePlan("b64", "SC@3", 3, "tcp", 64, 1, 5e-3, COST,
                           queue_limit=256)]
    cfg = ControllerConfig(control_period_s=0.25, drift_threshold=None,
                           drop_trigger=0.0, min_improvement=0.0)
    ctl = AdaptiveController(cands, config=cfg)
    rv = ctl.run(sc, initial="b1", engine="vectorized")
    re = ctl.run(sc, initial="b1", engine="event")
    assert rv.plan_keys == re.plan_keys
    assert rv.plan_keys[-1] == "b64"
    assert any(s.reason == "drops" for s in rv.switches)
    assert rv.dropped == re.dropped > 0


def test_controller_telemetry(rush_calm):
    from repro.obs import Recorder
    obs = Recorder()
    cfg = ControllerConfig(control_period_s=0.25, drift_threshold=0.3,
                           min_improvement=0.05, warmup_s=0.02)
    ctl = AdaptiveController(_cands(), config=cfg, obs=obs)
    res = ctl.run(rush_calm, engine="vectorized")
    snap = obs.metrics.snapshot()
    assert snap["controller.decisions"] == res.n_decisions
    assert snap["controller.replans"] == res.n_replans
    assert snap["controller.switches"] == res.n_switches
    ts, vs = obs.metrics.timeseries("controller.rate_hz")
    assert len(ts) == res.n_decisions and (vs > 0).all()
    names = [s.name for s in obs.tracer.spans]
    assert "replan" in names and "switch" in names
    assert any(n.startswith("era[") for n in names)


def test_bad_inputs_rejected(rush_calm, controller):
    with pytest.raises(ValueError):
        AdaptiveController([])
    with pytest.raises(ValueError):
        AdaptiveController(_cands() + [_cands()[0]])   # duplicate key
    with pytest.raises(ValueError):
        controller.run(rush_calm, engine="fluid")


def test_from_planner_grid(vgg_small):
    from repro.fleet import DeploymentPlanner, SearchSpace
    model, params = vgg_small
    fi = list(model.cut_points())
    planner = DeploymentPlanner(
        model, params, cs_curve=np.linspace(1.0, 0.3, len(fi)),
        layer_idx=fi, accuracy_fn=lambda s, n: 0.9, input_bytes=3072,
        n_frames=2)
    space = SearchSpace(split_points=tuple(fi), batch_sizes=(1, 8),
                        replica_counts=(1,), top_k_splits=1,
                        include_rc=True)
    ctl = AdaptiveController.from_planner(
        planner, space,
        config=ControllerConfig(control_period_s=0.25,
                                drift_threshold=0.3))
    # 2 candidates (1 split + RC) x 2 protocols x 2 batches x 1 replica
    assert len(ctl.candidates) == 8
    sc = RegimeChangeTrace.from_phases(
        _mix(), [Phase(0.5, 300.0), Phase(0.5, 40.0)], seed=1)
    rv = ctl.run(sc, engine="vectorized")
    re = ctl.run(sc, engine="event")
    assert rv.plan_keys == re.plan_keys
    assert rv.n_offered == len(sc.trace)
    assert rv.drop_fraction == 0.0


def test_study_adapt(vgg_small, toy_data):
    from repro.api import Study
    from repro.api.study import StudyScenario
    model, params = vgg_small
    xs, ys = toy_data
    study = Study(model=model, params=params, data=(xs[:8], ys[:8]),
                  scenario=StudyScenario(channel=CHANNEL))
    sc = RegimeChangeTrace.from_phases(
        _mix(), [Phase(0.5, 300.0), Phase(0.5, 40.0)], seed=1)
    out = study.adapt(sc, batch_sizes=(1, 4), replica_counts=(1,),
                      top_k_splits=1,
                      config=ControllerConfig(control_period_s=0.25,
                                              drift_threshold=0.3))
    assert set(out) == {"adaptive", "static", "controller"}
    assert out["adaptive"].n_offered == len(sc.trace)
    assert out["static"].n_switches == 0
    # the static baseline is the best fixed plan, so adaptive never
    # loses by more than hysteresis slack on a tiny scenario
    assert out["adaptive"].p99_s <= out["static"].p99_s * 1.5
