"""Hypothesis property-based tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.saliency import local_maxima
from repro.kernels import ref
from repro.models.layers import rmsnorm
from repro.netsim.channel import Channel
from repro.netsim.protocols import simulate_tcp, simulate_udp
from repro.netsim.simulator import chunk_mask_from_packets

SET = dict(deadline=None, max_examples=25)


@settings(**SET)
@given(n_bytes=st.integers(1, 500_000), loss=st.floats(0, 0.3),
       seed=st.integers(0, 100))
def test_tcp_always_delivers(n_bytes, loss, seed):
    ch = Channel(1e-4, 1e9, 1e9, loss_rate=loss, seed=seed)
    r = simulate_tcp(n_bytes, ch)
    assert r.delivered.all()
    assert r.duration_s >= ch.serialization_s(min(n_bytes, 1500))


@settings(**SET)
@given(n_bytes=st.integers(1, 500_000), loss=st.floats(0, 0.9),
       seed=st.integers(0, 100))
def test_udp_duration_independent_of_delivery(n_bytes, loss, seed):
    ch = Channel(1e-4, 1e9, 1e9, loss_rate=loss, seed=seed)
    r = simulate_udp(n_bytes, ch)
    full = ch.serialization_s(1500) * r.n_packets + ch.latency_s
    assert r.duration_s <= full + 1e-12
    assert 0.0 <= r.loss_fraction <= 1.0


@settings(**SET)
@given(n_elems=st.integers(1, 5000), elem_bytes=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 50), loss=st.floats(0, 0.5))
def test_chunk_mask_covers_all_elements(n_elems, elem_bytes, seed, loss):
    rng = np.random.default_rng(seed)
    import math
    n_pkts = max(1, math.ceil(n_elems * elem_bytes / 1500))
    delivered = rng.random(n_pkts) >= loss
    mask = chunk_mask_from_packets(n_elems, delivered, elem_bytes, 1500)
    assert mask.shape == (n_elems,)
    if delivered.all():
        assert mask.all()
    if not delivered.any():
        assert not mask.any()


@settings(**SET)
@given(data=st.lists(st.floats(-10, 10), min_size=3, max_size=40))
def test_local_maxima_are_maxima(data):
    arr = np.asarray(data)
    for p in local_maxima(arr, tol=1e-9):
        assert 0 < p < len(arr) - 1
        left = arr[:p][::-1]
        right = arr[p + 1:]
        nl = next((x for x in left if abs(x - arr[p]) > 1e-9), None)
        nr = next((x for x in right if abs(x - arr[p]) > 1e-9), None)
        assert nl is None or nl < arr[p]
        assert nr is None or nr < arr[p]


@settings(**SET)
@given(b=st.integers(1, 4), n=st.integers(1, 6), c=st.integers(1, 8))
def test_rmsnorm_output_rms_is_one(b, n, c):
    x = jax.random.normal(jax.random.PRNGKey(b * 100 + n), (b, 8 * c)) * n
    y = rmsnorm(x, jnp.ones((8 * c,)))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-2)


@settings(**SET)
@given(scale=st.floats(0.01, 10.0), seed=st.integers(0, 1000))
def test_quantisation_bound_property(scale, seed):
    """Dequantised wire payload is within amax/254 of the true latent."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    f = jax.random.normal(ks[0], (8, 32)) * scale
    w = jax.random.normal(ks[1], (32, 16)) * 0.2
    q, s = ref.bottleneck_compress_ref(f, w, jnp.zeros((16,)))
    z = jax.nn.relu(f @ w)
    deq = ref.bottleneck_decompress_ref(q, s)
    amax = np.asarray(jnp.max(jnp.abs(z), 1))
    err = np.max(np.abs(np.asarray(deq) - np.asarray(z)), 1)
    assert (err <= amax / 254.0 + 1e-6).all()


@settings(**SET)
@given(b=st.integers(0, 5), rows=st.integers(1, 9), c=st.integers(1, 67),
       kind=st.sampled_from(["f32", "int8", "ae8"]), seed=st.integers(0, 50))
def test_wire_byte_format_roundtrip(b, rows, c, kind, seed):
    """The split-wire byte format survives serialise -> parse -> decode
    for every payload kind, across odd tile shapes and the empty batch:
    f32 is exact, int8 respects the symmetric per-row error bound, ae8
    agrees with the reference encode/decode chain."""
    from repro.core import bottleneck as B
    from repro.runtime import wire as W
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.standard_normal((b, rows, c)) * 3.0, jnp.float32)
    ae = (B.init_bottleneck(jax.random.PRNGKey(seed), (c,), rate=0.5)
          if kind == "ae8" else None)
    pkt = W.encode_activation(f, ae, quantize=kind != "f32")
    buf = W.to_bytes(pkt)
    back = W.from_bytes(buf)
    assert back.kind == kind and tuple(back.shape) == pkt.data.shape
    assert pkt.nbytes == len(buf)
    np.testing.assert_array_equal(back.data, pkt.data)
    out = np.asarray(W.decode_activation(back, ae))
    if kind == "f32":
        assert out.shape == (b, rows, c)
        np.testing.assert_array_equal(out, np.asarray(f))
    elif kind == "int8":
        assert out.shape == (b, rows, c)
        if b:                       # per-row bound: amax/(2*127) + rounding
            err = np.abs(out - np.asarray(f)).reshape(-1, c).max(1)
            amax = np.abs(np.asarray(f)).reshape(-1, c).max(1)
            assert (err <= amax / 254.0 + 1e-6).all()
    else:
        want = np.asarray(B.decode_wire(
            ae, jnp.asarray(pkt.data),
            jnp.asarray(pkt.scales).reshape((b, rows, 1))))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)
    assert out.shape == (b, rows, c)    # decoded back to channel width


@settings(**SET)
@given(b=st.integers(1, 4), rows=st.integers(1, 9), c=st.integers(1, 67),
       kind=st.sampled_from(["f32", "int8", "ae8"]), seed=st.integers(0, 50))
def test_fused_wire_path_equals_eager(b, rows, c, kind, seed):
    """Fused boundary contract at the wire level: jitted encode ->
    zero-copy frame -> parse -> jitted decode produces byte-identical
    payloads and bit-identical activations vs the eager WirePacket path,
    for random shapes/batches across every payload kind."""
    from repro.core import bottleneck as B
    from repro.runtime import wire as W
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.standard_normal((b, rows, c)) * 3.0, jnp.float32)
    ae = (B.init_bottleneck(jax.random.PRNGKey(seed), (c,), rate=0.5)
          if kind == "ae8" else None)
    quantize = kind != "f32"
    assert W.wire_kind(ae, quantize) == kind
    pkt = W.encode_activation(f, ae, quantize=quantize)
    buf_eager = W.to_bytes(pkt)
    out_eager = np.asarray(W.decode_activation(W.from_bytes(buf_eager), ae))
    enc = jax.jit(lambda v: W.encode_arrays(v, ae, quantize=quantize))
    data, scales = enc(f)
    buf_fused = W.frame_arrays(kind, data, scales)
    assert buf_fused == buf_eager
    d2, s2 = W.parse_arrays(buf_fused)
    dec = jax.jit(lambda d, s: W.decode_arrays(kind, d, s, ae))
    out_fused = np.asarray(dec(d2, s2))
    np.testing.assert_array_equal(out_fused, out_eager)


@settings(**SET)
@given(n_hops=st.integers(1, 3), n_micro=st.integers(1, 6),
       seed=st.integers(0, 10_000))
def test_pipeline_closed_form_matches_event_engine(n_hops, n_micro, seed):
    """The planner fast path's contract: on loss-free paths the closed
    forms in ``netsim.analytic`` reproduce ``simulate_pipeline``'s
    sequential *and* pipelined makespans to 1e-9 relative — across random
    stage/hop/path tensors, n_micro=1, zero-byte hops and pass-through
    (zero-time) stages."""
    import math

    from repro.netsim import analytic
    from repro.netsim.simulator import (NetworkConfig, NetworkPath,
                                        simulate_pipeline)
    rng = np.random.default_rng(seed)
    hops = tuple(NetworkConfig(str(rng.choice(["tcp", "udp"])),
                               Channel(float(rng.choice([1e-6, 1e-4, 1e-2])),
                                       float(rng.choice([1e6, 20e6, 1e9])),
                                       float(rng.choice([20e6, 1e9])),
                                       seed=k))
                 for k in range(n_hops))
    path = NetworkPath(hops)
    stage_s = [float(rng.choice([0.0, 1e-4, 2e-3, 5e-2]))
               for _ in range(n_hops + 1)]
    hop_bytes = [int(rng.choice([0, 1, 1500, 20_000, 300_000]))
                 for _ in range(n_hops)]
    pipe = simulate_pipeline(stage_s, hop_bytes, path, n_micro=n_micro)
    cf_pipe, cf_seq = analytic.closed_form_pipeline(stage_s, hop_bytes,
                                                    path, n_micro=n_micro)
    assert math.isclose(cf_pipe, pipe.latency_s, rel_tol=1e-9, abs_tol=1e-15)
    assert math.isclose(cf_seq, pipe.sequential_s, rel_tol=1e-9,
                        abs_tol=1e-15)


@settings(**SET)
@given(sq=st.sampled_from([32, 64]), sk=st.sampled_from([32, 64, 128]),
       g=st.sampled_from([1, 2, 4]), seed=st.integers(0, 100))
def test_attention_softmax_convexity(sq, sk, g, seed):
    """Attention output is a convex combination of V rows."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, 2 * g, 16))
    k = jax.random.normal(ks[1], (1, sk, 2, 16))
    v = jax.random.normal(ks[2], (1, sk, 2, 16))
    out = ref.flash_attention_ref(q, k, v, causal=sq <= sk)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4


@settings(**SET)
@given(n=st.integers(1, 400), k=st.integers(1, 4),
       max_batch=st.integers(1, 16),
       window_ms=st.sampled_from([0.0, 0.1, 2.0, 10.0]),
       queue_limit=st.integers(1, 120),
       service_us=st.floats(10.0, 2000.0), per_item=st.floats(0.0, 1e7),
       load=st.floats(0.2, 5.0), seed=st.integers(0, 1000))
def test_cluster_engines_agree_on_random_fleets(n, k, max_batch, window_ms,
                                                queue_limit, service_us,
                                                per_item, load, seed):
    """The vectorized cluster engine replays the event engine exactly:
    identical drop/batch/served counts and percentile agreement for
    random arrival processes, service costs, and cluster configs."""
    from repro.fleet.cluster import ClusterConfig
    from repro.fleet.vectorized import (check_against_event_engine,
                                        simulate_cluster_vectorized)
    from repro.serving.engine import BatchCostModel
    cost = BatchCostModel(flops_per_item=per_item, flops_per_s=1e12,
                          fixed_overhead_s=service_us * 1e-6)
    cfg = ClusterConfig(n_replicas=k, max_batch=max_batch,
                        batch_window_s=window_ms * 1e-3,
                        queue_limit=queue_limit)
    cap = k * max_batch / cost.service_time(max_batch)
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / (cap * load), n))
    stats = simulate_cluster_vectorized(t, cost, cfg)
    # raises AssertionError on any count mismatch or percentile drift
    check_against_event_engine(t, cost, cfg, stats)


@settings(**SET)
@given(n=st.integers(2, 300), rate=st.floats(100.0, 20_000.0),
       seed=st.integers(0, 100))
def test_cluster_engines_agree_on_bursty_arrivals(n, rate, seed):
    """Non-poisson (MMPP) arrival processes through both engines."""
    from repro.fleet.cluster import ClusterConfig
    from repro.fleet.traffic import bursty_arrivals
    from repro.fleet.vectorized import (check_against_event_engine,
                                        simulate_cluster_vectorized)
    from repro.serving.engine import BatchCostModel
    rng = np.random.default_rng(seed)
    t = bursty_arrivals(rate, n, rng)
    cost = BatchCostModel(flops_per_item=1e6, flops_per_s=1e12,
                          fixed_overhead_s=1e-3)
    cfg = ClusterConfig(n_replicas=2, max_batch=4, batch_window_s=2e-3,
                        queue_limit=32)
    stats = simulate_cluster_vectorized(t, cost, cfg)
    check_against_event_engine(t, cost, cfg, stats)


@settings(deadline=None, max_examples=15)
@given(rates=st.lists(st.floats(200.0, 20_000.0), min_size=2, max_size=4),
       max_switches=st.integers(0, 3), seed=st.integers(0, 50))
def test_controller_never_exceeds_max_switches(rates, max_switches, seed):
    """However hostile the regime changes, voluntary switches stay
    within the configured bound (forced replica reconfigs excepted —
    there are none here)."""
    from repro.fleet import (AdaptiveController, CandidatePlan,
                             ControllerConfig, DeviceClass, Phase,
                             RegimeChangeTrace)
    from repro.serving.engine import BatchCostModel
    cost = BatchCostModel(flops_per_item=1e7, flops_per_s=1e12,
                          fixed_overhead_s=2e-4)
    cands = [CandidatePlan("b1", "SC@3", 3, "tcp", 1, 1, 5e-3, cost),
             CandidatePlan("b64", "SC@3", 3, "tcp", 64, 1, 5e-3, cost)]
    mix = (DeviceClass.make("edge-embedded",
                            Channel(1e-4, 100e6, 100e6, seed=1)),)
    sc = RegimeChangeTrace.from_phases(
        mix, [Phase(0.5, r) for r in rates], seed=seed)
    cfg = ControllerConfig(control_period_s=0.2, drift_threshold=0.2,
                           min_improvement=0.0,
                           max_switches=max_switches)
    res = AdaptiveController(cands, config=cfg).run(sc)
    assert res.n_switches <= max_switches
    assert res.n_forced == 0


@settings(deadline=None, max_examples=10)
@given(rate=st.floats(200.0, 5_000.0), seed=st.integers(0, 50),
       engine=st.sampled_from(["vectorized", "event"]))
def test_controller_with_triggers_disabled_is_exactly_static(rate, seed,
                                                             engine):
    """Drift detection off + no faults ⇒ the adaptive run IS the static
    run, bit-for-bit, on either engine."""
    from repro.fleet import (AdaptiveController, CandidatePlan,
                             ControllerConfig, DeviceClass, Phase,
                             RegimeChangeTrace)
    from repro.serving.engine import BatchCostModel
    cost = BatchCostModel(flops_per_item=1e7, flops_per_s=1e12,
                          fixed_overhead_s=2e-4)
    cands = [CandidatePlan("b1", "SC@3", 3, "tcp", 1, 1, 5e-3, cost),
             CandidatePlan("b8", "SC@3", 3, "tcp", 8, 1, 5e-3, cost)]
    mix = (DeviceClass.make("edge-embedded",
                            Channel(1e-4, 100e6, 100e6, seed=1)),)
    sc = RegimeChangeTrace.from_phases(mix, [Phase(1.0, rate)], seed=seed)
    cfg = ControllerConfig(control_period_s=0.25, drift_threshold=None,
                           drop_trigger=None, queue_trigger=None)
    ctl = AdaptiveController(cands, config=cfg)
    a = ctl.run(sc, initial="b8", engine=engine)
    s = ctl.run_static(sc, "b8", engine=engine)
    assert np.array_equal(a.latencies, s.latencies)
    assert a.plan_keys == s.plan_keys == ("b8",)
    assert a.n_switches == 0 and a.n_replans == 0


@settings(**SET)
@given(b=st.integers(1, 4), rows=st.integers(1, 9), c=st.integers(1, 67),
       kind=st.sampled_from(["f32", "int8", "ae8"]), seed=st.integers(0, 50))
def test_checksummed_frames_preserve_zero_fault_bytes(b, rows, c, kind, seed):
    """The SEI2 (checksummed) frame is the SEI1 frame with a new magic
    and an 8-byte CRC pair spliced after the dims — the payload bytes
    are untouched — and checksum=False stays the historical layout."""
    from repro.core import bottleneck as B
    from repro.runtime import wire as W
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.standard_normal((b, rows, c)) * 3.0, jnp.float32)
    ae = (B.init_bottleneck(jax.random.PRNGKey(seed), (c,), rate=0.5)
          if kind == "ae8" else None)
    pkt = W.encode_activation(f, ae, quantize=kind != "f32")
    v1, v2 = W.to_bytes(pkt), W.to_bytes(pkt, checksum=True)
    assert v1[:4] == b"SEI1" and v2[:4] == b"SEI2"
    head = 6 + 4 * len(pkt.shape)
    assert v1[4:head] == v2[4:head]          # kind + dims identical
    assert v2[head + 8:] == v1[head:]        # payload bit-identical
    back = W.from_bytes(v2)
    np.testing.assert_array_equal(back.data, pkt.data)
    np.testing.assert_array_equal(back.scales, pkt.scales)
    out1 = np.asarray(W.decode_activation(W.from_bytes(v1), ae))
    out2 = np.asarray(W.decode_activation(back, ae))
    np.testing.assert_array_equal(out1, out2)


@settings(**SET)
@given(seed=st.integers(0, 1000), drop=st.floats(0, 1), corr=st.floats(0, 1),
       strag=st.floats(0, 1), rid=st.integers(0, 40), hop=st.integers(0, 3))
def test_fault_schedule_is_pure_function_of_seed(seed, drop, corr, strag,
                                                 rid, hop):
    """The injected fault schedule depends only on (seed, rid, hop,
    attempt) — never on query order or instance identity — and every
    burst ends within max_consecutive attempts."""
    from repro.runtime.faults import TRANSFER_FAULTS, FaultPlan
    kw = dict(seed=seed, drop_rate=drop, corrupt_rate=corr,
              straggle_rate=strag, max_consecutive=5)
    sched = FaultPlan(**kw).transfer_schedule(rid, hop, 8)
    again = tuple(FaultPlan(**kw).transfer_fault(rid, hop, a)
                  for a in reversed(range(8)))[::-1]
    assert sched == again
    assert all(f is None or f in TRANSFER_FAULTS for f in sched)
    assert all(f is None for f in sched[5:])     # bounded burst


@settings(deadline=None, max_examples=5)
@given(seed=st.integers(0, 30), drop=st.floats(0.2, 0.7),
       corr=st.floats(0.0, 0.4))
def test_faulted_runtime_is_deterministic_and_fused_agrees(
        vgg_small, toy_data, seed, drop, corr):
    """Same FaultPlan seed ⇒ identical fault counts, retry/backoff
    sequence and bit-identical logits across fresh runtimes and across
    fused=True/False; retried (non-degraded) outputs equal fault-free."""
    from repro.runtime.engine import SplitRuntime
    from repro.runtime.faults import FaultPlan, RecoveryPolicy
    model, params = vgg_small
    x = jnp.asarray(toy_data[0][:2])
    ch = Channel(1e-3, 100e6, 100e6, seed=0)
    plan = FaultPlan(seed=seed, drop_rate=drop, corrupt_rate=corr)
    pol = RecoveryPolicy(max_attempts=8)

    def run(fused):
        rt = SplitRuntime(model, params, 3, channel=ch, fused=fused,
                          faults=plan, recovery=pol)
        return rt.infer(x, iters=1, rid=seed)

    a, b2, c2 = run(False), run(False), run(True)
    np.testing.assert_array_equal(a.logits, b2.logits)
    np.testing.assert_array_equal(a.logits, c2.logits)
    for k in ("retries", "backoff_s", "downgrades", "faults"):
        assert a.meta["recovery"][k] == b2.meta["recovery"][k]
        assert a.meta["recovery"][k] == c2.meta["recovery"][k]
    if not a.meta["degraded"]:
        base = SplitRuntime(model, params, 3, channel=ch).infer(x, iters=1)
        np.testing.assert_array_equal(a.logits, base.logits)
