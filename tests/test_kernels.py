"""Per-kernel interpret-mode validation against the pure-jnp oracles,
sweeping shapes / dtypes / GQA ratios / windows as the brief requires."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.bottleneck_compress import (bottleneck_compress,
                                               resolve_backend)
from repro.kernels.bottleneck_decompress import (bottleneck_decompress,
                                                 bottleneck_decompress_any)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_scan import rwkv6_scan


def _qkv(key, b, sq, sk, h, kh, d, dtype):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (b, sq, h, d), dtype),
            jax.random.normal(ks[1], (b, sk, kh, d), dtype),
            jax.random.normal(ks[2], (b, sk, kh, d), dtype))


FLASH_CASES = [
    # b, sq, sk, h, kh, d, causal, window, dtype
    (2, 256, 256, 4, 2, 64, True, None, jnp.float32),
    (1, 128, 512, 4, 4, 128, True, None, jnp.float32),
    (2, 256, 256, 8, 2, 64, True, 128, jnp.float32),
    (1, 256, 256, 2, 1, 64, False, None, jnp.float32),
    (1, 256, 256, 4, 1, 64, True, None, jnp.bfloat16),
    (1, 512, 512, 2, 2, 128, True, 256, jnp.float32),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_sweep(case):
    b, sq, sk, h, kh, d, causal, win, dtype = case
    q, k, v = _qkv(jax.random.PRNGKey(hash(case) % 2**31), b, sq, sk, h, kh, d, dtype)
    out = flash_attention(q, k, v, causal=causal, window=win, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("block", [(64, 64), (128, 256), (256, 128)])
def test_flash_attention_block_shapes(block):
    bq, bk = block
    q, k, v = _qkv(jax.random.PRNGKey(9), 1, 256, 256, 2, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


COMPRESS_CASES = [
    (128, 256, 128, jnp.float32), (256, 512, 256, jnp.float32),
    (128, 1024, 512, jnp.bfloat16), (512, 128, 64, jnp.float32),
]


@pytest.mark.parametrize("case", COMPRESS_CASES)
def test_bottleneck_compress_sweep(case):
    n, c, l, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(n + c), 3)
    f = jax.random.normal(ks[0], (n, c), dtype)
    w = (jax.random.normal(ks[1], (c, l)) * 0.05).astype(dtype)
    b = (jax.random.normal(ks[2], (l,)) * 0.1).astype(dtype)
    q, s = bottleneck_compress(f, w, b, interpret=True)
    qr, sr = ref.bottleneck_compress_ref(f, w, b)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    # int8 codes may differ by 1 ulp at rounding boundaries
    assert int(jnp.abs(q.astype(jnp.int32) - qr.astype(jnp.int32)).max()) <= 1
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-4)


def _wire_case(key, n, l, c, dtype):
    """Random int8 codes + positive row scales + a decoder (L, C)."""
    ks = jax.random.split(key, 4)
    q = jax.random.randint(ks[0], (n, l), -127, 128, jnp.int32).astype(jnp.int8)
    s = (jax.random.uniform(ks[1], (n, 1)) * 0.1 + 1e-3).astype(jnp.float32)
    w = (jax.random.normal(ks[2], (l, c)) * 0.05).astype(dtype)
    b = (jax.random.normal(ks[3], (c,)) * 0.1).astype(dtype)
    return q, s, w, b


DECOMPRESS_CASES = [
    # n, l, c, dtype — MXU-aligned shapes for the raw kernel
    (128, 64, 256, jnp.float32), (256, 128, 512, jnp.float32),
    (128, 128, 1024, jnp.bfloat16), (512, 32, 128, jnp.float32),
]


@pytest.mark.parametrize("case", DECOMPRESS_CASES)
def test_bottleneck_decompress_sweep(case):
    n, l, c, dtype = case
    q, s, w, b = _wire_case(jax.random.PRNGKey(n + c), n, l, c, dtype)
    f = bottleneck_decompress(q, s, w, b, interpret=True)
    fr = ref.bottleneck_decode_ref(q, s, w, b)
    assert f.dtype == jnp.float32 and f.shape == (n, c)
    np.testing.assert_allclose(np.asarray(f), np.asarray(fr), atol=1e-4)


ANY_DECODE_CASES = [
    # lead dims, L, C — odd / non-128-aligned shapes the padding must absorb
    ((3, 7), 10, 33), ((130,), 24, 600), ((1, 5, 9), 16, 48), ((2,), 1, 1),
]


@pytest.mark.parametrize("case", ANY_DECODE_CASES)
def test_bottleneck_decompress_any_odd_shapes(case):
    lead, l, c = case
    n = int(np.prod(lead))
    q, s, w, b = _wire_case(jax.random.PRNGKey(n + c), n, l, c, jnp.float32)
    q, s = q.reshape(lead + (l,)), s.reshape(lead + (1,))
    out_i = bottleneck_decompress_any(q, s, w, b, backend="interpret")
    out_r = bottleneck_decompress_any(q, s, w, b, backend="ref")
    assert out_i.shape == lead + (c,) and out_r.shape == lead + (c,)
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_r),
                               atol=1e-5)


def test_decompress_shares_backend_contract():
    """The decode kernel routes through the same resolve_backend as the
    compress side: 'auto' means ref off-TPU, unknown names raise."""
    q, s, w, b = _wire_case(jax.random.PRNGKey(0), 6, 8, 12, jnp.float32)
    default = bottleneck_decompress_any(q, s, w, b)      # auto via env
    explicit = bottleneck_decompress_any(q, s, w, b,
                                         backend=resolve_backend())
    np.testing.assert_array_equal(np.asarray(default), np.asarray(explicit))
    with pytest.raises(ValueError, match="unknown bottleneck backend"):
        bottleneck_decompress_any(q, s, w, b, backend="bogus")


def test_compress_decompress_kernel_roundtrip():
    """Kernel-path encode -> kernel-path decode stays within the wire
    quantisation error bound of the float AE round-trip."""
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    f = jax.random.normal(ks[0], (64, 96))
    we = jax.random.normal(ks[1], (96, 32)) * 0.1
    be = jnp.zeros((32,))
    wd = jax.random.normal(ks[2], (32, 96)) * 0.1
    bd = jax.random.normal(ks[3], (96,)) * 0.1
    from repro.kernels.bottleneck_compress import bottleneck_compress_any
    q, s = bottleneck_compress_any(f, we, be, backend="interpret")
    got = bottleneck_decompress_any(q, s, wd, bd, backend="interpret")
    z = jax.nn.relu(f @ we + be)
    want = z @ wd + bd
    # per-row dequant error <= amax/(2*127); the decoder matmul amplifies
    # by at most sum |wd| over the latent dim
    amp = float(jnp.abs(wd).sum(axis=0).max())
    bound = float(jnp.max(jnp.abs(z))) / 127.0 * 0.5 * amp + 1e-4
    assert float(jnp.abs(got - want).max()) <= bound


def test_compress_roundtrip_error_bound():
    """|dequant(quant(z)) - z| <= amax/127 per row — the wire-fidelity bound."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    f = jax.random.normal(ks[0], (64, 256))
    w = jax.random.normal(ks[1], (256, 128)) * 0.1
    b = jnp.zeros((128,))
    q, s = bottleneck_compress(f, w, b, interpret=True)
    z = jax.nn.relu(f @ w + b)
    deq = ref.bottleneck_decompress_ref(q, s)
    bound = np.asarray(jnp.max(jnp.abs(z), axis=1)) / 127.0 * 0.5 + 1e-6
    err = np.max(np.abs(np.asarray(deq) - np.asarray(z)), axis=1)
    assert (err <= bound + 1e-5).all()


RWKV_CASES = [(2, 128, 2, 64, 64), (1, 64, 4, 32, 16), (1, 256, 1, 64, 128)]


@pytest.mark.parametrize("case", RWKV_CASES)
def test_rwkv6_scan_sweep(case):
    b, s, h, d, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(s + d), 5)
    r = jax.random.normal(ks[0], (b, s, h, d)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, d)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, d)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, d)))
    u = jax.random.normal(ks[4], (h, d)) * 0.3
    out, st = rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    oref, stref = ref.rwkv6_scan_ref(r, k, v, w, u, jnp.zeros((b, h, d, d)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(oref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(stref), atol=1e-4)


def test_ops_dispatch_cpu_uses_ref():
    q, k, v = _qkv(jax.random.PRNGKey(0), 1, 64, 64, 2, 2, 32, jnp.float32)
    out = ops.attention_op(q, k, v)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


MAMBA_CASES = [(2, 64, 128, 16, 32, 64), (1, 128, 256, 16, 128, 256),
               (2, 32, 64, 8, 16, 64)]


@pytest.mark.parametrize("case", MAMBA_CASES)
def test_mamba_scan_sweep(case):
    from repro.kernels.mamba_scan import mamba_scan
    bsz, s, di, ds, chunk, bd = case
    ks = jax.random.split(jax.random.PRNGKey(s + di), 4)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (bsz, s, di))) * 0.1
    b = jax.random.normal(ks[1], (bsz, s, ds)) * 0.5
    c = jax.random.normal(ks[2], (bsz, s, ds)) * 0.5
    x = jax.random.normal(ks[3], (bsz, s, di))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(0), (di, ds)) * 0.3)
    y = mamba_scan(dt, b, c, x, a, chunk=chunk, bd=bd, interpret=True)
    yr = ref.mamba_scan_ref(dt, b, c, x, a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)


def test_mamba_scan_matches_model_mixer():
    """The kernel computes the same recurrence the model's mamba_seq runs."""
    from repro.configs import get_config
    from repro.models import mamba as M
    from repro.models.common import reduced
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config("jamba-v0.1-52b")),
                              dtype="float32")
    p = M.init_mamba(jax.random.PRNGKey(0), cfg)
    bsz, s = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (bsz, s, cfg.d_model))
    y_model, _ = M.mamba_seq(p, x, cfg, chunk=8)
    # recompute via the kernel path from the same intermediates
    di, ds, dc = M.d_inner(cfg), cfg.mamba_d_state, cfg.mamba_d_conv
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    pad = jnp.zeros((bsz, dc - 1, di), xi.dtype)
    xp = jnp.concatenate([pad, xi], axis=1)
    xc = sum(xp[:, i:i + s, :] * p["conv"][i] for i in range(dc)) + p["conv_b"]
    xc = jax.nn.silu(xc)
    dt, B, C = M._ssm_params(p, xc, ds)
    a = -jnp.exp(p["A_log"])
    from repro.kernels.mamba_scan import mamba_scan
    y_scan = mamba_scan(dt, B, C, xc.astype(jnp.float32), a,
                        chunk=8, bd=di, interpret=True)
    y = y_scan + p["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_model),
                               rtol=2e-3, atol=2e-3)
