"""Sharding rules + HLO cost analyzer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import SHAPES, batch_struct, input_specs
from repro.launch.hlo_cost import HloCost, parse_module
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.sharding import rules


def _fake_mesh_sizes():
    """A 16x16-like mesh stand-in for spec resolution (no devices needed)."""
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    return FakeMesh()


def test_param_specs_divisibility():
    mesh = _fake_mesh_sizes()
    for arch in ("llama3-8b", "whisper-tiny", "qwen3-moe-235b-a22b"):
        cfg = get_config(arch)
        ps = jax.eval_shape(lambda k: T.init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = rules.param_specs(ps, mesh)

        def check(path, leaf, spec):
            for dim, ax in zip(leaf.shape, spec):
                if ax is not None:
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    n = 1
                    for a in axes:
                        n *= dict(data=16, model=16, pod=2)[a]
                    assert dim % n == 0, (path, leaf.shape, spec)
        jax.tree_util.tree_map_with_path(check, ps, specs)


def test_whisper_heads_fall_back_to_replicated():
    mesh = _fake_mesh_sizes()
    cfg = get_config("whisper-tiny")
    ps = jax.eval_shape(lambda k: T.init_params(k, cfg),
                        jax.ShapeDtypeStruct((2,), jnp.uint32))
    rules.param_specs(ps, mesh)
    # d_model=384 divides 16? 384/16=24 -> yes on 'data'/'model' axes; but
    # H*hd = 384 also divides; the kv_pos cache spec is the whisper risk
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 128, 100))
    cspecs = rules.cache_specs(cache, mesh)
    # cross-attn cache n_frames=1500 is not divisible by 16 -> None there
    ck_spec = cspecs["l0"]["ck"]
    assert ck_spec[2] is None


def test_batch_specs_batch1_replicated():
    mesh = _fake_mesh_sizes()
    cfg = get_config("rwkv6-1.6b")
    bs = batch_struct(cfg, SHAPES["long_500k"], with_labels=False)
    specs = rules.batch_specs(bs, mesh)
    assert specs["tokens"][0] is None  # batch=1 cannot shard


def test_input_specs_cover_all_kinds():
    for shape in SHAPES.values():
        for arch in ("llama3-8b", "whisper-tiny", "internvl2-76b"):
            cfg = get_config(arch)
            specs = input_specs(cfg, shape)
            assert isinstance(specs, dict) and specs


def test_shard_fn_identity_on_host_mesh():
    mesh = make_host_mesh()
    sf = rules.make_shard_fn(mesh)
    x = jnp.ones((4, 8, 16))
    np.testing.assert_array_equal(np.asarray(sf(x, "residual")), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(sf(x, "nonexistent-kind")), np.asarray(x))


# ----------------------------------------------------- HLO cost analyzer ----
def test_hlo_cost_counts_scan_trip():
    """Analyzer must match hand-count on scan+remat (XLA raw is ~8x off)."""
    D, L, B = 128, 4, 16

    def loss(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(
            jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable),
            x, ws)
        return jnp.sum(y ** 2)

    ws = jnp.ones((L, D, D))
    x = jnp.ones((B, D))
    c = jax.jit(jax.grad(loss)).lower(ws, x).compile()
    hc = HloCost(c.as_text())
    exact = 8 * L * B * D * D   # fwd + recompute + 2 bwd matmuls
    assert abs(hc.flops - exact) / exact < 0.05
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):      # older jax: one dict per partition
        ca = ca[0]
    raw = ca["flops"]
    assert raw < exact / 2      # demonstrates why the analyzer exists


def test_hlo_parse_module_structure():
    def f(x):
        return (x @ x.T).sum()
    c = jax.jit(f).lower(jnp.ones((32, 32))).compile()
    comps, entry, symtab = parse_module(c.as_text())
    assert entry in comps
    assert symtab


def test_collective_parse_on_sharded_program():
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = make_host_mesh()
    from jax.sharding import NamedSharding

    def f(a, b):
        return (a @ b).sum()

    sh = NamedSharding(mesh, P(None, "model"))
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32, sharding=sh)
    b = jax.ShapeDtypeStruct((64, 64), jnp.float32, sharding=sh)
    comp = jax.jit(f, in_shardings=(sh, sh)).lower(a, b).compile()
    hc = HloCost(comp.as_text())
    assert hc.flops > 0


def test_inference_profile_replicates_over_data():
    mesh = _fake_mesh_sizes()
    cfg = get_config("llama3-8b")
    ps = jax.eval_shape(lambda k: T.init_params(k, cfg),
                        jax.ShapeDtypeStruct((2,), jnp.uint32))
    train_specs = rules.param_specs(ps, mesh, profile="train")
    inf_specs = rules.param_specs(ps, mesh, profile="inference")
    t_leaves = jax.tree.leaves(train_specs, is_leaf=lambda s: isinstance(s, P))
    i_leaves = jax.tree.leaves(inf_specs, is_leaf=lambda s: isinstance(s, P))
    assert any("data" in str(s) for s in t_leaves)
    assert not any("data" in str(s) for s in i_leaves)
    assert any("model" in str(s) for s in i_leaves)


def test_hlo_scope_bytes_attribution():
    """flash_attention HBM bytes are scope-tagged for the kernel-adjusted
    roofline term."""
    from repro.models.layers import attention

    q = jnp.ones((1, 1024, 4, 64))
    k = jnp.ones((1, 1024, 2, 64))
    v = jnp.ones((1, 1024, 2, 64))
    c = jax.jit(lambda q, k, v: attention(q, k, v, causal=True)).lower(
        q, k, v).compile()
    hc = HloCost(c.as_text())
    assert hc.scope_bytes.get("flash_attention", 0) > 0
    assert hc.scope_bytes["flash_attention"] <= hc.bytes + 1e-6


def test_head_seq_fallback_changes_spec():
    mesh = _fake_mesh_sizes()
    # 24 heads don't divide 16: baseline drops the constraint, fallback
    # shards the sequence dim instead
    sizes = rules.mesh_axis_sizes(mesh)
    dp = "data"
    cands = rules.ACT_SPECS["heads"](dp)
    shape = (32, 4096, 24, 128)
    assert not rules._fits(tuple(cands[0]), shape, sizes)
    assert rules._fits(tuple(cands[1]), shape, sizes)
