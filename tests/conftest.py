import os
import sys

# make `repro` importable without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def vgg_small():
    """A tiny trainable VGG + params (session-cached)."""
    from repro.models.vgg import vgg_cifar
    model = vgg_cifar(n_classes=8, input_hw=16, width_mult=0.25)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="session")
def toy_data():
    from repro.data.synthetic import toy_images
    xs, ys = toy_images(64, hw=16, seed=0)
    return xs, ys
