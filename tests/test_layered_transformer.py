"""Paper claim (ii): the saliency-based split search generalises beyond
images — exercised on transformer backbones via ``transformer_as_layered``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.saliency import cumulative_saliency, candidate_split_points
from repro.models import transformer as T
from repro.models.common import reduced
from repro.models.layered import transformer_as_layered


@pytest.fixture(scope="module")
def llama_layered():
    cfg = reduced(get_config("llama3-8b"), n_layers=4, dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, transformer_as_layered(cfg, params)


def test_layered_matches_forward(llama_layered):
    cfg, params, lay = llama_layered
    batch = {"tokens": jnp.arange(2 * 16, dtype=jnp.int32).reshape(2, 16) % cfg.vocab}
    want = T.logits_from_x(params, cfg, T.forward(params, cfg, batch)["x"])
    lp = lay.init(jax.random.PRNGKey(0))
    got = lay.apply(lp, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_cut_points_exclude_head(llama_layered):
    cfg, params, lay = llama_layered
    cuts = lay.cut_points()
    assert len(cuts) == cfg.n_layers + 1  # embed + each block
    assert (len(lay.layers) - 1) not in cuts


def test_cs_curve_on_token_sequences(llama_layered):
    """Saliency needs only activations+grads: attention-free of images."""
    cfg, params, lay = llama_layered
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
    # labels = next-token sample (class = vocab id at last position)
    labels = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)

    # adapt: LayeredModel input is the batch dict; logits (B,S,V); use the
    # per-position one-hot cotangent by flattening positions into batch
    maps_model = lay
    logits, acts = maps_model.apply_capture(maps_model.init(jax.random.PRNGKey(0)), batch)
    assert len(acts) == len(maps_model.layers)

    cs = cumulative_saliency(maps_model, maps_model.init(jax.random.PRNGKey(0)),
                             batch, labels, layer_idx=list(range(1, len(maps_model.layers) - 1)))
    assert np.all(np.isfinite(cs))
    assert cs.shape == (cfg.n_layers,)
    cands = candidate_split_points(maps_model, cs,
                                   list(range(1, len(maps_model.layers) - 1)))
    assert all(c in set(maps_model.cut_points()) for c in cands)
