"""End-to-end Split-Et-Impera pipeline on the trainable VGG:
CS curve -> candidates -> netsim -> QoS suggestion (paper Fig. 1 flow)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import bottleneck as B
from repro.core.qos import QoSRequirements, suggest
from repro.core.saliency import candidate_split_points, cumulative_saliency
from repro.core.scenarios import PLATFORMS, Scenario
from repro.core.split import SplitPlan
from repro.models.vgg import feature_index
from repro.netsim.channel import Channel
from repro.netsim.simulator import ApplicationSimulator, NetworkConfig


@pytest.fixture(scope="module")
def pipeline(vgg_small, toy_data):
    model, params = vgg_small
    xs, ys = toy_data
    fi = feature_index(model)
    cs = cumulative_saliency(model, params, jnp.asarray(xs[:16]),
                             jnp.asarray(ys[:16]), layer_idx=fi)
    cands = candidate_split_points(model, cs, fi, top_n=3)
    if not cands:  # untrained nets can be peak-free; fall back to pools
        cands = model.cut_points()[4:10:3]
    cut = cands[0]
    f_shape = jax.eval_shape(
        lambda x: model.apply_range(params, x, 0, cut + 1),
        jax.ShapeDtypeStruct((1, 16, 16, 3), jnp.float32)).shape
    ae = B.init_bottleneck(jax.random.PRNGKey(0), f_shape[1:], 0.5)
    return model, params, cs, cands, ae


def _netcfg(proto, loss=0.0):
    return NetworkConfig(proto, Channel(100e-6, 1e9, 1e9, loss_rate=loss, seed=0))


def test_sc_tcp_simulation(pipeline, toy_data):
    model, params, cs, cands, ae = pipeline
    xs, ys = toy_data
    sim = ApplicationSimulator(model, params, _netcfg("tcp", 0.05), ae=ae)
    sc = Scenario("SC", SplitPlan(cands[0]), PLATFORMS["edge-embedded"],
                  PLATFORMS["server-gpu"])
    v = sim.simulate(sc, xs[:16], ys[:16], n_frames=8)
    assert v.latency_s > 0 and 0.0 <= v.accuracy <= 1.0
    assert v.meta["wire_bytes"] > 0
    assert v.meta["mean_tx"] > 0


def test_rc_udp_accuracy_degrades_with_loss(pipeline, toy_data):
    model, params, cs, cands, ae = pipeline
    xs, ys = toy_data
    rc = Scenario("RC")
    accs = []
    for loss in (0.0, 0.6):
        sim = ApplicationSimulator(model, params, _netcfg("udp", loss), ae=ae)
        v = sim.simulate(rc, xs[:32], ys[:32], n_frames=8)
        accs.append(v.accuracy)
    # the fixture model is untrained (random-level accuracy), so corruption
    # can wiggle accuracy either way within sampling noise; the trained-model
    # degradation claim is exercised by benchmarks/bench_protocol.py (Fig. 4)
    assert accs[1] <= accs[0] + 0.10


def test_tcp_accuracy_loss_invariant(pipeline, toy_data):
    model, params, cs, cands, ae = pipeline
    xs, ys = toy_data
    rc = Scenario("RC")
    accs = []
    for loss in (0.0, 0.2):
        sim = ApplicationSimulator(model, params, _netcfg("tcp", loss), ae=ae)
        v = sim.simulate(rc, xs[:16], ys[:16], n_frames=4)
        accs.append(v.accuracy)
    assert accs[0] == accs[1]


def test_lc_scenario(pipeline, toy_data):
    model, params, cs, cands, ae = pipeline
    xs, ys = toy_data
    sim = ApplicationSimulator(model, params, _netcfg("tcp"), ae=ae)
    v = sim.simulate(Scenario("LC"), xs[:16], ys[:16])
    assert v.meta["wire_bytes"] == 0
    assert v.latency_s > 0


def test_qos_suggestion_end_to_end(pipeline, toy_data):
    model, params, cs, cands, ae = pipeline
    xs, ys = toy_data
    sim = ApplicationSimulator(model, params, _netcfg("tcp", 0.02), ae=ae)
    verdicts = [sim.simulate(Scenario("RC"), xs[:16], ys[:16], n_frames=4)]
    for c in cands[:2]:
        f_shape = jax.eval_shape(
            lambda x: model.apply_range(params, x, 0, c + 1),
            jax.ShapeDtypeStruct((1, 16, 16, 3), jnp.float32)).shape
        ae_c = B.init_bottleneck(jax.random.PRNGKey(1), f_shape[1:], 0.5)
        sim_c = ApplicationSimulator(model, params, _netcfg("tcp", 0.02), ae=ae_c)
        verdicts.append(sim_c.simulate(Scenario("SC", SplitPlan(c)),
                                       xs[:16], ys[:16], n_frames=4))
    qos = QoSRequirements(max_latency_s=10.0, min_accuracy=0.0)
    best = suggest(verdicts, qos)
    assert best is not None
