"""The strongest cache-correctness test: prefill + decode must reproduce the
full-sequence forward, token by token, for every architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.common import reduced

from test_models_smoke import make_batch

FAMILY_REPS = ["llama3-8b", "deepseek-moe-16b", "rwkv6-1.6b",
               "jamba-v0.1-52b", "whisper-tiny", "internvl2-76b"]


@pytest.mark.parametrize("arch", FAMILY_REPS)
def test_prefill_decode_matches_forward(arch):
    import dataclasses
    # f32: this is a cache-logic equivalence test; bf16 noise through deep
    # reduced stacks (jamba: 8 layers) otherwise dominates the comparison
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32")
    if cfg.moe is not None:
        # capacity-based MoE drops tokens group-dependently; for an exact
        # prefill==forward equivalence the test needs drop-free capacity
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=float(cfg.moe.n_experts)))
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    b, s_total, s_prompt = 2, 24, 16
    full = make_batch(cfg, b=b, s=s_total, with_labels=False, seed=3)
    st_total = full["tokens"].shape[1]
    st_prompt = st_total - (s_total - s_prompt)
    prompt = dict(full, tokens=full["tokens"][:, :st_prompt])

    # ground truth: full forward logits at each position
    out = T.forward(params, cfg, full)
    gt = np.asarray(T.logits_from_x(params, cfg, out["x"]).astype(jnp.float32))

    logits, cache, pos = T.prefill(params, cfg, prompt, cache_seq_len=64)
    # VLM positions include the patch prefix
    offset = cfg.n_patches if cfg.family == "vlm" else 0
    got = np.asarray(logits)
    want = gt[:, offset + st_prompt - 1]
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    # decode the remaining ground-truth tokens and compare logits stepwise
    for i in range(st_prompt, st_total):
        tok = full["tokens"][:, i:i + 1]
        logits, cache = T.serve_step(params, cfg, cache, tok,
                                     jnp.asarray(offset + i, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits), gt[:, offset + i],
                                   rtol=1e-3, atol=1e-3)


def test_sliding_window_decode_matches_windowed_forward():
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config("llama3-8b")), sliding_window=8,
                              dtype="float32")
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    b, s = 1, 24
    batch = make_batch(cfg, b=b, s=s, with_labels=False, seed=5)
    out = T.forward(params, cfg, batch)
    gt = np.asarray(T.logits_from_x(params, cfg, out["x"]).astype(jnp.float32))

    prompt = dict(batch, tokens=batch["tokens"][:, :16])
    logits, cache, _ = T.prefill(params, cfg, prompt, cache_seq_len=s)
    np.testing.assert_allclose(np.asarray(logits), gt[:, 15], rtol=1e-3, atol=1e-3)
    for i in range(16, s):
        tok = batch["tokens"][:, i:i + 1]
        logits, cache = T.serve_step(params, cfg, cache, tok,
                                     jnp.asarray(i, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits), gt[:, i],
                                   rtol=1e-3, atol=1e-3)
