"""N-way multi-tier splits: cut-list legality, the K+1-stage runtime
chain, multi-hop flow pricing, pipelined microbatching, and the tier
planner — plus the 1-cut compatibility contract at every seam."""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scenarios import PLATFORMS, Scenario
from repro.core.split import (SplitPlan, hop_payload_bytes, legal_cut_lists,
                              normalize_cuts, validate_cuts)
from repro.core.stats import flops_split, flops_stages
from repro.fleet.planner import (Tier, TierPlan, TierTopology, plan_tiers,
                                 suggest_tier_plan)
from repro.netsim.channel import Channel, compose_channels
from repro.netsim.simulator import (NetworkConfig, NetworkPath,
                                    flow_latency_s, measure_flow,
                                    simulate_pipeline)
from repro.runtime.engine import SplitRuntime
from repro.runtime.partition import make_partition


# ------------------------------------------------------------- legality ----
def test_normalize_and_validate_cuts(vgg_small):
    model, _ = vgg_small
    cuts = model.cut_points()
    assert normalize_cuts(cuts[0]) == (cuts[0],)
    assert normalize_cuts([cuts[0], cuts[2]]) == (cuts[0], cuts[2])
    assert validate_cuts(model, cuts[1]) == (cuts[1],)
    assert validate_cuts(model, (cuts[0], cuts[3])) == (cuts[0], cuts[3])
    with pytest.raises(ValueError, match="at least one cut"):
        validate_cuts(model, ())
    with pytest.raises(ValueError, match="strictly increasing"):
        validate_cuts(model, (cuts[2], cuts[2]))
    with pytest.raises(ValueError, match="strictly increasing"):
        validate_cuts(model, (cuts[3], cuts[1]))
    bad = [i for i in range(len(model.layers)) if i not in cuts][0]
    with pytest.raises(ValueError, match="not legal"):
        validate_cuts(model, (cuts[0], bad) if bad > cuts[0] else (bad,))


def test_normalize_cuts_rejects_shuffled_lists_everywhere():
    """Monotonicity is enforced at construction, not only at model
    validation: a shuffled cut list can never become a design point."""
    from repro.api.types import SplitCandidate
    with pytest.raises(ValueError, match="strictly increasing"):
        normalize_cuts((4, 2))
    with pytest.raises(ValueError, match="strictly increasing"):
        SplitPlan(None, splits=(5, 2))
    with pytest.raises(ValueError, match="strictly increasing"):
        SplitCandidate.from_any((4, 2))
    with pytest.raises(ValueError, match="strictly increasing"):
        SplitCandidate.sc((3, 3))


def test_legal_cut_lists_are_increasing_combinations(vgg_small):
    model, _ = vgg_small
    cuts = model.cut_points()
    lists = legal_cut_lists(model, 2)
    assert len(lists) == len(list(itertools.combinations(cuts, 2)))
    for cl in lists:
        assert validate_cuts(model, cl) == cl
    assert legal_cut_lists(model, 1) == [(c,) for c in cuts]
    with pytest.raises(ValueError):
        legal_cut_lists(model, 0)


def test_flops_stages_partition_total(vgg_small):
    model, params = vgg_small
    cuts = model.cut_points()
    pair = (cuts[1], cuts[4])
    stages = flops_stages(model, params, pair, batch=2)
    assert len(stages) == 3 and all(s > 0 for s in stages)
    head, tail = flops_split(model, params, pair[0], batch=2)
    assert stages[0] == head and sum(stages[1:]) == tail


def test_hop_payload_bytes_matches_single_cut(vgg_small):
    model, params = vgg_small
    cuts = model.cut_points()
    plan2 = SplitPlan(None, splits=(cuts[1], cuts[3]))
    hops = hop_payload_bytes(model, params, plan2, batch=2)
    assert len(hops) == 2 and all(b > 0 for b in hops)
    for i, c in enumerate(plan2.splits):
        single = hop_payload_bytes(model, params, SplitPlan(c), batch=2)
        assert hops[i] == single[0]


# ----------------------------------------------------- runtime equivalence ----
def test_every_2cut_pair_matches_unsplit(vgg_small, toy_data):
    """Acceptance: for every legal 2-cut pair the executed 3-stage
    SplitRuntime (f32 wire) matches the unsplit model to the 1-cut
    tolerance."""
    model, params = vgg_small
    xs, _ = toy_data
    x = jnp.asarray(xs[:2])
    full = np.asarray(model.apply(params, x))
    for pair in legal_cut_lists(model, 2):
        rt = SplitRuntime(model, params, pair, quantize=False)
        res = rt.infer(x, iters=1)
        np.testing.assert_allclose(res.logits, full, atol=1e-5,
                                   err_msg=f"cuts={pair}")
        assert res.splits == pair and len(res.hops) == 2
        assert res.wire_bytes == sum(h["bytes"] for h in res.hops)


def test_three_cut_partition_stage_chain(vgg_small, toy_data):
    model, params = vgg_small
    xs, _ = toy_data
    x = jnp.asarray(xs[:2])
    cuts = tuple(model.cut_points()[i] for i in (1, 3, 5))
    part = make_partition(model, params, cuts)
    assert part.n_stages == 4 and part.split_layer == cuts[0]
    y = np.asarray(part.forward_stages(x))
    np.testing.assert_allclose(y, np.asarray(model.apply(params, x)),
                               atol=1e-5)
    np.testing.assert_allclose(y, np.asarray(part.full(x)), atol=1e-5)
    for hop in range(3):
        shape = part.boundary_shape(batch=2, hop=hop)
        assert shape == tuple(model.activation_shapes(
            params, 2)[cuts[hop]])
    assert "stage2" in part.describe()


def test_multicut_runtime_int8_and_per_hop_pricing(vgg_small, toy_data):
    model, params = vgg_small
    xs, _ = toy_data
    x = xs[:2]
    cuts = (model.cut_points()[1], model.cut_points()[4])
    hops = [Channel(1e-3, 50e6, 50e6, seed=0), Channel(5e-4, 1e9, 1e9, seed=1)]
    rt = SplitRuntime(model, params, cuts, channel=hops, quantize=True)
    res = rt.infer(x, iters=1)
    ref = rt.reference(x)
    assert np.argmax(res.logits, -1).tolist() == np.argmax(ref, -1).tolist()
    assert len(res.hops) == 2 and len(res.stage_s) == 3
    assert res.transfer_s == sum(h["transfer_s"] for h in res.hops) > 0
    # the slow first hop must dominate the fast second
    assert res.hops[0]["transfer_s"] > res.hops[1]["transfer_s"]
    assert res.head_s == res.stage_s[0]
    assert res.tail_s == pytest.approx(sum(res.stage_s[1:]))
    with pytest.raises(ValueError, match="priced hops"):
        SplitRuntime(model, params, cuts, channel=[hops[0]])


# ------------------------------------------------------- multi-hop flows ----
@pytest.fixture(scope="module")
def two_hop_path():
    return NetworkPath((NetworkConfig("tcp", Channel(1e-3, 20e6, 20e6, seed=1)),
                        NetworkConfig("tcp", Channel(1e-3, 30e6, 30e6, seed=2))))


def test_measure_flow_multihop_aggregates(vgg_small, two_hop_path):
    model, params = vgg_small
    cuts = (model.cut_points()[1], model.cut_points()[4])
    sc = Scenario("SC", SplitPlan(None, splits=cuts))
    flow = measure_flow(sc, two_hop_path, model, params, 3072, n_frames=4,
                        batch=4)
    assert len(flow["stage_s"]) == 3 and len(flow["hop_bytes"]) == 2
    assert flow["edge_s"] == flow["stage_s"][0]
    assert flow["server_s"] == pytest.approx(sum(flow["stage_s"][1:]))
    assert flow["wire_bytes"] == sum(flow["hop_bytes"])
    for f in range(4):
        assert flow["wire_s"][f] == pytest.approx(
            flow["hop_wire_s"][0][f] + flow["hop_wire_s"][1][f])
    assert flow_latency_s(flow) == pytest.approx(
        flow["edge_s"] + float(np.mean(flow["wire_s"])) + flow["server_s"])
    # a 2-cut plan over a single link is a configuration error
    nc = two_hop_path[0]
    with pytest.raises(ValueError, match="hop"):
        measure_flow(sc, NetworkPath((nc,)), model, params, 3072)


def test_measure_flow_multihop_tiers_price_stages(vgg_small, two_hop_path):
    model, params = vgg_small
    cuts = (model.cut_points()[1], model.cut_points()[4])
    sc = Scenario("SC", SplitPlan(None, splits=cuts))
    tiers = (PLATFORMS["mcu"], PLATFORMS["edge-accelerator"],
             PLATFORMS["server-gpu"])
    flow = measure_flow(sc, two_hop_path, model, params, 3072, n_frames=2,
                        tiers=tiers)
    stage_f = flops_stages(model, params, cuts, batch=1)
    for s, t, f in zip(flow["stage_s"], tiers, stage_f):
        assert s == pytest.approx(t.compute_time(f))


def test_measure_flow_accepts_hop_sequence_for_one_cut(vgg_small):
    """A bare hop list with a 1-cut plan routes through the path branch
    (regression: it used to fall into the NetworkConfig-only branch and
    crash)."""
    model, params = vgg_small
    cut = model.cut_points()[2]
    hop = NetworkConfig("tcp", Channel(1e-3, 50e6, 50e6, seed=0))
    flow = measure_flow(Scenario("SC", SplitPlan(cut)), [hop], model,
                        params, 3072, n_frames=2)
    assert len(flow["stage_s"]) == 2 and len(flow["hop_bytes"]) == 1
    ref = measure_flow(Scenario("SC", SplitPlan(cut)), hop, model, params,
                       3072, n_frames=2)
    assert flow["wire_bytes"] == ref["wire_bytes"]
    assert flow["edge_s"] == pytest.approx(ref["edge_s"])


def test_measure_flow_path_warns_when_cost_is_dropped(vgg_small,
                                                      two_hop_path):
    """Multi-hop flows price analytically; silently discarding an
    explicit cost source would be a trap, so it warns."""
    from repro.runtime.calibrate import calibrate
    model, params = vgg_small
    cuts = (model.cut_points()[1], model.cut_points()[4])
    table = calibrate(model, params, [cuts[0]], batch=1, iters=1)
    with pytest.warns(UserWarning, match="cost= is ignored"):
        flow = measure_flow(Scenario("SC", SplitPlan(None, splits=cuts)),
                            two_hop_path, model, params, 3072, n_frames=2,
                            cost=table)
    assert flow["cost_source"] == "analytic"


def test_measure_flow_rc_traverses_whole_path(vgg_small, two_hop_path):
    model, params = vgg_small
    flow = measure_flow(Scenario("RC"), two_hop_path, model, params, 3072,
                        n_frames=2)
    assert flow["hop_bytes"] == [3072, 3072]
    assert flow["edge_s"] == 0.0 and flow["server_s"] > 0
    assert flow["stage_s"][:2] == [0.0, 0.0]


# -------------------------------------------------- pipelined microbatching ----
def test_pipeline_n_micro_1_equals_sequential(two_hop_path):
    stage_s = [2e-3, 1e-3, 5e-4]
    pipe = simulate_pipeline(stage_s, [40_000, 20_000], two_hop_path,
                             n_micro=1)
    assert pipe.latency_s == pytest.approx(pipe.sequential_s)
    assert pipe.speedup == pytest.approx(1.0)


def test_pipeline_overlap_beats_sequential_when_bandwidth_bound(two_hop_path):
    """Comparable busy hops + non-trivial compute: overlap must win."""
    stage_s = [5e-3, 1e-3, 5e-4]
    pipe = simulate_pipeline(stage_s, [120_000, 60_000], two_hop_path,
                             n_micro=4)
    assert pipe.latency_s < pipe.sequential_s
    assert pipe.speedup > 1.2
    # makespan can never beat the slowest single resource
    ser0 = two_hop_path[0].channel.serialization_s(1500) * (120_000 // 1500)
    assert pipe.latency_s > max(max(stage_s), ser0)
    assert len(pipe.micro_done_s) == 4
    assert list(pipe.micro_done_s) == sorted(pipe.micro_done_s)


def test_pipeline_shape_validation(two_hop_path):
    with pytest.raises(ValueError, match="stage times"):
        simulate_pipeline([1e-3, 1e-3], [1000, 1000], two_hop_path)
    with pytest.raises(ValueError, match="n_micro"):
        simulate_pipeline([1e-3, 1e-3, 1e-3], [1000, 1000], two_hop_path,
                          n_micro=0)


def test_measure_flow_pipeline_beats_sequential(vgg_small, two_hop_path):
    """Acceptance: pipelined microbatching beats sequential multi-hop
    simulated latency on a bandwidth-bound scenario."""
    model, params = vgg_small
    cuts = (model.cut_points()[1], model.cut_points()[4])
    sc = Scenario("SC", SplitPlan(None, splits=cuts),
                  edge=PLATFORMS["mcu"])
    flow = measure_flow(sc, two_hop_path, model, params, 3072, n_frames=2,
                        batch=32, n_micro=4,
                        tiers=(PLATFORMS["mcu"], PLATFORMS["edge-embedded"],
                               PLATFORMS["server-gpu"]))
    assert flow["pipeline_s"] == flow["pipeline"].latency_s
    assert flow["pipeline_s"] < flow_latency_s(flow)
    assert flow["pipeline"].speedup > 1.1


# ------------------------------------------------------------ tier planner ----
@pytest.fixture(scope="module")
def topology():
    return TierTopology((
        Tier("device", "mcu", Channel(1e-3, 20e6, 20e6, seed=1)),
        Tier("edge", "edge-accelerator", Channel(1e-3, 30e6, 30e6, seed=2)),
        Tier("cloud", "server-gpu"),
    ))


def test_topology_validation():
    ch = Channel(1e-3, 20e6, 20e6)
    with pytest.raises(ValueError, match="at least 2"):
        TierTopology((Tier("solo", "mcu"),))
    with pytest.raises(ValueError, match="uplink"):
        TierTopology((Tier("a", "mcu"), Tier("b", "server-gpu")))
    with pytest.raises(KeyError, match="unknown platform"):
        Tier("x", "quantum", ch)
    topo = TierTopology((Tier("a", "mcu", ch), Tier("b", "server-gpu")))
    assert len(topo.path()) == 1 and topo.path()[0].channel is ch


def test_compose_channels_store_and_forward():
    a = Channel(1e-3, 20e6, 20e6, loss_rate=0.1, seed=3)
    b = Channel(2e-3, 100e6, 50e6, loss_rate=0.2, seed=4)
    c = compose_channels([a, b])
    assert c.latency_s == pytest.approx(3e-3)
    assert c.effective_bps == 20e6
    assert c.loss_rate == pytest.approx(1 - 0.9 * 0.8)
    assert compose_channels([a]) is a
    with pytest.raises(ValueError):
        compose_channels([])


def test_plan_tiers_searches_cuts_and_assignments(vgg_small, topology):
    model, params = vgg_small
    cuts = model.cut_points()
    cs = np.linspace(1.0, 0.3, len(cuts))
    plans = plan_tiers(model, params, topology, n_micro=4, cs_curve=cs,
                       layer_idx=cuts, batch=8)
    # 1-cut x 2 assignments + 2-cut x 1 assignment, all legal
    n1, n2 = len(cuts), len(legal_cut_lists(model, 2))
    assert len(plans) == 2 * n1 + n2
    assert all(plans[i].latency_s <= plans[i + 1].latency_s
               for i in range(len(plans) - 1))
    for p in plans:
        validate_cuts(model, p.splits)
        assert p.stage_tiers[0] == "device" and p.tier_index[0] == 0
        assert len(p.stage_tiers) == len(p.splits) + 1
        assert p.sequential_s >= p.latency_s or p.n_micro == 1
    two = [p for p in plans if len(p.splits) == 2]
    assert two and all(p.stage_tiers == ("device", "edge", "cloud")
                       for p in two)
    # a 2-cut pipelined plan must beat its own sequential schedule
    assert max(p.speedup for p in two) > 1.0


def test_plan_tiers_passthrough_prices_both_links(vgg_small, topology):
    """A device->cloud 1-cut plan skips the edge tier but still pays
    both physical links, with the payload on each."""
    model, params = vgg_small
    cut = model.cut_points()[2]
    plans = plan_tiers(model, params, topology, cut_pool=[cut],
                       cut_counts=[1], batch=4)
    skip = next(p for p in plans if p.tier_index == (0, 2))
    assert skip.hop_bytes[0] == skip.hop_bytes[1] > 0
    assert skip.stage_s[1] == 0.0               # pass-through edge tier
    rp = skip.runtime_path(topology)
    assert len(rp) == 1
    assert rp[0].channel.latency_s == pytest.approx(2e-3)   # composed
    stop = next(p for p in plans if p.tier_index == (0, 1))
    assert len(stop.hop_bytes) == 1             # ends at the edge tier
    assert len(stop.runtime_path(topology)) == 1


def test_plan_tiers_batch_scales_with_sample(vgg_small, topology):
    """With a sample pytree, a requested batch rescales stage times and
    payloads linearly (same first-order model as stage_times_and_
    payloads) instead of silently pricing at the sample's own batch."""
    import jax.numpy as jnp
    model, params = vgg_small
    cut = model.cut_points()[2]
    sample = jnp.zeros((2, 16, 16, 3), jnp.float32)
    one = plan_tiers(model, params, topology, cut_pool=[cut],
                     cut_counts=[1], batch=2, sample=sample)
    four = plan_tiers(model, params, topology, cut_pool=[cut],
                      cut_counts=[1], batch=8, sample=sample)
    p1 = next(p for p in one if p.tier_index == (0, 1))
    p4 = next(p for p in four if p.tier_index == (0, 1))
    assert p4.hop_bytes[0] == 4 * p1.hop_bytes[0]
    assert p4.stage_s[0] == pytest.approx(4 * p1.stage_s[0])


def test_suggest_tier_plan_respects_qos(vgg_small, topology):
    from repro.core.qos import QoSRequirements
    model, params = vgg_small
    cuts = model.cut_points()
    cs = np.linspace(1.0, 0.3, len(cuts))
    plans = plan_tiers(model, params, topology, cs_curve=cs, layer_idx=cuts)
    best = suggest_tier_plan(plans, QoSRequirements(10.0, 0.5))
    assert best is not None and best.accuracy_proxy >= 0.5
    feasible = [p for p in plans if p.satisfies(QoSRequirements(10.0, 0.5))]
    assert best.accuracy_proxy == max(p.accuracy_proxy for p in feasible)
    assert suggest_tier_plan(plans, QoSRequirements(1e-9, 0.99)) is None


def test_planner_search_tiers_method(vgg_small, topology):
    from repro.fleet.planner import DeploymentPlanner
    model, params = vgg_small
    cuts = model.cut_points()
    planner = DeploymentPlanner(
        model, params, cs_curve=np.linspace(1.0, 0.3, len(cuts)),
        layer_idx=cuts, accuracy_fn=lambda s, n: 0.9, input_bytes=3072)
    plans = planner.search_tiers(topology, cut_counts=[2])
    assert plans and all(len(p.splits) == 2 for p in plans)
    assert all(isinstance(p, TierPlan) for p in plans)


# ------------------------------------------------------------ study facade ----
@pytest.fixture(scope="module")
def path_study():
    from repro.api import Study
    return Study("vgg16", seed=0).profile().candidates()


def test_study_simulate_path_mode(path_study, two_hop_path):
    study = path_study
    study.simulate(path=two_hop_path, top_m=5)
    assert 1 <= len(study.verdicts) <= 5
    for v in study.verdicts:
        assert len(v.candidate.splits) == 2
        assert v.meta["sequential_s"] > 0 and "speedup" in v.meta
        assert v.latency_s == pytest.approx(
            v.meta["sequential_s"] / v.meta["speedup"])
    from repro.core.qos import QoSRequirements
    best = study.suggest(QoSRequirements(10.0, 0.0))
    assert best is not None and len(best.candidate.splits) == 2


def test_study_suggest_tiers_and_deploy(path_study, topology, toy_data):
    from repro.core.qos import QoSRequirements
    study = path_study
    plan = study.suggest(QoSRequirements(10.0, 0.4), tiers=topology)
    assert plan is not None and plan.accuracy_proxy >= 0.4
    assert study.tier_plans[0].latency_s <= plan.latency_s + 1e-12
    rt = study.deploy()
    assert tuple(rt.part.splits) == plan.splits
    xs, _ = toy_data
    x = np.asarray(xs[:2])
    res = rt.infer(x, iters=1)
    ref = rt.reference(x)
    assert (np.argmax(res.logits, -1) == np.argmax(ref, -1)).all()
    assert len(res.hops) == len(plan.splits)


def test_study_deploy_after_1hop_path_uses_simulated_hop(toy_data):
    """Regression: a 1-hop path simulation must hand its own link to the
    deployed runtime, not the study scenario's default channel."""
    from repro.api import Study
    from repro.core.qos import QoSRequirements
    study = Study("vgg16", seed=0).profile().candidates()
    wan = Channel(5e-3, 5e6, 5e6, seed=7)    # much slower than the default
    study.simulate(path=[NetworkConfig("tcp", wan)], top_m=3)
    assert study.suggest(QoSRequirements(10.0, 0.0)) is not None
    rt = study.deploy()
    assert len(rt.part.splits) == 1
    assert rt.hops[0][1] is wan


def test_study_deploy_explicit_multicut(path_study, toy_data):
    study = path_study
    cuts = tuple(study.model.cut_points()[i] for i in (1, 4))
    rt = study.deploy(candidate=cuts)
    xs, _ = toy_data
    res = rt.infer(np.asarray(xs[:2]), iters=1)
    assert res.splits == cuts and len(res.stage_s) == 3


def test_study_simulate_invalidates_stale_tier_plan(topology, two_hop_path):
    """A later simulate() must not leave an obsolete tier suggestion
    owning deploy(): with no fresh suggestion, deploy raises."""
    from repro.api import Study
    from repro.core.qos import QoSRequirements
    study = Study("vgg16", seed=0).profile().candidates()
    assert study.suggest(QoSRequirements(10.0, 0.0),
                         tiers=topology) is not None
    study.simulate()                         # new exploration, link mode
    with pytest.raises(RuntimeError, match="suggest"):
        study.deploy()
