"""Per-architecture smoke tests: reduced variant of each assigned arch runs
one forward/loss/train step and one decode step on CPU — shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import transformer as T
from repro.models.common import reduced
from repro.training.optimizer import OptConfig, adamw_init, adamw_update

SEQ = 32
BATCH = 2


def make_batch(cfg, b=BATCH, s=SEQ, with_labels=True, seed=0):
    rng = np.random.default_rng(seed)
    st = s - (cfg.n_patches if cfg.family == "vlm" else 0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, st)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_patches, cfg.d_frontend)), cfg.jdtype)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_frames, cfg.d_frontend)), cfg.jdtype)
    if with_labels:
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, st)), jnp.int32)
    return batch


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch_setup(request):
    cfg = reduced(get_config(request.param))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return request.param, cfg, params


def test_forward_shapes_no_nan(arch_setup):
    name, cfg, params = arch_setup
    batch = make_batch(cfg)
    out = T.forward(params, cfg, batch)
    x = out["x"]
    assert x.shape[0] == BATCH and x.shape[2] == cfg.d_model
    assert not bool(jnp.isnan(x.astype(jnp.float32)).any()), name
    logits = T.logits_from_x(params, cfg, x)
    assert logits.shape[-1] == cfg.vocab


def test_loss_finite(arch_setup):
    name, cfg, params = arch_setup
    loss, metrics = T.loss_fn(params, cfg, make_batch(cfg), chunk=16)
    assert np.isfinite(float(loss)), name
    assert float(loss) > 0


def test_one_train_step(arch_setup):
    name, cfg, params = arch_setup
    oc = OptConfig(lr=1e-3)
    opt = adamw_init(params, oc)
    batch = make_batch(cfg)

    def lf(p):
        return T.loss_fn(p, cfg, batch, chunk=16)[0]

    l0, grads = jax.value_and_grad(lf)(params)
    gn = sum(float(jnp.abs(g.astype(jnp.float32)).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, name
    params2, _ = adamw_update(params, grads, opt, oc)
    l1 = lf(params2)
    assert np.isfinite(float(l1))
    assert float(l1) < float(l0) + 1.0  # no explosion


def test_decode_step(arch_setup):
    name, cfg, params = arch_setup
    cache = T.init_cache(cfg, BATCH, 64)
    tok = jnp.ones((BATCH, 1), jnp.int32)
    logits, cache2 = T.serve_step(params, cfg, cache, tok, jnp.asarray(3, jnp.int32))
    assert logits.shape == (BATCH, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), name
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_param_count_analytic_close(arch_setup):
    name, cfg, params = arch_setup
    real = sum(x.size for x in jax.tree.leaves(params))
    ana = cfg.param_counts()["total"]
    assert abs(real - ana) / real < 0.15, (name, real, ana)
