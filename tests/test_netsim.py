"""Discrete-event network simulator tests (paper §IV)."""
import numpy as np
import pytest

from repro.netsim.channel import Channel, INTERFACES
from repro.netsim.events import EventQueue
from repro.netsim.protocols import (n_packets_for, simulate_tcp, simulate_udp,
                                    simulate_transfer)


def test_event_queue_temporal_order():
    q = EventQueue()
    seen = []
    q.schedule(2.0, lambda: seen.append("b"))
    q.schedule(1.0, lambda: (seen.append("a"),
                             q.schedule(1.5, lambda: seen.append("a2"))))
    q.schedule(3.0, lambda: seen.append("c"))
    q.run()
    assert seen == ["a", "a2", "b", "c"]


def _ch(loss=0.0, seed=0):
    return Channel(latency_s=100e-6, capacity_bps=1e9,
                   interface_bps=INTERFACES["gigabit"], loss_rate=loss, seed=seed)


def test_tcp_delivers_everything():
    r = simulate_tcp(100_000, _ch(loss=0.2))
    assert r.delivered.all()
    assert r.n_transmissions > r.n_packets  # retransmits happened


def test_tcp_latency_grows_with_loss():
    lats = [np.mean([simulate_tcp(150_000, _ch(loss=p, seed=s), stream=s).duration_s
                     for s in range(8)]) for p in (0.0, 0.05, 0.15)]
    assert lats[0] < lats[1] < lats[2], lats


def test_tcp_zero_loss_matches_bandwidth_bound():
    ch = _ch(loss=0.0)
    n_bytes = 1_500_000
    r = simulate_tcp(n_bytes, ch)
    ideal = ch.serialization_s(n_bytes) + ch.latency_s
    assert r.duration_s >= ideal * 0.95
    assert r.duration_s <= ideal * 1.5  # windowing overhead is bounded


def test_udp_latency_loss_independent():
    durs = [simulate_udp(200_000, _ch(loss=p, seed=1)).duration_s
            for p in (0.0, 0.1, 0.3)]
    assert max(durs) - min(durs) < 0.2 * max(durs)


def test_udp_loss_fraction_tracks_rate():
    ch = _ch(loss=0.1, seed=3)
    r = simulate_udp(3_000_000, ch)
    assert abs(r.loss_fraction - 0.1) < 0.03


def test_udp_faster_than_tcp_under_loss():
    tcp = simulate_tcp(200_000, _ch(loss=0.1, seed=2))
    udp = simulate_udp(200_000, _ch(loss=0.1, seed=2))
    assert udp.duration_s < tcp.duration_s


def test_determinism():
    a = simulate_tcp(100_000, _ch(loss=0.1, seed=7), stream=4)
    b = simulate_tcp(100_000, _ch(loss=0.1, seed=7), stream=4)
    assert a.duration_s == b.duration_s and a.n_transmissions == b.n_transmissions


def test_interface_speed_caps_channel():
    fast_link = Channel(100e-6, 10e9, INTERFACES["fast-ethernet"], 0.0)
    assert fast_link.effective_bps == 100e6


def test_packetization():
    assert n_packets_for(1) == 1
    assert n_packets_for(1500) == 1
    assert n_packets_for(1501) == 2


def test_unknown_protocol():
    with pytest.raises(ValueError):
        simulate_transfer("sctp", 1000, _ch())
