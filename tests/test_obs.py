"""Telemetry layer: spans, metrics, Chrome export, null path, and the
four instrumented subsystems (events, cluster, runtime, planner)."""
import json

import numpy as np
import pytest

from repro.fleet.cluster import ClusterConfig, ClusterSim, ClusterStats
from repro.netsim.events import EventQueue
from repro.obs import (NULL, Histogram, MetricsRegistry, NullRecorder,
                       Recorder, Tracer, labelled, latency_buckets)
from repro.serving.engine import BatchCostModel


# ----------------------------------------------------------- chrome schema ----
def check_chrome_trace(path):
    """Validate the Chrome trace-event JSON contract Perfetto loads."""
    with open(path) as f:
        doc = json.load(f)
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert events, "empty trace"
    for e in events:
        assert e["ph"] in ("X", "i", "M")
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        assert isinstance(e["name"], str) and e["name"]
        if e["ph"] == "X":
            assert e["ts"] >= 0 and e["dur"] >= 0          # microseconds
        elif e["ph"] == "i":
            assert e["ts"] >= 0 and e["s"] == "t"
        else:
            assert e["name"] in ("process_name", "thread_name")
            assert "name" in e["args"]
    # every (pid, tid) track is named by metadata
    tracks = {(e["pid"], e["tid"]) for e in events if e["ph"] != "M"}
    named = {(e["pid"], e["tid"]) for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert tracks <= named
    return doc


# ------------------------------------------------------------------ tracer ----
def test_span_nesting_and_walk():
    tr = Tracer()
    with tr.span("outer", tid="t", cat="c") as outer:
        outer.args["k"] = 1
        with tr.span("inner", tid="t"):
            pass
    assert [s.name for s in outer.walk()] == ["outer", "inner"]
    assert outer.args == {"k": 1}
    assert outer.children[0].t0 >= outer.t0
    assert outer.children[0].t1 <= outer.t1 + 1e-9
    # both spans flat in the tracer, once each
    assert [s.name for s in tr.spans] == ["outer", "inner"]


def test_tracer_add_sim_clock():
    tr = Tracer()
    root = tr.add("a", 1.0, 3.0, clock="sim", tid="x", cat="k")
    tr.add("b", 1.5, 2.0, clock="sim", tid="x", parent=root)
    assert root.dur == pytest.approx(2.0)
    assert root.children[0].name == "b"


def test_chrome_export_schema_and_determinism(tmp_path):
    tr = Tracer()
    r = tr.add("root", 0.0, 1e-3, clock="sim", tid="requests", cat="fleet")
    tr.add("child", 0.0, 5e-4, clock="sim", tid="requests", parent=r)
    tr.instant("evt", 2e-4, clock="sim", tid="events")
    with tr.span("wall-op", tid="main"):
        pass
    p1, p2 = tmp_path / "t1.json", tmp_path / "t2.json"
    tr.to_chrome_trace(str(p1))
    tr.to_chrome_trace(str(p2))
    doc = check_chrome_trace(str(p1))
    assert p1.read_bytes() == p2.read_bytes()          # deterministic
    pids = {e["pid"] for e in doc["traceEvents"] if e["ph"] != "M"}
    assert pids == {1, 2}                              # sim + wall clocks


def test_chrome_export_clock_filter(tmp_path):
    tr = Tracer()
    tr.add("simmy", 0.0, 1.0, clock="sim", tid="a")
    with tr.span("wally"):
        pass
    p = tmp_path / "sim.json"
    tr.to_chrome_trace(str(p), clock="sim")
    doc = check_chrome_trace(str(p))
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert "simmy" in names and "wally" not in names


# ----------------------------------------------------------------- metrics ----
def test_counter_gauge():
    m = MetricsRegistry()
    c = m.counter("x.count")
    c.inc()
    c.inc(2.5)
    g = m.gauge("x.level")
    g.set(5.0)
    g.add(-2.0)
    assert m.snapshot()["x.count"] == pytest.approx(3.5)
    assert m.snapshot()["x.level"] == pytest.approx(3.0)
    # get-or-create returns the same instrument; kind conflicts raise
    assert m.counter("x.count") is c
    with pytest.raises(TypeError):
        m.gauge("x.count")


def test_histogram_percentiles():
    h = Histogram("lat", latency_buckets())
    vals = np.geomspace(1e-4, 1.0, 500)
    for v in vals:
        h.observe(float(v))
    assert h.n == 500
    assert h.percentile(50) == pytest.approx(np.percentile(vals, 50), rel=0.3)
    assert h.percentile(99) <= h.vmax * (1 + 1e-9)
    assert h.percentile(0) >= h.vmin * (1 - 1e-9)
    h.reset()
    assert h.n == 0 and np.isnan(h.percentile(50))


def test_timeseries_and_labelled():
    m = MetricsRegistry()
    assert labelled("runtime.stage_s", k=2) == "runtime.stage_s{k=2}"
    m.record(labelled("runtime.stage_s", k=2), 0.1, 5.0)
    m.record(labelled("runtime.stage_s", k=2), 0.2, 6.0)
    t, v = m.timeseries("runtime.stage_s{k=2}")
    np.testing.assert_allclose(t, [0.1, 0.2])
    np.testing.assert_allclose(v, [5.0, 6.0])
    assert m.timeseries("nope")[0].size == 0


# --------------------------------------------------------------- null path ----
def test_null_recorder_surface():
    n = NullRecorder()
    assert not n.enabled and not NULL.enabled
    with n.tracer.span("x") as sp:
        sp.args["k"] = 1                               # swallowed, no error
    n.tracer.add("a", 0, 1)
    n.tracer.instant("b", 0)
    n.metrics.counter("c").inc()
    n.metrics.gauge("g").add(2.0)
    n.metrics.histogram("h").observe(1.0)
    n.metrics.record("s", 0.0, 1.0)
    assert n.metrics.timeseries("s")[0].size == 0
    assert n.metrics.snapshot() == {}
    rep = n.report()
    assert rep.spans == () and rep.series_names() == []


def test_queue_default_obs_is_shared_null():
    assert EventQueue().obs is NULL
    cost = BatchCostModel(flops_per_item=1e6, flops_per_s=1e11)
    assert ClusterSim(cost, ClusterConfig()).obs is NULL


# ------------------------------------------------- events instrumentation ----
def test_cancel_after_fire_is_noop():
    q = EventQueue()
    fired = []
    h = q.schedule(1.0, lambda: fired.append(1))
    q.run()
    assert fired == [1]
    h.cancel()                  # already fired: harmless
    q.schedule(2.0, lambda: fired.append(2))
    q.run()
    assert fired == [1, 2] and q.n_fired == 2 and q.n_cancelled == 0


def test_run_max_events_exhaustion():
    q = EventQueue()

    def again():
        q.schedule(q.now + 1.0, again)

    q.schedule(0.0, again)
    with pytest.raises(RuntimeError, match="event budget"):
        q.run(max_events=10)
    # the traced loop enforces the same budget
    q2 = EventQueue(obs=Recorder())

    def again2():
        q2.schedule(q2.now + 1.0, again2)

    q2.schedule(0.0, again2)
    with pytest.raises(RuntimeError, match="event budget"):
        q2.run(max_events=10)


def test_cancelled_events_counted_never_spanned():
    rec = Recorder()
    q = EventQueue(obs=rec)
    q.schedule_named(1.0, lambda: None, "keep")
    q.schedule_named(2.0, lambda: None, "drop").cancel()
    q.run()
    names = [s.name for s in rec.tracer.spans]
    assert "keep" in names and "drop" not in names
    snap = rec.metrics.snapshot()
    assert snap["events.fired"] == 1 and snap["events.cancelled"] == 1
    assert q.n_fired == 1 and q.n_cancelled == 1


def test_event_chain_span_wraps_run():
    rec = Recorder()
    q = EventQueue(obs=rec)
    q.schedule(0.5, lambda: None)
    q.schedule(1.5, lambda: None)
    q.run()
    chains = [s for s in rec.tracer.spans if s.name == "event-chain"]
    assert len(chains) == 1
    assert chains[0].args["n_events"] == 2
    assert chains[0].t1 == pytest.approx(1.5)


def test_traced_and_null_runs_agree():
    def drive(q):
        out = []
        for i in range(20):
            h = q.schedule_named(0.1 * (i + 1), lambda i=i: out.append(i),
                                 f"e{i}")
            if i % 3 == 0:
                h.cancel()
        q.run()
        return out, q.now

    assert drive(EventQueue()) == drive(EventQueue(obs=Recorder()))


# ------------------------------------------------ cluster instrumentation ----
def test_cluster_stats_empty_run_nan():
    s = ClusterStats()
    assert np.isnan(s.percentile(50))
    assert np.isnan(s.percentile(99))
    assert np.isnan(s.mean_batch())
    assert s.drop_fraction() == 0.0


@pytest.fixture()
def traced_cluster():
    cost = BatchCostModel(flops_per_item=5e6, flops_per_s=1e11)
    rec = Recorder(window_s=0.01)
    sim = ClusterSim(cost, ClusterConfig(n_replicas=2, max_batch=4), obs=rec)
    t = np.cumsum(np.random.default_rng(0).exponential(1 / 400.0, 150))
    for i, ti in enumerate(t):
        sim.offer(i, float(ti), tx_s=1e-4, tx_bytes=1024)
    stats = sim.run()
    return rec, sim, stats


def test_cluster_request_lifecycle_spans(traced_cluster):
    rec, sim, stats = traced_cluster
    reqs = [s for s in rec.tracer.spans if s.name == "request"]
    assert len(reqs) == len(stats.served) == 150
    by_rid = {r.args["rid"]: r for r in reqs}
    for r in stats.served:
        span = by_rid[r.rid]
        parts = {c.name: c for c in span.children}
        assert "service" in parts and "wire" in parts
        # children tile the request span exactly
        assert sum(c.dur for c in span.children) == pytest.approx(span.dur)
        assert parts["service"].dur == pytest.approx(r.t_done - r.t_dispatch)
    # batch spans land on per-replica tracks
    tids = {s.tid for s in rec.tracer.spans if s.name.startswith("batch[")}
    assert tids <= {"replica0", "replica1"} and tids


def test_cluster_windowed_series(traced_cluster):
    rec, sim, stats = traced_cluster
    rep = rec.report()
    for name in ("fleet.arrival_rate_hz", "fleet.queue_depth",
                 "fleet.drop_fraction", "fleet.utilization",
                 "fleet.inflight_bytes", "fleet.latency_p50_s",
                 "fleet.latency_p99_s"):
        t, v = rep.timeseries(name)
        assert len(t) > 3, name
        assert np.all(np.diff(t) > 0), name
    # arrivals counter reconciles with the simulation
    assert rec.metrics.snapshot()["fleet.arrivals"] == 150
    assert rec.metrics.snapshot()["fleet.served"] == 150
    # inflight bytes returns to zero once everything arrived
    _, inflight = rep.timeseries("fleet.inflight_bytes")
    assert inflight[-1] == 0


def test_cluster_trace_bit_reproducible(tmp_path):
    def once(path):
        cost = BatchCostModel(flops_per_item=5e6, flops_per_s=1e11)
        rec = Recorder(window_s=0.01)
        sim = ClusterSim(cost, ClusterConfig(n_replicas=2, max_batch=4),
                         obs=rec)
        t = np.cumsum(np.random.default_rng(7).exponential(1 / 300.0, 80))
        for i, ti in enumerate(t):
            sim.offer(i, float(ti))
        sim.run()
        rec.report().to_chrome_trace(path, clock="sim")

    p1, p2 = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    once(p1)
    once(p2)
    assert open(p1, "rb").read() == open(p2, "rb").read()
    check_chrome_trace(p1)


def test_cluster_untraced_matches_traced_simulation():
    """Telemetry must not perturb the simulation itself."""
    def once(obs):
        cost = BatchCostModel(flops_per_item=5e6, flops_per_s=1e11)
        sim = ClusterSim(cost, ClusterConfig(n_replicas=2, max_batch=4,
                                             queue_limit=8), obs=obs)
        t = np.cumsum(np.random.default_rng(3).exponential(1 / 2000.0, 300))
        for i, ti in enumerate(t):
            sim.offer(i, float(ti))
        s = sim.run()
        return (len(s.served), s.dropped, s.batches,
                [(r.rid, r.t_dispatch, r.t_done) for r in s.served])

    assert once(None) == once(Recorder())


# ------------------------------------------------ runtime instrumentation ----
@pytest.fixture(scope="module")
def observed_infer():
    from repro.api import Study

    study = Study("vgg16", seed=0)
    report = study.observe(window_s=0.02)
    rt = study.deploy(candidate="SC@8")
    x = np.asarray(study._x[:2])
    result = rt.infer(x, iters=2)
    return study, report, result


def test_runtime_span_tree_reconciles(observed_infer):
    study, report, result = observed_infer
    root = result.trace
    assert root is not None and root.name == "infer"
    leaves = [s for s in root.walk() if not s.children and s is not root]
    total = sum(s.dur for s in leaves)
    assert abs(root.dur - result.total_s) <= 0.01 * result.total_s
    assert abs(total - result.total_s) <= 0.01 * result.total_s
    kinds = {c.name for c in root.children}
    assert any(k.startswith("stage") for k in kinds)
    assert any(k.startswith("hop") for k in kinds)
    hop = next(c for c in root.children if c.name.startswith("hop"))
    assert [g.name for g in hop.children] == ["encode", "transfer", "decode"]


def test_runtime_series_and_chrome_export(observed_infer, tmp_path):
    study, report, result = observed_infer
    assert "runtime.stage_s{k=0}" in report.series_names()
    _, v = report.timeseries("runtime.stage_s{k=0}")
    assert v[-1] > 0
    p = str(tmp_path / "rt.json")
    report.to_chrome_trace(p)
    doc = check_chrome_trace(p)
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {"infer", "encode", "transfer", "decode"} <= names


def test_runtime_trace_built_even_without_obs(vgg_small):
    from repro.runtime.engine import SplitRuntime
    model, params = vgg_small
    rt = SplitRuntime(model, params, model.cut_points()[1])
    x = np.random.default_rng(0).standard_normal(
        (2,) + tuple(model.input_shape)).astype(np.float32)
    res = rt.infer(x, iters=1)
    assert res.trace is not None
    assert res.trace.dur == pytest.approx(res.total_s)


# ------------------------------------------------ planner instrumentation ----
def test_plan_tiers_phase_spans(vgg_small):
    from repro.fleet.planner import Tier, TierTopology, plan_tiers
    from repro.netsim.channel import Channel
    model, params = vgg_small
    topo = TierTopology((
        Tier("edge", "edge-embedded", Channel(1e-3, 20e6, 20e6, seed=1)),
        Tier("cloud", "server-gpu"),
    ))
    rec = Recorder()
    plans = plan_tiers(model, params, topo, refine=4, obs=rec)
    assert plans
    spans = {s.name: s for s in rec.tracer.spans if s.cat == "planner"}
    assert set(spans) == {"planner.screen", "planner.refine"}
    assert spans["planner.screen"].args["n_combos"] >= len(plans)
    assert spans["planner.refine"].args["n_refined"] >= 1
    snap = rec.metrics.snapshot()
    assert snap["planner.screen_combos"] == spans["planner.screen"].args[
        "n_combos"]
    assert snap["planner.refined_plans"] >= 1
    # wall spans are ordered: screen strictly before refine
    assert spans["planner.screen"].t1 <= spans["planner.refine"].t0 + 1e-9


# --------------------------------------------------- end-to-end via Study ----
def test_study_observe_fleet_and_runtime(tmp_path):
    """The acceptance path: one report covering a fleet simulation and a
    live infer, exported as schema-valid Chrome JSON."""
    from repro.api import Study
    from repro.fleet.cluster import ClusterConfig, ClusterSim
    from repro.serving.engine import BatchCostModel

    study = Study("vgg16", seed=0)
    report = study.observe(window_s=0.01)
    assert study.observe() is not None                 # idempotent re-arm

    # fleet half: a cluster on the shared recorder
    cut = study.model.cut_points()[2]
    cost = BatchCostModel.for_split(study.model, study.params, cut,
                                    study.scenario.server)
    sim = ClusterSim(cost, ClusterConfig(n_replicas=2, max_batch=8),
                     obs=report.recorder)
    t = np.cumsum(np.random.default_rng(1).exponential(1 / 500.0, 120))
    for i, ti in enumerate(t):
        sim.offer(i, float(ti), tx_s=2e-4, tx_bytes=study.input_bytes)
    stats = sim.run()
    assert len(stats.served) == 120

    # runtime half: a real infer through the same study
    rt = study.deploy(candidate=f"SC@{cut}")
    res = rt.infer(np.asarray(study._x[:2]), iters=2)
    assert abs(res.trace.dur - res.total_s) <= 0.01 * res.total_s

    p = str(tmp_path / "study.json")
    report.to_chrome_trace(p)
    doc = check_chrome_trace(p)
    names = {e["name"] for e in doc["traceEvents"]}
    assert "request" in names and "infer" in names
    assert len(report.spans) > 150
    assert "fleet.queue_depth" in report.series_names()
    assert "runtime.stage_s{k=0}" in report.series_names()
    # summary renders without error and mentions both subsystems
    text = report.summary()
    assert "fleet" in text and "spans" in text


def test_trace_seed_provenance():
    from repro.fleet.traffic import DeviceClass, generate_trace
    from repro.netsim.channel import Channel
    mix = [DeviceClass.make("mcu", Channel(1e-3, 10e6, 10e6, seed=1))]
    tr = generate_trace(mix, 10, 100.0, seed=123)
    assert tr.seed == 123
    assert tr.for_device("mcu").seed == 123
