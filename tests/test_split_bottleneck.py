"""Split execution + bottleneck AE tests (paper §III Eqs. 3-4)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bottleneck as B
from repro.core.split import SplitPlan, legal_cuts, wire_payload_bytes


def test_split_without_ae_is_identity(vgg_small, toy_data):
    model, params = vgg_small
    xs, _ = toy_data
    x = jnp.asarray(xs[:4])
    full = model.apply(params, x)
    for cut in model.cut_points()[::5]:
        y = B.split_forward(model, params, None, cut, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(full), atol=1e-5)


def test_bottleneck_shapes_and_compression(vgg_small, toy_data):
    model, params = vgg_small
    xs, _ = toy_data
    x = jnp.asarray(xs[:4])
    cut = model.cut_points()[4]
    f = model.apply_range(params, x, 0, cut + 1)
    ae = B.init_bottleneck(jax.random.PRNGKey(0), f.shape[1:], rate=0.5)
    z = B.encode(ae, f)
    assert z.shape[-1] == B.latent_channels(f.shape[-1], 0.5)
    r = B.reconstruct(ae, f)
    assert r.shape == f.shape
    y = B.split_forward(model, params, ae, cut, x)
    assert y.shape == (4, model.n_classes)


def test_corrupt_mask_changes_output(vgg_small, toy_data):
    model, params = vgg_small
    xs, _ = toy_data
    x = jnp.asarray(xs[:2])
    cut = model.cut_points()[3]
    f = model.apply_range(params, x, 0, cut + 1)
    ae = B.init_bottleneck(jax.random.PRNGKey(0), f.shape[1:], rate=0.5)
    clean = B.split_forward(model, params, ae, cut, x)
    z_shape = B.encode(ae, f).shape
    mask = jnp.ones(z_shape).at[:, ..., : z_shape[-1] // 2].set(0.0)
    corrupted = B.split_forward(model, params, ae, cut, x, corrupt_mask=mask)
    assert float(jnp.abs(clean - corrupted).max()) > 1e-4


def test_train_bottleneck_reduces_loss(vgg_small):
    from repro.data.synthetic import toy_image_iter
    model, params = vgg_small
    it = toy_image_iter(16, hw=16, seed=1)
    it = map(lambda t: (jnp.asarray(t[0]), jnp.asarray(t[1])), it)
    cut = model.cut_points()[4]
    ae, losses = B.train_bottleneck(model, params, cut, it, steps=30, lr=1e-3)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.8, losses[:3] + losses[-3:]


def test_payload_bytes_and_plan(vgg_small):
    model, params = vgg_small
    plan = SplitPlan(split_layer=model.cut_points()[2], compression=0.5)
    nb = wire_payload_bytes(model, params, plan, batch=1)
    assert nb > 0
    # halving compression halves payload (up to channel rounding)
    plan2 = SplitPlan(split_layer=plan.split_layer, compression=0.25)
    nb2 = wire_payload_bytes(model, params, plan2, batch=1)
    assert nb2 < nb
    assert plan.describe(model)
    assert legal_cuts(model) == model.cut_points()


def test_finetune_runs(vgg_small):
    from repro.data.synthetic import toy_image_iter
    model, params = vgg_small
    it = map(lambda t: (jnp.asarray(t[0]), jnp.asarray(t[1])),
             toy_image_iter(8, hw=16, seed=2))
    cut = model.cut_points()[4]
    ae, _ = B.train_bottleneck(model, params, cut, it, steps=3, lr=1e-3)
    p2, ae2, losses = B.finetune(model, params, ae, cut, it, steps=3, lr=1e-4)
    assert all(np.isfinite(l) for l in losses)
