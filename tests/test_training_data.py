"""Training substrate: optimizer, loss goes down, checkpoint round-trip,
synthetic data sanity."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import toy_images, token_batch, token_iter
from repro.models.common import reduced
from repro.training import checkpoint as ckpt
from repro.training.optimizer import (OptConfig, adam_init, adam_update,
                                      adamw_init, adamw_update, global_norm)
from repro.training.train import make_train_step, init_train_state


def test_adam_converges_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    opt = adam_init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, opt = adam_update(params, g, opt, lr=0.05)
    assert float(jnp.abs(params["x"]).max()) < 0.05


def test_adamw_weight_decay_shrinks():
    oc = OptConfig(lr=0.1, weight_decay=0.1, grad_clip=None)
    params = {"w": jnp.ones((4,)) * 10}
    st = adamw_init(params, oc)
    zero_g = {"w": jnp.zeros((4,))}
    p2, _ = adamw_update(params, zero_g, st, oc)
    assert float(p2["w"][0]) < 10.0


def test_grad_clip():
    """Clipping actually bounds the applied update: with wd=0, b1=0 the
    first Adam step moves each weight by at most ~lr regardless of the
    raw gradient norm, and the clipped-gradient step matches the step a
    pre-scaled gradient would take."""
    g = {"w": jnp.ones((100,)) * 100}
    gn = float(global_norm(g))
    assert gn > 1.0
    params = {"w": jnp.zeros((100,))}
    oc = OptConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    st = adamw_init(params, oc)
    p_clip, _ = adamw_update(params, g, st, oc)
    g_scaled = {"w": g["w"] / gn}
    p_ref, _ = adamw_update(params, g_scaled, adamw_init(params, oc), oc)
    np.testing.assert_allclose(np.asarray(p_clip["w"]),
                               np.asarray(p_ref["w"]), rtol=1e-5)


def test_lm_training_loss_decreases():
    cfg = reduced(get_config("llama3.2-3b"), vocab=64, n_layers=2)
    oc = OptConfig(lr=3e-3)
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg, oc)
    step = jax.jit(make_train_step(cfg, oc))
    it = token_iter(8, 32, cfg.vocab, seed=0)
    losses = []
    for i in range(40):
        b = next(it)
        params, opt, m = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::8]


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": [jnp.ones((4,)), {"c": jnp.zeros((2, 2), jnp.int32)}]}
    path = os.path.join(tmp_path, "ck.npz")
    ckpt.save(path, tree)
    out = ckpt.restore(path, jax.tree.map(jnp.zeros_like, tree))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_toy_images_learnable_structure():
    xs, ys = toy_images(32, hw=16, seed=0)
    assert xs.shape == (32, 16, 16, 3) and np.isfinite(xs).all()
    assert set(np.unique(ys)) <= set(range(8))
    # different classes produce different mean silhouettes
    m0 = xs[ys == ys[0]].mean(0)
    other = ys[ys != ys[0]]
    if len(other):
        m1 = xs[ys == other[0]].mean(0)
        assert np.abs(m0 - m1).mean() > 1e-3


def test_token_batch_structure():
    b = token_batch(4, 64, 97, seed=1)
    assert b["tokens"].shape == (4, 64) and b["labels"].shape == (4, 64)
    # labels are next-token shifted
    det = (5 * b["tokens"][:, :-1] + 7) % 97
    frac = (b["labels"][:, :-1] == det).mean()
    assert frac > 0.6  # 80% deterministic by construction
