"""Saliency / CS-curve tests (paper §III core)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.saliency import (candidate_split_points, cumulative_saliency,
                                 layer_saliency_maps, local_maxima)
from repro.models.vgg import feature_index


def test_cs_curve_shape_and_range(vgg_small, toy_data):
    model, params = vgg_small
    xs, ys = toy_data
    fi = feature_index(model)
    cs = cumulative_saliency(model, params, jnp.asarray(xs[:8]),
                             jnp.asarray(ys[:8]), layer_idx=fi)
    assert cs.shape == (len(fi),)
    assert cs.min() >= 0.0 and cs.max() <= 1.0 + 1e-9
    assert np.all(np.isfinite(cs))


def test_saliency_maps_shapes(vgg_small, toy_data):
    model, params = vgg_small
    xs, ys = toy_data
    maps = layer_saliency_maps(model, params, jnp.asarray(xs[:4]),
                               jnp.asarray(ys[:4]))
    assert len(maps) == len(model.layers)
    # all resized to the largest spatial grid
    assert maps[0].shape == (4, 16, 16)


def test_saliency_model_dependence(vgg_small, toy_data):
    """Sanity check (paper cites [20]): saliency must depend on the weights."""
    model, params = vgg_small
    xs, ys = toy_data
    fi = feature_index(model)
    cs1 = cumulative_saliency(model, params, jnp.asarray(xs[:8]),
                              jnp.asarray(ys[:8]), layer_idx=fi)
    params2 = model.init(jax.random.PRNGKey(42))
    cs2 = cumulative_saliency(model, params2, jnp.asarray(xs[:8]),
                              jnp.asarray(ys[:8]), layer_idx=fi)
    assert np.abs(cs1 - cs2).max() > 1e-3


def test_local_maxima_plateaus():
    assert local_maxima(np.array([0., 1., 0., 2., 2., 2., 1., 3., 0.]),
                        tol=1e-6) == [1, 4, 7]
    assert local_maxima(np.array([3., 2., 1.])) == []
    assert local_maxima(np.array([0., 1., 2.])) == []


def test_candidate_split_points(vgg_small, toy_data):
    model, params = vgg_small
    xs, ys = toy_data
    fi = feature_index(model)
    cs = cumulative_saliency(model, params, jnp.asarray(xs[:8]),
                             jnp.asarray(ys[:8]), layer_idx=fi)
    cands = candidate_split_points(model, cs, fi, top_n=5)
    legal = set(model.cut_points())
    assert all(c in legal for c in cands)
