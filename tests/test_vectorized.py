"""Vectorized cluster engine: exact agreement with the event engine
(the screen/refine contract of ``fleet.vectorized``), streaming stats,
the fluid overload fallback, and the planner/study ``engine=`` knob."""
import numpy as np
import pytest

from repro.fleet.cluster import ClusterConfig, ClusterSim
from repro.fleet.vectorized import (FLUID_MIN_REQUESTS, PCTL_RTOL,
                                    StreamingClusterStats,
                                    VectorClusterStats,
                                    VectorizedClusterSim,
                                    check_against_event_engine,
                                    fluid_cluster_stats,
                                    simulate_cluster_vectorized)
from repro.obs import Recorder
from repro.serving.engine import BatchCostModel


def _cost(service_s=1e-3, per_item=0.0):
    return BatchCostModel(flops_per_item=per_item, flops_per_s=1e12,
                          fixed_overhead_s=service_s)


def _poisson(rate, n, seed=0):
    return np.cumsum(np.random.default_rng(seed).exponential(1.0 / rate, n))


# ----------------------------------------------------- exact agreement ----
@pytest.mark.parametrize("cfg,rate", [
    # M/D/1, no batching window
    (ClusterConfig(n_replicas=1, max_batch=1, batch_window_s=0.0), 600.0),
    # batching + window, under capacity
    (ClusterConfig(n_replicas=2, max_batch=4, batch_window_s=2e-3), 1500.0),
    # overloaded with a small admission queue: drops everywhere
    (ClusterConfig(n_replicas=2, max_batch=8, batch_window_s=1e-3,
                   queue_limit=32), 9000.0),
    # queue_limit < max_batch: the L-bounded dispatch corner
    (ClusterConfig(n_replicas=1, max_batch=16, batch_window_s=5e-3,
                   queue_limit=5), 4000.0),
])
def test_vectorized_matches_event_engine(cfg, rate):
    t = _poisson(rate, 1200, seed=3)
    # check_event_engine raises on any count mismatch or percentile drift
    stats = simulate_cluster_vectorized(t, _cost(1e-3, 1e6), cfg,
                                        check_event_engine=True)
    assert isinstance(stats, VectorClusterStats)
    assert stats.n_served + stats.dropped == 1200


def test_unsorted_offers_keep_offer_order():
    cfg = ClusterConfig(n_replicas=2, max_batch=4, batch_window_s=2e-3)
    t = _poisson(2000.0, 500, seed=5)
    rng = np.random.default_rng(9)
    perm = rng.permutation(len(t))
    rids = np.arange(1000, 1000 + len(t))
    stats = simulate_cluster_vectorized(t[perm], _cost(), cfg,
                                        rids=rids[perm],
                                        check_event_engine=True)
    # arrays stay in offer order: request j's offer time is t[perm][j]
    assert np.array_equal(stats.t_offer, t[perm])
    assert np.array_equal(stats.rids, rids[perm])
    served = stats.served                     # event-engine compat records
    assert all(r.t_done >= r.t_dispatch >= r.t_offer for r in served)


def test_latency_arrays_match_event_records_elementwise():
    cfg = ClusterConfig(n_replicas=3, max_batch=8, batch_window_s=1e-3,
                        queue_limit=64)
    t = _poisson(12_000.0, 2000, seed=11)     # ~1.5x overload
    cost = _cost(5e-4, 2e6)
    vstats = simulate_cluster_vectorized(t, cost, cfg)
    sim = ClusterSim(cost, cfg)
    sim.offer_trace(enumerate(t.tolist()))
    est = sim.run()
    by_rid = {r.rid: r for r in est.served}
    m = ~vstats.drop_mask
    for rid, td, to in zip(vstats.rids[m], vstats.t_done[m],
                           vstats.t_offer[m]):
        assert abs((td - to) - by_rid[int(rid)].latency_s) < 1e-9


# ------------------------------------------------------------ streaming ----
def test_streaming_stats_counts_exact_percentiles_bucketed():
    cfg = ClusterConfig(n_replicas=2, max_batch=8, batch_window_s=2e-3,
                        queue_limit=128)
    t = _poisson(20_000.0, 5000, seed=2)
    cost = _cost(5e-4)
    exact = simulate_cluster_vectorized(t, cost, cfg)
    stream = simulate_cluster_vectorized(t, cost, cfg, streaming=True)
    assert isinstance(stream, StreamingClusterStats)
    # counts are exact; quantiles carry only the histogram bucket error
    assert stream.n_served == exact.n_served
    assert stream.dropped == exact.dropped
    assert stream.batches == exact.batches
    assert stream.drop_fraction() == exact.drop_fraction()
    assert stream.mean_batch() == exact.mean_batch()
    for p in (50, 99):
        a, b = exact.percentile(p), stream.percentile(p)
        assert abs(a - b) / a < 0.30, (p, a, b)   # 9 buckets/decade
    with pytest.raises(RuntimeError):
        stream.latencies()


# ------------------------------------------------------- wrapper parity ----
def test_vectorized_cluster_sim_is_a_drop_in():
    cfg = ClusterConfig(n_replicas=2, max_batch=4, batch_window_s=2e-3)
    cost = _cost(1e-3)
    t = _poisson(1800.0, 800, seed=7)
    ref = ClusterSim(cost, cfg)
    ref.offer_trace(enumerate(t.tolist()))
    est = ref.run()

    vec = VectorizedClusterSim(cost, cfg)
    half = len(t) // 2
    vec.offer_trace((i, float(ti)) for i, ti in enumerate(t[:half]))
    vec.offer_array(t[half:])                 # bulk intake, auto rids
    stats = vec.run(check_event_engine=True)
    assert stats is vec.stats
    assert stats.n_served == len(est.served)
    assert stats.dropped == est.dropped
    assert stats.batches == est.batches


def test_offer_trace_four_tuples_forward_tx_metadata():
    # the ClusterSim.offer_trace bugfix: 4-field rows must reach offer()
    cost = _cost(1e-3)
    cfg = ClusterConfig(n_replicas=1, max_batch=2, batch_window_s=1e-3)
    rec = Recorder(window_s=0.01)
    sim = ClusterSim(cost, cfg, obs=rec)
    t = _poisson(500.0, 40, seed=1)
    sim.offer_trace((i, float(ti), 1e-4, 2048) for i, ti in enumerate(t))
    stats = sim.run()
    wires = [s for s in rec.tracer.spans if s.name == "wire"]
    assert len(wires) == len(stats.served) == 40
    assert all(s.args["bytes"] == 2048 for s in wires)
    # and the 2-field form still works
    sim2 = ClusterSim(cost, cfg)
    sim2.offer_trace(enumerate(t.tolist()))
    assert len(sim2.run().served) == 40


def test_vectorized_emits_fleet_series_and_counters():
    cfg = ClusterConfig(n_replicas=2, max_batch=4, batch_window_s=2e-3,
                        queue_limit=16)
    cost = _cost(1e-3)
    t = _poisson(4000.0, 1500, seed=13)
    rec = Recorder(window_s=0.01)
    vec = VectorizedClusterSim(cost, cfg, obs=rec)
    vec.offer_array(t, tx_s=np.full(len(t), 1e-4),
                    tx_bytes=np.full(len(t), 1024))
    stats = vec.run()
    rep = rec.report()
    for name in ("fleet.arrival_rate_hz", "fleet.queue_depth",
                 "fleet.drop_fraction", "fleet.utilization",
                 "fleet.inflight_bytes", "fleet.latency_p50_s",
                 "fleet.latency_p99_s"):
        ts, _ = rep.timeseries(name)
        assert len(ts) > 3, name
        assert np.all(np.diff(ts) > 0), name
    snap = rec.metrics.snapshot()
    assert snap["fleet.arrivals"] == 1500
    assert snap["fleet.drops"] == stats.dropped
    assert snap["fleet.served"] == stats.n_served
    assert snap["fleet.batches"] == stats.batches
    assert any(s.name == "cluster.vectorized" for s in rec.tracer.spans)


# ------------------------------------------------------- fluid fallback ----
def test_auto_mode_stays_exact_on_small_runs():
    cfg = ClusterConfig(n_replicas=1, max_batch=4, batch_window_s=1e-3)
    stats = simulate_cluster_vectorized(_poisson(1000.0, 300, seed=4),
                                        _cost(), cfg, mode="auto")
    assert isinstance(stats, VectorClusterStats)


def test_auto_mode_falls_back_to_fluid_in_deep_overload():
    cfg = ClusterConfig(n_replicas=1, max_batch=8, batch_window_s=1e-3,
                        queue_limit=256)
    cost = _cost(1e-3)
    cap = cfg.max_batch / cost.service_time(cfg.max_batch)
    n = FLUID_MIN_REQUESTS
    t = _poisson(5.0 * cap, n, seed=6)        # 5x sustained overload
    stats = simulate_cluster_vectorized(t, cost, cfg, mode="auto")
    assert isinstance(stats, StreamingClusterStats)
    # deep overload: the fluid drop fraction approaches 1 - 1/load
    assert abs(stats.drop_fraction() - 0.8) < 0.05
    # fluid is approximate by design: checking it is a contract error
    with pytest.raises(ValueError):
        simulate_cluster_vectorized(t, cost, cfg, mode="fluid",
                                    check_event_engine=True)


def test_fluid_matches_exact_in_overload_regime():
    cfg = ClusterConfig(n_replicas=2, max_batch=8, batch_window_s=1e-3,
                        queue_limit=64)
    cost = _cost(1e-3)
    cap = 2 * cfg.max_batch / cost.service_time(cfg.max_batch)
    t = _poisson(4.0 * cap, 40_000, seed=8)
    exact = simulate_cluster_vectorized(t, cost, cfg)
    fluid = fluid_cluster_stats(t, cost, cfg)
    assert abs(fluid.drop_fraction() - exact.drop_fraction()) < 0.05
    assert fluid.percentile(50) == pytest.approx(exact.percentile(50),
                                                 rel=0.5)


# ----------------------------------------------------- engine=... knob ----
def test_planner_engine_knob_parity(request):
    from repro.core.qos import QoSRequirements
    from repro.fleet import (DeploymentPlanner, SearchSpace,
                             generate_trace)
    from repro.fleet.planner import simulate_deployment
    from repro.models.vgg import feature_index
    from repro.netsim.channel import Channel
    from repro.fleet import DeviceClass

    model, params = request.getfixturevalue("vgg_small")
    fi = feature_index(model)
    cs = np.linspace(1.0, 0.2, len(fi))

    def accuracy_fn(scenario, netcfg):
        return 0.9 if scenario.kind != "LC" else 0.6

    planner = DeploymentPlanner(model, params, cs_curve=cs, layer_idx=fi,
                                accuracy_fn=accuracy_fn,
                                input_bytes=16 * 16 * 3 * 4, n_frames=4)
    mix = [DeviceClass.make("mcu", Channel(1e-3, 1e6, 1e6, seed=1)),
           DeviceClass.make("edge-embedded",
                            Channel(1e-4, 50e6, 50e6, seed=2))]
    legal = set(model.cut_points())
    sps = tuple(sp for sp in fi if sp in legal)[:2]
    space = SearchSpace(split_points=sps, protocols=("tcp",),
                        batch_sizes=(1, 4), replica_counts=(1,),
                        top_k_splits=1)
    trace = generate_trace(mix, 300, 150.0, seed=23)

    pe = planner.search(trace, mix, space, engine="event")
    pv = planner.search(trace, mix, space, engine="vectorized")
    assert len(pe) == len(pv) > 0
    for a, b in zip(pe, pv):
        assert a.drop_fraction == b.drop_fraction
        if np.isfinite(a.p99_s):
            assert b.p99_s == pytest.approx(a.p99_s, rel=PCTL_RTOL)
    # screen/refine contract: every Pareto-front point is event-priced
    assert all(p.engine == "event" for p in planner.pareto_front(pv))
    assert any(p.engine == "vectorized" for p in pv)

    with pytest.raises(ValueError):
        planner.search(trace, mix, space, engine="warp")

    qos = QoSRequirements(max_latency_s=10.0, min_accuracy=0.0)
    plans = planner.suggest(qos, (trace, mix), space, points=pv)
    re_ = simulate_deployment(plans, trace, mix, planner, engine="event")
    rv = simulate_deployment(plans, trace, mix, planner,
                             engine="vectorized", check_event_engine=True)
    assert re_ and set(re_) == set(rv)
    for key in re_:
        assert re_[key]["engine"] == "event"
        assert rv[key]["engine"] == "vectorized"
        assert rv[key]["n_served"] == re_[key]["n_served"]
        assert rv[key]["p99_s"] == pytest.approx(re_[key]["p99_s"],
                                                 rel=PCTL_RTOL)


# ------------------------------------------------- randomized sweep ----
# (the hypothesis property tests live in test_properties.py with the
# rest of the hypothesis suite; this seeded sweep keeps the agreement
# contract exercised even where hypothesis is not installed)
def test_engines_agree_on_seeded_random_sweep():
    rng = np.random.default_rng(42)
    for _ in range(40):
        n = int(rng.integers(1, 400))
        k = int(rng.integers(1, 5))
        max_batch = int(rng.integers(1, 17))
        cfg = ClusterConfig(
            n_replicas=k, max_batch=max_batch,
            batch_window_s=float(rng.choice([0.0, 1e-4, 2e-3, 1e-2])),
            queue_limit=int(rng.integers(1, 120)))
        cost = BatchCostModel(flops_per_item=float(rng.uniform(0, 1e7)),
                              flops_per_s=1e12,
                              fixed_overhead_s=float(rng.uniform(1e-5,
                                                                 2e-3)))
        cap = k * max_batch / cost.service_time(max_batch)
        t = np.cumsum(rng.exponential(
            1.0 / (cap * float(rng.uniform(0.2, 5.0))), n))
        stats = simulate_cluster_vectorized(t, cost, cfg)
        # raises AssertionError on any disagreement
        check_against_event_engine(t, cost, cfg, stats)
