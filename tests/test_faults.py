"""The fault layer: wire hardening, deterministic injection, recovery.

The contract under test (ISSUE 10 / CONTRIBUTING "fault injection"):
zero-fault paths stay bit-identical to the historical byte streams;
every injected fault is survived — retried paths produce bit-identical
outputs, degraded paths are explicitly flagged; and the whole schedule
is a deterministic function of ``(seed, rid, hop/stage, attempt)``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.netsim.channel import Channel
from repro.netsim.protocols import RetryBudgetExceeded, simulate_tcp
from repro.runtime import wire as W
from repro.runtime.engine import SplitRuntime, TailServer
from repro.runtime.faults import (FaultPlan, RecoveryExhausted,
                                  RecoveryPolicy, downgrade_ladder)
from repro.runtime.partition import make_partition

CUT = 3
CH = Channel(latency_s=0.005, capacity_bps=50e6, interface_bps=100e6,
             loss_rate=0.02, seed=3)


@pytest.fixture(scope="module")
def split_setup(vgg_small, toy_data):
    model, params = vgg_small
    xs, _ = toy_data
    return model, params, jnp.asarray(xs[:4])


# ---------------------------------------------------------------- wire ----
class TestWireHardening:
    def _frame(self, checksum):
        rng = np.random.default_rng(0)
        f = rng.normal(size=(2, 4, 8)).astype(np.float32)
        pkt = W.encode_activation(jnp.asarray(f))
        return pkt, W.to_bytes(pkt, checksum=checksum)

    def test_default_framing_unchanged(self):
        """checksum=False is the historical SEI1 layout, byte for byte."""
        pkt, buf = self._frame(False)
        assert buf[:4] == b"SEI1"
        assert pkt.nbytes == len(buf)
        # hand-assemble the v1 frame: magic|kind|ndim|dims|payload|scales
        import struct
        head = (b"SEI1" + struct.pack("<BB", 1, 3)
                + struct.pack("<3I", *pkt.shape))
        assert buf == head + pkt.data.tobytes() + pkt.scales.tobytes()

    def test_checksummed_frame_roundtrips(self):
        pkt, buf = self._frame(True)
        assert buf[:4] == b"SEI2"
        assert len(buf) == pkt.nbytes + 8     # pkt built v1: +8 CRC bytes
        out = W.from_bytes(buf)
        assert out.checksum
        assert np.array_equal(out.data, pkt.data)
        assert np.array_equal(out.scales, pkt.scales)
        # SEI2 payload bytes are the SEI1 payload bytes, just re-headed
        v1 = W.to_bytes(pkt)
        head = 6 + 4 * len(pkt.shape)
        assert buf[head + 8:] == v1[head:]

    @pytest.mark.parametrize("checksum", [False, True])
    def test_truncation_at_every_field_boundary(self, checksum):
        """Any prefix of a valid frame raises WireError, never a raw
        struct/IndexError or a garbage parse."""
        _, buf = self._frame(checksum)
        boundaries = {0, 1, 3, 4, 5, 6, 9, 13, 17}   # magic/kind/ndim/dims
        if checksum:
            boundaries |= {18, 21, 25}               # inside the CRC pair
        boundaries |= {len(buf) // 2, len(buf) - 5, len(buf) - 1}
        for cut in sorted(boundaries):
            with pytest.raises(W.WireError):
                W.from_bytes(buf[:cut])

    def test_crc_detects_payload_and_scale_flips(self):
        _, buf = self._frame(True)
        header_end = 6 + 4 * buf[5] + 8
        for off in (header_end, len(buf) - 2):
            bad = bytearray(buf)
            bad[off] ^= 0xFF
            with pytest.raises(W.WireError, match="CRC mismatch"):
                W.from_bytes(bytes(bad))

    def test_unknown_kind_id(self):
        _, buf = self._frame(False)
        bad = bytearray(buf)
        bad[4] = 7
        with pytest.raises(W.WireError, match="kind id 7"):
            W.from_bytes(bytes(bad))

    def test_wire_error_is_value_error_and_magic_msg(self):
        assert issubclass(W.WireError, ValueError)
        with pytest.raises(ValueError, match="magic"):
            W.from_bytes(b"NOPE" + b"\x00" * 16)

    def test_parse_arrays_bounds_checked(self):
        _, buf = self._frame(False)
        with pytest.raises(W.WireError, match="offset"):
            W.parse_arrays(buf[:10])


# ----------------------------------------------------------- fault plan ----
class TestFaultPlan:
    def test_schedule_is_deterministic_and_order_free(self):
        plan = FaultPlan(seed=11, drop_rate=0.3, corrupt_rate=0.2,
                         straggle_rate=0.1)
        sched = plan.transfer_schedule(rid=5, hop=0, n=6)
        # same draw, any order, fresh instance: identical
        again = FaultPlan(seed=11, drop_rate=0.3, corrupt_rate=0.2,
                          straggle_rate=0.1)
        assert sched == tuple(again.transfer_fault(5, 0, a)
                              for a in range(6))
        assert sched == tuple(again.transfer_fault(5, 0, a)
                              for a in reversed(range(6)))[::-1]
        # a different seed moves the schedule
        other = FaultPlan(seed=12, drop_rate=0.3, corrupt_rate=0.2,
                          straggle_rate=0.1)
        assert any(other.transfer_schedule(5, 0, 32)
                   != plan.transfer_schedule(5, 0, 32)
                   for _ in [0])

    def test_max_consecutive_bounds_every_burst(self):
        plan = FaultPlan(seed=0, drop_rate=1.0, stage_fault_rate=1.0,
                         max_consecutive=4)
        assert plan.transfer_fault(0, 0, 4) is None
        assert not plan.stage_fault(0, 0, 4)
        assert plan.transfer_fault(0, 0, 3) == "drop"

    def test_blackout_windows(self):
        plan = FaultPlan(blackouts=((0.1, 0.2), (0.5, 0.6)))
        assert plan.blackout_at(0.15) and not plan.blackout_at(0.3)
        assert plan.blackout_end(0.15) == 0.2
        assert plan.blackout_end(0.3) == 0.3
        with pytest.raises(ValueError, match="empty"):
            FaultPlan(blackouts=((0.2, 0.1),))

    def test_corrupt_bytes_deterministic_and_past_lo(self):
        plan = FaultPlan(seed=3)
        buf = bytes(range(64))
        a = plan.corrupt_bytes(buf, 1, 0, 2, lo=16)
        assert a == plan.corrupt_bytes(buf, 1, 0, 2, lo=16)
        assert a != buf and a[:16] == buf[:16]

    def test_recovery_policy_timeout_tracks_channel_rto(self):
        pol = RecoveryPolicy()
        rto = 2 * (2 * CH.latency_s) + CH.serialization_s(1500) + 1e-6
        assert pol.rto_s(CH) == pytest.approx(rto)
        assert pol.timeout_s(CH, 3000) == pytest.approx(
            rto + CH.serialization_s(3000))
        assert pol.timeout_s(None, 3000) == pol.default_timeout_s

    def test_backoff_caps_and_jitters_deterministically(self):
        pol = RecoveryPolicy(base_backoff_s=0.01, backoff_mult=2.0,
                             backoff_cap_s=0.05, jitter=0.1)
        b = [pol.backoff_s(a, seed=0, rid=0, hop=0) for a in range(8)]
        assert b == [pol.backoff_s(a, seed=0, rid=0, hop=0)
                     for a in range(8)]
        assert all(x <= 0.05 * 1.1 + 1e-12 for x in b)
        assert b[1] > b[0]

    def test_downgrade_ladders(self):
        assert downgrade_ladder("ae8") == ("ae8", "int8", "f32")
        assert downgrade_ladder("int8") == ("int8", "f32")
        assert downgrade_ladder("f32") == ("f32",)


# ------------------------------------------------------------- recovery ----
class TestRecovery:
    def test_drops_retry_to_bit_identical_logits(self, split_setup):
        model, params, x = split_setup
        base = SplitRuntime(model, params, CUT, channel=CH).infer(x, iters=1)
        plan = FaultPlan(seed=7, drop_rate=0.5)
        rt = SplitRuntime(model, params, CUT, channel=CH, faults=plan)
        r = rt.infer(x, iters=1, rid=0)
        rec = r.meta["recovery"]
        assert rec["faults"]["drop"] > 0 and rec["retries"] > 0
        assert not r.meta["degraded"] and not r.meta["local_fallback"]
        # the retried path delivered the SAME payload: logits identical
        assert np.array_equal(base.logits, r.logits)
        # retries are priced: timeouts + backoff pushed transfer_s up
        assert r.transfer_s > base.transfer_s
        assert rec["backoff_s"] > 0

    def test_corruption_detected_then_downgraded(self, split_setup):
        model, params, x = split_setup
        plan = FaultPlan(seed=1, corrupt_rate=0.95, max_consecutive=10)
        rt = SplitRuntime(model, params, CUT, channel=CH, faults=plan,
                          recovery=RecoveryPolicy(downgrade_after=2,
                                                  max_attempts=12))
        r = rt.infer(x, iters=1, rid=0)
        rec = r.meta["recovery"]
        assert rec["faults"]["corrupt"] >= 2
        assert rec["downgrades"] and r.meta["degraded"]
        assert rec["downgrades"][0]["to"] in ("int8", "f32")
        # every corrupted frame was *detected* (logged WireError), and
        # the run still completed with sane logits
        assert all(e["event"] == "corrupt" for e in rec["log"])
        assert np.isfinite(r.logits).all()

    def test_blackout_falls_back_locally(self, split_setup):
        model, params, x = split_setup
        plan = FaultPlan(seed=2, blackouts=((0.0, 1e9),))
        rt = SplitRuntime(model, params, CUT, channel=CH, faults=plan,
                          recovery=RecoveryPolicy(max_attempts=3))
        r = rt.infer(x, iters=1, rid=0)
        assert r.meta["local_fallback"] and r.meta["degraded"]
        assert r.meta["recovery"]["faults"]["blackout"] == 3
        assert not r.hops[0]["delivered"]
        # local fallback skips the codec: logits match the unsplit model
        np.testing.assert_allclose(r.logits, rt.reference(x),
                                   rtol=1e-5, atol=1e-5)

    def test_deadline_budget_escalates(self, split_setup):
        model, params, x = split_setup
        plan = FaultPlan(seed=4, drop_rate=1.0, max_consecutive=100)
        rt = SplitRuntime(model, params, CUT, channel=CH, faults=plan,
                          recovery=RecoveryPolicy(deadline_s=0.05,
                                                  max_attempts=100))
        r = rt.infer(x, iters=1, rid=0)
        assert r.meta["local_fallback"]

    def test_exhaustion_without_fallback_raises_typed(self, split_setup):
        model, params, x = split_setup
        plan = FaultPlan(seed=4, drop_rate=1.0, max_consecutive=100)
        rt = SplitRuntime(model, params, CUT, channel=CH, faults=plan,
                          recovery=RecoveryPolicy(max_attempts=3,
                                                  local_fallback=False))
        with pytest.raises(RecoveryExhausted):
            rt.infer(x, iters=1, rid=0)

    def test_stage_faults_retried_and_priced(self, split_setup):
        model, params, x = split_setup
        plan = FaultPlan(seed=3, stage_fault_rate=0.6, max_consecutive=4)
        rt = SplitRuntime(model, params, CUT, faults=plan)
        r = rt.infer(x, iters=1, rid=1)
        base = SplitRuntime(model, params, CUT).infer(x, iters=1)
        assert r.meta["recovery"]["faults"]["stage"] > 0
        assert np.array_equal(base.logits, r.logits)

    def test_all_requests_complete_under_chaos(self, split_setup):
        """The acceptance bar: 100% completion under mixed faults."""
        model, params, x = split_setup
        plan = FaultPlan(seed=5, drop_rate=0.25, corrupt_rate=0.2,
                         straggle_rate=0.1, stage_fault_rate=0.1,
                         blackouts=((0.02, 0.06),))
        rt = SplitRuntime(model, params, CUT, channel=CH, faults=plan,
                          recovery=RecoveryPolicy(deadline_s=2.0))
        base = SplitRuntime(model, params, CUT, channel=CH).infer(x, iters=1)
        done = 0
        for rid in range(12):
            r = rt.infer(x, iters=1, rid=rid)
            assert np.isfinite(r.logits).all()
            assert r.meta["recovery"]["t_virtual_s"] <= 2.0 + 1.0  # budget+legs
            if not r.meta["degraded"]:
                assert np.array_equal(base.logits, r.logits)
            done += 1
        assert done == 12

    def test_trace_reconciles_with_total(self, split_setup):
        model, params, x = split_setup
        plan = FaultPlan(seed=7, drop_rate=0.5, corrupt_rate=0.2)
        r = SplitRuntime(model, params, CUT, channel=CH,
                         faults=plan).infer(x, iters=1, rid=0)
        assert (r.trace.t1 - r.trace.t0) == pytest.approx(r.total_s,
                                                          rel=1e-9)
        names = [c.name for h in r.hops for c in [] ] # noqa: placeholder
        events = r.hops[0]["events"]
        assert sum(d for _, b, d in events if b == "encode") == \
            pytest.approx(r.encode_s)
        assert sum(d for _, b, d in events if b == "transfer") == \
            pytest.approx(r.transfer_s)

    def test_fault_counters_reach_obs(self, split_setup):
        from repro.obs import Recorder
        model, params, x = split_setup
        rec = Recorder()
        plan = FaultPlan(seed=7, drop_rate=0.5)
        rt = SplitRuntime(model, params, CUT, channel=CH, faults=plan,
                          obs=rec)
        rt.infer(x, iters=1, rid=0)
        rep = rec.report()
        counters = rep.counters()
        assert counters.get("runtime.fault.drop", 0) > 0
        assert counters.get("runtime.retry.attempts", 0) > 0
        assert counters.get("runtime.retry.timeouts", 0) > 0


# ------------------------------------------------------------ tail server ----
class TestTailServerFaults:
    def test_rejects_corrupted_frames(self, split_setup):
        model, params, x = split_setup
        part = make_partition(model, params, CUT, None)
        plan = FaultPlan(seed=0)
        srv = TailServer(part, n_slots=2, client_batch=int(x.shape[0]),
                         faults=plan)
        f = part.head(x)
        good = W.to_bytes(W.encode_activation(f), checksum=True)
        bad = plan.corrupt_bytes(good, 0, 0, 0, lo=6 + 4 * good[5] + 8)
        assert srv.submit(0, good) is True
        assert srv.submit(1, bad) is False
        assert srv.n_rejected == 1 and srv.rejected == [1]
        out = srv.drain()
        assert set(out) == {0}

    def test_blackout_step_serves_nothing(self, split_setup):
        model, params, x = split_setup
        part = make_partition(model, params, CUT, None)
        plan = FaultPlan(blackouts=((1.0, 2.0),))
        srv = TailServer(part, n_slots=2, client_batch=int(x.shape[0]),
                         faults=plan)
        f = part.head(x)
        srv.submit(0, W.to_bytes(W.encode_activation(f)))
        assert srv.step(now=1.5) == {}
        assert srv.n_blackout_steps == 1
        assert set(srv.step(now=2.5)) == {0}


# ---------------------------------------------------- netsim / planner ----
class TestRetryBudget:
    def test_typed_and_contextual(self):
        ch = Channel(latency_s=1e-4, capacity_bps=1e9, interface_bps=1e9,
                     loss_rate=0.999, seed=0)
        with pytest.raises(RetryBudgetExceeded) as ei:
            simulate_tcp(1500 * 4, ch, max_rounds=3)
        assert isinstance(ei.value, RuntimeError)
        assert ei.value.loss_rate == 0.999
        assert ei.value.rounds > 3

    def test_measure_flow_reports_retries(self, vgg_small, toy_data):
        from repro.core.scenarios import Scenario
        from repro.core.split import SplitPlan
        from repro.netsim.simulator import NetworkConfig, measure_flow
        model, params = vgg_small
        sc = Scenario("SC", SplitPlan(CUT))
        lossy = Channel(latency_s=0.002, capacity_bps=100e6,
                        interface_bps=100e6, loss_rate=0.3, seed=1)
        flow = measure_flow(sc, NetworkConfig("tcp", lossy), model, params,
                            16 * 16 * 3 * 4, n_frames=8)
        assert "retries" in flow and len(flow["retries"]) == 8
        assert all(r >= 0 for r in flow["retries"])
        assert any(r > 0 for r in flow["retries"])   # 30% loss resends

    def test_planner_counts_infeasible_legs(self, vgg_small):
        from repro.fleet.planner import DeploymentPlanner, SearchSpace
        from repro.fleet.traffic import DeviceClass, generate_trace
        from repro.models.vgg import feature_index
        model, params = vgg_small
        fi = feature_index(model)
        # a link so lossy every TCP frame blows the retry budget
        dead = Channel(1e-3, 1e6, 1e6, loss_rate=0.995, seed=0)
        dev = DeviceClass.make("mcu", dead)
        planner = DeploymentPlanner(
            model, params, cs_curve=np.linspace(1.0, 0.2, len(fi)),
            layer_idx=fi, accuracy_fn=lambda s, n: 0.9,
            input_bytes=16 * 16 * 3 * 4, n_frames=2)
        legal = set(model.cut_points())
        space = SearchSpace(split_points=tuple(sp for sp in fi
                                               if sp in legal)[:2],
                            protocols=("tcp",), batch_sizes=(1,),
                            replica_counts=(1,), top_k_splits=2,
                            include_rc=False, include_lc=True)
        trace = generate_trace([dev], 50, 50.0, seed=0)
        points = planner.search(trace, [dev], space)   # must not raise
        # infeasible legs were skipped + counted, not a crash, and no
        # point was priced on the budget-blowing leg
        assert planner.n_infeasible_legs > 0
        assert all(np.isfinite(p.p99_s) for p in points)


# ------------------------------------------------------------ controller ----
class TestControllerFaultTrigger:
    def test_runtime_fault_reports_trigger_replan(self):
        from repro.fleet import (AdaptiveController, CandidatePlan,
                                 ControllerConfig, DeviceClass, Phase,
                                 RegimeChangeTrace)
        from repro.serving.engine import BatchCostModel
        cost = BatchCostModel(flops_per_item=1e7, flops_per_s=1e12,
                              fixed_overhead_s=2e-4)
        cands = [CandidatePlan("b1", "SC@3", 3, "tcp", 1, 1, 5e-3, cost),
                 CandidatePlan("b8", "SC@3", 3, "tcp", 8, 1, 5e-3, cost)]
        mix = (DeviceClass.make(
            "edge-embedded", Channel(1e-4, 100e6, 100e6, seed=1)),)
        scenario = RegimeChangeTrace.from_phases(
            mix, [Phase(4.0, 400.0)], seed=7)
        cfg = ControllerConfig(control_period_s=1.0, drift_threshold=None,
                               drop_trigger=None, queue_trigger=None,
                               fault_trigger=3, min_improvement=-10.0,
                               cooldown_s=0.0)
        ctl = AdaptiveController(cands, config=cfg)
        ctl.report_faults(1.5, 5)          # a burst of runtime faults
        res = ctl.run(scenario, initial="b8", engine="vectorized")
        reasons = [s.reason for s in res.switches]
        assert "runtime-fault" in reasons
        # without reports, the same config never triggers
        ctl2 = AdaptiveController(cands, config=cfg)
        res2 = ctl2.run(scenario, initial="b8", engine="vectorized")
        assert all(s.reason != "runtime-fault" for s in res2.switches)


# ------------------------------------------------------------- facade ----
class TestStudyFacade:
    def test_deploy_threads_faults(self, vgg_small, toy_data):
        from repro.api import Study, StudyScenario
        model, params = vgg_small
        xs, ys = toy_data
        study = Study(model, StudyScenario(channel=CH, protocol="tcp"),
                      params=params, data=(xs[:16], ys[:16]))
        plan = FaultPlan(seed=0, drop_rate=0.3)
        pol = RecoveryPolicy(max_attempts=5)
        rt = study.deploy(candidate=f"SC@{CUT}", faults=plan, recovery=pol)
        assert rt.faults is plan and rt.recovery is pol
        r = rt.infer(jnp.asarray(xs[:4]), iters=1, rid=0)
        assert "recovery" in r.meta
