"""``repro.api`` facade tests: the Study chain must agree with the legacy
modules it wraps (saliency -> qos -> netsim), across model families."""
import numpy as np
import pytest

from repro.api import QoSRequirements, SplitCandidate, Study
from repro.api.types import AnalyticCost, CostStack, legal_split_candidates
from repro.core import qos as Q
from repro.core.saliency import candidate_split_points, cumulative_saliency
from repro.core.split import legal_cuts, validate_cut
from repro.netsim.simulator import flow_latency_s, measure_flow

# one entry per repro.configs family the facade must carry end-to-end:
# the paper's CNN, a dense LLM, an RNN-family (RWKV) stack, and enc-dec
CONFIG_NAMES = ["vgg16", "llama3.2-3b", "rwkv6-1.6b", "whisper-tiny"]
QOS = QoSRequirements(max_latency_s=10.0, min_accuracy=0.0)


@pytest.fixture(scope="module", params=CONFIG_NAMES)
def chained_study(request):
    study = Study(request.param, seq_len=16, batch=2, seed=0)
    verdict = study.profile().candidates().simulate().suggest(QOS)
    return request.param, study, verdict


def _legacy_candidates(study):
    """The candidate list computed with the pre-facade modules."""
    cs, li = study.cs_curve, study.layer_idx
    points = candidate_split_points(study.model, cs, li, top_n=3)
    if not points:
        ranked = sorted(legal_split_candidates(study.model, cs, li),
                        key=lambda c: -c.accuracy_proxy)
        points = [c.split_layer for c in ranked[:3]]
    return Q.rank_candidates(cs, li, points)


def _legacy_verdicts(study):
    netcfg = study.scenario.netcfg()
    verdicts = []
    for cand in _legacy_candidates(study):
        scen = cand.scenario(study.scenario.edge, study.scenario.server)
        flow = measure_flow(scen, netcfg, study.model, study.params,
                            study.input_bytes,
                            n_frames=study.scenario.n_frames,
                            sample=study._sample)
        verdicts.append(Q.SimVerdict(cand, flow_latency_s(flow),
                                     cand.accuracy_proxy))
    return verdicts


def test_profile_matches_legacy_saliency(chained_study):
    name, study, _ = chained_study
    cs = cumulative_saliency(study.model, study.params, study._x,
                             study._labels, layer_idx=study.layer_idx)
    np.testing.assert_allclose(np.asarray(study.cs_curve), np.asarray(cs),
                               rtol=1e-6, err_msg=name)


def test_candidates_match_legacy_ranking(chained_study):
    name, study, _ = chained_study
    assert ([c.label for c in study.candidate_list]
            == [c.label for c in _legacy_candidates(study)]), name
    for c in study.split_candidates():
        validate_cut(study.model, c.split_layer)   # all SC cuts are legal


def test_simulate_matches_legacy_flows(chained_study):
    name, study, _ = chained_study
    want = {v.candidate.label: v for v in _legacy_verdicts(study)}
    assert {v.candidate.label for v in study.verdicts} == set(want), name
    for v in study.verdicts:
        w = want[v.candidate.label]
        assert v.latency_s == pytest.approx(w.latency_s, rel=1e-9), name
        assert v.accuracy == pytest.approx(w.accuracy), name


def test_suggest_matches_legacy_choice(chained_study):
    name, study, verdict = chained_study
    legacy = Q.suggest(_legacy_verdicts(study), QOS)
    assert (verdict is None) == (legacy is None), name
    if verdict is not None:
        assert verdict.candidate.label == legacy.candidate.label, name
        assert verdict.latency_s == pytest.approx(legacy.latency_s, rel=1e-9)


def test_chain_is_lazily_cached(chained_study):
    _, study, _ = chained_study
    assert study.cs_curve is study.cs_curve
    assert study.candidate_list is study.candidate_list
    before = study.verdicts
    assert study.suggest(QOS) is study._suggested
    assert study.verdicts is before            # suggest didn't re-simulate


# ------------------------------------------------- vgg measured-accuracy ----
@pytest.fixture(scope="module")
def vgg_study(toy_data_small):
    xs, ys = toy_data_small
    return Study("vgg16", data=(xs, ys), seed=0).profile().candidates()


@pytest.fixture(scope="module")
def toy_data_small():
    from repro.data.synthetic import toy_images
    return toy_images(24, hw=16, seed=3)


def test_vgg_measured_accuracy_matches_simulator(vgg_study):
    """With eval data, Study.simulate measures accuracy through the same
    ApplicationSimulator path the pre-facade scripts used."""
    from repro.netsim.simulator import ApplicationSimulator
    study = vgg_study
    study.simulate()
    netcfg = study.scenario.netcfg()
    for v in study.verdicts:
        cand = v.candidate
        sim = ApplicationSimulator(study.model, study.params, netcfg,
                                   ae=study._ae_map.get(cand.split_layer))
        scen = cand.scenario(study.scenario.edge, study.scenario.server)
        w = sim.simulate(scen, np.asarray(study._x),
                         np.asarray(study._labels),
                         n_frames=study.scenario.n_frames)
        assert v.accuracy == pytest.approx(w.accuracy), cand.label
        assert v.latency_s == pytest.approx(w.latency_s, rel=1e-9), cand.label


def test_vgg_calibrated_simulation_and_deploy(vgg_study):
    """calibrate() switches every SC/RC cell to measured costs uniformly,
    and deploy() returns a runtime equivalent to the unsplit model."""
    study = vgg_study
    study.calibrate(iters=1)
    study.simulate()
    for v in study.verdicts:
        src = v.meta.get("cost_source")
        if v.candidate.kind in ("SC", "RC"):
            assert src == "measured", v.candidate.label
    best = study.suggest(QOS)
    assert best is not None
    cand = study.split_candidates()[0]
    rt = study.deploy(candidate=cand)
    x = np.asarray(study._x[:2])
    res = rt.infer(x, iters=1)
    assert res.split_layer == cand.split_layer
    assert (np.argmax(res.logits, -1)
            == np.argmax(rt.reference(x), -1)).all()


def test_deploy_refuses_uncut_designs(vgg_study):
    with pytest.raises(ValueError, match="nothing to split"):
        vgg_study.deploy(candidate="RC")


# ------------------------------------------------------- the type layer ----
def test_split_candidate_absorbs_legacy_shapes():
    from repro.core.split import SplitPlan
    c = SplitCandidate.from_any(SplitPlan(4, compression=0.25))
    assert (c.label, c.split_layer, c.compression) == ("SC@4", 4, 0.25)
    assert SplitCandidate.from_any(("RC", None)).kind == "RC"
    assert SplitCandidate.from_any("SC@7") == ("SC@7", 7)
    assert SplitCandidate.from_any(3) == ("SC@3", 3)
    # qos.Candidate is the same type, and tuple compatibility holds
    assert Q.Candidate is SplitCandidate
    label, split = SplitCandidate.sc(5, 0.8)
    assert (label, split) == ("SC@5", 5)
    with pytest.raises(ValueError):
        SplitCandidate.from_any(("SC@2", 3))
    with pytest.raises(TypeError):
        SplitCandidate.from_any(object())


def test_legal_split_candidates_single_authority(vgg_small):
    model, _ = vgg_small
    cands = legal_split_candidates(model)
    assert [c.split_layer for c in cands] == legal_cuts(model)
    for c in cands:
        c.validate(model)
    with pytest.raises(ValueError, match="not legal"):
        SplitCandidate.sc(len(model.layers) - 1).validate(model)


def test_cost_stack_prefers_first_source(vgg_small):
    from repro.runtime.calibrate import calibrate
    model, params = vgg_small
    split = model.cut_points()[1]
    table = calibrate(model, params, [split], batch=1, iters=1,
                      include_lc=False, include_rc=False)
    analytic = AnalyticCost(model, params, input_bytes=16 * 16 * 3 * 4)
    stack = CostStack([table, analytic])
    assert stack.flow_times("SC", split)["cost_source"] == "measured"
    other = model.cut_points()[2]
    assert stack.flow_times("SC", other)["cost_source"] == "analytic"
    assert stack.server_cost(split, analytic.server).flops_per_item > 0


def test_split_candidate_hash_consistent_with_eq():
    """Regression: identity is the design point, so candidates differing
    only in annotations dedupe in sets/dicts (and equality stays
    transitive with the tuple form)."""
    a, b = SplitCandidate.sc(4, accuracy_proxy=0.9), SplitCandidate.sc(4, 0.1)
    assert a == b and hash(a) == hash(b)
    assert a == ("SC@4", 4) and b == ("SC@4", 4)    # transitivity closes
    assert len({a, b}) == 1
    assert len({a, ("SC@4", 4)}) == 1
    assert {a: "x"}[("SC@4", 4)] == "x"             # tuple-keyed lookup
    assert SplitCandidate.sc(4) != SplitCandidate.sc(5)
    assert SplitCandidate.rc() != SplitCandidate.lc()
    # multi-cut candidates hash/dedupe the same way
    m1, m2 = SplitCandidate.sc((2, 5), 0.8), SplitCandidate.sc((2, 5), 0.2)
    assert m1 == m2 and len({m1, m2}) == 1
    assert m1 != SplitCandidate.sc((2, 6))
    assert m1 != SplitCandidate.sc(2)


def test_split_candidate_multicut_forms():
    c = SplitCandidate.sc((3, 7, 11))
    assert c.label == "SC@3+7+11" and c.splits == (3, 7, 11)
    assert c.split_layer == 3                       # scalar = first cut
    assert c.kind == "SC"
    assert SplitCandidate.from_any("SC@3+7+11") == c
    assert SplitCandidate.from_any((3, 7, 11)) == c
    assert SplitCandidate.from_any(c.plan()) == c
    plan = c.plan()
    assert plan.splits == (3, 7, 11) and plan.n_stages == 4
    # the 1-cut shape is untouched
    one = SplitCandidate.sc(3)
    assert one.label == "SC@3" and one.splits == (3,)
    assert tuple(one) == ("SC@3", 3)


def test_planner_removed_cost_source_rejected(vgg_small):
    """The deprecated cost_source=/calibration= pair was removed after
    its cycle; passing it is now a plain TypeError and the ``cost=``
    spelling stays warning-free."""
    from repro.fleet.planner import DeploymentPlanner
    from repro.runtime.calibrate import calibrate
    model, params = vgg_small
    split = model.cut_points()[1]
    table = calibrate(model, params, [split], batch=1, iters=1)
    cuts = model.cut_points()
    kw = dict(cs_curve=np.linspace(1.0, 0.3, len(cuts)), layer_idx=cuts,
              accuracy_fn=lambda s, n: 0.9, input_bytes=3072)
    with pytest.raises(TypeError):
        DeploymentPlanner(model, params, cost_source="measured",
                          calibration=table, **kw)
    with pytest.raises(TypeError):
        DeploymentPlanner(model, params, cost_source="analytic", **kw)
    # the repro.api spelling stays silent
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        planner = DeploymentPlanner(model, params, cost=table, **kw)
    assert planner.cost is not None


def test_measure_flow_deprecated_calibration_warns(vgg_small):
    from repro.core.scenarios import Scenario
    from repro.core.split import SplitPlan
    from repro.netsim.channel import Channel
    from repro.netsim.simulator import NetworkConfig
    from repro.runtime.calibrate import calibrate
    model, params = vgg_small
    split = model.cut_points()[1]
    table = calibrate(model, params, [split], batch=1, iters=1)
    netcfg = NetworkConfig("tcp", Channel(1e-3, 100e6, 100e6, seed=0))
    with pytest.warns(DeprecationWarning, match="calibration"):
        measure_flow(Scenario("SC", SplitPlan(split)), netcfg, model,
                     params, 3072, calibration=table)


def test_measure_flow_cost_equals_deprecated_calibration(vgg_small):
    from repro.core.scenarios import Scenario
    from repro.core.split import SplitPlan
    from repro.netsim.channel import Channel
    from repro.netsim.simulator import NetworkConfig
    from repro.runtime.calibrate import calibrate
    model, params = vgg_small
    split = model.cut_points()[1]
    table = calibrate(model, params, [split], batch=1, iters=1)
    netcfg = NetworkConfig("tcp", Channel(1e-3, 100e6, 100e6, seed=0))
    sc = Scenario("SC", SplitPlan(split))
    new = measure_flow(sc, netcfg, model, params, 16 * 16 * 3 * 4,
                       cost=table)
    with pytest.warns(DeprecationWarning):
        old = measure_flow(sc, netcfg, model, params, 16 * 16 * 3 * 4,
                           calibration=table)
    assert new["edge_s"] == old["edge_s"]
    assert new["server_s"] == old["server_s"]
    assert new["wire_bytes"] == old["wire_bytes"]
    assert new["cost_source"] == old["cost_source"] == "measured"
