"""Continuous batching must produce exactly the same tokens as serving each
request alone (greedy decode is deterministic)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.common import reduced
from repro.serving.continuous import ContinuousBatcher, StreamRequest
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(reduced(get_config("llama3-8b"), n_layers=2),
                              dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _single_reference(cfg, params, prompt, max_new):
    """Greedy decode one request via the static engine."""
    eng = ServingEngine(cfg, params, cache_slots=128)
    [req] = eng.run([Request(rid=0, prompt=prompt, max_new=max_new)])
    return req.out


def test_matches_single_request_decode(model):
    cfg, params = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (8, 12, 5)]
    want = [_single_reference(cfg, params, p, 6) for p in prompts]

    batcher = ContinuousBatcher(cfg, params, n_slots=2, cache_len=128)
    reqs = [StreamRequest(rid=i, prompt=p, max_new=6, arrival=i * 2)
            for i, p in enumerate(prompts)]
    done = batcher.run(reqs)
    assert len(done) == 3
    by_id = {r.rid: r.out for r in done}
    for i, w in enumerate(want):
        assert by_id[i] == w, (i, by_id[i], w)


def test_staggered_arrivals_fill_slots(model):
    cfg, params = model
    rng = np.random.default_rng(1)
    reqs = [StreamRequest(rid=i, prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                          max_new=4, arrival=i) for i in range(5)]
    batcher = ContinuousBatcher(cfg, params, n_slots=2, cache_len=64)
    done = batcher.run(reqs)
    assert len(done) == 5
    assert all(len(r.out) == 4 for r in done)
