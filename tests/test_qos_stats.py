"""QoS ranking/suggestion + model statistics (paper §IV outputs, §V-D)."""
import numpy as np
import pytest

from repro.core.qos import (Candidate, QoSRequirements, SimVerdict, pareto,
                            pareto_nd, rank_candidates, suggest)
from repro.core import stats as S


def _v(label, lat, acc):
    return SimVerdict(Candidate(label), lat, acc)


def test_suggest_picks_best_feasible():
    qos = QoSRequirements(max_latency_s=0.05, min_accuracy=0.7)
    vs = [_v("SC@15", 0.02, 0.85), _v("SC@11", 0.08, 0.90),
          _v("RC", 0.12, 0.92), _v("LC", 0.01, 0.60)]
    best = suggest(vs, qos)
    assert best.candidate.label == "SC@15"


def test_suggest_none_when_infeasible():
    qos = QoSRequirements(max_latency_s=0.001, min_accuracy=0.99)
    assert suggest([_v("RC", 0.1, 0.9)], qos) is None


def test_rank_candidates_order():
    cs = np.array([0.1, 0.9, 0.4, 0.7])
    ranked = rank_candidates(cs, [2, 5, 8, 11], [5, 11, 8])
    sc = [c for c in ranked if c.label.startswith("SC")]
    assert [c.split_layer for c in sc] == [5, 11, 8]
    assert ranked[0].label == "RC" and ranked[-1].label == "LC"


def test_rank_candidates_missing_split_point_raises():
    with pytest.raises(ValueError, match="no CS value"):
        rank_candidates(np.array([0.1, 0.9]), [2, 5], [5, 7])


def test_pareto_front():
    vs = [_v("a", 0.01, 0.5), _v("b", 0.02, 0.9), _v("c", 0.03, 0.8),
          _v("d", 0.05, 0.9)]
    front = [v.candidate.label for v in pareto(vs)]
    assert front == ["a", "b"]


def test_pareto_nd_three_objectives():
    items = [("a", (1.0, -0.9, 5.0)),    # fast, accurate, expensive
             ("b", (2.0, -0.9, 1.0)),    # slower, as accurate, cheap
             ("c", (2.0, -0.8, 2.0)),    # dominated by b
             ("d", (1.0, -0.9, 5.0))]    # duplicate of a: both survive
    keep = {p for p, _ in pareto_nd(items)}
    assert keep == {"a", "b", "d"}


# ------------------------------------------------------------ statistics ----
def test_vgg16_stats_match_paper():
    import jax
    from repro.models.vgg import vgg16
    model = vgg16()
    params = jax.eval_shape(lambda k: model.init(k),
                            jax.ShapeDtypeStruct((2,), np.uint32))
    # eval_shape gives shape-only params; summary only needs shapes
    params = model.init(jax.random.PRNGKey(0))
    t = S.totals(model, params, batch=16)
    assert t["total_params"] == 138_357_544            # Table II exact
    assert abs(t["mult_adds_G"] - 247.74) / 247.74 < 0.02
    assert abs(t["fwd_bwd_MB"] - 1735.26) / 1735.26 < 0.05


def test_summary_rows(vgg_small):
    model, params = vgg_small
    rows = S.summary(model, params, batch=4)
    assert len(rows) == len(model.layers)
    assert all(r.output_shape[0] == 4 for r in rows)
    assert S.format_table(rows)


def test_flops_split_partition(vgg_small):
    model, params = vgg_small
    total = sum(r.mult_adds for r in S.summary(model, params, 1)) * 2
    for cut in model.cut_points()[::6]:
        h, t = S.flops_split(model, params, cut, batch=1)
        assert h + t == total


def test_hil_platform_measures_real_time(vgg_small):
    """Paper §IV hardware-in-the-loop: measured segment time replaces the
    analytic model."""
    import jax
    import jax.numpy as jnp
    from repro.core.scenarios import HILPlatform
    model, params = vgg_small
    hil = HILPlatform("host-cpu")
    fwd = jax.jit(lambda x: model.apply(params, x))
    x = jnp.ones((4, 16, 16, 3))
    t = hil.measure("head", fwd, x)
    assert t > 0
    assert hil.compute_time(1e9, key="head") == t        # measured wins
    assert hil.compute_time(1e9, key="other") == 1e9 / hil.flops_per_s
