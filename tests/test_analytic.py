"""The planner fast path (``netsim.analytic``): closed-form transfer and
pipeline makespans vs the event engine, the two-phase screen/refine
contract of ``plan_tiers`` / ``DeploymentPlanner.search``, and the cached
stats surfaces the screen is built on."""
import math
import warnings

import numpy as np
import pytest

from repro.core import stats as S
from repro.core.scenarios import cut_payload_bytes_lut
from repro.core.split import SplitPlan, hop_payload_bytes, legal_cut_lists
from repro.fleet.planner import Tier, TierTopology, plan_tiers
from repro.netsim import analytic
from repro.netsim.channel import Channel
from repro.netsim.protocols import simulate_tcp, simulate_udp
from repro.netsim.simulator import (NetworkConfig, NetworkPath,
                                    simulate_pipeline)

REL = 1e-9
# link-bound (negligible RTT), ack-bound (RTT dominates a window), and a
# mid WAN profile
CHANNELS = [(1e-6, 1e9), (5e-2, 1e9), (1e-3, 20e6)]
# around the packet and window boundaries (window=32 -> 48000 B of MTUs)
SIZES = [0, 1, 1499, 1500, 1501, 32 * 1500, 32 * 1500 + 1, 300_000]


def _cfg(proto, lat, bps, seed=0, loss=0.0):
    return NetworkConfig(proto, Channel(lat, bps, bps, loss_rate=loss,
                                        seed=seed))


def _isclose(a, b):
    return math.isclose(a, b, rel_tol=REL, abs_tol=1e-15)


# ------------------------------------------------- transfer closed form ----
@pytest.mark.parametrize("proto", ["tcp", "udp"])
def test_transfer_closed_form_matches_event_engine(proto):
    sim = simulate_tcp if proto == "tcp" else simulate_udp
    for lat, bps in CHANNELS:
        ch = Channel(lat, bps, bps, seed=3)
        pp = analytic.path_params(NetworkPath((_cfg(proto, lat, bps),)))
        for n in SIZES:
            cf = float(analytic.transfer_duration_s(np.array([n]), pp)[0])
            ev = sim(n, ch).duration_s
            assert _isclose(cf, ev), (proto, lat, bps, n, cf, ev)


def test_transfer_closed_form_is_vectorized():
    """(n_combos, n_hops) tensors price hop-by-hop like the scalar
    event-engine calls, per-hop protocol/channel respected."""
    tcp_ch = Channel(1e-3, 20e6, 20e6)
    udp_ch = Channel(1e-4, 1e9, 1e9, seed=1)
    pp = analytic.path_params(NetworkPath((NetworkConfig("tcp", tcp_ch),
                                           NetworkConfig("udp", udp_ch))))
    bytes_ = np.array([[10_000, 50_000], [0, 1500]])
    out = analytic.transfer_duration_s(bytes_, pp)
    assert out.shape == (2, 2)
    for i in range(2):
        assert _isclose(out[i, 0],
                        simulate_tcp(int(bytes_[i, 0]), tcp_ch).duration_s)
        assert _isclose(out[i, 1],
                        simulate_udp(int(bytes_[i, 1]), udp_ch).duration_s)


def test_path_params_exact_flag_and_unknown_protocol():
    clean = NetworkPath((_cfg("tcp", 1e-3, 20e6),))
    lossy = NetworkPath((_cfg("tcp", 1e-3, 20e6, loss=0.1),))
    assert analytic.path_params(clean).exact
    assert not analytic.path_params(lossy).exact
    with pytest.raises(ValueError, match="unknown protocol"):
        analytic.path_params(NetworkPath((NetworkConfig(
            "quic", Channel(1e-3, 1e9, 1e9)),)))


# ------------------------------------------------- pipeline closed form ----
def _random_case(rng):
    K = int(rng.integers(1, 4))
    hops = tuple(_cfg(str(rng.choice(["tcp", "udp"])),
                      float(rng.choice([1e-6, 1e-4, 1e-3, 1e-2])),
                      float(rng.choice([1e6, 20e6, 1e9])), seed=k)
                 for k in range(K))
    stage_s = [float(rng.choice([0.0, 1e-4, 2e-3, 5e-2]))
               for _ in range(K + 1)]
    hop_bytes = [int(rng.choice([0, 1, 1500, 20_000, 300_000]))
                 for _ in range(K)]
    return NetworkPath(hops), stage_s, hop_bytes


def test_pipeline_closed_form_matches_event_engine_sweep():
    """Deterministic sweep incl. n_micro=1, zero-byte hops and
    pass-through (zero-time) stages — the hypothesis test widens this."""
    rng = np.random.default_rng(7)
    for trial in range(40):
        path, stage_s, hop_bytes = _random_case(rng)
        n_micro = int(rng.integers(1, 6))
        pipe = simulate_pipeline(stage_s, hop_bytes, path, n_micro=n_micro,
                                 check_closed_form=True)
        cf_pipe, cf_seq = analytic.closed_form_pipeline(
            stage_s, hop_bytes, path, n_micro=n_micro)
        assert _isclose(cf_pipe, pipe.latency_s)
        assert _isclose(cf_seq, pipe.sequential_s)


def test_closed_form_pipeline_validates_shapes():
    path = NetworkPath((_cfg("tcp", 1e-3, 20e6),))
    with pytest.raises(ValueError, match="stage times"):
        analytic.closed_form_pipeline([1e-3], [1000, 1000], path)
    with pytest.raises(ValueError, match="n_micro"):
        analytic.pipeline_makespan_s(np.zeros((1, 2)), np.zeros((1, 1)),
                                     analytic.path_params(path), n_micro=0)


def test_assert_event_match_raises_on_divergence():
    analytic.assert_event_match("x", 1.0, 1.0 + 1e-12)
    with pytest.raises(AssertionError, match="semantic authority"):
        analytic.assert_event_match("x", 1.0, 1.001)


# --------------------------------------------------- two-phase plan_tiers ----
@pytest.fixture(scope="module")
def topology():
    return TierTopology((
        Tier("device", "mcu", Channel(1e-3, 20e6, 20e6, seed=1)),
        Tier("edge", "edge-accelerator", Channel(1e-3, 30e6, 30e6, seed=2)),
        Tier("cloud", "server-gpu"),
    ))


def test_plan_tiers_default_sweep_is_exhaustive(vgg_small, topology):
    """Acceptance: the default sweep screens every combo (no truncation
    warning) and returns one plan per (cut list, assignment) combo."""
    model, params = vgg_small
    cuts = model.cut_points()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        plans = plan_tiers(model, params, topology, batch=8,
                           cs_curve=np.linspace(1.0, 0.3, len(cuts)),
                           layer_idx=cuts)
    n1, n2 = len(cuts), len(legal_cut_lists(model, 2))
    assert len(plans) == 2 * n1 + n2


def test_plan_tiers_screen_matches_event_engine_on_every_combo(
        vgg_small, topology):
    """The closed-form screen must price *every* combo (not only the
    refined shortlist) identically to the per-combo event engine on this
    loss-free topology."""
    model, params = vgg_small
    plans = plan_tiers(model, params, topology, batch=4, refine=0)
    assert plans and not any(p.refined for p in plans)
    full = topology.path()
    for p in plans:
        path = NetworkPath(full.hops[:p.tier_index[-1]])
        pipe = simulate_pipeline(list(p.stage_s), list(p.hop_bytes), path,
                                 n_micro=4)
        want = min(pipe.latency_s, pipe.sequential_s)
        assert _isclose(p.latency_s, want), p
        assert _isclose(p.sequential_s, pipe.sequential_s), p


def test_plan_tiers_refines_shortlist_and_marks_plans(vgg_small, topology):
    model, params = vgg_small
    plans = plan_tiers(model, params, topology, batch=4, refine=3)
    n_ref = sum(p.refined for p in plans)
    assert 3 <= n_ref < len(plans)
    # refinement on a loss-free path must not change any latency
    screen = plan_tiers(model, params, topology, batch=4, refine=0)
    for a, b in zip(plans, screen):
        assert a.splits == b.splits and a.tier_index == b.tier_index
        assert _isclose(a.latency_s, b.latency_s)


def test_plan_tiers_max_evals_bounds_refinement_not_the_sweep(
        vgg_small, topology):
    """max_evals caps only the exact-refinement stage: all combos are
    still returned, and the warning says what was skipped."""
    model, params = vgg_small
    cuts = model.cut_points()
    with pytest.warns(UserWarning, match="screened all") as rec:
        plans = plan_tiers(model, params, topology, batch=4,
                           cs_curve=np.linspace(1.0, 0.3, len(cuts)),
                           layer_idx=cuts, refine=10, max_evals=2)
    assert "re-priced only 2 plans" in str(rec[0].message)
    n1, n2 = len(cuts), len(legal_cut_lists(model, 2))
    assert len(plans) == 2 * n1 + n2          # the sweep stays exhaustive
    assert sum(p.refined for p in plans) == 2


def test_plan_tiers_lossy_links_repriced_by_event_engine(vgg_small):
    """On lossy links the screen is loss-blind, so refined survivors must
    carry the event engine's (loss-aware) latency."""
    model, params = vgg_small
    topo = TierTopology((
        Tier("device", "mcu", Channel(1e-3, 20e6, 20e6, loss_rate=0.2,
                                      seed=1)),
        Tier("cloud", "server-gpu"),
    ))
    plans = plan_tiers(model, params, topo, batch=4, refine=4)
    refined = [p for p in plans if p.refined]
    assert refined
    for p in refined:
        pipe = simulate_pipeline(list(p.stage_s), list(p.hop_bytes),
                                 NetworkPath(topo.path().hops[:1]),
                                 n_micro=4)
        assert _isclose(p.latency_s, min(pipe.latency_s, pipe.sequential_s))
    # TCP retransmissions under 20% loss must show up in refined prices
    screen = plan_tiers(model, params, topo, batch=4, refine=0)
    by_key = {(p.splits, p.tier_index): p for p in screen}
    assert any(p.latency_s > by_key[(p.splits, p.tier_index)].latency_s
               for p in refined)
    # fixpoint guarantee: the final ordering's head and its whole
    # (latency, -proxy) Pareto front are event-priced, so the QoS winner
    # downstream can never clear the bar on a loss-blind screen price
    from repro.core.qos import QoSRequirements
    from repro.fleet.planner import _pareto2_indices, suggest_tier_plan
    assert plans[0].refined
    assert all(plans[i].refined for i in _pareto2_indices(plans))
    best = suggest_tier_plan(plans, QoSRequirements(10.0, 0.0))
    assert best is not None and best.refined


# ------------------------------------------------- two-phase fleet search ----
def test_search_refine_returns_subset_with_identical_points(vgg_small):
    from repro.fleet import (DeploymentPlanner, DeviceClass, SearchSpace,
                             generate_trace)
    model, params = vgg_small
    from repro.models.vgg import feature_index
    fi = feature_index(model)
    planner = DeploymentPlanner(
        model, params, cs_curve=np.linspace(1.0, 0.2, len(fi)),
        layer_idx=fi, accuracy_fn=lambda s, n: 0.9,
        input_bytes=16 * 16 * 3 * 4, n_frames=4)
    mix = [DeviceClass.make("mcu", Channel(1e-3, 1e6, 1e6, seed=1)),
           DeviceClass.make("edge-embedded",
                            Channel(1e-4, 50e6, 50e6, seed=2))]
    legal = set(model.cut_points())
    space = SearchSpace(split_points=tuple(sp for sp in fi
                                           if sp in legal)[:3],
                        batch_sizes=(1, 4), top_k_splits=3)
    trace = generate_trace(mix, 200, 100.0, seed=5)
    full = planner.search(trace, mix, space)
    fast = planner.search(trace, mix, space, refine=1)
    assert 0 < len(fast) < len(full)
    key = lambda p: (p.device, p.label, p.protocol, p.max_batch,  # noqa: E731
                     p.n_replicas)
    by_key = {key(p): p for p in full}
    for p in fast:
        assert p == by_key[key(p)]            # identical exact evaluation
    # the fastest leg per device survives screening
    for d in ("mcu", "edge-embedded"):
        best = min((p for p in full if p.device == d),
                   key=lambda p: p.p99_s)
        assert any(key(p) == key(best) for p in fast) or best.label == "LC"


# ------------------------------------------------------- cached surfaces ----
def test_summary_rows_cached_per_key(vgg_small):
    model, params = vgg_small
    a = S.summary(model, params, batch=4)
    assert S.summary(model, params, batch=4) is a
    assert S.summary(model, params, batch=8) is not a
    # a params pytree with identical leaf shapes hits the same entry
    clone = [dict(p) if isinstance(p, dict) else p for p in params]
    assert S.summary(model, clone, batch=4) is a


def test_flops_prefix_matches_flops_stages(vgg_small):
    model, params = vgg_small
    cuts = model.cut_points()
    prefix = S.flops_prefix(model, params, batch=2)
    assert prefix.shape == (len(model.layers) + 1,)
    pair = (cuts[1], cuts[3])
    bounds = [0] + [c + 1 for c in pair] + [len(model.layers)]
    want = S.flops_stages(model, params, pair, batch=2)
    got = [float(prefix[b] - prefix[a]) for a, b in zip(bounds, bounds[1:])]
    assert got == pytest.approx(want)


def test_cut_payload_lut_matches_hop_payload_bytes(vgg_small):
    model, params = vgg_small
    lut = cut_payload_bytes_lut(model, params, batch=4, compression=0.5)
    for cut in model.cut_points():
        want = hop_payload_bytes(model, params, SplitPlan(cut), batch=4)[0]
        assert int(lut[cut]) == want


def test_legal_cut_lists_cached(vgg_small):
    model, _ = vgg_small
    assert legal_cut_lists(model, 2) is legal_cut_lists(model, 2)
    assert legal_cut_lists(model, 1) == [(c,) for c in model.cut_points()]
