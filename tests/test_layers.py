"""Unit tests for the transformer primitives."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (apply_rope, attention, decode_attention,
                                 repeat_kv, rmsnorm, rope_tables)


def test_rmsnorm_scale_invariance():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    w = jnp.ones((64,))
    y1, y2 = rmsnorm(x, w), rmsnorm(x * 10.0, w)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_rmsnorm_unit_rms():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    y = rmsnorm(x, jnp.ones((128,)))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_rope_preserves_norm_and_relative_property():
    hd = 64
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 2, hd))
    cos, sin = rope_tables(jnp.arange(8), hd, 10000.0)
    qr = apply_rope(q, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(qr), axis=-1),
                               np.linalg.norm(np.asarray(q), axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 2, hd))
    kr = apply_rope(k, cos, sin)
    dots = np.einsum("bshd,bshd->bsh", np.asarray(qr)[:, :4], np.asarray(kr)[:, 1:5])
    cos2, sin2 = rope_tables(jnp.arange(8) + 100, hd, 10000.0)
    qr2, kr2 = apply_rope(q, cos2, sin2), apply_rope(k, cos2, sin2)
    dots2 = np.einsum("bshd,bshd->bsh", np.asarray(qr2)[:, :4], np.asarray(kr2)[:, 1:5])
    np.testing.assert_allclose(dots, dots2, atol=1e-3)


def test_chunked_attention_matches_plain():
    b, s, h, kh, d = 2, 1024, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    big = attention(q, k, v, causal=True, q_chunk=256, kv_chunk=256)  # chunked
    small = attention(q[:, :512], k[:, :512], v[:, :512], causal=True)  # plain path
    np.testing.assert_allclose(np.asarray(big[:, :512]), np.asarray(small),
                               atol=2e-5)


def test_attention_rows_convex_combination():
    """softmax(QK)V stays inside the convex hull of V rows."""
    b, s, h, d = 1, 128, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    out = attention(q, k, v, causal=True)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4


def test_decode_attention_matches_full():
    b, s, h, kh, d = 2, 16, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    full = attention(q, k, v, causal=True)
    # last query token via decode path
    kv_pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    dec = decode_attention(q[:, -1:], k, v, kv_pos, jnp.full((b,), s - 1))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               atol=1e-5)


def test_repeat_kv():
    k = jnp.arange(2 * 3 * 2 * 4).reshape(2, 3, 2, 4)
    r = repeat_kv(k, 3)
    assert r.shape == (2, 3, 6, 4)
    np.testing.assert_array_equal(np.asarray(r[:, :, 0]), np.asarray(r[:, :, 2]))
    np.testing.assert_array_equal(np.asarray(r[:, :, 3]), np.asarray(r[:, :, 5]))
