"""Live split-execution runtime tests: partition equivalence, wire
round-trips, kernel routing on CPU, multi-client batching, and the
measured-calibration path into the simulators."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bottleneck as B
from repro.core.split import validate_cut
from repro.kernels.bottleneck_compress import (bottleneck_compress_any,
                                               resolve_backend)
from repro.runtime import wire as W
from repro.runtime.calibrate import CalibrationTable, calibrate
from repro.runtime.engine import SplitRuntime, TailServer, run_clients
from repro.runtime.partition import make_partition


# ------------------------------------------------------------- partition ----
def test_split_vs_unsplit_every_legal_cut(vgg_small, toy_data):
    """tail(head(x)) == apply(x) at every legal cut (f32, no codec)."""
    model, params = vgg_small
    xs, _ = toy_data
    x = jnp.asarray(xs[:4])
    full = np.asarray(model.apply(params, x))
    for cut in model.cut_points():
        part = make_partition(model, params, cut)
        y = np.asarray(part.tail(part.head(x)))
        np.testing.assert_allclose(y, full, atol=1e-5,
                                   err_msg=f"cut={cut}")


def test_illegal_cut_raises(vgg_small):
    model, params = vgg_small
    bad = [i for i in range(len(model.layers))
           if i not in model.cut_points()][0]
    with pytest.raises(ValueError, match="not legal"):
        validate_cut(model, bad)
    with pytest.raises(ValueError, match="not legal"):
        make_partition(model, params, len(model.layers) - 1)


def test_boundary_shape_matches_head_output(vgg_small, toy_data):
    model, params = vgg_small
    xs, _ = toy_data
    part = make_partition(model, params, model.cut_points()[3])
    f = part.head(jnp.asarray(xs[:2]))
    assert tuple(f.shape) == part.boundary_shape(batch=2)


# ------------------------------------------------------------------ wire ----
def test_wire_f32_roundtrip_exact():
    f = jnp.asarray(np.random.default_rng(0).standard_normal((3, 5, 8)),
                    jnp.float32)
    f2 = W.roundtrip(f, quantize=False)
    np.testing.assert_array_equal(np.asarray(f2), np.asarray(f))


def test_wire_int8_roundtrip_error_bound():
    """Symmetric int8: per-row error <= amax/(2*127) (+ rounding eps)."""
    rng = np.random.default_rng(1)
    f = jnp.asarray(rng.standard_normal((4, 6, 32)) * 3.0, jnp.float32)
    f2 = np.asarray(W.roundtrip(f, quantize=True))
    err = np.abs(f2 - np.asarray(f)).reshape(-1, 32).max(axis=1)
    amax = np.abs(np.asarray(f)).reshape(-1, 32).max(axis=1)
    bound = amax / (2 * 127.0) + 1e-6
    assert (err <= bound).all(), (err.max(), bound.min())


def test_wire_bytes_self_describing():
    f = jnp.asarray(np.random.default_rng(2).standard_normal((2, 7, 16)),
                    jnp.float32)
    pkt = W.encode_activation(f, quantize=True)
    buf = W.to_bytes(pkt)
    back = W.from_bytes(buf)
    assert back.kind == "int8" and tuple(back.shape) == (2, 7, 16)
    np.testing.assert_array_equal(back.data, pkt.data)
    np.testing.assert_allclose(back.scales, pkt.scales)
    assert pkt.nbytes == len(buf)
    with pytest.raises(ValueError, match="magic"):
        W.from_bytes(b"XXXX" + buf[4:])


def test_wire_ae8_matches_reference_encode_wire():
    """The kernel-routed ae8 path == core.bottleneck.encode_wire."""
    rng = np.random.default_rng(3)
    f = jnp.asarray(rng.standard_normal((6, 48)), jnp.float32)
    ae = B.init_bottleneck(jax.random.PRNGKey(0), (48,), rate=0.5)
    pkt = W.encode_activation(f, ae)
    q_ref, s_ref = B.encode_wire(ae, f)
    np.testing.assert_array_equal(pkt.data, np.asarray(q_ref))
    np.testing.assert_allclose(pkt.scales, np.asarray(s_ref).reshape(-1, 1),
                               rtol=1e-6)
    # decode side: dequant + AE decoder == decode_wire
    f_hat = W.decode_activation(W.from_bytes(W.to_bytes(pkt)), ae)
    np.testing.assert_allclose(np.asarray(f_hat),
                               np.asarray(B.decode_wire(ae, q_ref, s_ref)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------- kernel route ----
def test_kernel_auto_routes_off_tpu():
    assert resolve_backend("auto") in ("kernel", "ref")
    if jax.devices()[0].platform != "tpu":
        assert resolve_backend() == "ref"
    assert resolve_backend("interpret") == "interpret"
    with pytest.raises(ValueError):
        resolve_backend("vulkan")


def test_compress_any_ref_matches_interpret():
    """Pure-JAX route == Pallas interpret route, including padding shapes."""
    rng = np.random.default_rng(4)
    for n, c in [(8, 48), (130, 16)]:          # 130 exercises N-padding
        f = jnp.asarray(rng.standard_normal((n, c)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((c, 24)) * 0.1, jnp.float32)
        b = jnp.zeros((24,), jnp.float32)
        q_r, s_r = bottleneck_compress_any(f, w, b, backend="ref")
        q_i, s_i = bottleneck_compress_any(f, w, b, backend="interpret",
                                           bn=128, bc=512)
        np.testing.assert_allclose(np.asarray(s_r), np.asarray(s_i),
                                   rtol=1e-5)
        # rounding at the .5 boundary may differ by 1 code in fp
        assert np.abs(np.asarray(q_r, np.int32)
                      - np.asarray(q_i, np.int32)).max() <= 1


# ----------------------------------------------------------- end-to-end ----
def test_runtime_f32_wire_is_exact(vgg_small, toy_data):
    model, params = vgg_small
    xs, _ = toy_data
    x = xs[:2]
    cut = model.cut_points()[4]
    rt = SplitRuntime(model, params, cut, quantize=False)
    res = rt.infer(x, iters=1)
    np.testing.assert_allclose(res.logits, rt.reference(x), atol=1e-5)
    assert res.wire_bytes > 0 and res.transfer_s == 0.0
    assert res.total_s >= res.compute_s > 0


def test_runtime_int8_wire_close_and_timed(vgg_small, toy_data):
    from repro.netsim.channel import Channel
    model, params = vgg_small
    xs, _ = toy_data
    x = xs[:2]
    cut = model.cut_points()[2]
    ch = Channel(1e-3, 100e6, 100e6, seed=0)
    rt = SplitRuntime(model, params, cut, channel=ch, quantize=True)
    res = rt.infer(x, iters=1)
    ref = rt.reference(x)
    # int8 wire: small perturbation, same decisions
    assert np.argmax(res.logits, -1).tolist() == np.argmax(ref, -1).tolist()
    rel = np.abs(res.logits - ref).max() / (np.abs(ref).max() + 1e-9)
    assert rel < 0.15, rel
    assert res.transfer_s > 0 and res.meta["n_packets"] >= 1
    # int8 payload beats the f32 payload by ~4x
    raw = SplitRuntime(model, params, cut, quantize=False).infer(x, iters=1)
    assert res.wire_bytes < raw.wire_bytes / 2


def test_total_s_is_transfer_inclusive_and_reconciles(vgg_small, toy_data):
    """Regression pin: ``RuntimeResult.total_s`` includes the netsim-priced
    transfer time (``compute_s + transfer_s``) and reconciles exactly with
    the per-stage/per-hop breakdown and the ``build_infer_spans`` root —
    a dropped ``transfer_s`` would undercount end-to-end latency on every
    slow link."""
    from repro.netsim.channel import Channel
    model, params = vgg_small
    xs, _ = toy_data
    # a slow, high-latency link so transfer dominates unambiguously
    ch = Channel(latency_s=0.05, capacity_bps=1e6, interface_bps=1e6)
    rt = SplitRuntime(model, params, model.cut_points()[2], channel=ch)
    res = rt.infer(xs[:2], iters=1)
    assert res.transfer_s > 0
    assert res.total_s == res.compute_s + res.transfer_s
    assert res.total_s > res.compute_s          # the transfer is in there
    parts = sum(res.stage_s) + sum(h["encode_s"] + h["transfer_s"]
                                   + h["decode_s"] for h in res.hops)
    assert abs(parts - res.total_s) < 1e-12
    assert abs(res.trace.dur - res.total_s) < 1e-9


def test_multi_client_tail_batching(vgg_small, toy_data):
    model, params = vgg_small
    xs, _ = toy_data
    cut = model.cut_points()[3]
    clients = [xs[i:i + 1] for i in range(5)]
    results, server = run_clients(model, params, cut, clients,
                                  n_slots=2, quantize=False)
    assert sorted(results) == list(range(5))
    assert server.n_batches >= 3          # 5 clients through 2 slots
    assert server.n_served == 5
    for cid, x in enumerate(clients):
        ref = np.asarray(model.apply(params, jnp.asarray(x)))
        np.testing.assert_allclose(results[cid], ref, atol=1e-4,
                                   err_msg=f"client {cid}")


def test_tail_server_empty_step(vgg_small):
    model, params = vgg_small
    part = make_partition(model, params, model.cut_points()[0])
    server = TailServer(part, n_slots=2)
    assert server.step() == {} and server.drain() == {}


# ----------------------------------------------------------- calibration ----
@pytest.fixture(scope="module")
def cal_setup():
    from repro.models.vgg import vgg_cifar
    model = vgg_cifar(n_classes=4, input_hw=8, width_mult=0.25)
    params = model.init(jax.random.PRNGKey(1))
    splits = model.cut_points()[:3]
    table = calibrate(model, params, splits, batch=1, iters=1)
    return model, params, splits, table


def test_calibration_table_roundtrip(tmp_path, cal_setup):
    model, params, splits, table = cal_setup
    for sp in splits:
        e = table.lookup("SC", sp)
        assert e.head_s > 0 and e.tail_s > 0 and e.wire_bytes > 0
    assert table.splits() == sorted(splits)
    p = str(tmp_path / "cal.json")
    table.to_json(p)
    back = CalibrationTable.from_json(p)
    assert back.model_name == table.model_name
    assert back.lookup("SC", splits[0]) == table.lookup("SC", splits[0])
    assert back.lookup("RC").server_s > 0
    assert back.lookup("LC").edge_s > 0


def test_measured_flow_uses_calibration(cal_setup):
    from repro.core.scenarios import Scenario
    from repro.core.split import SplitPlan
    from repro.netsim.channel import Channel
    from repro.netsim.simulator import NetworkConfig, measure_flow

    model, params, splits, table = cal_setup
    netcfg = NetworkConfig("tcp", Channel(1e-3, 100e6, 100e6, seed=0))
    sc = Scenario("SC", SplitPlan(splits[1]))
    input_bytes = 8 * 8 * 3 * 4

    flow_a = measure_flow(sc, netcfg, model, params, input_bytes)
    assert flow_a["cost_source"] == "analytic"
    flow_m = measure_flow(sc, netcfg, model, params, input_bytes,
                          calibration=table)
    e = table.lookup("SC", splits[1])
    assert flow_m["cost_source"] == "measured"
    assert flow_m["edge_s"] == pytest.approx(e.edge_s)
    assert flow_m["server_s"] == pytest.approx(e.server_s)
    assert flow_m["wire_bytes"] == e.wire_bytes
    assert len(flow_m["wire_s"]) == 8
    # uncovered cell falls back to analytic
    other = [c for c in model.cut_points() if c not in splits][0]
    flow_f = measure_flow(Scenario("SC", SplitPlan(other)), netcfg, model,
                          params, input_bytes, calibration=table)
    assert flow_f["cost_source"] == "analytic"


def test_measured_flow_rescales_calibration_batch(cal_setup):
    """A table calibrated at batch B serves batch-1 flows at 1/B cost."""
    from repro.core.scenarios import Scenario
    from repro.core.split import SplitPlan
    from repro.netsim.channel import Channel
    from repro.netsim.simulator import NetworkConfig, measure_flow

    model, params, splits, _ = cal_setup
    table2 = calibrate(model, params, splits[:1], batch=2, iters=1)
    e = table2.lookup("SC", splits[0])
    netcfg = NetworkConfig("tcp", Channel(1e-3, 100e6, 100e6, seed=0))
    sc = Scenario("SC", SplitPlan(splits[0]))
    flow1 = measure_flow(sc, netcfg, model, params, 8 * 8 * 3 * 4,
                         calibration=table2, batch=1)
    assert flow1["edge_s"] == pytest.approx(e.edge_s / 2)
    assert flow1["server_s"] == pytest.approx(e.server_s / 2)
    assert flow1["wire_bytes"] == pytest.approx(e.wire_bytes / 2, abs=1)
    flow2 = measure_flow(sc, netcfg, model, params, 8 * 8 * 3 * 4,
                         calibration=table2, batch=2)
    assert flow2["edge_s"] == pytest.approx(e.edge_s)
    assert flow2["wire_bytes"] == e.wire_bytes


def test_planner_measured_cost_source(cal_setup):
    from repro.core.qos import QoSRequirements
    from repro.fleet import (DeviceClass, DeploymentPlanner, SearchSpace,
                             generate_trace)
    from repro.netsim.channel import Channel

    model, params, splits, table = cal_setup

    def accuracy_fn(scenario, netcfg):
        return 0.9

    fi = list(model.cut_points())
    cs = np.linspace(1.0, 0.3, len(fi))
    input_bytes = 8 * 8 * 3 * 4
    planner = DeploymentPlanner(model, params, cs_curve=cs, layer_idx=fi,
                                accuracy_fn=accuracy_fn,
                                input_bytes=input_bytes, cost=table)
    mix = [DeviceClass.make("edge-embedded",
                            Channel(5e-4, 100e6, 100e6, seed=2))]
    trace = generate_trace(mix, 50, 20.0, seed=0)
    space = SearchSpace(split_points=tuple(splits), batch_sizes=(1,),
                        replica_counts=(1,), top_k_splits=2)
    points = planner.search(trace, mix, space)
    assert points
    # the flow the planner cached is the measured one
    flow = planner._flow(mix[0], f"SC@{splits[0]}", splits[0], "tcp")
    assert flow["cost_source"] == "measured"
    plans = planner.suggest(QoSRequirements(10.0, 0.5), (trace, mix),
                            points=points)
    assert plans["edge-embedded"] is not None

    # the deprecated cost_source=/calibration= pair is gone for good
    with pytest.raises(TypeError):
        DeploymentPlanner(model, params, cs_curve=cs, layer_idx=fi,
                          accuracy_fn=accuracy_fn, input_bytes=input_bytes,
                          cost_source="measured", calibration=table)
