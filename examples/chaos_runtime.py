"""Chaos walkthrough: the split runtime surviving a hostile link.

A seeded :class:`~repro.runtime.faults.FaultPlan` injects transfer
drops, frame corruption and a mid-run tail-server blackout into the
live split runtime; the :class:`~repro.runtime.faults.RecoveryPolicy`
answers with RTO-derived timeouts, capped exponential backoff, codec
downgrade and — when the server leg is hopeless — full local fallback.
The contract demonstrated here:

 1. every request completes within its deadline budget — 100%
    completion, no exceptions escape;
 2. retried (non-degraded) requests produce logits *bit-identical* to
    the fault-free run — recovery is invisible to the model;
 3. degraded requests are flagged in ``RuntimeResult.meta`` and priced
    honestly (backoff + timeout seconds land in ``total_s``);
 4. the whole schedule is deterministic: rerunning this script yields
    the same faults, the same retries, the same bytes.

Run:  PYTHONPATH=src python examples/chaos_runtime.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import Channel, Study, StudyScenario
from repro.runtime.faults import FaultPlan, RecoveryPolicy


def main():
    channel = Channel(2e-3, 50e6, 100e6, loss_rate=0.02, seed=2)
    study = Study("vgg16", StudyScenario(edge="edge-embedded",
                                         channel=channel))
    cut = study.model.cut_points()[1]
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal((1, 16, 16, 3)).astype(np.float32)
          for _ in range(8)]

    # fault-free reference: the bit-identity baseline
    clean = study.deploy(candidate=f"SC@{cut}")
    base = [np.asarray(clean.infer(x, iters=1).logits) for x in xs]

    # a hostile link: 35% drops, 25% corrupt frames, and the tail
    # server goes dark for a window mid-run
    plan = FaultPlan(seed=7, drop_rate=0.35, corrupt_rate=0.25,
                     straggle_rate=0.1, straggle_s=0.02,
                     blackouts=((0.05, 0.08),))
    policy = RecoveryPolicy(max_attempts=6, deadline_s=2.0,
                            downgrade_after=2)
    report = study.observe()
    rt = study.deploy(candidate=f"SC@{cut}", faults=plan,
                      recovery=policy)
    done = degraded = identical = 0
    for rid, x in enumerate(xs):
        r = rt.infer(x, iters=1, rid=rid)
        done += 1
        rv = r.meta["recovery"]
        if r.meta["degraded"]:
            degraded += 1
        elif np.array_equal(np.asarray(r.logits), base[rid]):
            identical += 1
        flags = []
        if rv["local_fallback"]:
            flags.append("local-fallback")
        elif r.meta["degraded"]:
            flags.append("degraded")
        print(f"  rid={rid}: {sum(rv['faults'].values())} faults, "
              f"{rv['retries']} retries, "
              f"backoff {rv['backoff_s'] * 1e3:.1f} ms, "
              f"total {r.total_s * 1e3:.1f} ms"
              + (f"  [{','.join(flags)}]" if flags else ""))
    print(f"completion: {done}/{len(xs)} "
          f"({identical} bit-identical to fault-free, {degraded} degraded)")
    assert done == len(xs), "every request must complete"
    assert identical + degraded == done

    counters = {k: v for k, v in report.metrics.snapshot().items()
                if k.startswith(("runtime.fault.", "runtime.retry."))}
    print("telemetry:")
    for k, v in counters.items():
        print(f"  {k} = {v:g}")
    assert counters.get("runtime.retry.attempts", 0) > 0

    # determinism: a fresh runtime under the same plan reproduces the
    # run exactly — logits, fault counts, backoff schedule
    rt2 = study.deploy(candidate=f"SC@{cut}", faults=plan,
                       recovery=policy)
    for rid, x in enumerate(xs):
        a = rt.infer(x, iters=1, rid=rid)
        b = rt2.infer(x, iters=1, rid=rid)
        assert np.array_equal(np.asarray(a.logits), np.asarray(b.logits))
        assert a.meta["recovery"]["faults"] == b.meta["recovery"]["faults"]
    print("determinism: second runtime reproduced the run exactly")
    print("ok")


if __name__ == "__main__":
    main()
