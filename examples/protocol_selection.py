"""Transmission-protocol selection (paper §V-C, Fig. 4).

Sweeps packet-loss rates over TCP and UDP for the RC scenario and prints
the accuracy/latency trade-off the engineer would use to pick a protocol
under the application's QoS.

Run:  PYTHONPATH=src python examples/protocol_selection.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import trained_vgg
from repro.core.qos import QoSRequirements
from repro.core.scenarios import Scenario
from repro.data.synthetic import toy_images
from repro.netsim.channel import Channel
from repro.netsim.simulator import ApplicationSimulator, NetworkConfig


def main():
    model, params = trained_vgg()
    xs, ys = toy_images(128, hw=16, seed=777)
    qos = QoSRequirements(max_latency_s=0.0005, min_accuracy=0.8)
    print(f"QoS: latency <= {qos.max_latency_s * 1e3} ms, accuracy >= {qos.min_accuracy}")
    print(f"{'proto':6s} {'loss':>5s} {'acc':>7s} {'lat ms':>8s}  feasible")
    for proto in ("tcp", "udp"):
        for loss in (0.0, 0.05, 0.1, 0.2, 0.3):
            net = NetworkConfig(proto, Channel(100e-6, 1e9, 1e9,
                                               loss_rate=loss, seed=11))
            sim = ApplicationSimulator(model, params, net)
            v = sim.simulate(Scenario("RC"), xs, ys, n_frames=8)
            ok = v.satisfies(qos)
            print(f"{proto:6s} {loss:5.2f} {v.accuracy:7.3f} "
                  f"{v.latency_s * 1e3:8.3f}  {'YES' if ok else 'no'}")
    print("\nreading: TCP keeps accuracy but blows the latency budget under "
          "loss; UDP keeps latency but loses accuracy — pick per QoS.")


if __name__ == "__main__":
    main()
