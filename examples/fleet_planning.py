"""Fleet-scale deployment planning through ``repro.api``: which splits
for this *population*?

The single-link quickstart answers "which design for one client".  This
one scales the question to a deployment with one Study object:

  1. ``fit`` + ``profile`` + ``candidates``: CS curve and split points,
  2. ``bottlenecks``: AEs for the top CS-ranked cuts,
  3. describe the fleet — three device classes behind different channels —
     and generate a 1000-request diurnal trace over the mix,
  4. ``simulate(fleet=...)``: search split x protocol x batch x replicas
     per device class (accuracy measured by netsim on loss-corrupted
     tensors, queueing by the fleet cluster model),
  5. ``pareto()``: the per-class front over (p99, accuracy, server FLOPs/s),
  6. ``suggest()`` one QoS-feasible plan per class, then jointly validate
     the chosen plans against the mixed trace on shared replicas.

Run:  PYTHONPATH=src python examples/fleet_planning.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import (Channel, DeviceClass, INTERFACES, QoSRequirements,
                       Study, generate_trace, simulate_deployment,
                       toy_image_iter, toy_images)

# Every random draw in this walkthrough is seeded explicitly so the run —
# and any trace artifact exported from it — is bit-reproducible in CI.
SEED_STUDY = 0       # Study params / synthetic sample
SEED_DATA = 55       # toy evaluation images
SEED_AE = 9          # bottleneck AE data stream
SEED_TRACE = 42      # fleet arrival trace (recorded on Trace.seed)


def main():
    print("== 1. model + CS curve ==")
    xs, ys = toy_images(64, hw=16, seed=SEED_DATA)
    lc = Study("vgg16", seed=SEED_STUDY).fit(steps=30)
    study = Study("vgg16", data=(xs[:32], ys[:32]), seed=SEED_STUDY,
                  lc=(lc.model, lc.params)).fit(steps=300)
    print(f"   test accuracy: {study.eval_accuracy():.3f}")
    study.profile().candidates(top_n=3)
    cands = [c.split_layer for c in study.split_candidates()]
    print(f"   candidate split points: {cands}")

    print("== 2. bottleneck AEs for the top cuts ==")
    study.bottlenecks(steps=150, lr=2e-3, cuts=cands[:2],
                      data_iter=toy_image_iter(32, hw=16, seed=SEED_AE))

    print("== 3. the fleet: 3 device classes, 1000-request diurnal trace ==")
    mix = [
        DeviceClass.make("mcu",
                         Channel(2e-3, 10e6, 10e6, loss_rate=0.08, seed=1),
                         weight=2.0),
        DeviceClass.make("edge-embedded",
                         Channel(5e-4, INTERFACES["fast-ethernet"],
                                 INTERFACES["fast-ethernet"],
                                 loss_rate=0.02, seed=2),
                         weight=1.5),
        DeviceClass.make("edge-accelerator",
                         Channel(1e-4, INTERFACES["gigabit"],
                                 INTERFACES["gigabit"], seed=3),
                         weight=1.0),
    ]
    trace = generate_trace(mix, 1000, 400.0, pattern="diurnal",
                           seed=SEED_TRACE)
    assert trace.seed == SEED_TRACE      # provenance rides the Trace
    for d in mix:
        sub = trace.for_device(d.name)
        print(f"   {d.name:18s} {len(sub.requests):4d} requests "
              f"({len(sub.requests) / len(trace.requests):.0%}), "
              f"loss {d.channel.loss_rate:.0%}")
    print(f"   horizon {trace.horizon_s:.2f} s, "
          f"mean rate {trace.mean_rate_hz():.0f} req/s")

    print("== 4. search split x protocol x batch x replicas ==")
    study.simulate(fleet=(trace, mix),
                   protocols=("tcp", "udp"), batch_sizes=(1, 8, 32),
                   replica_counts=(1, 2), top_k_splits=2,
                   include_rc=True, include_lc=True)
    print(f"   evaluated {len(study.plan_points)} deployment options")

    qos = QoSRequirements(max_latency_s=0.05, min_accuracy=0.5)
    print(f"== 5. Pareto front (QoS: p99 <= {qos.max_latency_s * 1e3:.0f} ms, "
          f"accuracy >= {qos.min_accuracy}) ==")
    hdr = (f"   {'device':18s} {'design':7s} {'proto':5s} {'b':>3s} {'r':>2s} "
           f"{'p50 ms':>8s} {'p99 ms':>8s} {'acc':>6s} {'srv GFLOP/s':>12s}  qos")
    print(hdr)
    for p in study.pareto():
        print(f"   {p.device:18s} {p.label:7s} {str(p.protocol):5s} "
              f"{p.max_batch:3d} {p.n_replicas:2d} {p.p50_s * 1e3:8.2f} "
              f"{p.p99_s * 1e3:8.2f} {p.accuracy:6.3f} "
              f"{p.server_flops_per_s / 1e9:12.2f}  "
              f"{'YES' if p.satisfies(qos) else 'no'}")

    print("== 6. suggested per-class plans + joint validation ==")
    plans = study.suggest(qos)
    feasible = 0
    for name, p in plans.items():
        if p is None:
            print(f"   {name:18s} -> no feasible design (relax QoS or "
                  f"change the network)")
        else:
            feasible += 1
            print(f"   {name:18s} -> {p.label} over {p.protocol}, "
                  f"batch {p.max_batch}, {p.n_replicas} replica(s): "
                  f"p99 {p.p99_s * 1e3:.2f} ms, acc {p.accuracy:.3f}")
    report = simulate_deployment(plans, trace, mix, study.planner)
    for (split, b, r, _w), g in sorted(report.items(),
                                       key=lambda kv: str(kv[0])):
        print(f"   shared cluster split={split} batch={b} replicas={r}: "
              f"{g['n_served']} served from {', '.join(g['devices'])} | "
              f"p50 {g['p50_s'] * 1e3:.2f} ms, p99 {g['p99_s'] * 1e3:.2f} ms, "
              f"mean batch {g['mean_batch']:.1f}, "
              f"util {g['utilization']:.0%}, drops {g['drop_fraction']:.1%}")
    print(f"\nFEASIBLE DEPLOYMENTS: {feasible}/{len(mix)} device classes")
    if feasible == 0:
        raise SystemExit("no QoS-feasible deployment found")


if __name__ == "__main__":
    main()
