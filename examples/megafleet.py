"""Capacity-plan a million-client fleet in seconds: screen replica
counts with the vectorized cluster engine, refine the winner exactly,
and export the telemetry to Perfetto.

The fleet stack has two cluster engines behind one interface:

* ``ClusterSim`` (the event engine) — the semantic authority.  One
  Python event per arrival/dispatch/completion: exact, observable, and
  ~10^5 requests/s.
* ``simulate_cluster_vectorized`` — the same admission-queue +
  dynamic-batching + replica dynamics replayed arrival-level in NumPy:
  identical drop decisions and latencies, ~10^7 requests/s.

That 100x gap is what makes this walkthrough possible: a full diurnal
day of a million clients is screened per candidate in well under a
second, then the chosen plan is re-checked against the event engine on
a slice (``check_event_engine=True`` asserts exact drop/batch/served
counts and percentile agreement), so the fast path never gets to be
quietly wrong.

  1. generate a 10^6-request diurnal trace (vectorized thinning),
  2. screen n_replicas in 2..9 with streaming stats (O(histogram)
     memory — no per-request arrays at the megafleet scale),
  3. pick the smallest cluster meeting the QoS (drop <1%, p99 < 60 ms),
  4. refine: re-run a slice through BOTH engines and assert agreement,
  5. re-run the winner under a Recorder: windowed ``fleet.*`` series +
     a Perfetto trace at ``results/megafleet/trace.json``.

Run:  PYTHONPATH=src python examples/megafleet.py [--quick]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.fleet.cluster import ClusterConfig, ClusterSim
from repro.fleet.traffic import diurnal_arrivals
from repro.fleet.vectorized import simulate_cluster_vectorized
from repro.obs import Recorder
from repro.serving.engine import BatchCostModel

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                       "megafleet")
COST = BatchCostModel(flops_per_item=5e9, flops_per_s=60e12,
                      fixed_overhead_s=2e-4)
QOS_DROP, QOS_P99_S = 0.01, 0.060


def _cfg(k: int) -> ClusterConfig:
    return ClusterConfig(n_replicas=k, max_batch=64, batch_window_s=2e-3,
                         queue_limit=8192)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="10^5 clients instead of 10^6 (CI smoke)")
    args = ap.parse_args()
    n = 100_000 if args.quick else 1_000_000

    print("== 1. the fleet ==")
    # mean rate sized so the smallest candidate drowns and the largest
    # coasts: ~1.3x the 3-replica capacity at the diurnal mean
    per_replica = _cfg(1).max_batch / COST.service_time(_cfg(1).max_batch)
    rate = 4.0 * per_replica
    times = diurnal_arrivals(rate, n, np.random.default_rng(42),
                             period_s=max(4.0, n / rate / 2.0), depth=0.8)
    print(f"   {n:,} requests over {times[-1]:.1f} s, mean "
          f"{n / times[-1]:,.0f} req/s (one replica serves "
          f"{per_replica:,.0f} req/s)")

    print("== 2. screen replica counts (vectorized, streaming) ==")
    chosen = None
    for k in range(2, 10):
        stats = simulate_cluster_vectorized(times, COST, _cfg(k),
                                            streaming=True)
        drop, p99 = stats.drop_fraction(), stats.percentile(99.0)
        ok = drop < QOS_DROP and p99 < QOS_P99_S
        print(f"   n_replicas={k}: drop {drop:7.2%}  p99 {p99 * 1e3:7.2f} ms"
              f"  {'<- meets QoS' if ok and chosen is None else ''}")
        if ok and chosen is None:
            chosen = k
    if chosen is None:
        raise SystemExit("no candidate met the QoS — widen the sweep")

    print(f"== 3. refine n_replicas={chosen} against the event engine ==")
    n_slice = min(n, 20_000)
    simulate_cluster_vectorized(times[:n_slice], COST, _cfg(chosen),
                                check_event_engine=True)
    print(f"   {n_slice:,}-request slice: drop/batch/served counts exact, "
          f"percentiles within the 1e-6 contract")

    print("== 4. telemetry run + Perfetto export ==")
    rec = Recorder(window_s=times[-1] / 400.0)
    stats = simulate_cluster_vectorized(times, COST, _cfg(chosen), obs=rec)
    report = rec.report()
    t, depth = report.timeseries("fleet.queue_depth")
    _, util = report.timeseries("fleet.utilization")
    print(f"   served {stats.n_served:,} / {n:,} "
          f"(drop {stats.drop_fraction():.2%}), p99 "
          f"{stats.percentile(99.0) * 1e3:.2f} ms")
    print(f"   windowed series: {len(t)} samples, max queue depth "
          f"{depth.max():.0f}, mean utilization {util.mean():.1%}")
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "trace.json")
    report.to_chrome_trace(path, clock="sim",
                           metadata={"n_requests": n, "seed": 42,
                                     "n_replicas": chosen})
    print(f"   {path} (open in https://ui.perfetto.dev)")

    # sanity for CI: the cheaper-by-one cluster must NOT meet the QoS —
    # the walkthrough demonstrates a real capacity cliff, not headroom
    under = simulate_cluster_vectorized(times, COST, _cfg(chosen - 1),
                                        streaming=True)
    assert (under.drop_fraction() >= QOS_DROP
            or under.percentile(99.0) >= QOS_P99_S)
    print(f"   (n_replicas={chosen - 1} fails the QoS — {chosen} is the "
          f"capacity cliff, not headroom)")


if __name__ == "__main__":
    main()
