"""One telemetry report across the whole pipeline: plan, simulate the
fleet, execute the runtime — then open the trace in Perfetto.

``study.observe()`` arms a recorder; every stage that runs afterwards
records into it:

  1. ``suggest(qos, tiers=...)``: the two-phase tier planner leaves
     ``planner.screen`` / ``planner.refine`` phase spans and combo
     counters,
  2. a fleet ``ClusterSim`` fed a seeded diurnal trace (the same
     recorder via ``report.recorder``) emits per-request lifecycle
     spans — wire -> queue wait -> service — per-replica batch tracks,
     and windowed fleet time series (arrival rate, queue depth,
     utilization, p50/p99),
  3. ``deploy()`` + ``infer``: the live split runtime reconstructs a
     per-stage/per-hop span tree (encode -> transfer -> decode) that
     reconciles exactly to its measured total latency.

Two exports close the loop:

* ``results/obs/trace.json``      — both clocks (open in
  https://ui.perfetto.dev: pid 1 = simulated time, pid 2 = wall time),
* ``results/obs/fleet_trace.json`` — simulated clock only.  Every event
  in it derives from seeded simulation, so the file is bit-reproducible
  run to run: CI uploads it as an artifact and identical inputs must
  yield an identical file.

Run:  PYTHONPATH=src python examples/observability.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import (Channel, DeviceClass, QoSRequirements, Study, Tier,
                       TierTopology, generate_trace)
from repro.fleet.cluster import ClusterConfig, ClusterSim
from repro.serving.engine import BatchCostModel

SEED_STUDY = 0
SEED_TRACE = 42       # recorded on Trace.seed -> reproducible artifact
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "obs")


def main():
    study = Study("vgg16", seed=SEED_STUDY)
    report = study.observe(window_s=0.02)

    print("== 1. tier planning under observation ==")
    topo = TierTopology((
        Tier("edge", "edge-embedded", Channel(1e-3, 20e6, 20e6, seed=1)),
        Tier("cloud", "server-gpu"),
    ))
    best = study.suggest(QoSRequirements(max_latency_s=10.0,
                                         min_accuracy=0.0), tiers=topo)
    print(f"   best plan: cut after layer {best.splits[0]}, "
          f"pipelined {best.latency_s * 1e3:.2f} ms")
    planner_spans = [s for s in report.spans if s.cat == "planner"]
    for s in planner_spans:
        print(f"   span {s.name}: {s.dur * 1e3:.1f} ms  {s.args}")

    print("== 2. fleet simulation on the shared recorder ==")
    mix = [DeviceClass.make("mcu", Channel(2e-3, 10e6, 10e6, seed=1),
                            weight=2.0),
           DeviceClass.make("edge-embedded", Channel(5e-4, 100e6, 100e6,
                                                     seed=2))]
    trace = generate_trace(mix, 400, 300.0, pattern="diurnal",
                           seed=SEED_TRACE)
    print(f"   trace: {len(trace)} requests over {trace.horizon_s:.2f} s "
          f"(seed={trace.seed})")
    cost = BatchCostModel.for_split(study.model, study.params,
                                    best.splits[0], study.scenario.server)
    sim = ClusterSim(cost, ClusterConfig(n_replicas=2, max_batch=8),
                     obs=report.recorder)
    wire_bytes = study.input_bytes
    for r in trace.requests:
        sim.offer(r.rid, r.t_arrival, tx_s=5e-4, tx_bytes=wire_bytes)
    stats = sim.run()
    print(f"   served {len(stats.served)} in {stats.batches} batches, "
          f"p99 {stats.percentile(99) * 1e3:.2f} ms")
    t, depth = report.timeseries("fleet.queue_depth")
    _, util = report.timeseries("fleet.utilization")
    print(f"   windowed series: {len(t)} samples, "
          f"max queue depth {depth.max():.0f}, "
          f"mean utilization {util.mean():.1%}")

    print("== 3. live runtime under observation ==")
    runtime = study.deploy()
    x = np.asarray(study._x[:2])
    result = runtime.infer(x, iters=3)
    root = result.trace
    leaves = [s for s in root.walk() if not s.children and s is not root]
    print(f"   infer {result.total_s * 1e3:.3f} ms == "
          f"{sum(s.dur for s in leaves) * 1e3:.3f} ms over "
          f"{len(leaves)} leaf spans "
          f"({', '.join(c.name for c in root.children)})")

    print("== 4. export ==")
    os.makedirs(OUT_DIR, exist_ok=True)
    both = os.path.join(OUT_DIR, "trace.json")
    sim_only = os.path.join(OUT_DIR, "fleet_trace.json")
    report.to_chrome_trace(both)
    report.to_chrome_trace(sim_only, clock="sim",
                           metadata={"trace_seed": trace.seed,
                                     "study_seed": SEED_STUDY})
    print(f"   {both} (both clocks — open in https://ui.perfetto.dev)")
    print(f"   {sim_only} (simulated clock only, bit-reproducible)")
    print()
    print(report.summary())


if __name__ == "__main__":
    main()
