"""Live split-execution at a planner-suggested cut, end-to-end on CPU —
driven entirely through the ``repro.api`` Study facade.

The full calibrated-planning loop in one script:

 1. ``simulate(fleet=...)`` searches split x protocol x batch x replicas
    and ``suggest`` picks a deployment for an edge device class;
 2. ``deploy()`` *executes* that cut live: head forward, bottleneck int8
    wire (Pallas kernel path, auto-routed to the pure-JAX reference on
    CPU), netsim-priced transfer, tail forward;
 3. ``calibrate()`` turns the runtime's measurements into a
    CalibrationTable; re-running ``simulate`` then prices the same flow
    from measurements, and the two latencies are compared;
 4. five edge clients share one TailServer, batching tail requests
    through the slot pool.

Run:  PYTHONPATH=src python examples/split_runtime.py
"""
import os
import sys
from dataclasses import replace

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import (Channel, DeviceClass, QoSRequirements, Study,
                       StudyScenario, generate_trace, run_clients)


def main():
    channel = Channel(5e-4, 100e6, 100e6, loss_rate=0.02, seed=2)
    study = Study("vgg16", StudyScenario(edge="edge-embedded",
                                         channel=channel))
    model = study.model
    print(f"model: {model.name}, {len(model.layers)} layers, "
          f"legal cuts {model.cut_points()}")

    # --- 1. planner suggests a cut for the edge class ------------------
    device = DeviceClass.make("edge-embedded", channel)
    trace = generate_trace([device], 200, 60.0, seed=0)
    study.profile().candidates(top_n=4)
    study.simulate(fleet=(trace, [device]), include_rc=False,
                   batch_sizes=(1, 8), replica_counts=(1, 2))
    plans = study.suggest(QoSRequirements(max_latency_s=0.2,
                                          min_accuracy=0.1))
    plan = plans[device.name]
    assert plan is not None, "planner found no feasible deployment"
    split = plan.split_layer
    print(f"planner suggests {plan.label} over {plan.protocol} "
          f"(batch={plan.max_batch}, replicas={plan.n_replicas}, "
          f"p99={plan.p99_s * 1e3:.2f} ms) -> executing cut {split}")
    # the simulated-vs-executed comparison below must price the wire over
    # the protocol the runtime actually executes with
    study.scenario = replace(study.scenario, protocol=plan.protocol or "tcp")

    # --- 2. execute the suggested cut live -----------------------------
    rng = np.random.default_rng(0)
    x = rng.standard_normal((1, 16, 16, 3)).astype(np.float32)
    rt = study.deploy(device=device.name)
    res = rt.infer(x, iters=5)
    ref = rt.reference(x)
    agree = (np.argmax(res.logits, -1) == np.argmax(ref, -1)).all()
    print(f"executed: head {res.head_s * 1e3:.3f} ms | wire "
          f"{res.wire_bytes} B / {res.transfer_s * 1e3:.3f} ms | tail "
          f"{res.tail_s * 1e3:.3f} ms | total {res.total_s * 1e3:.3f} ms | "
          f"argmax agrees with unsplit: {agree}")

    # --- 3. calibrate the simulator with the measurements --------------
    def sc_latency(s: Study) -> tuple:
        v = next(v for v in s.verdicts if v.candidate.split_layer == split)
        return v.latency_s, v.meta["cost_source"]

    study.simulate()                       # analytic costs (study link)
    pa, src_a = sc_latency(study)
    study.calibrate(splits=[split], iters=5)
    study.simulate()                       # same link, measured costs
    pm, src_m = sc_latency(study)
    print(f"simulator: {src_m}-cost {pm * 1e3:.3f} ms "
          f"({abs(pm - res.total_s) / res.total_s * 100:.1f}% off executed) "
          f"vs {src_a} {pa * 1e3:.3f} ms "
          f"({abs(pa - res.total_s) / res.total_s * 100:.1f}% off)")

    # --- 4. five clients, one batched tail server ----------------------
    clients = [rng.standard_normal((1, 16, 16, 3)).astype(np.float32)
               for _ in range(5)]
    results, server = run_clients(study.model, study.params, split, clients,
                                  n_slots=2, quantize=True)
    occ = ",".join(map(str, server.occupancy))
    print(f"multi-client: {server.n_served} tail requests in "
          f"{server.n_batches} batched steps (occupancy {occ})")
    assert sorted(results) == list(range(5))
    print("ok")


if __name__ == "__main__":
    main()
