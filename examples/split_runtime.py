"""Live split-execution at a planner-suggested cut, end-to-end on CPU.

The full calibrated-planning loop in one script:

 1. the fleet planner searches split x protocol x batch x replicas and
    suggests a deployment for an edge device class;
 2. the live runtime *executes* that cut: head forward, bottleneck int8
    wire (Pallas kernel path, auto-routed to the pure-JAX reference on
    CPU), netsim-priced transfer, tail forward;
 3. the runtime's measurements become a CalibrationTable, the simulator
    re-costs the same flow with ``cost_source="measured"``, and the two
    latencies are compared;
 4. five edge clients share one TailServer, batching tail requests
    through the slot pool.

Run:  PYTHONPATH=src python examples/split_runtime.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.qos import QoSRequirements
from repro.core.scenarios import Scenario
from repro.core.split import SplitPlan
from repro.fleet import (DeviceClass, DeploymentPlanner, SearchSpace,
                         generate_trace)
from repro.models.vgg import feature_index, vgg_cifar
from repro.netsim.channel import Channel
from repro.netsim.simulator import (NetworkConfig, flow_latency_s,
                                    measure_flow)
from repro.runtime import SplitRuntime, calibrate, run_clients


def main():
    model = vgg_cifar(n_classes=8, input_hw=16, width_mult=0.25)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {model.name}, {len(model.layers)} layers, "
          f"legal cuts {model.cut_points()}")

    # --- 1. planner suggests a cut for the edge class ------------------
    def accuracy_fn(scenario, netcfg):        # analytic proxy (no training)
        base = 0.9 if scenario.kind != "LC" else 0.6
        return base - (netcfg.channel.loss_rate
                       if netcfg.protocol == "udp" else 0.0)

    fi = feature_index(model)
    cs = np.linspace(1.0, 0.3, len(fi))
    device = DeviceClass.make(
        "edge-embedded", Channel(5e-4, 100e6, 100e6, loss_rate=0.02, seed=2))
    planner = DeploymentPlanner(model, params, cs_curve=cs, layer_idx=fi,
                                accuracy_fn=accuracy_fn,
                                input_bytes=16 * 16 * 3 * 4)
    legal = set(model.cut_points())
    sps = tuple(sp for sp in fi if sp in legal)[:4]
    trace = generate_trace([device], 200, 60.0, seed=0)
    plans = planner.suggest(QoSRequirements(max_latency_s=0.2,
                                            min_accuracy=0.5),
                            (trace, [device]),
                            SearchSpace(split_points=sps, include_rc=False))
    plan = plans[device.name]
    assert plan is not None, "planner found no feasible deployment"
    split = plan.split_layer
    print(f"planner suggests {plan.label} over {plan.protocol} "
          f"(batch={plan.max_batch}, replicas={plan.n_replicas}, "
          f"p99={plan.p99_s * 1e3:.2f} ms) -> executing cut {split}")

    # --- 2. execute the suggested cut live -----------------------------
    rng = np.random.default_rng(0)
    x = rng.standard_normal((1, 16, 16, 3)).astype(np.float32)
    rt = SplitRuntime(model, params, split, channel=device.channel,
                      protocol=plan.protocol or "tcp", quantize=True)
    res = rt.infer(x, iters=5)
    ref = rt.reference(x)
    agree = (np.argmax(res.logits, -1) == np.argmax(ref, -1)).all()
    print(f"executed: head {res.head_s * 1e3:.3f} ms | wire "
          f"{res.wire_bytes} B / {res.transfer_s * 1e3:.3f} ms | tail "
          f"{res.tail_s * 1e3:.3f} ms | total {res.total_s * 1e3:.3f} ms | "
          f"argmax agrees with unsplit: {agree}")

    # --- 3. calibrate the simulator with the measurements --------------
    table = calibrate(model, params, [split], x=x, iters=5)
    netcfg = NetworkConfig(plan.protocol or "tcp", device.channel)
    sc = Scenario("SC", SplitPlan(split))
    flow_m = measure_flow(sc, netcfg, model, params, x.nbytes,
                          calibration=table)
    flow_a = measure_flow(sc, netcfg, model, params, x.nbytes)
    pm, pa = flow_latency_s(flow_m), flow_latency_s(flow_a)
    print(f"simulator: measured-cost {pm * 1e3:.3f} ms "
          f"({abs(pm - res.total_s) / res.total_s * 100:.1f}% off executed) "
          f"vs analytic {pa * 1e3:.3f} ms "
          f"({abs(pa - res.total_s) / res.total_s * 100:.1f}% off)")

    # --- 4. five clients, one batched tail server ----------------------
    clients = [rng.standard_normal((1, 16, 16, 3)).astype(np.float32)
               for _ in range(5)]
    results, server = run_clients(model, params, split, clients,
                                  n_slots=2, quantize=True)
    occ = ",".join(map(str, server.occupancy))
    print(f"multi-client: {server.n_served} tail requests in "
          f"{server.n_batches} batched steps (occupancy {occ})")
    assert sorted(results) == list(range(5))
    print("ok")


if __name__ == "__main__":
    main()
