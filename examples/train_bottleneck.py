"""Bottleneck training recipe (paper §III, Eqs. 3-4; §V hyperparams).

Stage 1: train the undercomplete AE alone (L_AE, backbone frozen,
         lr 5e-4, Adam — the paper's 50-epoch recipe at toy scale).
Stage 2: fine-tune everything end-to-end (L_task).
Reports the accuracy of the split model before/after each stage.

Run:  PYTHONPATH=src python examples/train_bottleneck.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_vgg, vgg_test_accuracy
from repro.core import bottleneck as B
from repro.data.synthetic import toy_image_iter, toy_images


def split_acc(model, params, ae, cut):
    xs, ys = toy_images(256, hw=16, seed=777)
    fwd = jax.jit(lambda xb: B.split_forward(model, params, ae, cut, xb))
    return float((np.asarray(fwd(jnp.asarray(xs))).argmax(-1) == ys).mean())


def main():
    model, params = trained_vgg()
    base = vgg_test_accuracy(model, params)
    cut = model.cut_points()[5]
    print(f"backbone accuracy: {base:.3f}; splitting after layer {cut}")

    it = map(lambda t: (jnp.asarray(t[0]), jnp.asarray(t[1])),
             toy_image_iter(32, hw=16, seed=9))

    # random AE: how much does an untrained bottleneck hurt?
    f_shape = jax.eval_shape(
        lambda x: model.apply_range(params, x, 0, cut + 1),
        jax.ShapeDtypeStruct((1, 16, 16, 3), jnp.float32)).shape
    ae0 = B.init_bottleneck(jax.random.PRNGKey(0), f_shape[1:], rate=0.5)
    print(f"split acc, untrained AE:      {split_acc(model, params, ae0, cut):.3f}")

    # stage 1: Eq. 3
    ae, losses = B.train_bottleneck(model, params, cut, it, steps=350, lr=2e-3)
    print(f"stage 1 (L_AE): loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    print(f"split acc, trained AE:        {split_acc(model, params, ae, cut):.3f}")

    # stage 2: Eq. 4
    # Eq. 4 is an MSE-to-target; at toy scale the CE form of L_task is far
    # better conditioned (MSE-to-onehot flattens the logit ranking) — both
    # are implemented, we fine-tune with CE here
    params2, ae2, tlosses = B.finetune(model, params, ae, cut, it,
                                       steps=120, lr=2e-4, loss_kind="ce")
    print(f"stage 2 (L_task): loss {tlosses[0]:.4f} -> {tlosses[-1]:.4f}")
    print(f"split acc, after fine-tune:   {split_acc(model, params2, ae2, cut):.3f}")


if __name__ == "__main__":
    main()
