"""End-to-end serving driver (deliverable b): batched requests against a
small transformer, served in the RC and SC styles.

The LM is trained briefly on the synthetic token stream, then:
  * a batch of prompts is served with the ServingEngine (prefill+decode),
  * the same inference is mapped onto the paper's split execution: the
    first half of the blocks is the "edge" head, the bottleneck compresses
    the residual stream (int8 wire payload via the Pallas-kernel path's
    reference), the netsim prices the transfer.

Run:  PYTHONPATH=src python examples/serve_split.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import token_iter
from repro.kernels import ref as kref
from repro.models.common import reduced
from repro.models.layered import transformer_as_layered
from repro.netsim.channel import Channel
from repro.netsim.protocols import simulate_transfer
from repro.serving.engine import Request, ServingEngine
from repro.training.optimizer import OptConfig
from repro.training.train import init_train_state, make_train_step


def main():
    cfg = reduced(get_config("llama3-8b"), vocab=128, n_layers=4)
    print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

    # --- quick train so generations are non-trivial -------------------
    oc = OptConfig(lr=3e-3)
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg, oc)
    step = jax.jit(make_train_step(cfg, oc))
    it = token_iter(8, 64, cfg.vocab, seed=0)
    for i in range(60):
        b = next(it)
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
    print(f"trained 60 steps, final loss {float(m['loss']):.3f}")

    # --- batched serving ----------------------------------------------
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 12).astype(np.int32),
                    max_new=8) for i in range(4)]
    engine = ServingEngine(cfg, params, cache_slots=64)
    done = engine.run(reqs)
    for r in done:
        print(f"request {r.rid}: prompt[:4]={r.prompt[:4].tolist()} -> {r.out}")

    # --- the same model through the split-computing lens ---------------
    lay = transformer_as_layered(cfg, params)
    cut = lay.cut_points()[len(lay.cut_points()) // 2]
    batch = {"tokens": jnp.asarray(np.stack([r.prompt for r in reqs]))}
    # head forward: embed + first blocks
    x = lay.layers[0].apply({}, batch)
    for l in lay.layers[1:cut + 1]:
        x = l.apply({}, x)
    # bottleneck-compress the wire payload (int8 + per-row scales)
    n, s, d = x.shape
    w = jax.random.normal(jax.random.PRNGKey(1), (d, d // 2)) * 0.05
    q8, scales = kref.bottleneck_compress_ref(x.reshape(n * s, d).astype(jnp.float32),
                                              w, jnp.zeros((d // 2,)))
    wire_bytes = q8.size + scales.size * 4
    raw_bytes = x.size * 2
    print(f"split after block {cut}: wire payload {wire_bytes} B "
          f"(raw residual would be {raw_bytes} B, {raw_bytes / wire_bytes:.1f}x larger)")
    ch = Channel(latency_s=5e-3, capacity_bps=160e6, interface_bps=160e6,
                 loss_rate=0.01, seed=0)  # Wi-Fi edge uplink
    tr = simulate_transfer("tcp", int(wire_bytes), ch)
    tr_raw = simulate_transfer("tcp", int(raw_bytes), ch)
    print(f"Wi-Fi transfer: compressed {tr.duration_s * 1e3:.1f} ms vs "
          f"raw {tr_raw.duration_s * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
