"""Re-plan a fleet deployment live as the workload drifts: the
morning rush ends, the adaptive controller notices, and it down-shifts
the serving plan — beating the *best possible* static deployment.

Static planning (``examples/fleet_planning.py``) answers "which split,
protocol, batch size, and replica count for THIS workload".  But real
workloads move: arrival rates swing, links degrade, replicas fail.  The
adaptive control loop (``fleet.controller``) closes the loop:

  signals ->- detect ->- screen ->- price ->- switch
    ^   windowed fleet   closed     vectorized   drain + warm-up,  |
    |   rate/queue/drop  -form      engine on    hysteresis,       |
    |                    shortlist  the window   bounded switches  |
    +--------------------- next control period -------------------+

The scenario: a 20k req/s rush (only a large serving batch keeps up)
then a calm 1.5k req/s tail (where that batch pays its batching window
on every single request).  A static deployment must pick one plan for
the whole day; the controller detects the rate drift at the phase
boundary, re-screens its candidates on the *observed* window, and
switches — paying an explicit, reported migration cost (requests that
land during warm-up are delayed, never lost).

  1. build the regime-change trace (rush -> calm, seeded),
  2. run the controller on the vectorized engine, then re-run on the
     event engine and assert the switch decisions are identical (the
     cross-engine contract),
  3. run every candidate statically and take the best — the honest
     baseline,
  4. compare p99s, show the switch timeline and migration bill,
  5. export the controller telemetry (``controller.*`` series, replan /
     switch / era spans) to Perfetto at
     ``results/adaptive_replanning/trace.json``.

Run:  PYTHONPATH=src python examples/adaptive_replanning.py [--quick]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.fleet import (AdaptiveController, CandidatePlan,
                         ControllerConfig, DeviceClass, Phase,
                         RegimeChangeTrace)
from repro.netsim.channel import Channel
from repro.obs import Recorder
from repro.serving.engine import BatchCostModel

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                       "adaptive_replanning")
# svc(1) = 0.21 ms, svc(64) = 0.84 ms: the big batch serves ~76k req/s
# but quadruples the calm-weather latency floor
COST = BatchCostModel(flops_per_item=1e7, flops_per_s=1e12,
                      fixed_overhead_s=2e-4)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter scenario (CI smoke)")
    args = ap.parse_args()

    print("== 1. the drifting workload ==")
    phases = ([Phase(1.0, 20_000.0), Phase(4.0, 1_500.0)] if args.quick
              else [Phase(2.0, 50_000.0), Phase(8.0, 2_500.0)])
    mix = (DeviceClass.make("edge-embedded",
                            Channel(1e-4, 100e6, 100e6, seed=1)),)
    scenario = RegimeChangeTrace.from_phases(mix, phases, seed=7)
    for t, ph in zip(scenario.boundaries, phases):
        print(f"   t={t:5.1f} s: {ph.rate_hz:8,.0f} req/s for "
              f"{ph.duration_s:.0f} s")
    print(f"   {len(scenario.trace):,} requests over "
          f"{scenario.horizon_s:.0f} s")

    candidates = [
        CandidatePlan("b1", "SC@3", 3, "tcp", 1, 1, 5e-3, COST),
        CandidatePlan("b8", "SC@3", 3, "tcp", 8, 1, 5e-3, COST),
        CandidatePlan("b64", "SC@3", 3, "tcp", 64, 1, 5e-3, COST),
    ]
    for c in candidates:
        print(f"   candidate {c.key}: serves up to "
              f"{c.capacity_hz():8,.0f} req/s, floor "
              f"{COST.service_time(c.max_batch) * 1e3:.2f} ms")

    print("== 2. the control loop (both engines) ==")
    rec = Recorder()
    cfg = ControllerConfig(control_period_s=0.25, drift_threshold=0.3,
                           min_improvement=0.05, warmup_s=0.02,
                           max_switches=4)
    ctl = AdaptiveController(candidates, config=cfg, obs=rec)
    adaptive = ctl.run(scenario, engine="vectorized")
    check = ctl.run(scenario, engine="event")
    assert check.plan_keys == adaptive.plan_keys
    assert [s.t_s for s in check.switches] == \
        [s.t_s for s in adaptive.switches]
    assert check.migration == adaptive.migration
    print(f"   engines agree: plan sequence {' -> '.join(adaptive.plan_keys)}"
          f" on vectorized AND event")
    for s in adaptive.switches:
        print(f"   t={s.t_s:5.2f} s: {s.from_key} -> {s.to_key} "
              f"({s.reason}; predicted p99 "
              f"{s.predicted_p99_s * 1e3:.2f} ms vs incumbent "
              f"{s.incumbent_p99_s * 1e3:.2f} ms)")

    print("== 3. the honest baseline: best static plan ==")
    static = ctl.best_static(scenario)
    print(f"   best fixed plan is {static.plan_keys[0]}: p99 "
          f"{static.p99_s * 1e3:.2f} ms, drop {static.drop_fraction:.2%}")

    print("== 4. adaptive vs static ==")
    improvement = static.p99_s / adaptive.p99_s
    mig = adaptive.migration
    print(f"   adaptive p99 {adaptive.p99_s * 1e3:.2f} ms "
          f"(drop {adaptive.drop_fraction:.2%}) — {improvement:.2f}x "
          f"better than the best static plan")
    print(f"   migration bill: {mig['n_delayed']} requests delayed "
          f"{mig['added_delay_s'] * 1e3:.0f} ms in total by warm-up "
          f"({adaptive.n_switches} switch(es), bound {cfg.max_switches})")
    assert adaptive.drop_fraction == 0.0
    assert adaptive.n_switches <= cfg.max_switches
    assert improvement > 1.5          # the headline, enforced

    print("== 5. telemetry -> Perfetto ==")
    report = rec.report()
    t, rate = report.timeseries("controller.rate_hz")
    print(f"   {adaptive.n_decisions} control decisions, observed rate "
          f"{rate.min():,.0f}..{rate.max():,.0f} req/s")
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "trace.json")
    report.to_chrome_trace(path, clock="sim",
                           metadata={"seed": 7,
                                     "plan_keys": list(adaptive.plan_keys),
                                     "improvement_x": improvement})
    print(f"   {path} (open in https://ui.perfetto.dev — eras, replans, "
          f"and switches on the sim-clock timeline)")


if __name__ == "__main__":
    main()
