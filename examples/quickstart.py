"""Quickstart: the full Split-Et-Impera design flow in one script.

  1. train a small VGG on the conveyor-belt toy task (paper §V scenario),
  2. compute the Grad-CAM Cumulative Saliency curve (Fig. 1-i),
  3. pick candidate split points at the CS local maxima,
  4. simulate LC / RC / SC over a TCP channel (Fig. 1-ii),
  5. let the QoS matcher suggest the best design (Fig. 1-iii).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from benchmarks.common import trained_vgg, vgg_test_accuracy
from repro.core import bottleneck as B
from repro.core.qos import QoSRequirements, rank_candidates, suggest
from repro.core.saliency import candidate_split_points, cumulative_saliency
from repro.core.scenarios import PLATFORMS, Scenario
from repro.core.split import SplitPlan
from repro.data.synthetic import toy_images
from repro.models.vgg import feature_index
from repro.netsim.channel import Channel
from repro.netsim.simulator import ApplicationSimulator, NetworkConfig


def main():
    print("== 1. train the model (paper §V: Adam, lr 5e-3) ==")
    model, params = trained_vgg(steps=300)
    print(f"   test accuracy: {vgg_test_accuracy(model, params):.3f}")

    print("== 2. cumulative saliency curve ==")
    xs, ys = toy_images(64, hw=16, seed=55)
    fi = feature_index(model)
    cs = cumulative_saliency(model, params, jnp.asarray(xs), jnp.asarray(ys),
                             layer_idx=fi)
    for l, v in zip(fi, cs):
        print(f"   layer {l:2d}: {'#' * int(v * 40)} {v:.3f}")

    print("== 3. candidate split points (CS local maxima) ==")
    cands = candidate_split_points(model, cs, fi, top_n=3)
    if not cands:
        cands = model.cut_points()[5:14:4]
    print("   candidates:", cands)
    ranked = rank_candidates(cs, fi, cands)
    for c in ranked:
        print(f"   {c.label:8s} accuracy proxy {c.accuracy_proxy:.3f}")

    print("== 4. communication-aware simulation (TCP, 1 Gb/s, 2% loss) ==")
    net = NetworkConfig("tcp", Channel(100e-6, 1e9, 1e9, loss_rate=0.02, seed=0))
    verdicts = []
    # LC runs a weaker local model (the whole point of the LC/RC trade-off)
    lc_model, lc_params = trained_vgg(steps=30)
    sim = ApplicationSimulator(model, params, net,
                               lc_model=lc_model, lc_params=lc_params)
    verdicts.append(sim.simulate(Scenario("RC"), xs[:32], ys[:32], n_frames=8))
    verdicts.append(sim.simulate(Scenario("LC"), xs[:32], ys[:32]))
    from repro.data.synthetic import toy_image_iter
    it = map(lambda t: (jnp.asarray(t[0]), jnp.asarray(t[1])),
             toy_image_iter(32, hw=16, seed=9))
    for cut in cands[:2]:
        ae, _ = B.train_bottleneck(model, params, cut, it, steps=150, lr=2e-3)
        sc_sim = ApplicationSimulator(model, params, net, ae=ae)
        verdicts.append(sc_sim.simulate(
            Scenario("SC", SplitPlan(cut), PLATFORMS["edge-accelerator"],
                     PLATFORMS["server-gpu"]), xs[:32], ys[:32], n_frames=8))
    for v in verdicts:
        print(f"   {v.candidate.label:8s} latency {v.latency_s * 1e3:8.2f} ms  "
              f"accuracy {v.accuracy:.3f}  wire {v.meta.get('wire_bytes', 0):>8d} B")

    print("== 5. QoS suggestion (20 FPS, accuracy >= 0.5) ==")
    qos = QoSRequirements(max_latency_s=0.05, min_accuracy=0.5)
    best = suggest(verdicts, qos)
    if best is None:
        print("   no design meets the constraints — relax QoS or change network")
    else:
        print(f"   suggested design: {best.candidate.label} "
              f"({best.latency_s * 1e3:.2f} ms, acc {best.accuracy:.3f})")


if __name__ == "__main__":
    main()
