"""Quickstart: the full Split-Et-Impera design flow through ``repro.api``.

One ``Study`` object carries the whole pipeline (paper Fig. 1):

  1. train a small VGG on the conveyor-belt toy task (paper §V scenario),
  2. compute the Grad-CAM Cumulative Saliency curve (Fig. 1-i),
  3. pick candidate split points at the CS local maxima,
  4. train bottleneck AEs and simulate LC / RC / SC over a TCP channel
     (Fig. 1-ii),
  5. let the QoS matcher suggest the best design (Fig. 1-iii).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import (Channel, NetworkConfig, QoSRequirements, Study,
                       toy_image_iter, toy_images)


def main():
    print("== 1. train the model (paper §V: Adam, lr 5e-3) ==")
    xs, ys = toy_images(64, hw=16, seed=55)
    # LC runs a weaker local model (the whole point of the LC/RC trade-off)
    lc = Study("vgg16").fit(steps=30)
    study = Study("vgg16", data=(xs[:32], ys[:32]),
                  lc=(lc.model, lc.params)).fit(steps=300)
    print(f"   test accuracy: {study.eval_accuracy():.3f}")

    print("== 2. cumulative saliency curve ==")
    study.profile()
    for l, v in zip(study.layer_idx, study.cs_curve):
        print(f"   layer {l:2d}: {'#' * int(v * 40)} {v:.3f}")

    print("== 3. candidate split points (CS local maxima) ==")
    study.candidates(top_n=3)
    for c in study.candidate_list:
        print(f"   {c.label:8s} accuracy proxy {c.accuracy_proxy:.3f}")

    print("== 4. communication-aware simulation (TCP, 1 Gb/s, 2% loss) ==")
    study.bottlenecks(steps=150, lr=2e-3,
                      data_iter=toy_image_iter(32, hw=16, seed=9))
    net = NetworkConfig("tcp", Channel(100e-6, 1e9, 1e9, loss_rate=0.02,
                                       seed=0))
    study.simulate(network=net)
    for v in study.verdicts:
        print(f"   {v.candidate.label:8s} latency {v.latency_s * 1e3:8.2f} ms  "
              f"accuracy {v.accuracy:.3f}  "
              f"wire {v.meta.get('wire_bytes', 0):>8d} B")

    print("== 5. QoS suggestion (20 FPS, accuracy >= 0.5) ==")
    qos = QoSRequirements(max_latency_s=0.05, min_accuracy=0.5)
    best = study.suggest(qos)
    if best is None:
        print("   no design meets the constraints — relax QoS or change network")
    else:
        print(f"   suggested design: {best.candidate.label} "
              f"({best.latency_s * 1e3:.2f} ms, acc {best.accuracy:.3f})")


if __name__ == "__main__":
    main()
