import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Multi-pod split computing (the TPU adaptation, DESIGN.md §3).

The paper's head/bottleneck/tail triple mapped onto a 2-pod mesh: the cut
becomes the cross-pod stage boundary, the bottleneck compresses the
activation crossing the inter-pod link, and `lax.ppermute` is the wire.
Runs on 8 emulated host devices as a (pod=2, data=2, model=2) mesh and
validates the pipelined output against the single-program forward.

Run:  PYTHONPATH=src python examples/multipod_pipeline.py
"""
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core import bottleneck as B
from repro.core.split import multipod_split_step
from repro.models import transformer as T
from repro.models.common import reduced


def main():
    assert len(jax.devices()) >= 8, "needs --xla_force_host_platform_device_count=8"
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
    cfg = reduced(get_config("llama3-8b"), n_layers=4, dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
    batch = {"tokens": tokens}

    # reference: ordinary single-program forward
    out = T.forward(params, cfg, batch)
    ref = np.asarray(T.logits_from_x(params, cfg, out["x"]))

    # 2-stage pipeline without bottleneck: must match exactly
    got = np.asarray(multipod_split_step(params, cfg, batch, mesh,
                                         ae=None, n_micro=4))
    err = np.abs(got - ref).max()
    print(f"pipeline (no bottleneck) vs forward: max err {err:.2e}")
    assert err < 1e-3

    # with a (random) 50% bottleneck on the wire: output degrades gracefully
    ae = B.init_bottleneck(jax.random.PRNGKey(2), (cfg.d_model,), rate=0.5)
    got_ae = np.asarray(multipod_split_step(params, cfg, batch, mesh,
                                            ae=ae, n_micro=4))
    print(f"pipeline with 50% bottleneck: output delta {np.abs(got_ae - ref).mean():.3f} "
          f"(wire payload halved: {cfg.d_model} -> {B.latent_channels(cfg.d_model, 0.5)} ch)")
    print("cross-pod hop carries", B.latent_channels(cfg.d_model, 0.5) * 4,
          "bytes/token instead of", cfg.d_model * 4)


if __name__ == "__main__":
    main()
