"""A planner-suggested 2-cut device -> edge -> cloud pipeline, executed
end-to-end on CPU — driven entirely through the ``repro.api`` facade.

The multi-tier design loop in one script:

 1. ``suggest(qos, tiers=...)`` searches every legal cut list x
    stage->tier assignment over a 3-tier topology, pricing each design
    sequentially *and* as a pipelined microbatch schedule (hop-k
    transfer overlapping stage-k+1 compute);
 2. ``deploy()`` executes the winning cut list live: a 3-stage
    ``SplitRuntime`` whose two wire hops ride the topology's links, with
    per-stage and per-hop wall-clock timing;
 3. the same design is re-simulated over the explicit ``path=`` mode to
    show the pipelined-vs-sequential latency the planner traded on.

Run:  PYTHONPATH=src python examples/multi_tier.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import (Channel, QoSRequirements, Study, Tier, TierTopology)


def main():
    # device -> edge over a bandwidth-bound wireless link, edge -> cloud
    # over a faster wired one
    topo = TierTopology((
        Tier("device", "mcu", Channel(1e-3, 20e6, 20e6, seed=1)),
        Tier("edge", "edge-accelerator", Channel(1e-3, 30e6, 30e6, seed=2)),
        Tier("cloud", "server-gpu"),
    ))
    study = Study("vgg16", batch=16)
    model = study.model
    print(f"model: {model.name}, {len(model.layers)} layers, "
          f"legal cuts {model.cut_points()}")

    # --- 1. search cut-list x tier-assignment --------------------------
    study.profile()
    plan = study.suggest(QoSRequirements(max_latency_s=0.25,
                                         min_accuracy=0.4),
                         tiers=topo, cut_counts=[2])
    assert plan is not None, "planner found no feasible tier plan"
    print(f"planner suggests cuts {plan.splits} on "
          f"{' -> '.join(plan.stage_tiers)}: pipelined "
          f"{plan.latency_s * 1e3:.2f} ms vs sequential "
          f"{plan.sequential_s * 1e3:.2f} ms "
          f"({plan.speedup:.2f}x, {plan.n_micro} microbatches, "
          f"CS proxy {plan.accuracy_proxy:.2f})")
    runners_up = [p for p in study.tier_plans[:4] if p is not plan]
    for p in runners_up[:3]:
        print(f"  also evaluated: cuts {p.splits} on "
              f"{' -> '.join(p.stage_tiers)} "
              f"({p.latency_s * 1e3:.2f} ms)")

    # --- 2. execute the 3-stage pipeline live --------------------------
    rng = np.random.default_rng(0)
    x = rng.standard_normal((2, 16, 16, 3)).astype(np.float32)
    rt = study.deploy()
    res = rt.infer(x, iters=3)
    ref = rt.reference(x)
    agree = (np.argmax(res.logits, -1) == np.argmax(ref, -1)).all()
    print(f"executed {len(res.stage_s)} stages: "
          + " | ".join(f"stage{k} {s * 1e3:.3f} ms"
                       for k, s in enumerate(res.stage_s)))
    for k, hop in enumerate(res.hops):
        print(f"  hop{k} (after cut {hop['cut']}): {hop['bytes']} B, "
              f"transfer {hop['transfer_s'] * 1e3:.3f} ms")
    print(f"total {res.total_s * 1e3:.3f} ms | argmax agrees with "
          f"unsplit: {agree}")

    # --- 3. pipelined vs sequential on the explicit path ---------------
    study.simulate(path=topo.path(), tiers=topo.platforms, top_m=4)
    for v in study.verdicts:
        print(f"simulated {v.candidate.label}: pipelined "
              f"{v.latency_s * 1e3:.2f} ms vs sequential "
              f"{v.meta['sequential_s'] * 1e3:.2f} ms "
              f"({v.meta['speedup']:.2f}x)")
    print("ok")


if __name__ == "__main__":
    main()
