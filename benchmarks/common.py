"""Shared benchmark helpers: a trained small VGG on the toy-conveyor task."""
from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def timed(fn, *args, iters: int = 5, warmup: int = 1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out  # us/call


@functools.lru_cache(maxsize=1)
def trained_vgg(steps: int = 300, hw: int = 16, batch: int = 32, lr: float = 5e-3):
    """Train the reduced VGG on the procedural toy task (paper §V recipe:
    Adam, lr 5e-3).  Cached via checkpoint so benches share one model."""
    from repro.data.synthetic import toy_image_iter, toy_images
    from repro.models.vgg import vgg_cifar
    from repro.training.checkpoint import restore, save
    from repro.training.optimizer import adam_init, adam_update

    model = vgg_cifar(n_classes=8, input_hw=hw, width_mult=0.5)
    params = model.init(jax.random.PRNGKey(0))
    path = os.path.join(RESULTS_DIR, f"vgg_toy_{hw}_{steps}.npz")
    if os.path.exists(path):
        return model, restore(path, params)

    opt = adam_init(params)

    @jax.jit
    def step(params, opt, x, y):
        def lf(p):
            logits = model.apply(p, x)
            lse = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(logits, y[:, None], 1)[:, 0]
            return jnp.mean(lse - gold)
        loss, g = jax.value_and_grad(lf)(params)
        params, opt = adam_update(params, g, opt, lr)
        return params, opt, loss

    it = toy_image_iter(batch, hw=hw, seed=0)
    for i in range(steps):
        xs, ys = next(it)
        params, opt, loss = step(params, opt, jnp.asarray(xs), jnp.asarray(ys))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    save(path, params)
    return model, params


def vgg_test_accuracy(model, params, n: int = 256, hw: int = 16) -> float:
    from repro.data.synthetic import toy_images
    xs, ys = toy_images(n, hw=hw, seed=777)
    logits = model.apply(params, jnp.asarray(xs))
    return float((np.asarray(logits).argmax(-1) == ys).mean())
