"""Fault-tolerance benchmark: recovery correctness, cost, and the
zero-fault contract.

Three seeded scenarios run the live split runtime through the fault
layer (ISSUE 10):

1. **zero-fault** — ``faults=None``: the fast path.  Asserted in-bench:
   the wire bytes are the historical SEI1 layout bit-for-bit (magic,
   header, payload — no CRC pair), and logits match the fused path.
   Any drift here is a wire-format regression, not noise.
2. **chaos** — drops + corruption + stragglers on every request.  The
   acceptance floor asserted in-bench: **100% completion** within the
   deadline budget, and every *non-degraded* request's logits are
   bit-identical to the zero-fault run.
3. **blackout** — the tail server goes dark permanently; every request
   must land on the local-fallback rung.

Fault counts, retry totals, backoff seconds and the virtual recovery
overhead are all deterministic functions of the FaultPlan seed (the
runtime prices timeouts/backoff on the simulated clock), so they gate
on the exact-replay band in ``perf_compare``; wall-clock overhead is
reported, not gated.

  PYTHONPATH=src python -m benchmarks.bench_faults [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.netsim.channel import Channel
from repro.runtime import wire as W
from repro.runtime.engine import SplitRuntime
from repro.runtime.faults import FaultPlan, RecoveryPolicy

from .common import RESULTS_DIR


def _model(quick: bool):
    import jax
    from repro.models.vgg import vgg_cifar
    if quick:
        model = vgg_cifar(n_classes=8, input_hw=16, width_mult=0.25)
        return model, model.init(jax.random.PRNGKey(0))
    from benchmarks.common import trained_vgg
    return trained_vgg()


def _assert_zero_fault_bytes(rt, x):
    """The zero-fault wire is the historical SEI1 frame, byte for byte."""
    import struct

    import jax.numpy as jnp
    f0 = rt.part.stage(0)(jnp.asarray(x))
    pkt = W.encode_activation(f0, rt.part.ae_map.get(rt.part.splits[0]))
    buf = W.to_bytes(pkt)
    head = (W.MAGIC + struct.pack("<BB", W._KINDS.index(pkt.kind), len(pkt.shape))
            + struct.pack(f"<{len(pkt.shape)}I", *pkt.shape))
    want = head + pkt.data.tobytes() + pkt.scales.tobytes()
    if buf != want:
        raise AssertionError(
            f"zero-fault frame drifted from the SEI1 layout "
            f"({len(buf)} vs {len(want)} B)")


def run(fast: bool = False, out_path: str = None) -> list:
    model, params = _model(fast)
    split = model.cut_points()[1]
    n_req = 6 if fast else 16
    ch = Channel(latency_s=2e-3, capacity_bps=50e6, interface_bps=100e6,
                 seed=0)
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal((1,) + tuple(model.input_shape)
                              ).astype(np.float32) for _ in range(n_req)]

    # --- 1. zero-fault: the fast path and its byte contract -------------
    rt0 = SplitRuntime(model, params, split, channel=ch, quantize=True)
    _assert_zero_fault_bytes(rt0, xs[0])
    rt0f = SplitRuntime(model, params, split, channel=ch, quantize=True,
                        fused=True)
    base = []
    clean_total = 0.0
    for x in xs:
        r = rt0.infer(x, iters=1)
        rf = rt0f.infer(x, iters=1)
        if not np.array_equal(r.logits, rf.logits):
            raise AssertionError("zero-fault fused logits diverged")
        base.append(np.asarray(r.logits))
        clean_total += r.total_s

    # --- 2. chaos: drops + corruption + stragglers ----------------------
    plan = FaultPlan(seed=7, drop_rate=0.35, corrupt_rate=0.25,
                     straggle_rate=0.1, straggle_s=0.01)
    pol = RecoveryPolicy(max_attempts=6, deadline_s=5.0, downgrade_after=2)
    rt = SplitRuntime(model, params, split, channel=ch, quantize=True,
                      faults=plan, recovery=pol)
    done = degraded = identical = 0
    faults = {}
    retries = timeouts = downgrades = fallbacks = 0
    backoff_s = chaos_total = 0.0
    for rid, x in enumerate(xs):
        r = rt.infer(x, iters=1, rid=rid)
        done += 1
        chaos_total += r.total_s
        rec = r.meta["recovery"]
        for k, v in rec["faults"].items():
            faults[k] = faults.get(k, 0) + v
        retries += rec["retries"]
        timeouts += rec["timeouts"]
        downgrades += len(rec["downgrades"])
        fallbacks += bool(rec["local_fallback"])
        backoff_s += rec["backoff_s"]
        if r.meta["degraded"]:
            degraded += 1
        elif np.array_equal(np.asarray(r.logits), base[rid]):
            identical += 1
    if done != n_req:
        raise AssertionError(f"completion {done}/{n_req} under chaos")
    if identical + degraded != n_req:
        raise AssertionError(
            f"{n_req - degraded - identical} retried requests diverged "
            f"from the fault-free logits")

    # --- 3. blackout: the server leg is hopeless ------------------------
    black = FaultPlan(seed=1, blackouts=((0.0, 1e9),))
    rtb = SplitRuntime(model, params, split, channel=ch, quantize=True,
                       faults=black,
                       recovery=RecoveryPolicy(max_attempts=3))
    n_fallback = 0
    for rid, x in enumerate(xs):
        r = rtb.infer(x, iters=1, rid=rid)
        if r.meta["local_fallback"]:
            n_fallback += 1
    if n_fallback != n_req:
        raise AssertionError(
            f"blackout: {n_fallback}/{n_req} requests fell back locally")

    report = {
        "quick": fast,
        "model": model.name,
        "split": split,
        "n_requests": n_req,
        "zero_fault": {
            # both asserted above; recorded so the gate notices if the
            # assertions are ever deleted
            "sei1_bit_identical": 1.0,
            "fused_bit_identical": 1.0,
        },
        "chaos": {
            "completion_rate": done / n_req,
            "identical": identical,
            "degraded": degraded,
            "faults": faults,
            "retries": retries,
            "timeouts": timeouts,
            "downgrades": downgrades,
            "local_fallbacks": fallbacks,
            "backoff_s": backoff_s,
            # virtual seconds the recovery machinery added per request
            # (timeout waits + backoff, on the simulated clock)
            "overhead_ms_per_req": (chaos_total - clean_total) / n_req * 1e3,
        },
        "blackout": {
            "fallback_rate": n_fallback / n_req,
        },
    }
    out_path = out_path or os.path.join(RESULTS_DIR, "faults",
                                        "bench_faults.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)

    c = report["chaos"]
    return [
        ("faults.zero_fault.sei1_bit_identical", 0.0, 1.0),
        ("faults.chaos.completion_rate", 0.0, c["completion_rate"]),
        ("faults.chaos.retries", 0.0, c["retries"]),
        ("faults.chaos.downgrades", 0.0, c["downgrades"]),
        ("faults.chaos.backoff_s", 0.0, round(c["backoff_s"], 6)),
        ("faults.chaos.overhead_ms_per_req", 0.0,
         round(c["overhead_ms_per_req"], 3)),
        ("faults.blackout.fallback_rate", 0.0,
         report["blackout"]["fallback_rate"]),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="untrained small model, 6 requests (CI smoke)")
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args()
    for row in run(fast=args.quick, out_path=args.out):
        print(",".join(map(str, row)))
