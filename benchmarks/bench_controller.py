"""Adaptive replanning benchmark: the drift-aware control loop vs the
best fixed plan on a regime-change workload.

The scenario is "the morning rush ends": a high-rate phase where only a
large serving batch keeps up, then a long calm tail where that batch
pays its batching window on every request.  A static deployment must
pick one plan for the whole day; the adaptive controller
(``fleet.controller``) watches windowed fleet signals, detects the
rate drift, re-screens the candidate space, and down-shifts — so its
p99 beats the *best possible* static plan, not a strawman.

Reported per configuration:

* **improvement_x** — best-static p99 over adaptive p99 (the headline);
* adaptive/static p99 and drop fractions, the switch count, and the
  explicit migration disruption (requests delayed by warm-up and the
  total added delay) — adaptation is not free and the cost is surfaced,
  not hidden;
* controller wall time and decisions/second (wall-clock — reported,
  never gated).

The quick configuration enforces the >=1.5x improvement floor
in-process.  Simulated numbers are deterministic given the seed, so the
CI gate pins p99s, drops, switch counts, and migration exactly (0.1%
band); wall-clock rows are excluded from the gate.

  PYTHONPATH=src python -m benchmarks.bench_controller [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.fleet import (AdaptiveController, CandidatePlan,
                         ControllerConfig, DeviceClass, Phase,
                         RegimeChangeTrace)
from repro.netsim.channel import Channel
from repro.serving.engine import BatchCostModel

from .common import RESULTS_DIR

# svc(1) = 0.21 ms (cap ~4.8k/s) ... svc(64) = 0.84 ms (cap ~76k/s):
# the large batch is the only rush survivor, the small batch is 4x
# snappier once the rush is over
COST = BatchCostModel(flops_per_item=1e7, flops_per_s=1e12,
                      fixed_overhead_s=2e-4)
CANDIDATES = [
    CandidatePlan("b1", "SC@3", 3, "tcp", 1, 1, 5e-3, COST),
    CandidatePlan("b8", "SC@3", 3, "tcp", 8, 1, 5e-3, COST),
    CandidatePlan("b64", "SC@3", 3, "tcp", 64, 1, 5e-3, COST),
]
MIX = (DeviceClass.make("edge-embedded",
                        Channel(1e-4, 100e6, 100e6, seed=1)),)
CONFIG = ControllerConfig(control_period_s=0.25, drift_threshold=0.3,
                          min_improvement=0.05, warmup_s=0.02,
                          max_switches=4)
FLOOR_X = 1.5                        # quick-mode acceptance floor


def _scenario(fast: bool) -> RegimeChangeTrace:
    phases = ([Phase(1.0, 20_000.0), Phase(4.0, 1_500.0)] if fast else
              [Phase(2.0, 50_000.0), Phase(8.0, 2_500.0)])
    return RegimeChangeTrace.from_phases(MIX, phases, seed=7)


def run(fast: bool = False, out_path: str = None) -> list:
    scenario = _scenario(fast)
    ctl = AdaptiveController(CANDIDATES, config=CONFIG)

    t0 = time.perf_counter()
    adaptive = ctl.run(scenario, engine="vectorized")
    wall_s = time.perf_counter() - t0
    static = ctl.best_static(scenario)
    improvement = static.p99_s / adaptive.p99_s

    # decision parity: the event engine must reach the identical plan
    # sequence (the controller's cross-engine contract)
    ev = ctl.run(scenario, engine="event")
    if ev.plan_keys != adaptive.plan_keys or \
            [s.t_s for s in ev.switches] != \
            [s.t_s for s in adaptive.switches]:
        raise SystemExit("engines diverged on switch decisions: "
                         f"{adaptive.plan_keys} vs {ev.plan_keys}")

    report = {
        "quick": fast,
        "n_requests": adaptive.n_offered,
        "horizon_s": scenario.horizon_s,
        "adaptive": {
            "p99_ms": adaptive.p99_s * 1e3,
            "p50_ms": adaptive.p50_s * 1e3,
            "drop_fraction": adaptive.drop_fraction,
            "plan_keys": list(adaptive.plan_keys),
            "n_switches": adaptive.n_switches,
            "n_decisions": adaptive.n_decisions,
            "migration": adaptive.migration,
        },
        "static": {
            "p99_ms": static.p99_s * 1e3,
            "drop_fraction": static.drop_fraction,
            "plan": static.plan_keys[0],
        },
        "improvement_x": improvement,
        "engines_agree": True,
        "wall": {
            "controller_s": wall_s,
            "decisions_per_s": adaptive.n_decisions / wall_s,
        },
    }
    rows = [
        ("controller.adaptive_p99_ms", 0.0,
         round(report["adaptive"]["p99_ms"], 4)),
        ("controller.static_p99_ms", 0.0,
         round(report["static"]["p99_ms"], 4)),
        ("controller.improvement_x", 0.0, round(improvement, 2)),
        ("controller.n_switches", 0.0, adaptive.n_switches),
        ("controller.migration_delayed", 0.0,
         adaptive.migration["n_delayed"]),
        ("controller.drop_fraction", 0.0,
         round(adaptive.drop_fraction, 6)),
        ("controller.wall_s", 0.0, round(wall_s, 3)),
    ]

    out_path = out_path or os.path.join(RESULTS_DIR, "controller",
                                        "bench_controller.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)

    if fast and improvement < FLOOR_X:
        raise SystemExit(
            f"adaptive improvement {improvement:.2f}x < {FLOOR_X:.1f}x "
            f"over best static (acceptance floor)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller scenario + the >=1.5x floor (CI smoke)")
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args()
    for row in run(fast=args.quick, out_path=args.out):
        print(",".join(map(str, row)))
