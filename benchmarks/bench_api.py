"""``repro.api`` facade benchmark: Study-pipeline overhead vs calling the
legacy modules directly.

The facade's promise is zero-cost: the Study chain dispatches to exactly
the functions a hand-stitched script would call (saliency -> ranking ->
measure_flow -> suggest), so its orchestration overhead must stay under
5% — gated via ``perf_compare gate --kind api`` against
``benchmarks/baselines/bench_api_quick.json``.

Writes a JSON artifact (results/api/bench_api.json) for CI upload.

  PYTHONPATH=src python -m benchmarks.bench_api [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.api import QoSRequirements, Study
from repro.api.types import legal_split_candidates
from repro.core import qos as Q
from repro.core.saliency import candidate_split_points, cumulative_saliency
from repro.models.vgg import vgg_cifar
from repro.netsim.simulator import flow_latency_s, measure_flow

from .common import RESULTS_DIR

QOS = QoSRequirements(max_latency_s=10.0, min_accuracy=0.0)


def _study_pipeline(model, params, x, labels):
    study = Study(model, params=params, seed=0)
    study._x, study._labels = x, labels         # identical profiling input
    return study.profile().candidates().simulate().suggest(QOS)


def _direct_pipeline(model, params, x, labels, scenario):
    """The same design flow, hand-stitched from the legacy modules."""
    from repro.models.vgg import feature_index
    li = feature_index(model)
    cs = cumulative_saliency(model, params, x, labels, layer_idx=li)
    points = candidate_split_points(model, cs, li, top_n=3)
    if not points:
        ranked = sorted(legal_split_candidates(model, cs, li),
                        key=lambda c: -c.accuracy_proxy)
        points = [c.split_layer for c in ranked[:3]]
    cands = Q.rank_candidates(cs, li, points)
    netcfg = scenario.netcfg()
    input_bytes = int(np.prod(x.shape[1:])) * 4
    verdicts = []
    for cand in cands:
        scen = cand.scenario(scenario.edge, scenario.server)
        flow = measure_flow(scen, netcfg, model, params, input_bytes,
                            n_frames=scenario.n_frames)
        verdicts.append(Q.SimVerdict(cand, flow_latency_s(flow),
                                     cand.accuracy_proxy))
    return Q.suggest(verdicts, QOS)


def _paired_ratio(fa, fb, iters: int) -> tuple:
    """(ratio a/b, best a, best b) over one window of interleaved runs.

    Process CPU time, not wall clock: the facade's cost is pure Python
    orchestration, and CPU time is blind to the other tenants of a
    shared runner.  Within the window, two aggregate estimators are both
    consistent for the true ratio — total-time ratio (load amortises
    over the horizon) and best-of-iters ratio (both mins converge to the
    unloaded cost) — and their min discards the residual same-process
    noise (GC, XLA thread scheduling) that inflates one of them.
    """
    tas, tbs = [], []
    for _ in range(iters):
        t0 = time.process_time()
        fa()
        tas.append(time.process_time() - t0)
        t0 = time.process_time()
        fb()
        tbs.append(time.process_time() - t0)
    ratio = min(sum(tas) / sum(tbs), min(tas) / min(tbs))
    return ratio, min(tas), min(tbs)


def bench_overhead(iters: int) -> dict:
    from repro.api.study import StudyScenario
    model = vgg_cifar(n_classes=8, input_hw=16, width_mult=0.25)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    x = np.asarray(rng.standard_normal((8, 16, 16, 3)), np.float32)
    labels = np.asarray(rng.integers(0, 8, 8), np.int32)
    scenario = StudyScenario()

    study = lambda: _study_pipeline(model, params, x, labels)
    direct = lambda: _direct_pipeline(model, params, x, labels, scenario)
    b_study, b_direct = study(), direct()       # warm the jit caches
    assert b_study.candidate.label == b_direct.candidate.label, \
        "facade and direct pipeline disagree — benchmark is meaningless"
    # three independent measurement windows, gated on their *median*: a
    # noise burst can corrupt one window in either direction without
    # moving the verdict, while a genuine facade regression (a stage
    # running twice, accidental recompute) shifts all three and trips
    # the <5% ceiling
    runs = sorted(_paired_ratio(study, direct, iters) for _ in range(3))
    ratio, study_s, direct_s = runs[1]
    return {
        "iters": iters,
        "direct_s": direct_s,
        "study_s": study_s,
        "window_ratios": [round(r[0], 4) for r in runs],
        "study_overhead_pct": (ratio - 1.0) * 100.0,
        "suggested": b_study.candidate.label,
    }


def run(fast: bool = False, out_path: str = None) -> list:
    """The ``benchmarks.run`` registry entrypoint (same contract as the
    other benches: write the JSON artifact, return metric rows)."""
    iters = 15 if fast else 40
    doc = {"quick": fast, "overhead": bench_overhead(iters)}
    o = doc["overhead"]
    # flat copy of the gated metric for perf_compare's path digging
    doc["study_overhead_pct"] = o["study_overhead_pct"]
    out_path = out_path or os.path.join(RESULTS_DIR, "api", "bench_api.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as fh:
        json.dump(doc, fh, indent=1)
    return [
        ("api.direct_ms", 0.0, round(o["direct_s"] * 1e3, 3)),
        ("api.study_ms", 0.0, round(o["study_s"] * 1e3, 3)),
        ("api.study_overhead_pct", 0.0,
         round(o["study_overhead_pct"], 2)),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (fewer timing iterations)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out = args.out or os.path.join(RESULTS_DIR, "api", "bench_api.json")
    run(fast=args.quick, out_path=out)
    with open(out) as fh:
        o = json.load(fh)["overhead"]
    print(f"direct pipeline  {o['direct_s'] * 1e3:9.2f} ms")
    print(f"Study pipeline   {o['study_s'] * 1e3:9.2f} ms")
    print(f"facade overhead  {o['study_overhead_pct']:9.2f} %  "
          f"(suggests {o['suggested']})")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
