"""Benchmark entrypoint: one section per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV (us_per_call = 0 for derived-metric
rows).  ``--fast`` trims the sweeps for CI-speed runs.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig2,fig3,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback

SECTIONS = {
    "tables": "benchmarks.bench_model_stats",
    "fig2": "benchmarks.bench_cs_curve",
    "fig3": "benchmarks.bench_split_latency",
    "fig4": "benchmarks.bench_protocol",
    "micro": "benchmarks.bench_micro",
    "fleet": "benchmarks.bench_fleet",
    "runtime": "benchmarks.bench_runtime",
    "api": "benchmarks.bench_api",
    "pipeline": "benchmarks.bench_pipeline",
    "planner": "benchmarks.bench_planner",
    "megafleet": "benchmarks.bench_megafleet",
    "controller": "benchmarks.bench_controller",
    "obs": "benchmarks.bench_obs",
    "faults": "benchmarks.bench_faults",
    "roofline": "benchmarks.roofline",
    # needs >=32 emulated devices; standalone: python -m benchmarks.bench_multipod_wire
    "multipod_wire": "benchmarks.bench_multipod_wire",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SECTIONS))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SECTIONS)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod_name = SECTIONS[name]
        try:
            import importlib
            mod = importlib.import_module(mod_name)
            for row in mod.run(fast=args.fast):
                print(",".join(str(x) for x in row), flush=True)
        except Exception:
            failures += 1
            print(f"{name},ERROR,0", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
