"""Split-runtime benchmark: executed latency vs simulator prediction.

For a grid of split points the same cut is (a) *executed* by the live
runtime (head -> int8 wire -> tail, per-stage wall clock, transfer priced
on the actual payload bytes) and (b) *predicted* by
``netsim.simulator.measure_flow`` twice — with the analytic
FLOPs/throughput cost model, and with the measured
:class:`~repro.runtime.calibrate.CalibrationTable` the runtime itself
emitted.  The per-split prediction error is the repo's ground-truth check
that the simulators mean something (paper claim iii), and the JSON
artifact is the CI regression gate's input.

Each split is additionally executed on the **fused-boundary** path
(``SplitRuntime(fused=True)``: codec jitted into the stages, only
framing/parse on the host) and the per-boundary overhead — the host-side
encode + decode work around one wire hop — is reported fused vs eager.
Two hard floors are asserted in-bench (back-to-back measurements, so
host load cancels): the fused wire payload is byte-identical to the
eager one, and the fused path cuts per-boundary overhead by >= 20%.

  PYTHONPATH=src python -m benchmarks.bench_runtime [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.core.scenarios import Scenario
from repro.core.split import SplitPlan
from repro.netsim.channel import Channel
from repro.netsim.simulator import (NetworkConfig, flow_latency_s,
                                    measure_flow)
from repro.runtime.calibrate import calibrate
from repro.runtime.engine import SplitRuntime

from .common import RESULTS_DIR


def _model(quick: bool):
    import jax
    from repro.models.vgg import vgg_cifar
    if quick:
        model = vgg_cifar(n_classes=8, input_hw=16, width_mult=0.25)
        return model, model.init(jax.random.PRNGKey(0))
    from benchmarks.common import trained_vgg
    return trained_vgg()


def _assert_payload_bit_identical(rt_eager, rt_fused, x, split):
    """The fused path must put the exact same bytes on the wire."""
    import jax.numpy as jnp
    from repro.runtime import wire as W
    xj = jnp.asarray(x)
    part_e, part_f = rt_eager.part, rt_fused.part
    f0 = part_e.stage(0)(xj)
    buf_e = W.to_bytes(W.encode_activation(f0, part_e.ae_map.get(split)))
    out0 = part_f.fused_segments()[0](xj)
    buf_f = W.frame_arrays(part_f.wire_kinds()[0], out0[0], out0[1])
    if buf_f != buf_e:
        raise AssertionError(
            f"split {split}: fused wire payload not bit-identical to eager "
            f"({len(buf_f)} vs {len(buf_e)} B)")


def _pick_splits(model, k: int = 4) -> list:
    cuts = model.cut_points()
    idx = np.linspace(0, len(cuts) - 1, min(k, len(cuts))).astype(int)
    return sorted({cuts[i] for i in idx})


def run(fast: bool = False, out_path: str = None) -> list:
    model, params = _model(fast)
    splits = _pick_splits(model, 3 if fast else 5)
    iters = 7 if fast else 10
    batch = 4                        # deterministic wire time dominates
    ch = Channel(latency_s=5e-4, capacity_bps=100e6, interface_bps=100e6,
                 seed=0)
    netcfg = NetworkConfig("tcp", ch)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((batch,) + tuple(model.input_shape)
                            ).astype(np.float32)
    input_bytes = x.nbytes

    rows = []
    table = None
    for split in splits:
        # calibrate and execute back-to-back so host-load drift between
        # the two passes doesn't masquerade as simulator error
        table = calibrate(model, params, [split], x=x, iters=iters,
                          include_rc=False, include_lc=False)
        rt = SplitRuntime(model, params, split, channel=ch, quantize=True)
        res = rt.infer(x, iters=iters)
        rt_f = SplitRuntime(model, params, split, channel=ch, quantize=True,
                            fused=True)
        res_f = rt_f.infer(x, iters=iters)
        if not np.array_equal(res.logits, res_f.logits):
            raise AssertionError(
                f"split {split}: fused logits diverged from eager")
        _assert_payload_bit_identical(rt, rt_f, x, split)
        sc = Scenario("SC", SplitPlan(split))
        flow_m = measure_flow(sc, netcfg, model, params, input_bytes,
                              cost=table, batch=batch)
        flow_a = measure_flow(sc, netcfg, model, params, input_bytes,
                              batch=batch)
        exec_s = res.total_s
        pred_m, pred_a = flow_latency_s(flow_m), flow_latency_s(flow_a)
        rows.append({
            "split": split,
            "exec_ms": exec_s * 1e3,
            "sim_measured_ms": pred_m * 1e3,
            "sim_analytic_ms": pred_a * 1e3,
            "err_measured_pct": abs(pred_m - exec_s) / exec_s * 100,
            "err_analytic_pct": abs(pred_a - exec_s) / exec_s * 100,
            "wire_bytes_exec": res.wire_bytes,
            "wire_bytes_sim": flow_m["wire_bytes"],
            "head_ms": res.head_s * 1e3,
            "tail_ms": res.tail_s * 1e3,
            "transfer_ms": res.transfer_s * 1e3,
            # host-side boundary work around the wire hop: eager = codec
            # dispatch + serialise/parse + codec compute; fused = framing
            # + parse only (the codec compute runs inside the stage jit)
            "per_boundary_overhead_s": {
                "eager": res.encode_s + res.decode_s,
                "fused": res_f.encode_s + res_f.decode_s,
            },
            "boundary_cut_pct": (1.0 - (res_f.encode_s + res_f.decode_s)
                                 / (res.encode_s + res.decode_s)) * 100,
            "exec_fused_ms": res_f.total_s * 1e3,
        })

    cut_pct = float(np.mean([r["boundary_cut_pct"] for r in rows]))
    if cut_pct < 20.0:
        raise AssertionError(
            f"fused boundary overhead cut {cut_pct:.1f}% < the 20% floor "
            f"(per split: {[round(r['boundary_cut_pct'], 1) for r in rows]})")
    report = {
        "quick": fast,
        "model": model.name,
        "n_splits": len(splits),
        "splits": rows,
        "max_err_measured_pct": max(r["err_measured_pct"] for r in rows),
        "mean_err_measured_pct": float(np.mean([r["err_measured_pct"]
                                                for r in rows])),
        "mean_err_analytic_pct": float(np.mean([r["err_analytic_pct"]
                                                for r in rows])),
        "boundary": {
            # mean over splits; the >=20% floor and payload bit-identity
            # are asserted above, so these are records, not gates
            "overhead_cut_pct": cut_pct,
            "fused_bit_identical": 1.0,
            "eager_overhead_ms": float(np.mean(
                [r["per_boundary_overhead_s"]["eager"] for r in rows])) * 1e3,
            "fused_overhead_ms": float(np.mean(
                [r["per_boundary_overhead_s"]["fused"] for r in rows])) * 1e3,
        },
    }
    out_path = out_path or os.path.join(RESULTS_DIR, "runtime",
                                        "bench_runtime.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)

    out = []
    for r in rows:
        out.append((f"runtime.split{r['split']}.exec_ms", 0.0,
                    round(r["exec_ms"], 3)))
        out.append((f"runtime.split{r['split']}.err_measured_pct", 0.0,
                    round(r["err_measured_pct"], 1)))
        out.append((f"runtime.split{r['split']}.err_analytic_pct", 0.0,
                    round(r["err_analytic_pct"], 1)))
    out.append(("runtime.max_err_measured_pct", 0.0,
                round(report["max_err_measured_pct"], 1)))
    out.append(("runtime.boundary.overhead_cut_pct", 0.0,
                round(report["boundary"]["overhead_cut_pct"], 1)))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="untrained small model, 3 splits (CI smoke)")
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args()
    for row in run(fast=args.quick, out_path=args.out):
        print(",".join(map(str, row)))
