"""Paper Tables I-II: the VGG16 network summary and aggregate statistics.

Exact targets from the paper: 138,357,544 params, 247.74 G mult-adds
(batch 16), 1735.26 MB forward/backward size."""
from __future__ import annotations

import json
import os

import jax

from repro.core import stats as S
from repro.models.vgg import vgg16

from .common import RESULTS_DIR


def run(fast: bool = False):
    model = vgg16()
    params = model.init(jax.random.PRNGKey(0))
    rows_tbl = S.summary(model, params, batch=16)
    t = S.totals(model, params, batch=16)
    os.makedirs(os.path.join(RESULTS_DIR, "paper"), exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "paper", "table1_2_stats.json"), "w") as f:
        json.dump({"totals": t,
                   "layers": [{"name": r.name, "kind": r.kind,
                               "shape": list(r.output_shape),
                               "params": r.n_params,
                               "mult_adds": r.mult_adds} for r in rows_tbl]},
                  f, indent=1)
    return [
        ("table2.total_params", 0.0, t["total_params"]),
        ("table2.params_match_paper", 0.0, int(t["total_params"] == 138_357_544)),
        ("table2.mult_adds_G", 0.0, round(t["mult_adds_G"], 2)),
        ("table2.fwd_bwd_MB", 0.0, round(t["fwd_bwd_MB"], 2)),
        ("table2.total_MB", 0.0, round(t["total_MB"], 2)),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
