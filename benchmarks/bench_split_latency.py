"""Paper Fig. 3: SC frame latency vs packet-loss rate, TCP, 1 Gb/s channel,
20 FPS (0.05 s) application constraint — split at feature ops 11 vs 15.

Uses the *full* VGG16 at 224x224 (the paper's actual network — Fig. 3
needs payload sizes and FLOPs, not accuracy): op 11 = block4_conv2,
op 15 = block5_conv2, 50%-compression bottleneck on the wire (f32 latent,
paper-faithful).  Expected (paper §V-B): the deeper split (15) ships 4x
fewer bytes and stays under 0.05 s at every loss rate; the shallow split
(11) violates the constraint beyond a few % loss.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.core import stats as S
from repro.core.qos import QoSRequirements
from repro.core.scenarios import PLATFORMS
from repro.models.vgg import feature_index, vgg16
from repro.netsim.channel import Channel
from repro.netsim.protocols import simulate_transfer

from .common import RESULTS_DIR

LOSS_RATES = [0.0, 0.01, 0.02, 0.03, 0.05, 0.08, 0.12]
QOS = QoSRequirements(max_latency_s=0.05)   # 20 FPS conveyor belt
COMPRESSION = 0.5
WIRE_BYTES_PER_ELEM = 4                      # paper AE: f32 latent


def run(fast: bool = False):
    model = vgg16()
    params = model.init(jax.random.PRNGKey(0))
    rows_tbl = S.summary(model, params, batch=1)
    fi = feature_index(model)
    # Orin-class edge accelerator: with a Nano-class 0.5 TF/s edge the head
    # compute (41-58 ms) dominates and inverts the paper's ordering; the
    # paper's Fig. 3 latencies are transmission-dominated (EXPERIMENTS.md)
    edge, server = PLATFORMS["edge-accelerator"], PLATFORMS["server-gpu"]

    out_rows, table = [], {}
    for op in (11, 15):                      # paper's Fig. 3 split points
        cut = fi[op - 1]                     # op index (1-based) -> layer idx
        head_f, tail_f = S.flops_split(model, params, cut, batch=1)
        feat = rows_tbl[cut].output_shape
        wire = int(np.prod(feat[1:-1])) * int(feat[-1] * COMPRESSION) \
            * WIRE_BYTES_PER_ELEM
        compute_s = edge.compute_time(head_f) + server.compute_time(tail_f)
        lat = {}
        for p in (LOSS_RATES[::2] if fast else LOSS_RATES):
            ch = Channel(1e-3, 1e9, 1e9, loss_rate=p, seed=3)
            transfers = [simulate_transfer("tcp", wire, ch, stream=s)
                         for s in range(16)]
            lat[p] = compute_s + float(np.mean([t.duration_s for t in transfers]))
        table[f"SC@{op}"] = {"wire_bytes": wire, "compute_s": compute_s,
                             "latency": lat}
        worst = max(lat.values())
        out_rows.append((f"fig3.SC@{op}.wire_bytes", 0.0, wire))
        out_rows.append((f"fig3.SC@{op}.latency_at_max_loss_s", 0.0,
                         round(worst, 5)))
        out_rows.append((f"fig3.SC@{op}.meets_20fps_all_loss", 0.0,
                         int(all(l <= QOS.max_latency_s for l in lat.values()))))
    os.makedirs(os.path.join(RESULTS_DIR, "paper"), exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "paper", "fig3_split_latency.json"), "w") as f:
        json.dump({"qos_max_latency_s": QOS.max_latency_s, "curves": table},
                  f, indent=1)
    return out_rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
