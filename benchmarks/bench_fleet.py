"""Fleet subsystem benchmark: traffic generation, cluster event
throughput, and planner search cost.

Writes a JSON artifact (results/fleet/bench_fleet.json) for CI upload and
prints the standard ``name,us_per_call,derived`` rows.

  PYTHONPATH=src python -m benchmarks.bench_fleet [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core.qos import QoSRequirements
from repro.fleet import (ClusterConfig, ClusterSim, DeviceClass,
                         DeploymentPlanner, SearchSpace, generate_trace)
from repro.netsim.channel import Channel
from repro.serving.engine import BatchCostModel

from .common import RESULTS_DIR


def _mix():
    return [DeviceClass.make("mcu", Channel(2e-3, 10e6, 10e6,
                                            loss_rate=0.08, seed=1), weight=2.0),
            DeviceClass.make("edge-embedded",
                             Channel(5e-4, 100e6, 100e6, loss_rate=0.02,
                                     seed=2), weight=1.5),
            DeviceClass.make("edge-accelerator",
                             Channel(1e-4, 1e9, 1e9, seed=3), weight=1.0)]


def bench_traffic(n: int) -> dict:
    out = {}
    for pattern in ("poisson", "bursty", "diurnal"):
        t0 = time.perf_counter()
        tr = generate_trace(_mix(), n, 500.0, pattern=pattern, seed=0)
        dt = time.perf_counter() - t0
        out[pattern] = {"n": n, "gen_s": dt, "req_per_s": n / dt,
                        "horizon_s": tr.horizon_s}
    return out


def bench_cluster(n: int) -> dict:
    """Event throughput at overload (every request queues and batches)."""
    tr = generate_trace(_mix(), n, 5000.0, seed=1)
    cost = BatchCostModel(flops_per_item=5e7, flops_per_s=60e12,
                          fixed_overhead_s=2e-4)
    sim = ClusterSim(cost, ClusterConfig(n_replicas=2, max_batch=16,
                                         batch_window_s=1e-3))
    sim.offer_trace((r.rid, r.t_arrival) for r in tr.requests)
    t0 = time.perf_counter()
    stats = sim.run()
    dt = time.perf_counter() - t0
    events = sim.q.n_fired + sim.q.n_cancelled
    return {"n_requests": n, "sim_s": dt, "events": events,
            "events_per_s": events / dt, "served": len(stats.served),
            "p50_ms": stats.percentile(50) * 1e3,
            "p99_ms": stats.percentile(99) * 1e3,
            "mean_batch": stats.mean_batch(),
            "cancelled_timers": sim.q.n_cancelled}


def bench_planner(n: int, quick: bool) -> dict:
    """Search-cost benchmark on the small VGG (accuracy via analytic proxy
    in --quick so CI needs no training; measured accuracy otherwise)."""
    import jax
    from repro.models.vgg import feature_index, vgg_cifar

    if quick:
        model = vgg_cifar(n_classes=8, input_hw=16, width_mult=0.25)
        params = model.init(jax.random.PRNGKey(0))

        def accuracy_fn(scenario, netcfg):
            base = 0.9 if scenario.kind != "LC" else 0.6
            return base - (netcfg.channel.loss_rate
                           if netcfg.protocol == "udp" else 0.0)
        kw = dict(accuracy_fn=accuracy_fn, input_bytes=16 * 16 * 3 * 4)
    else:
        from benchmarks.common import trained_vgg
        from repro.data.synthetic import toy_images
        model, params = trained_vgg()
        xs, ys = toy_images(32, hw=16, seed=55)
        kw = dict(eval_data=(xs, ys))

    fi = feature_index(model)
    cs = np.linspace(1.0, 0.2, len(fi))
    legal = set(model.cut_points())
    sps = tuple(sp for sp in fi if sp in legal)[:4]
    planner = DeploymentPlanner(model, params, cs_curve=cs, layer_idx=fi, **kw)
    space = SearchSpace(split_points=sps, batch_sizes=(1, 8, 32),
                        replica_counts=(1, 2), top_k_splits=2)
    mix = _mix()
    trace = generate_trace(mix, n, 400.0, pattern="diurnal", seed=42)
    t0 = time.perf_counter()
    points = planner.search(trace, mix, space)
    search_s = time.perf_counter() - t0
    front = planner.pareto_front(points)
    qos = QoSRequirements(max_latency_s=0.05, min_accuracy=0.5)
    feasible = sum(p.satisfies(qos) for p in points)
    plans = planner.suggest(qos, (trace, mix), space, points=points)
    return {"n_requests": n, "search_s": search_s, "n_points": len(points),
            "points_per_s": len(points) / search_s,
            "pareto_size": len(front), "n_feasible": feasible,
            "n_classes_planned": sum(p is not None for p in plans.values())}


def run(fast: bool = False, out_path: str = None) -> list:
    n = 1000 if fast else 5000
    report = {"quick": fast,
              "traffic": bench_traffic(n),
              "cluster": bench_cluster(n),
              "planner": bench_planner(min(n, 1000), quick=fast)}
    out_path = out_path or os.path.join(RESULTS_DIR, "fleet",
                                        "bench_fleet.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    tr, cl, pl = report["traffic"], report["cluster"], report["planner"]
    return [
        ("fleet.traffic.poisson_req_per_s", 0.0, int(tr["poisson"]["req_per_s"])),
        ("fleet.cluster.events_per_s", 0.0, int(cl["events_per_s"])),
        ("fleet.cluster.mean_batch", 0.0, round(cl["mean_batch"], 2)),
        ("fleet.cluster.p99_ms", 0.0, round(cl["p99_ms"], 3)),
        ("fleet.planner.points_per_s", 0.0, round(pl["points_per_s"], 1)),
        ("fleet.planner.pareto_size", 0.0, pl["pareto_size"]),
        ("fleet.planner.n_feasible", 0.0, pl["n_feasible"]),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small trace + analytic accuracy proxy (CI smoke)")
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args()
    for row in run(fast=args.quick, out_path=args.out):
        print(",".join(map(str, row)))
