"""Planner fast-path benchmark: vectorized closed-form screening vs the
per-combo event-engine path.

One device -> edge -> cloud topology over the quick VGG model, the full
``plan_tiers`` cut-list x assignment sweep measured three ways:

* **screen** — the vectorized closed-form pass (``netsim.analytic``)
  over every combo (``plan_tiers(refine=0)``), reported as plans/sec;
* **event** — the pre-fast-path cost: one ``simulate_pipeline``
  discrete-event run per combo (timed on a subset, reported as
  plans/sec) — the denominator of the headline speedup;
* **end-to-end** — the default two-phase ``plan_tiers`` (exhaustive
  screen + Pareto/top-K exact refinement) wall time.

All wall-clock numbers use the min-estimator over repeats (the host is
noisy; the minimum is the least-interference sample).  The screen's
correctness rides along: the max relative deviation between screened and
event-engine latencies over the subset is reported and must stay under
1e-9 (the closed form is exact on loss-free paths), and the quick
configuration enforces the >=10x screening speedup acceptance bar.

  PYTHONPATH=src python -m benchmarks.bench_planner [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time
import warnings

import numpy as np

from repro.fleet.planner import Tier, TierTopology, plan_tiers
from repro.netsim.channel import Channel
from repro.netsim.simulator import NetworkPath, simulate_pipeline

from .common import RESULTS_DIR


def _model(quick: bool):
    import jax
    from repro.models.vgg import vgg_cifar
    if quick:
        model = vgg_cifar(n_classes=8, input_hw=16, width_mult=0.25)
        return model, model.init(jax.random.PRNGKey(0))
    from benchmarks.common import trained_vgg
    return trained_vgg()


def _topology() -> TierTopology:
    return TierTopology((
        Tier("device", "edge-embedded", Channel(1e-3, 100e6, 100e6, seed=1)),
        Tier("edge", "edge-accelerator", Channel(1e-3, 25e6, 25e6, seed=2)),
        Tier("cloud", "server-gpu"),
    ))


def _min_wall(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(fast: bool = False, out_path: str = None) -> list:
    model, params = _model(fast)
    topo = _topology()
    cuts = model.cut_points()
    kw = dict(cs_curve=np.linspace(1.0, 0.3, len(cuts)), layer_idx=cuts,
              batch=16, n_micro=4)
    reps = 3 if fast else 5

    # default sweep: exhaustive screen + refinement, and no truncation
    # warning may fire (acceptance: the quick config is fully swept)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        plans = plan_tiers(model, params, topo, **kw)
    n_combos = len(plans)
    assert any(p.refined for p in plans), "refinement stage did not run"

    # screening-only plans/sec (stats caches are warm — steady state)
    screen_s = _min_wall(lambda: plan_tiers(model, params, topo,
                                            refine=0, **kw), reps)
    # per-combo event-engine path, timed on a subset (it is the slow
    # side; the subset spans the latency range via strided selection)
    sub = plans[::max(1, n_combos // 24)][:24]
    full = topo.path()

    def _event_price():
        out = []
        for p in sub:
            path = NetworkPath(full.hops[:p.tier_index[-1]])
            pipe = simulate_pipeline(list(p.stage_s), list(p.hop_bytes),
                                     path, n_micro=4)
            out.append(min(pipe.latency_s, pipe.sequential_s))
        return out

    event_s = _min_wall(_event_price, reps)
    event_lat = _event_price()
    # screen-vs-event correctness on the subset (loss-free -> exact)
    max_rel = max(abs(p.latency_s - ev) / ev
                  for p, ev in zip(sub, event_lat))

    e2e_s = _min_wall(lambda: plan_tiers(model, params, topo, **kw), reps)

    screen_pps = n_combos / screen_s
    event_pps = len(sub) / event_s
    speedup = screen_pps / event_pps

    report = {
        "quick": fast,
        "model": model.name,
        "n_combos": n_combos,
        "n_event_subset": len(sub),
        "screen": {
            "plans_per_s": screen_pps,
            "wall_ms": screen_s * 1e3,
            "speedup_vs_event_x": speedup,
        },
        "event": {"plans_per_s": event_pps},
        "plan_tiers": {"e2e_ms": e2e_s * 1e3},
        "verify": {"max_rel_err": max_rel},
    }
    out_path = out_path or os.path.join(RESULTS_DIR, "planner",
                                        "bench_planner.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)

    if max_rel > 1e-9:
        raise SystemExit(f"closed-form screen diverged from the event "
                         f"engine: max rel err {max_rel:.3e} > 1e-9")
    if fast and speedup < 10.0:
        raise SystemExit(f"screening speedup {speedup:.1f}x < 10x on the "
                         f"quick configuration (acceptance bar)")

    return [
        ("planner.n_combos", 0.0, n_combos),
        ("planner.screen_plans_per_s", 0.0, round(screen_pps, 1)),
        ("planner.event_plans_per_s", 0.0, round(event_pps, 1)),
        ("planner.screen_speedup_x", 0.0, round(speedup, 1)),
        ("planner.e2e_ms", 0.0, round(e2e_s * 1e3, 3)),
        ("planner.max_rel_err", 0.0, max_rel),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="untrained small model (CI smoke)")
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args()
    for row in run(fast=args.quick, out_path=args.out):
        print(",".join(map(str, row)))
