"""Multi-tier pipeline benchmark: 3-stage execution vs multi-hop
simulation, and pipelined microbatching vs sequential scheduling.

One bandwidth-bound device -> edge -> cloud scenario, measured three ways:

* **executed** — the live 3-stage ``SplitRuntime`` at a 2-cut pair
  (stage compute is real wall clock, the two wire hops are netsim-priced
  on the actual payload bytes);
* **simulated sequential** — ``measure_flow`` over the same 2-hop
  ``NetworkPath`` with the analytic per-stage cost model;
* **simulated pipelined** — the same flow chopped into microbatches so
  hop-k transfer overlaps stage-k+1 compute
  (``netsim.simulator.simulate_pipeline``).

The pipelined-vs-sequential speedup and both simulated latencies are
deterministic (event engine + analytic stage times) and are the CI gate
metrics; the simulated-vs-executed error is wall-clock-sensitive and
gates only on a generous absolute ceiling.

  PYTHONPATH=src python -m benchmarks.bench_pipeline [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.core.scenarios import PLATFORMS, Scenario
from repro.core.split import SplitPlan
from repro.netsim.channel import Channel
from repro.netsim.simulator import (NetworkConfig, NetworkPath,
                                    flow_latency_s, measure_flow)
from repro.runtime.engine import SplitRuntime

from .common import RESULTS_DIR


def _model(quick: bool):
    import jax
    from repro.models.vgg import vgg_cifar
    if quick:
        model = vgg_cifar(n_classes=8, input_hw=16, width_mult=0.25)
        return model, model.init(jax.random.PRNGKey(0))
    from benchmarks.common import trained_vgg
    return trained_vgg()


def _pick_pair(model) -> tuple:
    """An early/late 2-cut pair (big first payload, real middle stage)."""
    cuts = model.cut_points()
    return cuts[len(cuts) // 4], cuts[(3 * len(cuts)) // 4]


def run(fast: bool = False, out_path: str = None) -> list:
    model, params = _model(fast)
    pair = _pick_pair(model)
    batch = 16
    iters = 5 if fast else 10
    n_micro = 4
    # bandwidth-bound hops with comparable busy time (fast LAN carrying
    # the big early payload, slow WAN carrying the pooled-down one): the
    # overlap regime where microbatching pays
    path = NetworkPath((
        NetworkConfig("tcp", Channel(1e-3, 100e6, 100e6, seed=1)),
        NetworkConfig("tcp", Channel(1e-3, 25e6, 25e6, seed=2)),
    ))
    tiers = (PLATFORMS["edge-embedded"], PLATFORMS["edge-accelerator"],
             PLATFORMS["server-gpu"])

    rng = np.random.default_rng(0)
    x = rng.standard_normal((batch,) + tuple(model.input_shape)
                            ).astype(np.float32)

    rt = SplitRuntime(model, params, pair, channel=list(path.hops),
                      quantize=False)
    res = rt.infer(x, iters=iters)
    exec_s = res.total_s

    # compression=1.0: the runtime ships the raw f32 activation (no AE),
    # so the analytic payload model must price the uncompressed wire too
    sc = Scenario("SC", SplitPlan(None, splits=pair, compression=1.0),
                  edge=tiers[0], server=tiers[-1])
    flow = measure_flow(sc, path, model, params, x[0].nbytes, n_frames=4,
                        batch=batch, tiers=tiers, n_micro=n_micro)
    seq_s = flow_latency_s(flow)
    pipe = flow["pipeline"]

    report = {
        "quick": fast,
        "model": model.name,
        "splits": list(pair),
        "batch": batch,
        "n_micro": n_micro,
        "pipeline": {
            "sequential_ms": seq_s * 1e3,
            "pipelined_ms": pipe.latency_s * 1e3,
            "speedup": pipe.speedup,
            "stage_ms": [s * 1e3 for s in flow["stage_s"]],
            "hop_bytes": flow["hop_bytes"],
        },
        "sim_vs_exec": {
            "exec_ms": exec_s * 1e3,
            "sim_sequential_ms": seq_s * 1e3,
            "err_analytic_pct": abs(seq_s - exec_s) / exec_s * 100,
            "exec_stage_ms": [s * 1e3 for s in res.stage_s],
            "exec_transfer_ms": res.transfer_s * 1e3,
            "exec_wire_bytes": res.wire_bytes,
            "sim_wire_bytes": flow["wire_bytes"],
        },
    }
    out_path = out_path or os.path.join(RESULTS_DIR, "pipeline",
                                        "bench_pipeline.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)

    return [
        ("pipeline.sequential_ms", 0.0,
         round(report["pipeline"]["sequential_ms"], 3)),
        ("pipeline.pipelined_ms", 0.0,
         round(report["pipeline"]["pipelined_ms"], 3)),
        ("pipeline.speedup", 0.0, round(report["pipeline"]["speedup"], 3)),
        ("sim_vs_exec.exec_ms", 0.0,
         round(report["sim_vs_exec"]["exec_ms"], 3)),
        ("sim_vs_exec.err_analytic_pct", 0.0,
         round(report["sim_vs_exec"]["err_analytic_pct"], 1)),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="untrained small model (CI smoke)")
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args()
    for row in run(fast=args.quick, out_path=args.out):
        print(",".join(map(str, row)))
