"""Telemetry overhead benchmark: what does observability cost?

Two gated numbers, both measured as paired ratios (A and B run
back-to-back per pair, median of per-pair ratios — host drift hits both
sides of a pair equally, so the estimate stays stable at the sub-percent
scale the gate needs), then the minimum over independent repetitions
(noise can only inflate a ratio median, so min-of-repeats keeps one
noisy window from flaking the ceiling gate):

* ``overhead.null_pct`` — the instrumented ``netsim.events.EventQueue``
  with the default null recorder vs a verbatim copy of the
  pre-telemetry engine, on a bare self-rescheduling timer chain (the
  worst case: sub-microsecond events, nothing to amortise against).
  Gated at <1%: tracing *off* must cost nothing measurable.
* ``overhead.record_pct`` — ``SplitRuntime.infer`` on the jitted path
  with a live ``Recorder`` vs with telemetry off.  Gated at <5% (CI
  headroom; typically ~1-2%): recording spans + per-stage series must
  not distort the latencies it reports.

Also reported (not gated): the traced event loop's overhead on the same
bare chain — the honest upper bound for span-per-event recording, paid
only when tracing is on and only on sub-microsecond event workloads.

  PYTHONPATH=src python -m benchmarks.bench_obs [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import gc
import heapq
import json
import os
import statistics
import time

from .common import RESULTS_DIR


# A verbatim copy of the engine as it was before telemetry landed — the
# reference the null path is held to.  Keep in sync with the *shape* of
# repro.netsim.events (same assert, same loop body, 3-slot handle).
class _SeedHandle:
    __slots__ = ("time", "seq", "cancelled")

    def __init__(self, time, seq):
        self.time = time
        self.seq = seq
        self.cancelled = False

    def cancel(self):
        self.cancelled = True


class _SeedQueue:
    def __init__(self):
        self._q = []
        self._seq = 0
        self.now = 0.0
        self.n_fired = 0
        self.n_cancelled = 0

    def schedule(self, time, fn):
        assert time >= self.now - 1e-12, (time, self.now)
        h = _SeedHandle(time, self._seq)
        heapq.heappush(self._q, (time, self._seq, fn, h))
        self._seq += 1
        return h

    def run(self, until=float("inf"), max_events=10_000_000):
        n = 0
        while self._q and self._q[0][0] <= until:
            t, _, fn, h = heapq.heappop(self._q)
            if h.cancelled:
                self.n_cancelled += 1
                continue
            self.now = t
            fn()
            n += 1
            self.n_fired += 1
            if n >= max_events:
                raise RuntimeError("event budget exceeded")


def _chain(q, n_events: int) -> None:
    """Self-rescheduling timer chain with periodic cancellations (the
    cancel path is part of the hot loop too)."""
    state = {"n": 0}

    def tick():
        state["n"] += 1
        if state["n"] < n_events:
            h = q.schedule(q.now + 1e-6, tick)
            if state["n"] % 7 == 0:
                h.cancel()
                q.schedule(q.now + 1e-6, tick)

    q.schedule(0.0, tick)
    q.run()


def _paired_pct(make_a, make_b, bench, pairs: int) -> tuple:
    """Median over ``pairs`` of (B time / A time) - 1, in percent, with
    the order inside each pair alternating so drift cancels.  Returns
    (pct, min_a_s, min_b_s)."""
    ratios, ta_all, tb_all = [], [], []

    def one(make):
        obj = make()
        t0 = time.perf_counter()
        bench(obj)
        return time.perf_counter() - t0

    gc.collect()
    gc.disable()
    try:
        one(make_a), one(make_b)                      # warmup both sides
        for i in range(pairs):
            if i % 2:
                tb, ta = one(make_b), one(make_a)
            else:
                ta, tb = one(make_a), one(make_b)
            ratios.append(tb / ta)
            ta_all.append(ta)
            tb_all.append(tb)
    finally:
        gc.enable()
    pct = (statistics.median(ratios) - 1.0) * 100.0
    return pct, min(ta_all), min(tb_all)


def _best_of(measure, repeats: int) -> dict:
    """Min-by-pct over independent repetitions of a paired measurement.
    Host noise (scheduler interference, cache pollution from whatever
    ran before) can only *inflate* a median ratio, never deflate it at
    true ~0% overhead — so for a ceiling gate the minimum across
    repeats is the robust estimate, and one noisy window can't flake
    CI.  All repeat pcts are kept in the report for transparency."""
    results = [measure() for _ in range(repeats)]
    best = min(results, key=lambda r: r["pct"])
    best["repeat_pcts"] = [round(r["pct"], 3) for r in results]
    return best


def _null_overhead(n_events: int, pairs: int) -> dict:
    from repro.netsim.events import EventQueue
    pct, t_seed, t_null = _paired_pct(
        _SeedQueue, EventQueue, lambda q: _chain(q, n_events), pairs)
    return {"pct": pct, "seed_ms": t_seed * 1e3, "null_ms": t_null * 1e3,
            "n_events": n_events, "pairs": pairs}


def _traced_overhead(n_events: int, pairs: int) -> dict:
    from repro.netsim.events import EventQueue
    from repro.obs import Recorder
    pct, t_null, t_rec = _paired_pct(
        EventQueue, lambda: EventQueue(obs=Recorder()),
        lambda q: _chain(q, n_events), pairs)
    return {"pct": pct, "null_ms": t_null * 1e3, "traced_ms": t_rec * 1e3,
            "n_events": n_events, "pairs": pairs}


def _record_overhead(quick: bool, pairs: int) -> dict:
    """Recording cost on the live runtime's jitted path."""
    import numpy as np

    from repro.netsim.channel import Channel
    from repro.obs import Recorder
    from repro.runtime.engine import SplitRuntime

    from .bench_runtime import _model, _pick_splits

    model, params = _model(quick)
    split = _pick_splits(model, 3)[1]
    ch = Channel(latency_s=5e-4, capacity_bps=100e6, interface_bps=100e6,
                 seed=0)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4,) + tuple(model.input_shape)
                            ).astype(np.float32)
    rt_base = SplitRuntime(model, params, split, channel=ch, quantize=True)
    rec = Recorder()
    rt_obs = SplitRuntime(model, params, split, channel=ch, quantize=True,
                          obs=rec)
    iters = 3 if quick else 5
    pct, t_base, t_obs = _paired_pct(
        lambda: rt_base, lambda: rt_obs,
        lambda rt: rt.infer(x, iters=iters), pairs)
    return {"pct": pct, "base_ms_per_call": t_base / iters * 1e3,
            "obs_ms_per_call": t_obs / iters * 1e3, "split": split,
            "n_spans_recorded": len(rec.tracer.spans), "pairs": pairs}


def run(fast: bool = False, out_path: str = None) -> list:
    n_events = 10_000 if fast else 30_000
    pairs = 40 if fast else 60
    null = _best_of(lambda: _null_overhead(n_events, pairs), 3)
    traced = _traced_overhead(n_events, max(10, pairs // 2))
    record = _best_of(lambda: _record_overhead(fast, 15 if fast else 25), 2)

    report = {
        "quick": fast,
        "overhead": {
            # floor at 0: the gate ceiling is on added cost, and the
            # paired estimator can read slightly negative at true ~0%
            "null_pct": max(0.0, null["pct"]),
            "record_pct": max(0.0, record["pct"]),
            "traced_event_pct": traced["pct"],
        },
        "null": null,
        "traced": traced,
        "record": record,
    }
    out_path = out_path or os.path.join(RESULTS_DIR, "obs", "bench_obs.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)

    return [
        ("obs.null_overhead_pct", 0.0,
         round(report["overhead"]["null_pct"], 3)),
        ("obs.record_overhead_pct", 0.0,
         round(report["overhead"]["record_pct"], 3)),
        ("obs.traced_event_overhead_pct", 0.0,
         round(report["overhead"]["traced_event_pct"], 1)),
        ("obs.infer_base_ms", 0.0, round(record["base_ms_per_call"], 3)),
        ("obs.infer_recorded_ms", 0.0, round(record["obs_ms_per_call"], 3)),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller chains / fewer pairs (CI smoke)")
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args()
    for row in run(fast=args.quick, out_path=args.out):
        print(",".join(map(str, row)))
