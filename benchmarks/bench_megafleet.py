"""Megafleet benchmark: vectorized arrival-level cluster engine vs the
per-event ``ClusterSim`` loop, at the million-client scale.

One serving group (4 replicas, max_batch 64, 2 ms batching window,
queue_limit 8192) priced by a deterministic ``BatchCostModel`` — the
regime where the event loop is the planner bottleneck.  Three workloads:

* **poisson 2x** — Poisson arrivals at 2x the group's saturated
  capacity (the headline: deep overload is the planner's worst case and
  the vectorized engine's best, since long busy stretches collapse into
  the closed-form cadence);
* **poisson 1.2x** — mild overload (mixed tracked/bulk phases);
* **diurnal** — sinusoidal day/night swing crossing the capacity line
  twice per period (the ``examples/megafleet.py`` workload).

The headline metric is the **clients ratio**: requests/second through
the vectorized engine over requests/second through the event engine on
the identically-distributed workload, i.e. how many more clients one
planner core can screen at equal wall-clock.  Both sides use the
min-estimator over repeats.  Correctness rides along: a slice of the
headline workload runs through ``check_event_engine=True`` (exact drop /
batch / served counts, percentiles on the 1e-6 relative contract), and
the drop fraction + p99 of the full run are reported — they are
deterministic given the seed, so the CI gate pins them.

The quick configuration enforces the >=20x clients-ratio acceptance
floor in-process (the two sides are timed back-to-back, so host speed
cancels); the full run is sized for the >=100x headline.

  PYTHONPATH=src python -m benchmarks.bench_megafleet [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.fleet.cluster import ClusterConfig, ClusterSim
from repro.fleet.traffic import diurnal_arrivals
from repro.fleet.vectorized import simulate_cluster_vectorized
from repro.serving.engine import BatchCostModel

from .common import RESULTS_DIR

COST = BatchCostModel(flops_per_item=5e9, flops_per_s=60e12,
                      fixed_overhead_s=2e-4)
CFG = ClusterConfig(n_replicas=4, max_batch=64, batch_window_s=2e-3,
                    queue_limit=8192)
FLOOR_X = 20.0                       # quick-mode acceptance floor


def _capacity_hz(cost: BatchCostModel, cfg: ClusterConfig) -> float:
    """Saturated throughput: full batches back-to-back on every replica."""
    return cfg.n_replicas * cfg.max_batch / cost.service_time(cfg.max_batch)


def _min_wall(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _event_run(times: np.ndarray):
    sim = ClusterSim(COST, CFG)
    for i, t in enumerate(times):
        sim.offer(i, float(t))
    sim.run()
    return sim.stats


def _workloads(fast: bool):
    cap = _capacity_hz(COST, CFG)
    n_vec = 200_000 if fast else 1_000_000
    n_event = 20_000 if fast else 50_000
    rng = np.random.default_rng(7)
    mk_poisson = lambda lam, n: np.cumsum(rng.exponential(1.0 / lam, n))
    return n_event, [
        ("poisson_2x", mk_poisson(2.0 * cap, n_vec)),
        ("poisson_1.2x", mk_poisson(1.2 * cap, n_vec)),
        ("diurnal", diurnal_arrivals(
            2.0 * cap, n_vec, np.random.default_rng(8),
            period_s=max(4.0, n_vec / (2.0 * cap) / 2.0), depth=0.8)),
    ]


def run(fast: bool = False, out_path: str = None) -> list:
    reps = 3 if fast else 5
    n_event, workloads = _workloads(fast)
    sections, rows = {}, []
    headline_x = None
    for name, times in workloads:
        vec_s = _min_wall(
            lambda: simulate_cluster_vectorized(times, COST, CFG), reps)
        # the event loop is the slow side: time it on a prefix of the
        # same arrival stream (identical distribution, earlier horizon)
        ev_times = times[:n_event]
        ev_s = _min_wall(lambda: _event_run(ev_times), reps)
        vec_rps = len(times) / vec_s
        ev_rps = n_event / ev_s
        ratio = vec_rps / ev_rps
        vstats = simulate_cluster_vectorized(times, COST, CFG)
        sections[name] = {
            "n_vec": len(times), "n_event": n_event,
            "vec_wall_ms": vec_s * 1e3,
            "vec_reqs_per_s": vec_rps,
            "event_reqs_per_s": ev_rps,
            "clients_ratio_x": ratio,
            "drop_fraction": vstats.drop_fraction(),
            "p99_ms": vstats.percentile(99.0) * 1e3,
        }
        rows += [
            (f"megafleet.{name}.vec_reqs_per_s", 0.0, round(vec_rps, 1)),
            (f"megafleet.{name}.event_reqs_per_s", 0.0, round(ev_rps, 1)),
            (f"megafleet.{name}.clients_ratio_x", 0.0, round(ratio, 1)),
            (f"megafleet.{name}.drop_fraction", 0.0,
             round(vstats.drop_fraction(), 6)),
        ]
        if name == "poisson_2x":
            headline_x = ratio

    # screen/refine agreement on a slice of the headline stream: raises
    # if counts diverge or percentiles leave the stated tolerance
    agree_n = min(n_event, 20_000)
    agree = simulate_cluster_vectorized(
        workloads[0][1][:agree_n], COST, CFG, check_event_engine=True)
    verify = {
        "n": agree_n,
        "checked": True,
        "drop_fraction": agree.drop_fraction(),
    }
    rows.append(("megafleet.verify.n", 0.0, agree_n))

    report = {
        "quick": fast,
        "capacity_hz": _capacity_hz(COST, CFG),
        "headline_clients_ratio_x": headline_x,
        "workloads": sections,
        "verify": verify,
    }
    out_path = out_path or os.path.join(RESULTS_DIR, "megafleet",
                                        "bench_megafleet.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)

    if fast and headline_x < FLOOR_X:
        raise SystemExit(
            f"vectorized engine clients-ratio {headline_x:.1f}x < "
            f"{FLOOR_X:.0f}x on the quick configuration (acceptance floor)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workloads + the >=20x floor (CI smoke)")
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args()
    for row in run(fast=args.quick, out_path=args.out):
        print(",".join(map(str, row)))
