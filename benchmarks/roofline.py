"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run JSONs (``results/dryrun``) and derives, per the brief:

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_wire_bytes / (chips x link_bw)

HLO_FLOPs/bytes are the trip-count-corrected per-device numbers from
``repro.launch.hlo_cost`` (multiplied back to whole-job by device count);
collective bytes are ring-model wire bytes per device.  Dominant term =
bottleneck.  MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd-only), N active for
MoE; the ratio MODEL/HLO exposes remat+redundancy waste.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config
from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    """Whole-job useful FLOPs for this (arch, shape)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    counts = cfg.param_counts()
    n = counts["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def _kernel_io_bytes(arch: str, shape_name: str, chips: int) -> float:
    """Ideal kernel HBM traffic per device — what replaces the fallback
    paths' scope bytes when the Pallas kernels run on TPU:
      flash_attention: q,k,v read + o write per layer pass
      wkv/mamba scans: r,k,v,w / dt,x,B,C read + y write per layer pass."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return 0.0
    tokens = shape.global_batch * shape.seq_len
    passes = 4 if shape.kind == "train" else 1   # fwd + remat-fwd + bwd(2x io)
    total = 0.0
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.is_attn_layer(i))
    if not cfg.attn_free and n_attn:
        total += n_attn * (tokens * cfg.hd
                           * (2 * cfg.n_heads + 2 * cfg.n_kv_heads) * 2)
    if cfg.family == "ssm":          # wkv: 4 reads + 1 write of (S, D)
        total += cfg.n_layers * 5 * tokens * cfg.d_model * 4
    n_mamba = cfg.n_layers - n_attn if cfg.attn_period > 0 else 0
    if n_mamba:                      # dt,x read + y write of (S, di)
        di = cfg.mamba_expand * cfg.d_model
        total += n_mamba * 3 * tokens * di * 4
    return total * passes / chips


def analyse(rec: dict) -> dict:
    chips = rec["devices"]
    flops_dev = rec.get("flops_per_device") or 0.0
    bytes_dev = rec.get("bytes_per_device") or 0.0
    wire_dev = rec.get("collective_wire_bytes_total") or 0.0
    scope_dev = sum((rec.get("scope_bytes") or {}).values())
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = wire_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops_dev * chips
    # kernel-adjusted memory: fallback flash/wkv traffic replaced by the
    # Pallas kernels' ideal IO (scores/softmax stay in VMEM on TPU)
    kio = _kernel_io_bytes(rec["arch"], rec["shape"], chips)
    t_memory_k = max(0.0, bytes_dev - scope_dev + kio) / HBM_BW
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "memory_kernel_s": t_memory_k,
        "dominant": dom,
        "dominant_kernel": max({"compute": t_compute, "memory": t_memory_k,
                                "collective": t_coll}.items(),
                               key=lambda kv: kv[1])[0],
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "peak_mem_GiB": rec["memory"]["peak_estimate_bytes"] / 2 ** 30,
        "step_bound_s": max(terms.values()),
        "mfu_bound": (mf / chips / PEAK_FLOPS) / max(terms.values())
                     if max(terms.values()) > 0 else 0.0,
    }


def load_all(mesh_tag: str = "pod16x16") -> list:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, mesh_tag, "*.json"))):
        with open(path) as f:
            out.append(analyse(json.load(f)))
    return out


def format_markdown(rows: list) -> str:
    hdr = ("| arch | shape | compute s | memory s | mem(kernel) s "
           "| collective s | dominant | dom(kernel) | useful (6ND/HLO) "
           "| peak GiB |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['memory_kernel_s']:.3e} "
            f"| {r['collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['dominant_kernel']} "
            f"| {r['useful_ratio']:.2f} "
            f"| {r['peak_mem_GiB']:.1f} |")
    return "\n".join(lines)


def run(fast: bool = False):
    rows = load_all("pod16x16")
    out = []
    for r in rows:
        out.append((f"roofline.{r['arch']}.{r['shape']}.step_bound_s", 0.0,
                    round(r["step_bound_s"], 6)))
    out.append(("roofline.n_cases", 0.0, len(rows)))
    if rows:
        md = format_markdown(rows)
        path = os.path.join(os.path.dirname(RESULTS), "roofline_table.md")
        with open(path, "w") as f:
            f.write(md + "\n")
    return out


if __name__ == "__main__":
    rows = load_all("pod16x16")
    print(format_markdown(rows))
