"""Microbenchmarks: wall-clock us/call for the framework's hot host-side
paths (netsim event engine, saliency pass, kernels in interpret mode are
correctness-only and excluded from timing)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.saliency import cumulative_saliency
from repro.data.synthetic import toy_images
from repro.models.vgg import feature_index
from repro.netsim.channel import Channel
from repro.netsim.protocols import simulate_tcp, simulate_udp

from .common import timed, trained_vgg


def run(fast: bool = False):
    rows = []
    ch = Channel(100e-6, 1e9, 1e9, loss_rate=0.05, seed=0)
    us, r = timed(lambda: simulate_tcp(100_000, ch), iters=3)
    rows.append(("micro.netsim.tcp_100kB_us", us, r.n_transmissions))
    us, r = timed(lambda: simulate_udp(100_000, ch), iters=10)
    rows.append(("micro.netsim.udp_100kB_us", us, r.n_packets))

    model, params = trained_vgg()
    xs, ys = toy_images(8, hw=16, seed=1)
    fi = feature_index(model)
    us, _ = timed(lambda: cumulative_saliency(model, params, jnp.asarray(xs),
                                              jnp.asarray(ys), layer_idx=fi),
                  iters=2)
    rows.append(("micro.saliency.cs_curve_8imgs_us", us, len(fi)))

    fwd = jax.jit(lambda x: model.apply(params, x))
    x = jnp.asarray(xs)
    us, _ = timed(fwd, x, iters=10)
    rows.append(("micro.vgg.fwd_b8_us", us, 0))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
