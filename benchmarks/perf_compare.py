import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbs 1-2 driver: re-lowers the selected (arch x shape)
pairs with the optimisation flags on, into ``results/dryrun_opt``, and
prints before/after roofline terms against the baselines in
``results/dryrun``.

  PYTHONPATH=src python -m benchmarks.perf_compare [--pairs a:b,c:d]
"""
import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

DEFAULT_PAIRS = [
    ("llama3.2-3b", "prefill_32k"),   # worst useful-ratio (24 heads % 16)
    ("jamba-v0.1-52b", "decode_32k"), # most collective-bound
    ("qwen3-moe-235b-a22b", "train_4k"),  # compute-bound MoE giant
]


def main():
    from repro.launch.dryrun import run_case
    from benchmarks.roofline import analyse

    ap = argparse.ArgumentParser()
    ap.add_argument("--pairs", default=None,
                    help="comma list of arch:shape (default: the 3 picks)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    pairs = (DEFAULT_PAIRS if not args.pairs else
             [tuple(p.split(":")) for p in args.pairs.split(",")])

    print(f"{'pair':45s} {'variant':9s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'collect_s':>10s} {'dominant':>10s} {'useful':>7s} {'peakGiB':>8s}")
    for arch, shape in pairs:
        base_path = f"results/dryrun/pod16x16/{arch}__{shape}.json"
        with open(base_path) as f:
            base = analyse(json.load(f))
        opt_rec = run_case(arch, shape, multi_pod=False,
                           outdir="results/dryrun_opt", force=args.force,
                           optimized=True)
        opt = analyse(opt_rec)
        for tag, r in (("baseline", base), ("optimized", opt)):
            print(f"{arch + ' x ' + shape:45s} {tag:9s} {r['compute_s']:10.3e} "
                  f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
                  f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} "
                  f"{r['peak_mem_GiB']:8.1f}")
        dom = base["dominant"] + "_s"
        if opt[dom] > 0:
            print(f"{'':45s} -> dominant term ({base['dominant']}) "
                  f"{base[dom]:.3e} -> {opt[dom]:.3e} "
                  f"({base[dom] / opt[dom]:.2f}x)")


if __name__ == "__main__":
    main()
