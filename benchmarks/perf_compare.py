import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Two drivers in one module:

1. **roofline** (default, §Perf hillclimbs 1-2): re-lowers the selected
   (arch x shape) pairs with the optimisation flags on, into
   ``results/dryrun_opt``, and prints before/after roofline terms against
   the baselines in ``results/dryrun``.

     PYTHONPATH=src python -m benchmarks.perf_compare [--pairs a:b,c:d]

2. **gate** (the CI benchmark regression gate): compare a fresh benchmark
   JSON artifact against the committed snapshot in
   ``benchmarks/baselines/`` and fail (exit 1) on regression.  Latency
   metrics fail on >20% regression by default; wall-clock-sensitive
   metrics carry wider per-metric tolerances so machine variance doesn't
   flap the gate; prediction-error metrics also enforce an absolute
   ceiling.

     PYTHONPATH=src python -m benchmarks.perf_compare gate \\
         --kind fleet --current results/fleet/bench_fleet.json \\
         --baseline benchmarks/baselines/bench_fleet_quick.json
"""
import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


# --------------------------------------------------------------- gate ----
# (json_path, direction, rel_tolerance, abs_ceiling) — direction is the
# *good* direction; regression = moving the other way by > tolerance.
# Simulated latencies are deterministic given a seed, so 20% is generous;
# events_per_s / err_pct depend on the host wall clock and get slack.
GATE_SPECS = {
    "fleet": [
        ("cluster.p50_ms", "lower", None, None),
        ("cluster.p99_ms", "lower", None, None),
        ("cluster.mean_batch", "higher", None, None),
        ("planner.pareto_size", "higher", 0.50, None),
        ("planner.n_feasible", "higher", 0.50, None),
    ],
    # err_pct metrics are ratios of wall-clock measurements: the absolute
    # ceiling is the gate (a broken calibration path shows 100%+ errors),
    # relative drift is effectively unbounded so runner load can't flap it
    # the boundary-overhead cut is a wall-clock ratio measured
    # back-to-back inside the bench, where its >=20% floor (and the
    # payload bit-identity) is asserted; here it is presence-checked so
    # the metric can't silently vanish, while fused_bit_identical is a
    # deterministic 1.0 and gates exactly
    "runtime": [
        ("max_err_measured_pct", "lower", float("inf"), 45.0),
        ("mean_err_measured_pct", "lower", float("inf"), 30.0),
        ("boundary.overhead_cut_pct", "higher", float("inf"), None),
        ("boundary.fused_bit_identical", "higher", 0.001, None),
    ],
    # the repro.api facade must stay (near) zero-cost over hand-stitched
    # calls: overhead is a ratio of two wall clocks on the same workload,
    # so it gates on the absolute <5% ceiling, not relative drift
    "api": [
        ("study_overhead_pct", "lower", float("inf"), 5.0),
    ],
    # the planner fast path.  plans/sec and the screen-vs-event speedup
    # are wall-clock ratios that swing ~4x run-to-run on shared runners
    # (even with the min-estimator), so they are reported in the
    # artifact but NOT gated here — the hard >=10x speedup floor is
    # enforced inside bench_planner --quick itself, where the two sides
    # are measured back-to-back.  What gates: the end-to-end plan_tiers
    # wall time on a generous relative band, and the deterministic
    # closed-form==event-engine agreement on its 1e-9 contract ceiling.
    "planner": [
        ("plan_tiers.e2e_ms", "lower", 1.50, None),
        ("verify.max_rel_err", "lower", float("inf"), 1e-9),
    ],
    # the megafleet vectorized cluster engine.  The clients-ratio is a
    # wall-clock ratio of two back-to-back timings, so (as with the
    # planner speedup) the hard >=20x acceptance floor lives inside
    # bench_megafleet --quick and the ratio is reported, not gated.
    # What gates: the seeded drop fractions and tail latency — the
    # vectorized engine is an exact replay of the event engine, so these
    # are deterministic and any drift is a semantics change, not noise
    "megafleet": [
        ("workloads.poisson_2x.drop_fraction", "lower", 0.001, None),
        ("workloads.poisson_2x.p99_ms", "lower", 0.001, None),
        ("workloads.diurnal.drop_fraction", "lower", 0.001, None),
        ("workloads.diurnal.p99_ms", "lower", 0.001, None),
    ],
    # the adaptive replanning controller.  Everything simulated is
    # deterministic given the seed (both engines must even agree on the
    # switch sequence — bench_controller verifies that in-process), so
    # p99s, the improvement ratio, switch count, and migration
    # disruption gate on the exact-replay band; the >=1.5x improvement
    # floor lives inside bench_controller --quick; wall time is not
    # gated
    "controller": [
        ("adaptive.p99_ms", "lower", 0.001, None),
        ("static.p99_ms", "lower", 0.001, None),
        ("improvement_x", "higher", 0.001, None),
        ("adaptive.n_switches", "lower", 0.001, None),
        ("adaptive.migration.n_delayed", "lower", 0.001, None),
        ("adaptive.drop_fraction", "lower", 0.001, None),
    ],
    # telemetry must be free when off and cheap when on: both overheads
    # are paired-ratio medians of two wall clocks (bench_obs measures A
    # and B back-to-back per pair so host drift cancels), gated on hard
    # absolute ceilings — null recorder <1% on the bare event loop,
    # recording <5% on the runtime's jitted path
    "obs": [
        ("overhead.null_pct", "lower", float("inf"), 1.0),
        ("overhead.record_pct", "lower", float("inf"), 5.0),
    ],
    # the fault-tolerance layer.  Every gated metric is a deterministic
    # replay of the seeded FaultPlan (fault counts, retries, backoff
    # seconds, completion and fallback rates all live on the simulated
    # clock), so they gate on the exact band; the zero-fault byte
    # contract and 100% completion are *asserted* inside bench_faults
    # itself and presence-checked here; the wall-clock recovery
    # overhead is reported in the artifact, not gated
    "faults": [
        ("zero_fault.sei1_bit_identical", "higher", 0.001, None),
        ("zero_fault.fused_bit_identical", "higher", 0.001, None),
        ("chaos.completion_rate", "higher", 0.001, None),
        ("chaos.retries", "lower", 0.001, None),
        ("chaos.timeouts", "lower", 0.001, None),
        ("chaos.downgrades", "lower", 0.001, None),
        ("chaos.local_fallbacks", "lower", 0.001, None),
        ("chaos.backoff_s", "lower", 0.001, None),
        ("blackout.fallback_rate", "higher", 0.001, None),
    ],
    # simulated pipeline numbers are deterministic (event engine +
    # analytic stage times), so they gate at the default tolerance; the
    # speedup must not collapse; the sim-vs-exec error divides by a
    # wall clock and gates only on a generous absolute ceiling
    "pipeline": [
        ("pipeline.sequential_ms", "lower", None, None),
        ("pipeline.pipelined_ms", "lower", None, None),
        ("pipeline.speedup", "higher", None, None),
        ("sim_vs_exec.err_analytic_pct", "lower", float("inf"), 75.0),
    ],
}


def _dig(doc: dict, path: str):
    cur = doc
    for part in path.split("."):
        cur = cur[part]
    return cur


def compare_metrics(current: dict, baseline: dict, specs,
                    max_regress: float) -> list:
    """Returns rows ``(path, base, cur, regress_frac, ok, note)``."""
    rows = []
    for path, direction, tol, ceiling in specs:
        tol = max_regress if tol is None else tol
        try:
            base, cur = float(_dig(baseline, path)), float(_dig(current, path))
        except KeyError:
            rows.append((path, None, None, 0.0, False, "missing metric"))
            continue
        if base == 0:
            # sign depends on direction: growing from a zero baseline is a
            # regression only for lower-is-better metrics
            if cur == 0:
                regress = 0.0
            elif direction == "lower":
                regress = float("inf")
            else:
                regress = float("-inf")
        elif direction == "lower":
            regress = (cur - base) / abs(base)
        else:
            regress = (base - cur) / abs(base)
        ok = regress <= tol
        note = f"ceiling {ceiling}" if tol == float("inf") else f"tol {tol:.0%}"
        if ceiling is not None and cur > ceiling:
            ok = False
            note = f"ceiling {ceiling} exceeded"
        rows.append((path, base, cur, regress, ok, note))
    return rows


def run_gate(kind: str, current_path: str, baseline_path: str,
             max_regress: float = 0.20) -> bool:
    with open(current_path) as f:
        current = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    rows = compare_metrics(current, baseline, GATE_SPECS[kind], max_regress)
    print(f"gate[{kind}] {current_path} vs {baseline_path} "
          f"(max regression {max_regress:.0%})")
    print(f"{'metric':34s} {'baseline':>12s} {'current':>12s} "
          f"{'drift':>8s}  verdict")
    all_ok = True
    for path, base, cur, regress, ok, note in rows:
        all_ok &= ok
        if base is None:
            print(f"{path:34s} {'-':>12s} {'-':>12s} {'-':>8s}  FAIL ({note})")
            continue
        print(f"{path:34s} {base:12.3f} {cur:12.3f} {regress:8.1%}  "
              f"{'ok' if ok else 'FAIL'} ({note})")
    print(f"gate[{kind}]: {'PASS' if all_ok else 'FAIL'}")
    return all_ok


def gate_main(argv) -> int:
    ap = argparse.ArgumentParser(prog="perf_compare gate")
    ap.add_argument("--kind", required=True, choices=sorted(GATE_SPECS))
    ap.add_argument("--current", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="default relative regression tolerance (0.20 = 20%%)")
    args = ap.parse_args(argv)
    return 0 if run_gate(args.kind, args.current, args.baseline,
                         args.max_regress) else 1

DEFAULT_PAIRS = [
    ("llama3.2-3b", "prefill_32k"),   # worst useful-ratio (24 heads % 16)
    ("jamba-v0.1-52b", "decode_32k"), # most collective-bound
    ("qwen3-moe-235b-a22b", "train_4k"),  # compute-bound MoE giant
]


def main():
    if len(sys.argv) > 1 and sys.argv[1] == "gate":
        sys.exit(gate_main(sys.argv[2:]))
    roofline_main()


def roofline_main():
    from repro.launch.dryrun import run_case
    from benchmarks.roofline import analyse

    ap = argparse.ArgumentParser()
    ap.add_argument("--pairs", default=None,
                    help="comma list of arch:shape (default: the 3 picks)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    pairs = (DEFAULT_PAIRS if not args.pairs else
             [tuple(p.split(":")) for p in args.pairs.split(",")])

    print(f"{'pair':45s} {'variant':9s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'collect_s':>10s} {'dominant':>10s} {'useful':>7s} {'peakGiB':>8s}")
    for arch, shape in pairs:
        base_path = f"results/dryrun/pod16x16/{arch}__{shape}.json"
        with open(base_path) as f:
            base = analyse(json.load(f))
        opt_rec = run_case(arch, shape, multi_pod=False,
                           outdir="results/dryrun_opt", force=args.force,
                           optimized=True)
        opt = analyse(opt_rec)
        for tag, r in (("baseline", base), ("optimized", opt)):
            print(f"{arch + ' x ' + shape:45s} {tag:9s} {r['compute_s']:10.3e} "
                  f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
                  f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} "
                  f"{r['peak_mem_GiB']:8.1f}")
        dom = base["dominant"] + "_s"
        if opt[dom] > 0:
            print(f"{'':45s} -> dominant term ({base['dominant']}) "
                  f"{base[dom]:.3e} -> {opt[dom]:.3e} "
                  f"({base[dom] / opt[dom]:.2f}x)")


if __name__ == "__main__":
    main()
