"""§Perf hillclimb 3 (paper-representative): the cross-pod split wire.

Lowers the 2-stage multipod split pipeline (llama3-8b, 2x16x16 mesh) in
three wire configurations and measures the collective-permute bytes that
cross the pod boundary per step:

  raw      — no bottleneck: the bf16 residual stream crosses the link
  ae_f32   — paper-faithful 50% undercomplete AE, f32 latent on the wire
  ae_int8  — + int8 wire quantisation (what the `bottleneck_compress`
             Pallas kernel fuses on TPU): codes + one f32 scale/token

Run standalone:  PYTHONPATH=src python -m benchmarks.bench_multipod_wire
(sets the 512-device emulation flag itself; from benchmarks.run it only
executes when the device count allows).
"""
from __future__ import annotations

import json
import os


def _measure(mesh_shape=(2, 4, 4), batch=32, seq=2048, n_micro=4):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import bottleneck as B
    from repro.core.split import multipod_split_step
    from repro.launch.hlo_cost import HloCost
    from repro.models import transformer as T

    cfg = get_config("llama3-8b")
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat(mesh_shape, ("pod", "data", "model"))
    pstruct = jax.eval_shape(lambda k: T.init_params(k, cfg),
                             jax.ShapeDtypeStruct((2,), jnp.uint32))
    ae_struct = jax.eval_shape(
        lambda k: B.init_bottleneck(k, (cfg.d_model,), 0.5),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    tokens = jax.ShapeDtypeStruct((batch, seq), jnp.int32)

    out = {}
    for name, ae, quant in (("raw", None, False),
                            ("ae_f32", ae_struct, False),
                            ("ae_int8", ae_struct, True)):
        def step(params, aep, toks):
            return multipod_split_step(params, cfg, {"tokens": toks}, mesh,
                                       ae=aep, n_micro=n_micro,
                                       quantize_wire=quant)

        with mesh:
            lowered = jax.jit(step).lower(pstruct, ae, tokens)
            compiled = lowered.compile()
        hc = HloCost(compiled.as_text())
        cp = hc.collective_summary().get("collective-permute",
                                         {"wire_bytes": 0, "count": 0})
        out[name] = {"permute_wire_bytes": cp["wire_bytes"],
                     "permute_count": cp["count"]}
    return out


def run(fast: bool = False):
    import jax
    if len(jax.devices()) < 32:
        return [("multipod_wire.skipped_needs_device_emulation", 0.0, 1)]
    res = _measure()
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "perf_multipod_wire.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    rows = []
    for k, v in res.items():
        rows.append((f"multipod_wire.{k}.bytes", 0.0, v["permute_wire_bytes"]))
    if res["ae_int8"]["permute_wire_bytes"]:
        rows.append(("multipod_wire.raw_over_int8", 0.0,
                     round(res["raw"]["permute_wire_bytes"]
                           / res["ae_int8"]["permute_wire_bytes"], 2)))
    return rows


if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    for r in run():
        print(",".join(map(str, r)))
