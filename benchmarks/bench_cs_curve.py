"""Paper Fig. 2: the CS curve vs. actual split accuracy.

For the trained VGG: compute the CS curve over the feature ops, then for
every legal cut train a 50%-compression bottleneck (Eq. 3 recipe) and
measure test accuracy of the split model.  The paper's claim: CS local
maxima mark the cuts where accuracy is preserved — we report the curve,
the per-cut accuracies and their Pearson correlation.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bottleneck as B
from repro.core.saliency import candidate_split_points, cumulative_saliency
from repro.data.synthetic import toy_image_iter, toy_images
from repro.models.vgg import feature_index

from .common import RESULTS_DIR, trained_vgg, vgg_test_accuracy


def split_accuracy(model, params, cut: int, ae_steps: int = 400) -> float:
    # paper recipe is 50 epochs @ lr 5e-4 on CIFAR10; at toy scale the
    # equivalent total work is ~400 Adam steps @ 2e-3 (validated: recovers
    # base accuracy at good cuts)
    it = map(lambda t: (jnp.asarray(t[0]), jnp.asarray(t[1])),
             toy_image_iter(32, hw=16, seed=100 + cut))
    ae, _ = B.train_bottleneck(model, params, cut, it, steps=ae_steps, lr=2e-3)
    xs, ys = toy_images(256, hw=16, seed=777)
    fwd = jax.jit(lambda xb: B.split_forward(model, params, ae, cut, xb))
    preds = np.asarray(fwd(jnp.asarray(xs))).argmax(-1)
    return float((preds == ys).mean())


def run(fast: bool = False):
    model, params = trained_vgg()
    base_acc = vgg_test_accuracy(model, params)
    xs, ys = toy_images(64, hw=16, seed=55)
    fi = feature_index(model)
    cs = cumulative_saliency(model, params, jnp.asarray(xs), jnp.asarray(ys),
                             layer_idx=fi)
    cands = candidate_split_points(model, cs, fi, top_n=5)
    cuts = fi[1::2] if fast else fi
    cuts = [c for c in cuts if c in set(model.cut_points())]
    accs = {c: split_accuracy(model, params, c, ae_steps=150 if fast else 400)
            for c in cuts}
    cs_at = {c: float(cs[fi.index(c)]) for c in cuts}
    pairs = [(cs_at[c], accs[c]) for c in cuts]
    corr = float(np.corrcoef([p[0] for p in pairs], [p[1] for p in pairs])[0, 1])
    cand_accs = [accs[c] for c in cands if c in accs]
    noncand_accs = [accs[c] for c in cuts if c not in set(cands)]
    out = {
        "base_accuracy": base_acc,
        "cs_curve": {int(l): float(v) for l, v in zip(fi, cs)},
        "candidates": [int(c) for c in cands],
        "split_accuracy": {int(k): v for k, v in accs.items()},
        "pearson_cs_vs_accuracy": corr,
        "candidate_acc_mean": float(np.mean(cand_accs)) if cand_accs else None,
        "noncandidate_acc_min": float(np.min(noncand_accs)) if noncand_accs else None,
    }
    os.makedirs(os.path.join(RESULTS_DIR, "paper"), exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "paper", "fig2_cs_curve.json"), "w") as f:
        json.dump(out, f, indent=1)
    rows = [("fig2.base_accuracy", 0.0, base_acc),
            ("fig2.pearson_cs_vs_acc", 0.0, corr),
            ("fig2.n_candidates", 0.0, len(cands))]
    if cand_accs:
        # the paper's claim: CS peaks mark accuracy-preserving cuts
        rows.append(("fig2.candidate_acc_mean", 0.0, float(np.mean(cand_accs))))
        rows.append(("fig2.candidate_acc_drop_vs_base", 0.0,
                     round(base_acc - float(np.mean(cand_accs)), 4)))
    if noncand_accs:
        rows.append(("fig2.noncandidate_acc_min", 0.0, float(np.min(noncand_accs))))
    for c in cuts:
        rows.append((f"fig2.split@{c}.acc", 0.0, accs[c]))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
