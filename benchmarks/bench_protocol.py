"""Paper Fig. 4: RC accuracy (left) and latency (right) vs packet loss,
TCP vs UDP, 1 Gb/s full-duplex channel.

Expected (paper §V-C): TCP accuracy is loss-invariant but latency grows;
UDP latency is loss-invariant but accuracy falls (no recovery — the
receiver runs inference on the corrupted input tensor)."""
from __future__ import annotations

import json
import os

from repro.core.scenarios import Scenario
from repro.data.synthetic import toy_images
from repro.netsim.channel import Channel
from repro.netsim.simulator import ApplicationSimulator, NetworkConfig

from .common import RESULTS_DIR, trained_vgg

LOSS_RATES = [0.0, 0.05, 0.1, 0.2, 0.3]


def run(fast: bool = False):
    model, params = trained_vgg()
    xs, ys = toy_images(64 if fast else 128, hw=16, seed=777)
    rc = Scenario("RC")
    table = {"tcp": {}, "udp": {}}
    for proto in ("tcp", "udp"):
        for p in (LOSS_RATES[::2] if fast else LOSS_RATES):
            net = NetworkConfig(proto, Channel(100e-6, 1e9, 1e9,
                                               loss_rate=p, seed=11))
            sim = ApplicationSimulator(model, params, net)
            v = sim.simulate(rc, xs, ys, n_frames=8)
            table[proto][p] = {"accuracy": v.accuracy, "latency_s": v.latency_s}
    os.makedirs(os.path.join(RESULTS_DIR, "paper"), exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "paper", "fig4_protocol.json"), "w") as f:
        json.dump(table, f, indent=1)
    t, u = table["tcp"], table["udp"]
    ps = sorted(t)
    rows = [
        ("fig4.tcp.acc_flat", 0.0,
         int(abs(t[ps[0]]["accuracy"] - t[ps[-1]]["accuracy"]) < 1e-9)),
        ("fig4.tcp.latency_grows", 0.0,
         int(t[ps[-1]]["latency_s"] > t[ps[0]]["latency_s"])),
        ("fig4.udp.acc_drops", 0.0,
         int(u[ps[-1]]["accuracy"] < u[ps[0]]["accuracy"])),
        ("fig4.udp.latency_flat", 0.0,
         int(abs(u[ps[-1]]["latency_s"] - u[ps[0]]["latency_s"])
             < 0.2 * u[ps[0]]["latency_s"] + 1e-9)),
        ("fig4.udp.acc_at_max_loss", 0.0, u[ps[-1]]["accuracy"]),
        ("fig4.tcp.lat_at_max_loss_s", 0.0, t[ps[-1]]["latency_s"]),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
